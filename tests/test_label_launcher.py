"""Launcher-level smoke tests for the labeling campaign CLI.

The paper benchmarks every M(.) metric including the random baseline;
the launcher must accept exactly the selection module's metric set plus
``random`` (previously missing from the argparse choices).
"""
import pytest

from repro.core import selection
from repro.launch.label import METRIC_CHOICES, build_parser


def test_metric_choices_cover_selection_metrics_plus_random():
    assert set(METRIC_CHOICES) == set(selection.METRICS) | {"random"}


@pytest.mark.parametrize("metric", sorted(set(selection.METRICS) |
                                          {"random"}))
def test_launcher_accepts_every_metric(metric):
    args = build_parser().parse_args(["--metric", metric])
    assert args.metric == metric


def test_launcher_rejects_unknown_metric():
    with pytest.raises(SystemExit):
        build_parser().parse_args(["--metric", "bogus"])


def test_launcher_defaults():
    args = build_parser().parse_args([])
    assert args.metric == "margin" and args.service == "amazon"
    assert not args.live and args.budget is None
    assert args.sweep_page == 8192 and not args.sweep_async


def test_launcher_sweep_flags():
    args = build_parser().parse_args(["--sweep-page", "4096",
                                      "--sweep-async"])
    assert args.sweep_page == 4096 and args.sweep_async


def test_launcher_fit_flags():
    args = build_parser().parse_args([])
    assert args.fit_fused and not args.fit_async and not args.fit_resident
    args = build_parser().parse_args(["--no-fit-fused"])
    assert not args.fit_fused
    args = build_parser().parse_args(["--fit-async", "--fit-resident"])
    assert args.fit_async and args.fit_resident


def test_launcher_state_flags():
    args = build_parser().parse_args([])
    assert args.state == "" and args.sweep_ckpt_pages == 0
    assert args.iters_per_run == 0
    args = build_parser().parse_args(
        ["--state", "/tmp/s.json", "--sweep-ckpt-pages", "4",
         "--iters-per-run", "2"])
    assert args.state == "/tmp/s.json" and args.sweep_ckpt_pages == 4
    assert args.iters_per_run == 2


def test_launcher_annotation_flags():
    args = build_parser().parse_args([])
    assert args.annotator_noise == 0.0 and args.annotator_workers == 5
    assert args.label_repeats == 1 and not args.adaptive_repeats
    assert args.annotator_aggregate == "majority" and args.max_repeats == 0
    args = build_parser().parse_args(
        ["--annotator-noise", "0.2", "--label-repeats", "3",
         "--annotator-workers", "7", "--annotator-spammers", "0.1",
         "--annotator-aggregate", "ds", "--adaptive-repeats",
         "--max-repeats", "5", "--repeat-confidence", "0.8"])
    assert args.annotator_noise == 0.2 and args.label_repeats == 3
    assert args.annotator_workers == 7 and args.annotator_spammers == 0.1
    assert args.annotator_aggregate == "ds" and args.adaptive_repeats
    assert args.max_repeats == 5 and args.repeat_confidence == 0.8


def test_launcher_rejects_unknown_aggregator():
    with pytest.raises(SystemExit):
        build_parser().parse_args(["--annotator-aggregate", "mode"])


def test_build_annotation_off_for_perfect_oracle():
    from repro.core import AMAZON
    from repro.launch.label import build_annotation
    args = build_parser().parse_args([])
    assert build_annotation(args, 10, AMAZON) is None


def test_build_annotation_constructs_service():
    from repro.core import AMAZON
    from repro.launch.label import build_annotation
    args = build_parser().parse_args(
        ["--annotator-noise", "0.2", "--label-repeats", "3",
         "--annotator-aggregate", "ds"])
    svc = build_annotation(args, 10, AMAZON)
    assert svc is not None
    assert svc.policy.repeats == 3 and svc.policy.aggregator == "ds"
    assert svc.pricing is AMAZON
    assert svc.pool.cfg.num_classes == 10
    q = svc.expected_quality()
    assert q.avg_repeats == 3.0 and q.residual_error > 0.0
    # repeats alone (no noise) still needs the service: votes are charged
    args = build_parser().parse_args(["--label-repeats", "2"])
    assert build_annotation(args, 10, AMAZON) is not None


def test_launcher_mesh_flag_and_parse():
    from repro.launch.label import build_mesh
    args = build_parser().parse_args([])
    assert args.mesh == "" and build_mesh("") is None
    args = build_parser().parse_args(["--mesh", "data=1"])
    assert args.mesh == "data=1"
    mesh = build_mesh("data=1")
    assert mesh.axis_names == ("data",)
    assert mesh.devices.shape == (1,)


def test_mesh_campaign_smoke_under_forced_host_devices(tmp_path):
    """ROADMAP open item: --mesh data=N builds the host mesh and hands it
    to the scoring + fit engines.  One live iteration under 4 forced host
    devices must run and checkpoint (subprocess: device count is fixed at
    first jax init, so the flag cannot be set in-process)."""
    import json
    import os
    import subprocess
    import sys

    state = tmp_path / "mesh_state.json"
    env = dict(os.environ,
               XLA_FLAGS=(os.environ.get("XLA_FLAGS", "") +
                          " --xla_force_host_platform_device_count=4"),
               PYTHONPATH="src" + os.pathsep +
               os.environ.get("PYTHONPATH", ""))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.label", "--live",
         "--pool", "400", "--classes", "4", "--mesh", "data=4",
         "--iters-per-run", "1", "--state", str(state)],
        env=env, capture_output=True, text=True, timeout=600,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-2000:]
    report = json.loads(out.stdout)
    assert report["resumable"] and os.path.exists(state)


def test_run_campaign_state_file_preempt_and_resume(tmp_path):
    """Launcher-level fault tolerance: a campaign preempted by
    --iters-per-run resumes from its --state file and finishes with the
    economics of an uninterrupted run; the state file is consumed on
    completion."""
    import os

    import numpy as np
    import pytest as _pytest

    from repro.core import AMAZON, MCALConfig, make_emulated_task
    from repro.launch.label import run_campaign

    cfg = MCALConfig(seed=0)
    state = str(tmp_path / "state.json")

    def task():
        return make_emulated_task("cifar10", "resnet18", seed=0,
                                  pool_size=4000, sweep_page=512)

    plain, _ = run_campaign(task(), AMAZON, cfg)

    res, camp = run_campaign(task(), AMAZON, cfg, state_path=state,
                             iters_per_run=2)
    assert res is None and os.path.exists(state)   # preempted, resumable
    hops = 1
    while res is None:
        res, camp = run_campaign(task(), AMAZON, cfg, state_path=state,
                                 sweep_ckpt_pages=2, iters_per_run=2)
        hops += 1
        assert hops < 50
    assert hops > 1                                # actually resumed
    assert not os.path.exists(state)               # spent on completion
    assert res.total_cost == _pytest.approx(plain.total_cost, rel=1e-9)
    assert res.S_size == plain.S_size and res.B_size == plain.B_size
    np.testing.assert_array_equal(res.labels, plain.labels)
    # the full iteration trace survives the hops (history is persisted)
    assert len(res.history) == len(plain.history)
    assert [r.cstar for r in res.history] == \
        [r.cstar for r in plain.history]
    assert [r.B_size for r in res.history] == \
        [r.B_size for r in plain.history]


def test_run_campaign_resume_preserves_random_metric_stream(tmp_path):
    """--metric random draws from the campaign RNG; the persisted
    bit-generator state makes a preempted run's acquisitions identical
    to an uninterrupted one."""
    import numpy as np
    import pytest as _pytest

    from repro.core import AMAZON, MCALConfig, make_emulated_task
    from repro.launch.label import run_campaign

    cfg = MCALConfig(seed=0, metric="random", max_iters=8)
    state = str(tmp_path / "state.json")

    def task():
        return make_emulated_task("cifar10", "resnet18", seed=0,
                                  pool_size=4000, sweep_page=512)

    plain, plain_camp = run_campaign(task(), AMAZON, cfg)
    res = None
    while res is None:
        res, camp = run_campaign(task(), AMAZON, cfg, state_path=state,
                                 iters_per_run=2)
    np.testing.assert_array_equal(camp.pool.B_idx, plain_camp.pool.B_idx)
    assert res.total_cost == _pytest.approx(plain.total_cost, rel=1e-9)
    np.testing.assert_array_equal(res.labels, plain.labels)
