"""Launcher-level smoke tests for the labeling campaign CLI.

The paper benchmarks every M(.) metric including the random baseline;
the launcher must accept exactly the selection module's metric set plus
``random`` (previously missing from the argparse choices).
"""
import pytest

from repro.core import selection
from repro.launch.label import METRIC_CHOICES, build_parser


def test_metric_choices_cover_selection_metrics_plus_random():
    assert set(METRIC_CHOICES) == set(selection.METRICS) | {"random"}


@pytest.mark.parametrize("metric", sorted(set(selection.METRICS) |
                                          {"random"}))
def test_launcher_accepts_every_metric(metric):
    args = build_parser().parse_args(["--metric", metric])
    assert args.metric == metric


def test_launcher_rejects_unknown_metric():
    with pytest.raises(SystemExit):
        build_parser().parse_args(["--metric", "bogus"])


def test_launcher_defaults():
    args = build_parser().parse_args([])
    assert args.metric == "margin" and args.service == "amazon"
    assert not args.live and args.budget is None
    assert args.sweep_page == 8192 and not args.sweep_async


def test_launcher_sweep_flags():
    args = build_parser().parse_args(["--sweep-page", "4096",
                                      "--sweep-async"])
    assert args.sweep_page == 4096 and args.sweep_async
