"""Per-architecture smoke tests: reduced configs of the same family run a
real forward + one train step on CPU; output shapes asserted, no NaNs.
Prefill/decode consistency is also checked (decode logits == forward logits
at the same position)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke
from repro.models.registry import get_model


def _batch(cfg, B=2, T=32, rng=None):
    rng = np.random.default_rng(0)
    batch = {}
    if cfg.family == "vlm" and cfg.frontend_tokens:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, T - cfg.frontend_tokens)), jnp.int32)
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_tokens, cfg.d_model)), jnp.float32)
    elif cfg.family == "audio":
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
        batch["audio_frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_tokens, cfg.d_model)), jnp.float32)
    else:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_finite(arch):
    cfg = get_smoke(arch)
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    B, T = 2, 32
    batch = _batch(cfg, B, T)
    hidden = model.forward(params, batch, mesh=None)
    assert hidden.shape == (B, T, cfg.d_model), hidden.shape
    logits = model.logits(params, hidden)
    assert logits.shape == (B, T, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), "non-finite logits"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_reduces_loss(arch):
    from repro.training.train_loop import make_train_step, init_train_state
    from repro.configs.base import TrainConfig

    cfg = get_smoke(arch)
    model = get_model(cfg)
    tc = TrainConfig(learning_rate=1e-2, schedule="constant", total_steps=10)
    state = init_train_state(model, tc, jax.random.key(1))
    B, T = 2, 32
    batch = _batch(cfg, B, T)
    batch["labels"] = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab_size, batch["tokens"].shape),
        jnp.int32)
    step = make_train_step(model, tc, mesh=None)
    losses = []
    for _ in range(4):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all(), losses
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    """decode_step at position T must match forward logits at position T
    given the prefill cache (KV-cache correctness)."""
    cfg = get_smoke(arch)
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    B, T = 2, 16
    batch = _batch(cfg, B, T + 1)
    full_hidden = model.forward(params, batch, mesh=None)
    full_logits = model.logits(params, full_hidden[:, -1:, :])

    # prefill on the first T tokens, then decode token T
    def cut(x):
        return x[:, :T] if x.ndim == 2 else x
    pre_batch = {k: cut(v) for k, v in batch.items()}
    hidden, cache = model.prefill(params, pre_batch, mesh=None)

    S = T + 8
    full_cache = model.init_cache(B, S)
    full_cache = _load_prefill(cfg, full_cache, cache, T)
    last_tok = batch["tokens"][:, -1:]
    logits, _ = model.decode_step(params, full_cache, last_tok,
                                  jnp.int32(_prefill_len(cfg, T)), mesh=None)
    np.testing.assert_allclose(
        np.asarray(logits, np.float32), np.asarray(full_logits, np.float32),
        rtol=0.1, atol=0.05)


def _prefill_len(cfg, T):
    if cfg.family == "vlm" and cfg.frontend_tokens:
        return T  # prefill consumed patches + (T - patches) text tokens
    return T


def _load_prefill(cfg, full_cache, prefill_cache, T):
    """Copy prefill outputs into a zero-initialized decode cache."""
    if cfg.family == "ssm":
        return prefill_cache  # states are the cache
    if cfg.family == "hybrid":
        out = dict(full_cache)
        out["ssm"] = prefill_cache["ssm"]
        out["attn"] = {
            k: jax.lax.dynamic_update_slice(
                full_cache["attn"][k],
                prefill_cache["attn"][k].astype(full_cache["attn"][k].dtype),
                (0, 0, 0, 0, 0))
            for k in ("k", "v")
        }
        return out
    if cfg.family == "audio":
        out = {}
        for k in ("k", "v"):
            out[k] = jax.lax.dynamic_update_slice(
                full_cache[k], prefill_cache[k].astype(full_cache[k].dtype),
                (0, 0, 0, 0, 0))
        out["xk"], out["xv"] = prefill_cache["xk"], prefill_cache["xv"]
        return out
    return {
        k: jax.lax.dynamic_update_slice(
            full_cache[k], prefill_cache[k].astype(full_cache[k].dtype),
            (0, 0, 0, 0, 0))
        for k in ("k", "v")
    }
