"""Streaming pool-sweep runtime vs its host/engine oracles.

Every sink must agree EXACTLY with its oracle — the top-k reservoir with
``PoolScoringEngine.top_k`` (``lax.top_k`` over the full pool), the
streaming rank with ``selection.rank_for_machine_labeling`` over full-pool
stats, the feature emitter with ``PoolScoringEngine.pool_features`` — and
a mid-pool cursor save/restore must be bit-identical to an uninterrupted
sweep.  The grids include ragged final pages and duplicate-row ties (both
sides tie-break by first global index); page sizes are pow2 multiples of
the engine microbatch so every row is computed inside a microbatch of the
same shape on both paths (the module docstring of ``serving.sweep``
explains why that makes exactness a sound contract).
"""
import json

import numpy as np
import pytest

import jax

from repro.configs.base import ModelConfig
from repro.core import selection as sel
from repro.core.scoring import PoolScoringEngine, ScoringConfig
from repro.models.registry import get_model
from repro.serving.sweep import (EngineSweepAdapter, FeatureSink,
                                 HostTaskAdapter, PoolSweepRunner,
                                 RankTop1Sink, StatsSink, SweepCheckpoint,
                                 SweepConfig, TopKSink)

METRICS = ("margin", "entropy", "least_confidence")


@pytest.fixture(scope="module")
def sweep_setup():
    cfg = ModelConfig(name="sweep-probe", family="mlp", num_layers=2,
                      d_model=64, num_classes=10, input_dim=32,
                      dtype="float32", remat="none")
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    x = np.random.default_rng(0).normal(size=(2000, 32)).astype(np.float32)
    engine = PoolScoringEngine(model, ScoringConfig(microbatch=256))
    runner = PoolSweepRunner(EngineSweepAdapter(engine),
                             SweepConfig(page_rows=512))
    return engine, runner, params, x


# ---------------------------------------------------------------------------
# sink oracle grids
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("n", [100, 512, 1000, 1537, 2000])
@pytest.mark.parametrize("k", [1, 7, 64])
def test_topk_sink_matches_engine_topk(sweep_setup, metric, n, k):
    """Top-k reservoir == lax.top_k over the full pool, exactly — order
    included (most-uncertain-first), across ragged final pages."""
    engine, runner, params, x = sweep_setup
    got = runner.run(params, x[:n], TopKSink(k, metric))
    want = engine.top_k(params, x[:n], k, metric)
    np.testing.assert_array_equal(got, want)


def test_topk_sink_duplicate_row_ties(sweep_setup):
    """Duplicate rows spanning pages produce exact score ties; both sides
    must break them by FIRST global index."""
    engine, runner, params, x = sweep_setup
    xd = np.tile(x[:50], (20, 1))   # 1000 rows, 50 distinct, cross-page ties
    got = runner.run(params, xd, TopKSink(64, "margin"))
    want = engine.top_k(params, xd, 64, "margin")
    np.testing.assert_array_equal(got, want)


def test_topk_sink_k_larger_than_pool(sweep_setup):
    engine, runner, params, x = sweep_setup
    got = runner.run(params, x[:100], TopKSink(500, "margin"))
    want = engine.top_k(params, x[:100], 500, "margin")
    np.testing.assert_array_equal(got, want)
    assert got.shape == (100,)


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("n", [100, 1000, 1537, 2000])
def test_rank_sink_matches_host_ranking(sweep_setup, metric, n):
    """Streaming L(.) rank + top1 == rank_for_machine_labeling over the
    engine's full-pool stats, exactly (same fp32 field, same stable
    argsort)."""
    engine, runner, params, x = sweep_setup
    order, top1 = runner.run(params, x[:n], RankTop1Sink(metric))
    stats, _ = engine.score_host(params, x[:n])
    np.testing.assert_array_equal(
        order, sel.rank_for_machine_labeling(stats, metric))
    np.testing.assert_array_equal(top1, np.asarray(stats.top1, np.int64))


@pytest.mark.parametrize("n", [512, 1300, 2000])
def test_feature_sink_matches_pool_features(sweep_setup, n):
    """Paged feature emission is bit-equal to the engine's unpaged
    device-resident emission (the k-center consumer's contract)."""
    engine, runner, params, x = sweep_setup
    feats = runner.run(params, x[:n], FeatureSink())
    assert isinstance(feats, jax.Array)   # device-resident, no host trip
    want = engine.pool_features(params, x[:n])
    np.testing.assert_array_equal(np.asarray(feats), np.asarray(want))


@pytest.mark.parametrize("n", [100, 1537, 2000])
def test_stats_sink_matches_engine_score(sweep_setup, n):
    engine, runner, params, x = sweep_setup
    packed = runner.run(params, x[:n], StatsSink())
    stats, _ = engine.score_host(params, x[:n])
    np.testing.assert_array_equal(np.asarray(packed.margin), stats.margin)
    np.testing.assert_array_equal(np.asarray(packed.entropy), stats.entropy)
    np.testing.assert_array_equal(np.asarray(packed.top1), stats.top1)


# ---------------------------------------------------------------------------
# resumable cursor
# ---------------------------------------------------------------------------


def _sink_grid():
    return [TopKSink(32, "entropy"), RankTop1Sink("margin"), FeatureSink(),
            StatsSink()]


def _fresh(sink):
    return type(sink)(**({"k": sink.k, "metric": sink.metric}
                         if isinstance(sink, TopKSink) else
                         {"metric": sink.metric}
                         if isinstance(sink, RankTop1Sink) else {}))


def _as_arrays(result):
    if isinstance(result, tuple):
        return [np.asarray(r) for r in result]
    return [np.asarray(result)]


@pytest.mark.parametrize("sink", _sink_grid(), ids=lambda s: s.kind)
@pytest.mark.parametrize("stop_page", [0, 1, 2, 3, 4])
def test_cursor_save_restore_bit_identical(sweep_setup, sink, stop_page):
    """Cut the cursor at every page boundary (including before the first
    and after the last page), round-trip it through JSON, resume with a
    FRESH sink instance: the fold must be bit-identical to an
    uninterrupted sweep."""
    _, runner, params, x = sweep_setup    # 2000 rows / 512-page = 4 pages
    ckpt = runner.run_until(params, x, _fresh(sink), stop_page)
    assert ckpt.next_page == min(stop_page, runner.n_pages(x.shape[0]))
    restored = SweepCheckpoint.from_json(ckpt.to_json())
    resumed = runner.run(params, x, _fresh(sink), checkpoint=restored)
    uninterrupted = runner.run(params, x, _fresh(sink))
    for a, b in zip(_as_arrays(resumed), _as_arrays(uninterrupted)):
        np.testing.assert_array_equal(a, b)


def test_cursor_checkpoint_is_json(sweep_setup):
    _, runner, params, x = sweep_setup
    ckpt = runner.run_until(params, x, RankTop1Sink(), 2)
    blob = json.loads(ckpt.to_json())
    assert blob["next_page"] == 2 and blob["n"] == 2000
    assert blob["sink_kind"] == "rank"


def test_cursor_validation_rejects_mismatches(sweep_setup):
    _, runner, params, x = sweep_setup
    ckpt = runner.run_until(params, x, RankTop1Sink(), 1)
    with pytest.raises(ValueError):   # wrong sink kind
        runner.run(params, x, TopKSink(8, "margin"), checkpoint=ckpt)
    with pytest.raises(ValueError):   # wrong pool size
        runner.run(params, x[:1000], RankTop1Sink(), checkpoint=ckpt)
    other = PoolSweepRunner(runner.adapter, SweepConfig(page_rows=256))
    with pytest.raises(ValueError):   # wrong page size
        other.run(params, x, RankTop1Sink(), checkpoint=ckpt)
    with pytest.raises(ValueError):   # wrong rank metric
        runner.run(params, x, RankTop1Sink("entropy"), checkpoint=ckpt)
    tk = runner.run_until(params, x, TopKSink(16, "margin"), 1)
    with pytest.raises(ValueError):   # wrong top-k metric
        runner.run(params, x, TopKSink(16, "entropy"), checkpoint=tk)
    with pytest.raises(ValueError):   # wrong k
        runner.run(params, x, TopKSink(8, "margin"), checkpoint=tk)


def test_cursor_unfilled_reservoir_is_strict_json(sweep_setup):
    """A top-k reservoir checkpointed before k valid rows have folded
    holds -inf sentinels; the cursor must still be strict JSON (no
    -Infinity literals) and resume bit-identically."""
    _, runner, params, x = sweep_setup
    ckpt = runner.run_until(params, x, TopKSink(1000, "margin"), 1)
    blob = ckpt.to_json()
    json.loads(blob)   # json.dumps(allow_nan=False) round-trip holds
    assert "Infinity" not in blob
    resumed = runner.run(params, x, TopKSink(1000, "margin"),
                         checkpoint=SweepCheckpoint.from_json(blob))
    full = runner.run(params, x, TopKSink(1000, "margin"))
    np.testing.assert_array_equal(resumed, full)


# ---------------------------------------------------------------------------
# async handle
# ---------------------------------------------------------------------------


def test_submit_future_matches_sync_run(sweep_setup):
    _, runner, params, x = sweep_setup
    fut = runner.submit(params, x, TopKSink(16, "margin"))
    sync = runner.run(params, x, TopKSink(16, "margin"))
    np.testing.assert_array_equal(fut.result(), sync)
    assert fut.done()


def test_submit_map_result(sweep_setup):
    _, runner, params, x = sweep_setup
    cand = np.arange(5000, 7000)
    fut = runner.submit(params, x, TopKSink(8, "margin"),
                        map_result=lambda rows: cand[rows])
    sync = cand[runner.run(params, x, TopKSink(8, "margin"))]
    np.testing.assert_array_equal(fut.result(), sync)


# ---------------------------------------------------------------------------
# host adapter (emulated paper-scale replays) + task routing
# ---------------------------------------------------------------------------


def test_emulated_machine_label_sweep_matches_host_path():
    from repro.core import make_emulated_task

    task = make_emulated_task("cifar10", "resnet18", seed=0,
                              pool_size=5000, sweep_page=1024)
    task.train(np.arange(200), task.human_label(np.arange(200)))
    idx = np.arange(300, 4800)
    order, top1 = task.machine_label_sweep(idx, "margin")
    stats, _ = task.score(idx)
    np.testing.assert_array_equal(
        order, sel.rank_for_machine_labeling(stats, "margin"))
    np.testing.assert_array_equal(top1, np.asarray(stats.top1, np.int64))


def test_host_adapter_cursor_resume():
    from repro.core import make_emulated_task

    task = make_emulated_task("cifar10", "resnet18", seed=1,
                              pool_size=3000)
    task.train(np.arange(100), task.human_label(np.arange(100)))
    runner = PoolSweepRunner(HostTaskAdapter(task.score),
                             SweepConfig(page_rows=700))
    idx = np.arange(3000)
    ckpt = runner.run_until(None, idx, RankTop1Sink(), 2)
    resumed = runner.run(None, idx, RankTop1Sink(),
                         checkpoint=SweepCheckpoint.from_json(ckpt.to_json()))
    full = runner.run(None, idx, RankTop1Sink())
    np.testing.assert_array_equal(resumed[0], full[0])
    np.testing.assert_array_equal(resumed[1], full[1])


def test_live_task_sweep_routing_matches_engine_paths():
    """LiveTask's rerouted pool passes (top-k, L(.) rank, anchors) agree
    with the direct engine paths."""
    from repro.core import LiveTask
    from repro.data.synth import make_classification

    x, y = make_classification(900, num_classes=10, dim=16,
                               difficulty=0.3, seed=2)
    task = LiveTask(features=x, groundtruth=y, num_classes=10, epochs=3,
                    seed=2, sweep_page=256, score_microbatch=256)
    task.train(np.arange(200), y[:200])

    cand = np.arange(300, 900)
    picks = task.topk_candidates("margin", 32, cand)
    want = cand[task._engine.top_k(task._params, task._pool(cand), 32,
                                   "margin")]
    np.testing.assert_array_equal(picks, want)

    order, top1 = task.machine_label_sweep(cand, "margin")
    stats, _ = task.score(cand)
    np.testing.assert_array_equal(
        order, sel.rank_for_machine_labeling(stats, "margin"))
    np.testing.assert_array_equal(top1, np.asarray(stats.top1, np.int64))

    anchors = task.anchor_features(np.arange(200))
    want_feats = np.asarray(task._engine.pool_features(
        task._params, task._pool(np.arange(200))), np.float32)
    np.testing.assert_array_equal(anchors, want_feats)

    fut = task.submit_candidates("margin", 32, cand)
    np.testing.assert_array_equal(fut.result(), picks)


# ---------------------------------------------------------------------------
# serving-side pool sweep
# ---------------------------------------------------------------------------


def test_serve_engine_score_pool_pages_match_batch_loop():
    """ServeEngine.score_pool == the per-batch score loop (the pre-sweep
    pattern) to serving fp tolerance, ragged tail included; and the
    cursor resumes mid-pool."""
    import jax.numpy as jnp
    from repro.configs import get_smoke
    from repro.serving.engine import ServeEngine

    cfg = get_smoke("qwen2-1.5b")
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(3)
    N, T, page = 10, 8, 4
    pool = {"tokens": rng.integers(0, cfg.vocab_size, (N, T)).astype(
        np.int32)}
    eng = ServeEngine(model, params, max_seq=T + 4, batch_size=page)

    packed = eng.score_pool(pool, page_rows=page)
    assert int(packed.margin.shape[0]) == N

    margins, top1 = [], []
    for lo in range(0, N, page):
        stats = eng.score({"tokens": jnp.asarray(pool["tokens"][lo:lo + page])})
        margins.append(np.asarray(stats.margin))
        top1.append(np.asarray(stats.top1))
    np.testing.assert_allclose(np.asarray(packed.margin),
                               np.concatenate(margins), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(packed.top1),
                                  np.concatenate(top1))

    runner = eng._sweep_runner(page)
    ckpt = runner.run_until(params, pool, StatsSink(), 1)
    resumed = runner.run(params, pool, StatsSink(),
                         checkpoint=SweepCheckpoint.from_json(ckpt.to_json()))
    np.testing.assert_array_equal(np.asarray(resumed.margin),
                                  np.asarray(packed.margin))

    fut = eng.score_pool_async(pool, page_rows=page)
    np.testing.assert_array_equal(np.asarray(fut.result().margin),
                                  np.asarray(packed.margin))
