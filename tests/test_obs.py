"""Runtime metrics & profiling layer (src/repro/obs/).

Unit level: log-bucket histogram boundaries, registry thread safety
under concurrent rounds, span nesting + exception unwinding, bound
(per-tenant) label merging, Prometheus exposition format.

System level: a fully instrumented noisy emulated campaign must make
byte-identical decisions to its metrics-off sibling (``trace.diff``
clean — metric events are observability kinds), disabled mode
(``metrics=None``) is the identity on every instrumented site, and
``launch/report.py --metrics`` renders the per-engine panel for a solo
campaign AND an N=4 tenant fleet from recorded telemetry alone.
"""
import json
import math
import os
import threading
import time

import numpy as np
import pytest

from repro.obs import (DEFAULT_BUCKETS, MetricsRegistry, log_buckets,
                       prometheus_lines, profile_block, cache_hit_rates,
                       queue_stats, span_rollup)
from repro.obs.metrics import _Hist


# ---------------------------------------------------------------------------
# histogram buckets
# ---------------------------------------------------------------------------


def test_log_buckets_cover_range_log_spaced():
    b = log_buckets(1e-3, 10.0, per_decade=2)
    assert b[0] == pytest.approx(1e-3)
    assert b[-1] >= 10.0
    ratios = [b[i + 1] / b[i] for i in range(len(b) - 1)]
    assert all(r == pytest.approx(math.sqrt(10.0)) for r in ratios)
    with pytest.raises(ValueError):
        log_buckets(0.0, 1.0)
    with pytest.raises(ValueError):
        log_buckets(2.0, 1.0)


def test_histogram_bucket_boundaries():
    h = _Hist((1.0, 10.0, 100.0))
    # upper-edge inclusive: v <= bounds[i] lands in bucket i
    for v, slot in ((0.5, 0), (1.0, 0), (1.0001, 1), (10.0, 1),
                    (99.0, 2), (100.0, 2), (101.0, 3), (1e9, 3)):
        before = list(h.counts)
        h.observe(v)
        assert h.counts[slot] == before[slot] + 1, (v, slot)
    assert h.count == 8
    assert h.min == 0.5 and h.max == 1e9
    assert h.sum == pytest.approx(0.5 + 1.0 + 1.0001 + 10.0 + 99.0
                                  + 100.0 + 101.0 + 1e9)
    # bounded memory: bucket count never grows with observations
    assert len(h.counts) == 4


def test_histogram_empty_minmax_null():
    d = _Hist((1.0,)).to_dict()
    assert d["min"] is None and d["max"] is None and d["count"] == 0


# ---------------------------------------------------------------------------
# registry: counters/gauges/labels/thread safety
# ---------------------------------------------------------------------------


def test_counters_gauges_label_keyed():
    m = MetricsRegistry()
    m.inc("hits_total", engine="scoring")
    m.inc("hits_total", 2.0, engine="fit")
    m.inc("hits_total", engine="scoring")
    m.set_gauge("depth", 3.0, queue="ann")
    assert m.add_gauge("depth", -1.0, queue="ann") == 2.0
    snap = m.snapshot()
    vals = {tuple(sorted(c["labels"].items())): c["value"]
            for c in snap["counters"] if c["name"] == "hits_total"}
    assert vals[(("engine", "scoring"),)] == 2.0
    assert vals[(("engine", "fit"),)] == 2.0
    assert snap["gauges"][0]["value"] == 2.0


def test_label_name_cannot_collide_with_metric_params():
    # spans label their histogram rows name=<span name>; the registry's
    # positional-only params must not swallow such labels
    m = MetricsRegistry()
    m.inc("c_total", 1.0, name="x", value="y")
    m.observe("span_seconds", 0.5, name="sweep")
    snap = m.snapshot()
    assert snap["counters"][0]["labels"] == {"name": "x", "value": "y"}
    assert snap["histograms"][0]["labels"] == {"name": "sweep"}


def test_registry_thread_safety_under_concurrent_rounds():
    m = MetricsRegistry()
    threads, per, n = 8, 500, []

    def tenant_round(t):
        with m.bind(tenant=f"t{t}"):
            for i in range(per):
                m.inc("iters_total")
                m.observe("lat", i * 1e-4)
                m.add_gauge("depth", 1)
                m.add_gauge("depth", -1)

    ths = [threading.Thread(target=tenant_round, args=(t,))
           for t in range(threads)]
    for th in ths:
        th.start()
    for th in ths:
        th.join()
    snap = m.snapshot()
    counters = [c for c in snap["counters"] if c["name"] == "iters_total"]
    assert len(counters) == threads             # one series per tenant
    assert sum(c["value"] for c in counters) == threads * per
    hists = [h for h in snap["histograms"] if h["name"] == "lat"]
    assert sum(h["count"] for h in hists) == threads * per
    gauges = [g for g in snap["gauges"] if g["name"] == "depth"]
    assert all(g["value"] == 0.0 for g in gauges)   # balanced +1/-1


def test_bind_merges_and_explicit_labels_win():
    m = MetricsRegistry()
    with m.bind(tenant="t0", engine="fleet"):
        m.inc("x_total", engine="fit")   # explicit engine wins
    m.inc("x_total", engine="fit")       # outside bind: no tenant label
    snap = m.snapshot()
    labels = sorted(tuple(sorted(c["labels"].items()))
                    for c in snap["counters"])
    assert labels == [(("engine", "fit"),),
                      (("engine", "fit"), ("tenant", "t0"))]


# ---------------------------------------------------------------------------
# spans: nesting, exception unwinding, decorator
# ---------------------------------------------------------------------------


class _FakeTrace:
    def __init__(self):
        self.events = []

    def emit(self, kind, **payload):
        self.events.append({"kind": kind, "payload": payload})


def test_span_nesting_paths():
    m = MetricsRegistry()
    tr = _FakeTrace()
    m.attach_trace(tr)
    with m.span("round"):
        with m.span("iteration"):
            with m.span("sweep"):
                pass
        with m.span("fit"):
            pass
    paths = [e["payload"]["path"] for e in tr.events]
    assert paths == ["round/iteration/sweep", "round/iteration",
                     "round/fit", "round"]


def test_span_exception_unwinds_stack_and_reraises():
    m = MetricsRegistry()
    tr = _FakeTrace()
    m.attach_trace(tr)
    with pytest.raises(ValueError, match="boom"):
        with m.span("outer"):
            with m.span("inner"):
                raise ValueError("boom")
    assert [e["payload"]["status"] for e in tr.events] == ["error", "error"]
    snap = m.snapshot()
    errs = {c["labels"]["name"]: c["value"] for c in snap["counters"]
            if c["name"] == "span_errors_total"}
    assert errs == {"inner": 1.0, "outer": 1.0}
    # the stack unwound: a fresh span is top-level again
    with m.span("clean"):
        pass
    assert tr.events[-1]["payload"]["path"] == "clean"


def test_span_decorator_and_fence():
    import jax.numpy as jnp

    m = MetricsRegistry()

    @m.span("scored")
    def score(x):
        return x * 2

    assert score(3) == 6
    with m.span("fenced") as sp:
        sp.fence(jnp.arange(8) * 2.0)
    snap = m.snapshot()
    names = {h["labels"]["name"] for h in snap["histograms"]
             if h["name"] == "span_seconds"}
    assert names == {"scored", "fenced"}


def test_span_timing_is_wall_clock():
    m = MetricsRegistry()
    with m.span("nap"):
        time.sleep(0.02)
    h = m.snapshot()["histograms"][0]
    assert h["min"] >= 0.02


# ---------------------------------------------------------------------------
# exports: prometheus + profile_block
# ---------------------------------------------------------------------------


def test_prometheus_exposition_format(tmp_path):
    m = MetricsRegistry(buckets=(0.1, 1.0))
    m.inc("labels_total", 3.0, engine="fit")
    m.set_gauge("depth", 2.0)
    m.observe("lat_seconds", 0.05)
    m.observe("lat_seconds", 5.0)
    lines = prometheus_lines(m.snapshot())
    assert "# TYPE repro_labels_total counter" in lines
    assert 'repro_labels_total{engine="fit"} 3.0' in lines
    assert "repro_depth 2.0" in lines
    # cumulative buckets + overflow +Inf == count
    assert "repro_lat_seconds_bucket{le=\"0.1\"} 1" in lines
    assert "repro_lat_seconds_bucket{le=\"+Inf\"} 2" in lines
    assert "repro_lat_seconds_count 2" in lines
    p = tmp_path / "m.prom"
    m.write_prometheus(str(p))
    assert p.read_text().splitlines() == lines
    assert not os.path.exists(str(p) + ".tmp")   # atomic rename


def test_profile_block_disabled_and_exception_transparent(tmp_path):
    with profile_block("", enabled=True) as on:
        assert on is False
    with profile_block(str(tmp_path), enabled=False) as on:
        assert on is False
    with pytest.raises(RuntimeError, match="body"):
        with profile_block("", enabled=True):
            raise RuntimeError("body")


# ---------------------------------------------------------------------------
# campaign level: disabled-mode identity + replay diff stays clean
# ---------------------------------------------------------------------------


def _campaign_run(path, metrics=None):
    from repro.annotation import make_annotation_service
    from repro.core import AMAZON, MCALConfig, make_emulated_task
    from repro.core.mcal import MCALCampaign
    from repro.trace import TraceStore

    ann = make_annotation_service(
        10, noise=0.2, repeats=3, max_repeats=5, adaptive=True,
        aggregator="ds", pricing=AMAZON, seed=0)
    task = make_emulated_task("cifar10", "resnet18", seed=0,
                              pool_size=4000, sweep_page=512)
    task.annotation = ann
    cfg = MCALConfig(seed=0, label_quality=ann.expected_quality())
    camp = MCALCampaign(task, AMAZON, cfg)
    with TraceStore(str(path), "obs-noisy-s0") as tr:
        camp.attach_trace(tr)
        if metrics is not None:
            metrics.attach_trace(tr)
            camp.attach_metrics(metrics)
        res = camp.run()
        if metrics is not None:
            metrics.emit_snapshot(scope="test")
    return res


@pytest.fixture(scope="module")
def sibling_runs(tmp_path_factory):
    """The same noisy campaign twice: metrics off, then fully
    instrumented (metric events interleaved into the trace)."""
    d = tmp_path_factory.mktemp("obs")
    off, on = d / "off.jsonl", d / "on.jsonl"
    res_off = _campaign_run(off)
    m = MetricsRegistry()
    res_on = _campaign_run(on, m)
    return {"off": (str(off), res_off), "on": (str(on), res_on),
            "registry": m}


def test_metrics_do_not_change_decisions(sibling_runs):
    _, res_off = sibling_runs["off"]
    _, res_on = sibling_runs["on"]
    assert res_on.total_cost == res_off.total_cost
    assert res_on.decision == res_off.decision
    assert len(res_on.history) == len(res_off.history)
    for got, want in zip(res_on.history, res_off.history):
        assert got.to_dict() == want.to_dict()


def test_replay_diff_clean_between_instrumented_and_not(sibling_runs):
    from repro.trace import diff, replay
    p_off, _ = sibling_runs["off"]
    p_on, res_on = sibling_runs["on"]
    assert diff(p_off, p_on) is None
    # and the interleaved trace still replays to the live result
    rp = replay(p_on)
    assert rp.total_cost == res_on.total_cost
    assert len(rp.history) == len(res_on.history)


def test_metric_events_are_observability_kinds(sibling_runs):
    from repro.trace.replay import OBSERVABILITY_KINDS, REPLAY_KINDS
    from repro.trace.store import read_trace
    assert {"metric_span", "metric_snapshot"} <= OBSERVABILITY_KINDS
    assert not {"metric_span", "metric_snapshot"} & REPLAY_KINDS
    p_on, _ = sibling_runs["on"]
    kinds = {e.kind for e in read_trace(p_on)}
    assert {"metric_span", "metric_snapshot"} <= kinds


def test_registry_saw_every_campaign_site(sibling_runs):
    snap = sibling_runs["registry"].snapshot()
    counters = {c["name"] for c in snap["counters"]}
    assert {"annotation_labels_total", "annotation_votes_total",
            "annotation_agg_rounds_total", "campaign_iterations_total",
            "pack_cache_hits_total", "pack_cache_misses_total"} <= counters
    spans = {h["labels"]["name"] for h in snap["histograms"]
             if h["name"] == "span_seconds"}
    assert {"bootstrap", "iteration", "commit", "annotate"} <= spans


def test_disabled_mode_is_identity_on_engine_sites():
    # every instrumented site guards on `metrics is None`; spot-check the
    # device selection engine end to end (cheap) — same indices with and
    # without a registry
    from repro.core.selection_device import k_center_greedy_device
    rng = np.random.default_rng(0)
    X = rng.integers(0, 16, (128, 8)).astype(np.float32)
    m = MetricsRegistry()
    a = k_center_greedy_device(X, 10)
    b = k_center_greedy_device(X, 10, metrics=m)
    np.testing.assert_array_equal(a, b)
    spans = [h for h in m.snapshot()["histograms"]
             if h["name"] == "span_seconds"]
    assert spans and spans[0]["labels"]["name"] == "kcenter"


# ---------------------------------------------------------------------------
# report --metrics: solo + fleet, from recorded telemetry alone
# ---------------------------------------------------------------------------


def test_report_metrics_panel_solo(sibling_runs, capsys):
    from repro.launch import report
    p_on, _ = sibling_runs["on"]
    report.main([p_on, "--metrics"])
    out = capsys.readouterr().out
    assert "== metrics ==" in out
    assert "iteration" in out and "annotate" in out
    assert "compile cache:" in out
    # and the JSON view carries the rollup + raw snapshot
    report.main([p_on, "--metrics", "--json"])
    blob = json.loads(capsys.readouterr().out)
    assert blob["metrics"]["spans"]
    assert blob["metrics"]["snapshot"]["counters"]


@pytest.fixture(scope="module")
def fleet_dir(tmp_path_factory):
    """An instrumented N=4 tenant fleet over shared engines: tenant
    traces + standalone metrics.jsonl + metrics.prom in one dir."""
    from repro.core import AMAZON, MCALConfig
    from repro.core.tenant import TenantSpec
    from repro.data.synth import make_classification
    from repro.launch.orchestrator import build_fleet

    d = str(tmp_path_factory.mktemp("fleet"))
    x, y = make_classification(400, num_classes=4, difficulty=0.3, seed=0)
    specs = [TenantSpec(f"t{i}", priority=i % 2, seed=i,
                        cfg=MCALConfig(seed=i, max_iters=2,
                                       delta0_frac=0.1, test_frac=0.2))
             for i in range(4)]
    m = MetricsRegistry()
    orch = build_fleet(x, y, specs, service=AMAZON, trace_dir=d,
                       concurrent=True, metrics=m,
                       engine_kw=dict(epochs=2, score_microbatch=128,
                                      sweep_page=128))
    try:
        orch.run()
    finally:
        m.write_prometheus(os.path.join(d, "metrics.prom"))
        orch.close()
    return d


def test_fleet_metrics_stream_separate_and_attributed(fleet_dir):
    from repro.trace.store import read_trace
    assert os.path.exists(os.path.join(fleet_dir, "metrics.jsonl"))
    events = read_trace(os.path.join(fleet_dir, "metrics.jsonl"))
    assert events and all(e.kind in ("metric_span", "metric_snapshot")
                          for e in events)
    roll = span_rollup(events)
    tenants = {t for (_, t) in roll if t}
    assert tenants == {"t0", "t1", "t2", "t3"}   # per-tenant attribution
    # every tenant's round + engine time shows up
    assert all(("round", f"t{i}") in roll for i in range(4))
    # the final fleet snapshot carries cache hits + compiled-program gauges
    snap = [e.payload["snapshot"] for e in events
            if e.kind == "metric_snapshot"][-1]
    rates = cache_hit_rates(snap)
    assert "scoring" in rates and rates["scoring"]["hits"] > 0
    gauges = {g["name"] for g in snap["gauges"]}
    assert "compiled_programs" in gauges


def test_tenant_decision_streams_stay_clean_under_metrics(fleet_dir,
                                                          tmp_path):
    # a metrics-off solo campaign with tenant t0's config must diff
    # clean against the instrumented fleet's t0 trace
    from repro.core import AMAZON, MCALConfig
    from repro.core.mcal import MCALCampaign
    from repro.core.task import LiveTask
    from repro.data.synth import make_classification
    from repro.trace import TraceStore, diff

    x, y = make_classification(400, num_classes=4, difficulty=0.3, seed=0)
    task = LiveTask(features=x, groundtruth=y, num_classes=4, seed=0,
                    epochs=2, score_microbatch=128, sweep_page=128)
    camp = MCALCampaign(task, AMAZON,
                        MCALConfig(seed=0, max_iters=2, delta0_frac=0.1,
                                   test_frac=0.2))
    solo = tmp_path / "solo.jsonl"
    with TraceStore(str(solo), "t0") as tr:
        camp.attach_trace(tr)
        camp.run()
    assert diff(str(solo), os.path.join(fleet_dir, "t0.jsonl")) is None


def test_report_metrics_panel_fleet(fleet_dir, capsys):
    from repro.launch import report
    report.main([fleet_dir, "--metrics"])
    out = capsys.readouterr().out
    for t in ("t0", "t1", "t2", "t3"):
        assert f"campaign {t}" in out
    assert "== metrics ==" in out
    assert "tenant" in out                      # per-tenant span rows
    assert "compile cache:" in out
    # the prom snapshot is scrapeable next to the traces
    prom = open(os.path.join(fleet_dir, "metrics.prom")).read()
    assert "# TYPE repro_span_seconds histogram" in prom


def test_report_watch_tolerates_vanished_trace(sibling_runs, tmp_path):
    # the watched file appears only after the first poll: the loop must
    # re-wait instead of raising (rotated/mid-restart traces)
    import shutil

    from repro.launch import report
    p_on, _ = sibling_runs["on"]
    target = tmp_path / "late.jsonl"
    done = []

    def watcher():
        report.main([str(target), "--watch", "0.05"])
        done.append(True)

    th = threading.Thread(target=watcher)
    th.start()
    time.sleep(0.15)                 # a few failing polls
    shutil.copy(p_on, target)        # trace "rotates" into place
    th.join(timeout=30.0)
    assert done, "watch loop did not recover after the trace appeared"


def test_report_non_watch_still_raises_on_missing(tmp_path):
    from repro.launch import report
    with pytest.raises(OSError):
        report.main([str(tmp_path / "nope.jsonl")])


def test_queue_stats_rollup():
    m = MetricsRegistry()
    m.add_gauge("queue_depth", 1, queue="annotation")
    m.observe("queue_wait_seconds", 0.2, queue="annotation")
    m.observe("queue_wait_seconds", 0.4, queue="annotation")
    st = queue_stats(m.snapshot())["annotation"]
    assert st["depth"] == 1.0 and st["waits"] == 2
    assert st["wait_mean"] == pytest.approx(0.3)
    assert st["wait_max"] == pytest.approx(0.4)
