"""Broker-thread lifecycle: every engine worker is a daemon, joins on
close, and refuses work afterwards.

Regression suite for the leak where ``PoolSweepRunner.submit``,
``FitEngine.submit_fit``/``submit_call``, and ``AnnotationService.submit``
each spun up a worker thread that was neither daemonized nor ever joined
— a process that touched any async path could only exit by having its
non-daemon workers die with it (or not exit at all under a runner that
joins threads).  All three now share :class:`repro.core.worker.
SerialWorker` and expose idempotent ``close()``/context-manager
teardown, called from campaign teardown.
"""
import numpy as np
import pytest

from repro.core.worker import SerialWorker, WorkerClosed


# ---------------------------------------------------------------------------
# SerialWorker semantics
# ---------------------------------------------------------------------------


def test_worker_runs_jobs_in_order():
    out = []
    with SerialWorker("t") as w:
        futs = [w.submit(out.append, i) for i in range(8)]
        for f in futs:
            f.result(timeout=5)
    assert out == list(range(8))


def test_worker_thread_is_daemon():
    w = SerialWorker("t")
    w.submit(lambda: None).result(timeout=5)
    assert w._thread is not None and w._thread.daemon
    w.close()


def test_worker_close_joins_thread():
    w = SerialWorker("t")
    w.submit(lambda: None).result(timeout=5)
    th = w._thread
    assert th.is_alive()
    w.close()
    assert not th.is_alive() and not w.alive


def test_worker_close_idempotent_and_lazy():
    w = SerialWorker("t")
    w.close()           # never started: still fine
    w.close()
    w2 = SerialWorker("t2")
    w2.submit(lambda: 1).result(timeout=5)
    w2.close()
    w2.close()


def test_worker_submit_after_close_raises():
    w = SerialWorker("t")
    w.submit(lambda: 1).result(timeout=5)
    w.close()
    with pytest.raises(WorkerClosed):
        w.submit(lambda: 2)


def test_worker_propagates_exceptions():
    with SerialWorker("t") as w:
        f = w.submit(lambda: 1 / 0)
        with pytest.raises(ZeroDivisionError):
            f.result(timeout=5)
        # the worker survives a failing job
        assert w.submit(lambda: 7).result(timeout=5) == 7


def test_worker_close_reports_joined_thread():
    w = SerialWorker("t")
    assert w.close(timeout=1) is True       # never started: nothing leaks
    w2 = SerialWorker("t2")
    w2.submit(lambda: None).result(timeout=5)
    assert w2.close(timeout=5) is True
    assert w2.close(timeout=5) is True      # idempotent, still joined


def test_worker_close_times_out_on_stuck_job_and_warns():
    import threading
    gate = threading.Event()
    w = SerialWorker("t")
    w.submit(gate.wait)
    with pytest.warns(RuntimeWarning, match="failed to join"):
        assert w.close(timeout=0.05) is False    # leaked (daemon) thread
    gate.set()                              # unstick; the daemon drains
    w._thread.join(timeout=5)
    assert not w.alive


def test_worker_close_drains_queued_jobs():
    done = []
    w = SerialWorker("t")
    futs = [w.submit(done.append, i) for i in range(32)]
    w.close()           # close waits for everything already queued
    for f in futs:
        f.result(timeout=5)
    assert done == list(range(32))


# ---------------------------------------------------------------------------
# the three brokered engines
# ---------------------------------------------------------------------------


def _live_task(n=96, annotation=None):
    from repro.core.task import LiveTask
    from repro.data.synth import make_classification
    x, y = make_classification(n, num_classes=3, difficulty=0.3, seed=0)
    return LiveTask(features=x, groundtruth=y, num_classes=3, epochs=2,
                    score_microbatch=32, sweep_page=32, seed=0,
                    annotation=annotation)


def test_sweep_runner_close_joins_and_refuses():
    task = _live_task()
    task.train(np.arange(32), task.groundtruth[:32])
    fut = task.submit_candidates("margin", 4, np.arange(32, 96))
    assert len(fut.result()) == 4
    runner = task._sweep
    assert runner._exec is not None and runner._exec.alive
    runner.close()
    assert not runner._exec.alive
    with pytest.raises(WorkerClosed):
        task.submit_candidates("margin", 4, np.arange(32, 96))
    # synchronous sweeps remain valid after close
    assert len(task.topk_candidates("margin", 4, np.arange(32, 96))) == 4
    task.close()


def test_fit_engine_close_joins_and_refuses():
    task = _live_task()
    c = task.submit_train(np.arange(32), task.groundtruth[:32]).result()
    assert c > 0
    eng = task._fit
    assert eng._exec is not None and eng._exec.alive
    eng.close()
    assert not eng._exec.alive
    with pytest.raises(WorkerClosed):
        task.submit_train(np.arange(32), task.groundtruth[:32])
    # synchronous training remains valid after close
    assert task.train(np.arange(32), task.groundtruth[:32]) > 0
    task.close()


def test_annotation_service_close_joins_and_refuses():
    from repro.annotation import make_annotation_service
    svc = make_annotation_service(3, n_workers=5, noise=0.2, repeats=3,
                                  seed=0)
    idx = np.arange(16)
    gt = np.zeros(16, np.int64)
    labels = svc.submit(idx, gt).result()
    assert labels.shape == (16,)
    assert svc._exec is not None and svc._exec.alive
    svc.close()
    assert not svc._exec.alive
    with pytest.raises(WorkerClosed):
        svc.submit(idx, gt)
    # the synchronous request path survives close
    assert svc.annotate(idx, gt).shape == (16,)
    svc.close()         # idempotent


def test_campaign_close_tears_down_all_brokers():
    """End-to-end regression: a campaign that exercised every async path
    leaves ZERO broker threads after ``close()`` — and close is
    idempotent."""
    from repro.annotation import make_annotation_service
    from repro.core import AMAZON, MCALCampaign, MCALConfig
    svc = make_annotation_service(3, n_workers=5, noise=0.1, repeats=3,
                                  seed=0)
    task = _live_task(annotation=svc)
    cfg = MCALConfig(max_iters=2, delta0_frac=0.1, test_frac=0.2,
                     sweep_async=True, fit_async=True,
                     label_quality=svc.expected_quality())
    camp = MCALCampaign(task, AMAZON, cfg)
    camp.run()
    # the async campaign exercised both engine brokers (the annotation
    # broker only starts on submit(), which the campaign never uses)
    workers = [w for w in (task._sweep._exec, task._fit._exec)
               if w is not None]
    assert workers and all(w.alive for w in workers), \
        "campaign never exercised a broker thread"
    camp.close()
    assert not any(w.alive for w in workers)
    if svc._exec is not None:          # task.close() closed the service
        assert not svc._exec.alive
    camp.close()        # idempotent


def test_run_campaign_closes_workers(tmp_path):
    """The launcher's ``run_campaign`` joins every broker in its
    teardown path."""
    from repro.core import AMAZON, MCALConfig
    from repro.launch.label import run_campaign
    task = _live_task()
    cfg = MCALConfig(max_iters=2, delta0_frac=0.1, test_frac=0.2,
                     sweep_async=True, fit_async=True)
    res, camp = run_campaign(task, AMAZON, cfg)
    assert res is not None
    for eng in (task._sweep, task._fit):
        assert eng._exec is None or not eng._exec.alive
