"""Annotation-service runtime: noisy oracles, device vote aggregation,
the async broker, and the campaign integration.

The oracle-test contract (same spirit as the selection/sweep/fit
engines): device majority vote agrees EXACTLY with the host reference
(integer counts, first-class-index tie-break on both sides); device
Dawid-Skene EM posteriors are atol-bounded against the float64 host EM
with IDENTICAL argmax labels — across seeded (items, workers, classes,
repeats, ragged-batch) grids.
"""
import json

import numpy as np
import pytest

from repro.annotation import (AGGREGATORS, AnnotationService, AnnotatorConfig,
                              AnnotatorPool, BudgetExceeded, RepeatPolicy,
                              VoteAggregator, dawid_skene_host,
                              majority_vote_host, make_annotation_service,
                              make_annotator_pool, vote_counts_host)
from repro.annotation.aggregate import AggregateConfig
from repro.core.cost import CostLedger, LabelingService


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _vote_matrix(n, workers, classes, repeats, *, noise=0.25,
                 spammer_frac=0.0, seed=0):
    """A round-robin (n, workers) vote matrix with ``repeats`` votes per
    item — ``AnnotatorPool.vote_matrix``, the service's worker schedule."""
    pool = make_annotator_pool(workers, classes, noise=noise,
                               spammer_frac=spammer_frac, seed=seed)
    rng = np.random.default_rng(seed + 1)
    gt = rng.integers(0, classes, n)
    return pool.vote_matrix(np.arange(n), gt, repeats), gt, pool


# ---------------------------------------------------------------------------
# the noisy oracle
# ---------------------------------------------------------------------------


def test_pool_confusions_are_row_stochastic():
    pool = make_annotator_pool(7, 5, noise=0.3, spammer_frac=0.3,
                               biased_frac=0.2, seed=3)
    assert pool.confusion.shape == (7, 5, 5)
    np.testing.assert_allclose(pool.confusion.sum(axis=2), 1.0, atol=1e-12)
    assert len(pool.profiles) == 7
    assert set(pool.profiles) <= {"reliable", "spammer", "biased"}


def test_pool_profile_mix_counts():
    pool = make_annotator_pool(10, 4, noise=0.2, spammer_frac=0.2,
                               biased_frac=0.3, seed=0)
    assert sum(p == "spammer" for p in pool.profiles) == 2
    assert sum(p == "biased" for p in pool.profiles) == 3


def test_annotate_deterministic_per_seed_worker_item():
    """A worker is a consistent annotator: the same (seed, worker, item)
    request always returns the same vote — across calls, orderings, and
    pool instances (what makes resumed campaigns replay identically)."""
    cfg = AnnotatorConfig(n_workers=4, num_classes=6, noise=0.4, seed=11)
    a, b = AnnotatorPool(cfg), AnnotatorPool(cfg)
    rng = np.random.default_rng(0)
    idx = rng.choice(5000, 300, replace=False)
    gt = rng.integers(0, 6, 300)
    for w in range(4):
        v1 = a.annotate(idx, gt, w)
        v2 = b.annotate(idx, gt, w)
        np.testing.assert_array_equal(v1, v2)
        # a permuted request sees the same per-item votes
        p = rng.permutation(300)
        np.testing.assert_array_equal(a.annotate(idx[p], gt[p], w), v1[p])


def test_annotate_zero_noise_is_perfect():
    pool = make_annotator_pool(3, 5, noise=0.0, seed=0)
    gt = np.arange(5).repeat(4)
    for w in range(3):
        np.testing.assert_array_equal(
            pool.annotate(np.arange(20), gt, w), gt)


def test_spammer_is_uninformative_and_reliable_is_not():
    pool = make_annotator_pool(4, 4, noise=0.1, spammer_frac=0.25, seed=2)
    spam = pool.profiles.index("spammer")
    rel = pool.profiles.index("reliable")
    rng = np.random.default_rng(0)
    gt = rng.integers(0, 4, 4000)
    idx = np.arange(4000)
    acc_spam = np.mean(pool.annotate(idx, gt, spam) == gt)
    acc_rel = np.mean(pool.annotate(idx, gt, rel) == gt)
    assert acc_spam < 0.35 and acc_rel > 0.8


def test_expected_majority_error_monotone_in_repeats():
    pool = make_annotator_pool(7, 10, noise=0.25, seed=0)
    errs = [pool.expected_majority_error(r) for r in (1, 3, 5, 7)]
    assert all(a >= b for a, b in zip(errs, errs[1:]))
    assert errs[0] == pytest.approx(pool.per_vote_error())


# ---------------------------------------------------------------------------
# aggregation: device vs host oracle grids
# ---------------------------------------------------------------------------

GRID = [
    # (items, workers, classes, repeats)
    (1, 3, 2, 3),
    (7, 5, 10, 1),
    (60, 5, 10, 3),
    (100, 7, 4, 5),
    (513, 5, 10, 3),       # pow2-boundary ragged batch
    (1024, 3, 3, 2),
    (1500, 9, 25, 7),
]


@pytest.mark.parametrize("n,workers,classes,repeats", GRID)
def test_majority_device_matches_host_exactly(n, workers, classes, repeats):
    votes, _, _ = _vote_matrix(n, workers, classes, repeats,
                               seed=n + repeats)
    lh, ch = majority_vote_host(votes, classes)
    agg = VoteAggregator(classes, AggregateConfig(microbatch=256))
    ld, cd = agg.majority(votes)
    np.testing.assert_array_equal(lh, ld)
    np.testing.assert_allclose(ch, cd, atol=1e-7)


def test_majority_tie_breaks_by_first_class_index():
    # 1-1 and 2-2 ties; class order deliberately descending
    votes = np.asarray([[3, 1, -1, -1],
                        [2, 0, 2, 0],
                        [-1, -1, -1, -1]], np.int32)
    lh, ch = majority_vote_host(votes, 4)
    ld, cd = VoteAggregator(4).majority(votes)
    np.testing.assert_array_equal(lh, [1, 0, 0])   # lowest class wins ties
    np.testing.assert_array_equal(ld, lh)
    assert ch[2] == 0.0 and cd[2] == 0.0           # no votes -> class 0


@pytest.mark.parametrize("n,workers,classes,repeats", GRID)
def test_dawid_skene_device_matches_host(n, workers, classes, repeats):
    votes, _, _ = _vote_matrix(n, workers, classes, repeats,
                               seed=2 * n + repeats, spammer_frac=0.2)
    ref = dawid_skene_host(votes, classes)
    agg = VoteAggregator(classes, AggregateConfig(microbatch=256))
    dev = agg.dawid_skene(votes)
    np.testing.assert_array_equal(ref.labels, dev.labels)
    np.testing.assert_allclose(ref.posterior, dev.posterior, atol=1e-4)
    np.testing.assert_allclose(ref.confusion, dev.confusion, atol=1e-4)
    np.testing.assert_allclose(ref.prior, dev.prior, atol=1e-4)


def test_dawid_skene_identifies_the_spammer():
    votes, gt, pool = _vote_matrix(3000, 5, 10, 5, noise=0.15,
                                   spammer_frac=0.2, seed=0)
    res = VoteAggregator(10).dawid_skene(votes)
    est_acc = np.einsum("wcc->w", res.confusion) / 10
    spam = pool.profiles.index("spammer")
    rel = [w for w in range(5) if pool.profiles[w] == "reliable"]
    assert est_acc[spam] < 0.3
    assert all(est_acc[w] > 0.7 for w in rel)


def test_dawid_skene_beats_majority_with_spammers():
    votes, gt, _ = _vote_matrix(4000, 5, 10, 5, noise=0.25,
                                spammer_frac=0.4, seed=1)
    maj, _ = majority_vote_host(votes, 10)
    ds = VoteAggregator(10).dawid_skene(votes)
    acc_maj = np.mean(maj == gt)
    acc_ds = np.mean(ds.labels == gt)
    assert acc_ds > acc_maj   # down-weighting spammers must pay off


def test_aggregator_pack_buckets_stay_logarithmic():
    """Growing request batches reuse O(log N) compiled programs — the
    pack_shape bucketing contract every engine shares."""
    agg = VoteAggregator(4, AggregateConfig(microbatch=64))
    for n in range(1, 600, 7):
        votes = np.zeros((n, 3), np.int32)
        agg.majority(votes)
    assert len(agg.cache_keys()) <= 8


def test_aggregate_entry_point_and_unknown_method():
    votes, _, _ = _vote_matrix(50, 5, 4, 3)
    agg = VoteAggregator(4)
    l1, c1, ds = agg.aggregate(votes, "majority")
    assert ds is None and len(l1) == 50
    l2, c2, ds2 = agg.aggregate(votes, "ds")
    assert ds2 is not None
    np.testing.assert_array_equal(l2, ds2.labels)
    with pytest.raises(ValueError):
        agg.aggregate(votes, "mode")


def test_vote_counts_host_ignores_missing():
    votes = np.asarray([[0, -1, 1], [-1, -1, -1]], np.int32)
    counts = vote_counts_host(votes, 3)
    np.testing.assert_array_equal(counts, [[1, 1, 0], [0, 0, 0]])


# ---------------------------------------------------------------------------
# resident vote matrices: upload once, scatter deltas, aggregate in place
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,workers,classes,repeats", [
    (1, 3, 2, 3), (60, 5, 10, 3), (513, 5, 10, 3), (100, 7, 4, 5),
])
@pytest.mark.parametrize("method", ["majority", "ds"])
def test_resident_aggregate_bit_identical_to_reupload(n, workers, classes,
                                                      repeats, method):
    """``aggregate_resident`` over an uploaded batch is the SAME compiled
    program over the same buffer contents as ``aggregate`` re-uploading
    the host matrix — bit-identical outputs, not just close ones."""
    votes, _, _ = _vote_matrix(n, workers, classes, repeats, seed=n)
    agg = VoteAggregator(classes, AggregateConfig(microbatch=256))
    res = agg.upload(votes)
    lr, cr, dsr = agg.aggregate_resident(res, method)
    lh, ch, dsh = agg.aggregate(votes, method)
    np.testing.assert_array_equal(lr, lh)
    np.testing.assert_array_equal(cr, ch)     # bit-equal, no atol
    if method == "ds":
        np.testing.assert_array_equal(dsr.posterior, dsh.posterior)
        np.testing.assert_array_equal(dsr.confusion, dsh.confusion)


@pytest.mark.parametrize("k", [1, 5, 8, 23])   # ragged + pow2 row counts
def test_resident_scatter_matches_host_after_row_updates(k):
    """A top-up round scatters only its changed rows; aggregating the
    resident buffer must agree with the host oracles over the UPDATED
    matrix exactly (majority bit-equal, DS atol with identical argmax)."""
    n, workers, classes = 120, 7, 5
    votes, gt, pool = _vote_matrix(n, workers, classes, 3, seed=k)
    agg = VoteAggregator(classes, AggregateConfig(microbatch=256))
    res = agg.upload(votes)
    # the top-up: k rows gain two more votes each
    rows = np.random.default_rng(k).choice(n, size=k, replace=False)
    updated = votes.copy()
    updated[rows] = pool.vote_matrix(rows, gt[rows], 5)
    res = agg.scatter(res, rows, updated[rows])

    lr, cr, _ = agg.aggregate_resident(res, "majority")
    lh, ch = majority_vote_host(updated, classes)
    np.testing.assert_array_equal(lr, lh)
    np.testing.assert_allclose(cr, ch, atol=1e-7)

    _, _, dsr = agg.aggregate_resident(res, "ds")
    dsh = dawid_skene_host(updated, classes)
    np.testing.assert_array_equal(dsr.labels, dsh.labels)
    np.testing.assert_allclose(dsr.posterior, dsh.posterior, atol=1e-4)
    # untouched rows kept their original votes on device
    keep = np.setdiff1d(np.arange(n), rows)
    np.testing.assert_array_equal(np.asarray(res.dev)[keep], votes[keep])


def test_resident_scatter_empty_and_padding_are_idempotent():
    votes, _, _ = _vote_matrix(40, 5, 4, 3, seed=9)
    agg = VoteAggregator(4)
    res = agg.upload(votes)
    before = np.asarray(res.dev).copy()
    # k=0 is a no-op returning the same buffer
    assert agg.scatter(res, np.zeros(0, np.int32),
                       np.zeros((0, 5), np.int32)) is res
    # k=3 pads to 8 by repeating row 0 — the duplicate scatters must not
    # corrupt anything (same value lands on the same row repeatedly)
    rows = np.asarray([4, 17, 4], np.int32)      # a repeated row too
    vals = np.stack([votes[4], votes[17], votes[4]])
    res2 = agg.scatter(res, rows, vals)
    np.testing.assert_array_equal(np.asarray(res2.dev), before)


# ---------------------------------------------------------------------------
# the service: charging, adaptive repeats, broker, persistence
# ---------------------------------------------------------------------------


def _gt(n, classes=10, seed=0):
    rng = np.random.default_rng(seed)
    return np.arange(n), rng.integers(0, classes, n)


def test_service_charges_repeats_times_tier_pricing():
    tiered = LabelingService("tiered", 0.04,
                             tiers=((0, 0.04), (1000, 0.01)))
    svc = make_annotation_service(10, n_workers=5, noise=0.1, repeats=3,
                                  pricing=tiered, seed=0)
    idx, gt = _gt(500)
    svc.annotate(idx, gt)
    # 1500 votes: first 1000 at $0.04, the 500 past the boundary at $0.01
    assert svc.votes_bought == 1500
    assert svc.ledger.human == pytest.approx(1000 * 0.04 + 500 * 0.01)
    assert svc.ledger.human_labels == 500
    # the next batch continues at the discounted tier
    svc.annotate(idx + 500, gt)
    assert svc.ledger.human == pytest.approx(1000 * 0.04 + 2000 * 0.01)


def test_service_label_accuracy_improves_with_repeats():
    accs = {}
    for repeats in (1, 5):
        svc = make_annotation_service(10, n_workers=5, noise=0.3,
                                      repeats=repeats, seed=0)
        idx, gt = _gt(3000)
        labels = svc.annotate(idx, gt)
        accs[repeats] = np.mean(labels == gt)
    assert accs[5] > accs[1]


def test_adaptive_repeats_saves_votes_and_stays_accurate():
    idx, gt = _gt(2000)
    flat = make_annotation_service(10, n_workers=7, noise=0.2, repeats=5,
                                   seed=0)
    lab_flat = flat.annotate(idx, gt)
    adap = make_annotation_service(10, n_workers=7, noise=0.2, repeats=2,
                                   max_repeats=5, adaptive=True,
                                   confidence=0.9, seed=0)
    lab_adap = adap.annotate(idx, gt)
    assert adap.votes_bought < flat.votes_bought          # the point
    assert adap.votes_bought >= 2 * len(idx)              # min repeats
    assert 2.0 <= adap.avg_repeats() <= 5.0
    acc_flat = np.mean(lab_flat == gt)
    acc_adap = np.mean(lab_adap == gt)
    assert acc_adap >= acc_flat - 0.02   # near-flat accuracy, fewer votes


def test_adaptive_confidence_extremes():
    idx, gt = _gt(300)
    never = make_annotation_service(10, n_workers=5, noise=0.2, repeats=2,
                                    max_repeats=5, adaptive=True,
                                    confidence=0.0, seed=0)
    never.annotate(idx, gt)
    assert never.votes_bought == 2 * len(idx)   # everyone already confident
    always = make_annotation_service(10, n_workers=5, noise=0.2, repeats=2,
                                     max_repeats=5, adaptive=True,
                                     confidence=1.1, seed=0)
    always.annotate(idx, gt)
    assert always.votes_bought == 5 * len(idx)  # nobody ever clears it


def test_service_budget_refuses_overdraft_without_phantom_state():
    svc = make_annotation_service(
        10, n_workers=5, noise=0.1, repeats=2, seed=0,
        pricing=LabelingService("svc", 0.04), budget=10.0)
    idx, gt = _gt(100)
    svc.annotate(idx, gt)                 # 200 votes = $8
    before = (svc.request_cursor, svc.votes_bought,
              svc.ledger.human, svc.ledger.human_labels)
    with pytest.raises(BudgetExceeded):
        svc.annotate(idx + 100, gt)       # base rounds would pass $10
    # transactional refusal: nothing charged, counted, or cursor-advanced
    # (a retried batch replays identically)
    assert (svc.request_cursor, svc.votes_bought,
            svc.ledger.human, svc.ledger.human_labels) == before
    assert svc.ledger.human <= 10.0


def test_adaptive_topups_stop_at_budget_instead_of_raising():
    """The mandatory base rounds are budget-checked up front; adaptive
    top-ups degrade gracefully — an unaffordable round just stops the
    topping-up and the batch still returns labels."""
    idx, gt = _gt(100)
    svc = make_annotation_service(
        10, n_workers=5, noise=0.3, repeats=2, max_repeats=5,
        adaptive=True, confidence=1.1,   # would top up everyone forever
        pricing=LabelingService("svc", 0.04), budget=10.0, seed=0)
    labels = svc.annotate(idx, gt)       # base 200 votes = $8; one $4
    assert len(labels) == 100            # top-up round is unaffordable
    assert svc.votes_bought == 200
    assert svc.ledger.human == pytest.approx(8.0)


def test_repeat_policy_validation():
    with pytest.raises(AssertionError):
        RepeatPolicy(repeats=0)
    with pytest.raises(AssertionError):
        RepeatPolicy(repeats=3, max_repeats=2)
    with pytest.raises(AssertionError):
        RepeatPolicy(aggregator="mode")
    with pytest.raises(AssertionError):
        # more repeats than workers: one vote per worker max
        AnnotationService(make_annotator_pool(3, 10),
                          RepeatPolicy(repeats=4))
    # adaptive silent-no-op guards: no top-up headroom, and single-vote
    # majority confidence is identically 1.0 (nothing ever tops up)
    with pytest.raises(AssertionError):
        RepeatPolicy(repeats=2, adaptive=True)
    with pytest.raises(AssertionError):
        RepeatPolicy(repeats=1, max_repeats=5, adaptive=True,
                     aggregator="majority")
    # single-vote adaptivity IS meaningful under DS posteriors
    p = RepeatPolicy(repeats=1, max_repeats=5, adaptive=True,
                     aggregator="ds")
    assert p.cap == 5


def test_adaptive_single_vote_ds_actually_tops_up():
    """The allowed single-vote adaptive shape (DS posteriors) must
    really buy extra votes for unsure items — the majority twin of this
    config is rejected at policy construction as a silent no-op."""
    idx, gt = _gt(1000)
    svc = make_annotation_service(10, n_workers=5, noise=0.3, repeats=1,
                                  max_repeats=5, adaptive=True,
                                  aggregator="ds", seed=0)
    labels = svc.annotate(idx, gt)
    assert svc.votes_bought > len(idx)       # top-ups fired
    single = make_annotation_service(10, n_workers=5, noise=0.3,
                                     repeats=1, seed=0)
    acc1 = np.mean(single.annotate(idx, gt) == gt)
    assert np.mean(labels == gt) > acc1      # and bought accuracy


def test_broker_submit_matches_sync_annotate():
    """The broker is the async twin of ``annotate``: the same request
    batches in the same order produce identical labels and charges (they
    serialize on the worker thread, one cursor step per batch)."""
    idx, gt = _gt(400)
    sync = make_annotation_service(10, n_workers=5, noise=0.2, repeats=3,
                                   aggregator="ds", seed=4)
    ref = [sync.annotate(idx[:150], gt[:150]),
           sync.annotate(idx[150:], gt[150:])]

    broker = make_annotation_service(10, n_workers=5, noise=0.2, repeats=3,
                                     aggregator="ds", seed=4)
    futs = [broker.submit(idx[:150], gt[:150]),
            broker.submit(idx[150:], gt[150:])]
    got = [f.result() for f in futs]
    np.testing.assert_array_equal(np.concatenate(ref),
                                  np.concatenate(got))
    assert broker.votes_bought == sync.votes_bought
    assert broker.request_cursor == sync.request_cursor == 2


def test_service_state_roundtrip_replays_identically():
    """The pending-request cursor + ledger + worker stats survive a
    JSON round-trip: the resumed service buys the identical votes."""
    def fresh():
        return make_annotation_service(10, n_workers=5, noise=0.25,
                                       repeats=2, max_repeats=4,
                                       adaptive=True, aggregator="ds",
                                       seed=7)
    idx, gt = _gt(600)
    a = fresh()
    a.annotate(idx[:300], gt[:300])
    blob = json.dumps(a.state_dict())     # strict JSON
    b = fresh()
    b.load_state_dict(json.loads(blob))
    assert b.request_cursor == a.request_cursor
    assert b.votes_bought == a.votes_bought
    la = a.annotate(idx[300:], gt[300:])
    lb = b.annotate(idx[300:], gt[300:])
    np.testing.assert_array_equal(la, lb)
    assert a.ledger.human == pytest.approx(b.ledger.human)
    np.testing.assert_array_equal(a.worker_accuracy(), b.worker_accuracy())


def test_single_vote_batches_keep_analytic_estimates():
    """Regression: a repeats=1 majority batch has confidence == 1.0 and
    every vote trivially 'agrees' with itself — folding that would
    report a perfect crowd (0.0 residual, 1.0 worker accuracy) for an
    arbitrarily noisy pool.  The estimators must keep the analytic
    prior instead."""
    svc = make_annotation_service(10, n_workers=5, noise=0.3, repeats=1,
                                  seed=0)
    idx, gt = _gt(2000)
    labels = svc.annotate(idx, gt)
    true_err = float(np.mean(labels != gt))
    assert true_err > 0.2                      # the pool really is noisy
    est = svc.estimated_residual_error()
    assert est == pytest.approx(svc.expected_quality().residual_error)
    assert abs(est - true_err) < 0.15          # analytic, not 0.0
    np.testing.assert_array_equal(svc.worker_accuracy(), np.ones(5))


def test_calibrate_uses_the_real_worker_population():
    """Regression: calibration must measure the SAME workers that answer
    real requests (same profiles + confusion matrices), only on salted
    vote randomness — a reseeded pool resamples the per-worker noise
    jitter and measures a different crowd."""
    svc = make_annotation_service(10, n_workers=5, noise=0.25,
                                  spammer_frac=0.2, repeats=3, seed=3)
    q = svc.calibrate(n=4096)
    # ground truth: the real pool's own aggregated error on a fresh batch
    idx, gt = _gt(4096, seed=77)
    labels = svc.annotate(idx, gt)
    real_err = float(np.mean(labels != gt))
    assert abs(q.residual_error - real_err) < 0.02
    # and the calibration stream is disjoint from real request draws
    same = svc.pool.annotate(idx[:500], gt[:500], 0)
    from repro.annotation.oracle import AnnotatorPool
    salted = AnnotatorPool(svc.pool.cfg, draw_salt=0x5CA1AB1E)
    np.testing.assert_array_equal(salted.confusion, svc.pool.confusion)
    assert not np.array_equal(salted.annotate(idx[:500], gt[:500], 0),
                              same)


def test_calibrate_measures_quality_without_side_effects():
    """calibrate() reports the residual error the policy actually
    delivers (sharper than the analytic majority bound for DS +
    adaptive) and leaves the service's cursor/ledger/stats untouched —
    and it is deterministic, so resumed campaigns rebuild the identical
    label_quality config."""
    svc = make_annotation_service(10, n_workers=5, noise=0.15,
                                  spammer_frac=0.2, repeats=2,
                                  max_repeats=4, adaptive=True,
                                  aggregator="ds", seed=0)
    q1 = svc.calibrate()
    assert svc.votes_bought == 0 and svc.request_cursor == 0
    assert svc.ledger.human == 0.0 and svc._conf_n == 0
    q2 = svc.calibrate()
    assert q1 == q2                       # deterministic
    assert 2.0 <= q1.avg_repeats <= 4.0   # adaptive top-ups measured
    # DS + adaptive beats the plain-majority analytic bound here
    assert q1.residual_error < svc.expected_quality().residual_error
    # and it tracks the error a real batch of this policy actually makes
    idx, gt = _gt(3000, seed=9)
    labels = svc.annotate(idx, gt)
    assert abs(q1.residual_error - np.mean(labels != gt)) < 0.05


def test_service_quality_estimates():
    svc = make_annotation_service(10, n_workers=5, noise=0.2, repeats=3,
                                  seed=0)
    q = svc.expected_quality()
    assert q.avg_repeats == 3.0
    assert 0.0 < q.residual_error < 0.5
    # before any batch: the analytic estimate; after: the posterior proxy
    assert svc.estimated_residual_error() == pytest.approx(q.residual_error)
    idx, gt = _gt(1000)
    labels = svc.annotate(idx, gt)
    est = svc.estimated_residual_error()
    true_err = float(np.mean(labels != gt))
    assert abs(est - true_err) < 0.15
    acc = svc.worker_accuracy()
    assert acc.shape == (5,) and np.all((0 <= acc) & (acc <= 1))


# ---------------------------------------------------------------------------
# campaign integration (the acceptance scenario)
# ---------------------------------------------------------------------------


def _noisy_task(pool_size=4000, *, noise=0.2, repeats=3, seed=0,
                aggregator="majority", adaptive=False, max_repeats=None,
                service=None):
    from repro.core import AMAZON, make_emulated_task
    t = make_emulated_task("cifar10", "resnet18", seed=0,
                           pool_size=pool_size, sweep_page=512)
    t.annotation = make_annotation_service(
        t.num_classes, n_workers=5, noise=noise, repeats=repeats,
        max_repeats=max_repeats, adaptive=adaptive, aggregator=aggregator,
        pricing=service or AMAZON, seed=seed)
    return t


def test_noisy_campaign_end_to_end_margin_noise02_repeats3():
    """The acceptance scenario: --metric margin --annotator-noise 0.2
    --label-repeats 3 — the campaign finishes, meets the accuracy target
    once the residual aggregated-label error is accounted for, and the
    ledger charges repeats-inclusive human cost."""
    from repro.core import AMAZON, MCALCampaign, MCALConfig
    task = _noisy_task()
    lq = task.annotation.expected_quality()
    cfg = MCALConfig(seed=0, metric="margin", label_quality=lq)
    camp = MCALCampaign(task, AMAZON, cfg)
    camp.bootstrap()
    while not camp.done:
        camp.iteration()
    res = camp.commit()
    # every row labeled, error within target + the labels' own residual
    assert np.all(res.labels >= 0)
    assert res.measured_error <= cfg.eps_target + lq.residual_error
    # repeats-inclusive economics: every vote charged at the tier rate
    led = camp.pool.ledger
    assert led.human_votes == task.annotation.votes_bought
    assert led.human_votes == 3 * led.human_labels
    assert led.human == pytest.approx(led.human_votes *
                                      AMAZON.price_per_label)
    assert res.ledger["human_votes"] == led.human_votes


def test_noisy_campaign_hybrid_reaches_adjusted_target():
    """With a budget for the residual (eps 0.1, light noise) the noisy
    campaign still machine-labels a meaningful slice and the TRUE error
    honors the target with the residual folded in."""
    from repro.core import AMAZON, MCALCampaign, MCALConfig
    task = _noisy_task(noise=0.1, aggregator="ds")
    lq = task.annotation.expected_quality()
    cfg = MCALConfig(seed=0, eps_target=0.1, label_quality=lq)
    camp = MCALCampaign(task, AMAZON, cfg)
    camp.bootstrap()
    while not camp.done:
        camp.iteration()
    res = camp.commit()
    assert res.decision == "hybrid" and res.S_size > 0
    assert res.measured_error <= cfg.eps_target + lq.residual_error


def test_commit_evaluation_oracle_buys_no_votes():
    """Regression (the pricing-bypass bug): commit()'s ground-truth
    evaluation used task.human_label, which with an annotation service
    attached would consume pool-size annotation requests NEVER charged
    through CostLedger.pay_human (and corrupt measured_error with vote
    noise).  Every vote the service sells must now land in the campaign
    ledger."""
    from repro.core import AMAZON, MCALCampaign, MCALConfig
    task = _noisy_task(pool_size=2000)
    cfg = MCALConfig(seed=0,
                     label_quality=task.annotation.expected_quality())
    camp = MCALCampaign(task, AMAZON, cfg)
    camp.bootstrap()
    while not camp.done:
        camp.iteration()
    camp.commit()
    svc = task.annotation
    led = camp.pool.ledger
    # no free/evaluation request ever hit the service...
    assert svc.votes_bought == led.human_votes
    # ...and everything the service sold was paid for at the tier rate
    assert led.human == pytest.approx(
        svc.pricing.cost(svc.votes_bought))


def test_noisy_campaign_tiered_service_charges_boundaries():
    """Tier boundaries are honored across the whole campaign: total human
    spend equals the piecewise integral of the tier schedule over the
    cumulative vote count."""
    from repro.core import MCALCampaign, MCALConfig
    tiered = LabelingService("tiered", 0.04,
                             tiers=((0, 0.04), (2000, 0.02), (6000, 0.01)))
    task = _noisy_task(pool_size=2000, service=tiered)
    cfg = MCALConfig(seed=0,
                     label_quality=task.annotation.expected_quality())
    camp = MCALCampaign(task, tiered, cfg)
    camp.bootstrap()
    while not camp.done:
        camp.iteration()
    camp.commit()
    led = camp.pool.ledger
    assert led.human == pytest.approx(tiered.cost(led.human_votes))
    assert led.human < led.human_votes * 0.04   # the discount really bit


def test_noisy_campaign_resumes_bit_identically(tmp_path):
    """Launcher-level: a preempted noisy-oracle campaign (annotation
    state in --state) finishes with the exact labels, votes, and ledger
    of an uninterrupted run."""
    import os

    from repro.core import AMAZON, MCALConfig
    from repro.launch.label import run_campaign

    cfg = MCALConfig(seed=0, metric="margin")

    def task():
        return _noisy_task(adaptive=True, repeats=2, max_repeats=4,
                           aggregator="ds")

    t0 = task()
    cfg = MCALConfig(seed=0, metric="margin",
                     label_quality=t0.annotation.expected_quality())
    plain, plain_camp = run_campaign(t0, AMAZON, cfg)

    state = str(tmp_path / "state.json")
    res, camp, hops = None, None, 0
    t1 = None
    while res is None:
        t1 = task()
        res, camp = run_campaign(t1, AMAZON, cfg, state_path=state,
                                 iters_per_run=2)
        hops += 1
        assert hops < 50
    assert hops > 1 and not os.path.exists(state)
    np.testing.assert_array_equal(res.labels, plain.labels)
    assert res.total_cost == pytest.approx(plain.total_cost, rel=1e-12)
    assert t1.annotation.votes_bought == t0.annotation.votes_bought
    assert t1.annotation.request_cursor == t0.annotation.request_cursor
    assert camp.pool.ledger.human_votes == plain_camp.pool.ledger.human_votes


def test_aggregators_constant_matches_service_module():
    from repro.launch.label import AGGREGATE_CHOICES
    assert set(AGGREGATE_CHOICES) == set(AGGREGATORS)
