"""Halo-exchange windowed attention == full windowed attention, verified
on a real 4-way sequence-sharded mesh (subprocess: device count must be
set before jax initializes)."""
import json
import os
import subprocess
import sys

import numpy as np

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp, numpy as np
from repro.models.layers import blockwise_attention
from repro.serving.halo_attention import halo_window_attention

from repro.compat import make_mesh
mesh = make_mesh((4,), ("model",), axis_types=True)
rng = np.random.default_rng(0)
results = {}
for (B, T, H, Hk, hd, w) in [(2, 128, 4, 4, 16, 16), (1, 256, 4, 2, 8, 64),
                             (2, 64, 2, 2, 8, 16)]:
    q = jnp.asarray(rng.normal(size=(B, T, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, Hk, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, Hk, hd)), jnp.float32)
    with mesh:
        out = halo_window_attention(q, k, v, window=w, mesh=mesh,
                                    axis="model", batch_axes=())
    ref = blockwise_attention(q, k, v, causal=True, window=w, kv_chunk=32)
    err = float(jnp.max(jnp.abs(out - ref)))
    results[f"{B}x{T}x{H}x{Hk}x{hd}w{w}"] = err
print(json.dumps(results))
"""


def test_halo_matches_full_windowed_attention():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    for cfg, err in out.items():
        assert err < 2e-5, (cfg, err)
