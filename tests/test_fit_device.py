"""Fused-scan retrain engine oracle grids.

The engine contract (same spirit as the selection/sweep engines): the
fused ``lax.scan`` program and the per-step host loop consume the
IDENTICAL permutation sequence (``fit_device.epoch_orders``) over the
identical ``fit_plan`` schedule, so on a CPU host the trained params and
the per-step loss trace must agree BIT-EXACTLY — across ragged epoch
tails, sub-batch pools, and pow2 bucket boundaries.  The async fit path
must leave campaign economics untouched: an ``fit_async`` campaign's
iteration records match the synchronous campaign's exactly.
"""
import json

import numpy as np
import pytest

import jax

from repro import compat
from repro.configs.base import ModelConfig, TrainConfig
from repro.models.registry import get_model
from repro.training.fit_device import (FitConfig, FitEngine, epoch_orders,
                                       fit_plan)


def _make_engine(epochs=3, batch=32, dim=8, classes=5, **kw):
    cfg = ModelConfig(name="fit-test", family="mlp", num_layers=2,
                      d_model=32, num_classes=classes, input_dim=dim,
                      dtype="float32", remat="none")
    model = get_model(cfg)
    tc = TrainConfig(learning_rate=1e-2, schedule="constant",
                     weight_decay=1e-4, grad_clip=1.0)
    return model, tc, FitEngine(model, tc,
                                FitConfig(epochs=epochs, batch_size=batch),
                                **kw)


def _data(n, dim=8, classes=5, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(n, dim)).astype(np.float32),
            rng.integers(0, classes, n).astype(np.int32))


def _leaves_equal(a, b):
    la, lb = compat.tree_leaves(a), compat.tree_leaves(b)
    assert len(la) == len(lb)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# oracle grids: fused scan vs per-step host loop, exact
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,batch,epochs", [
    (64, 32, 2),     # even split
    (100, 32, 3),    # ragged epoch tail (wraps into the permutation front)
    (20, 64, 3),     # sub-batch pool (n < batch -> pow2 batch, wrap)
    (257, 64, 2),    # pow2 bucket boundary (spe jumps 4 -> 8)
    (5, 32, 2),      # tiny pool (bs floors at 8)
])
def test_fused_matches_hostloop_exact(n, batch, epochs):
    _, _, eng = _make_engine(epochs=epochs, batch=batch)
    x, y = _data(n)
    key = jax.random.key(7)
    p_fused, l_fused = eng.fit(key, x, y)
    p_ref, l_ref = eng.fit_reference(key, x, y)
    assert _leaves_equal(p_fused, p_ref), \
        "fused params diverged from the per-step host loop"
    np.testing.assert_array_equal(np.asarray(l_fused), np.asarray(l_ref))
    spe, bs, n_pad = fit_plan(n, batch)
    assert l_fused.shape == (epochs * spe,)


def test_fit_deterministic_and_seed_sensitive():
    _, _, eng = _make_engine()
    x, y = _data(80)
    p1, l1 = eng.fit(jax.random.key(3), x, y)
    p2, l2 = eng.fit(jax.random.key(3), x, y)
    assert _leaves_equal(p1, p2)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    _, l3 = eng.fit(jax.random.key(4), x, y)
    assert not np.array_equal(np.asarray(l1), np.asarray(l3))


def test_epoch_orders_prefix_is_permutation():
    """The first-n prefix of every epoch order is a permutation of
    [0, n); padding rows are stably pushed to the tail."""
    kd = jax.random.key_data(jax.random.key(0))
    for n, n_pad in ((100, 128), (128, 128), (5, 8)):
        orders = np.asarray(epoch_orders(kd, 4, n_pad, np.int32(n)))
        assert orders.shape == (4, n_pad)
        for row in orders:
            assert sorted(row[:n].tolist()) == list(range(n))
            assert sorted(row[n:].tolist()) == list(range(n, n_pad))
    # different epochs shuffle differently
    assert not np.array_equal(orders[0], orders[1])


# ---------------------------------------------------------------------------
# compile-cache bucketing
# ---------------------------------------------------------------------------


def test_growing_pool_reuses_compile_cache():
    """Successive MCAL iterations with growing |B| inside one pack_shape
    bucket share ONE compiled program; a wide size range stays O(log N)."""
    _, _, eng = _make_engine(epochs=1, batch=32)
    for n in (130, 160, 200, 256):   # all bucket to (8, 32, 256)
        x, y = _data(n)
        eng.fit(jax.random.key(0), x, y)
    assert eng.cache_keys() == [(8, 32, 256)]
    for n in (300, 600, 1200):
        x, y = _data(n)
        eng.fit(jax.random.key(0), x, y)
    assert len(eng.cache_keys()) == 4   # one new bucket per pow2 doubling


def test_warm_prebuilds_cache_from_keys():
    _, _, eng = _make_engine(epochs=1, batch=32)
    x, y = _data(100)
    eng.fit(jax.random.key(0), x, y)
    keys = eng.cache_keys()
    _, _, eng2 = _make_engine(epochs=1, batch=32)
    # JSON round-trip: checkpoints persist keys as lists
    assert eng2.warm(json.loads(json.dumps(keys))) == len(keys)
    assert eng2.cache_keys() == keys


# ---------------------------------------------------------------------------
# campaign-resident pool
# ---------------------------------------------------------------------------


def test_resident_extension_matches_oneshot_fit():
    """Scatter-extending the device-resident pool across MCAL-style
    acquisitions trains bit-identically to uploading the whole set."""
    _, _, eng = _make_engine(epochs=2, batch=32)
    x, y = _data(200)
    key = jax.random.key(5)
    p_full, l_full = eng.fit(key, x, y)
    _, _, eng2 = _make_engine(epochs=2, batch=32)
    for lo, hi in ((0, 40), (40, 90), (90, 200)):   # crosses a bucket grow
        eng2.extend_resident(x[lo:hi], y[lo:hi])
    assert eng2.resident_size == 200
    p_res, l_res = eng2.fit_resident(key)
    assert _leaves_equal(p_full, p_res)
    np.testing.assert_array_equal(np.asarray(l_full), np.asarray(l_res))


def test_resident_reset_and_empty_raises():
    _, _, eng = _make_engine()
    with pytest.raises(ValueError):
        eng.fit_resident(jax.random.key(0))
    x, y = _data(30)
    eng.extend_resident(x, y)
    assert eng.resident_size == 30
    eng.reset_resident()
    assert eng.resident_size == 0


# ---------------------------------------------------------------------------
# mesh wiring
# ---------------------------------------------------------------------------


def test_mesh_fit_matches_unmeshed():
    """The mesh program (state shardings via state_pspecs, the
    mesh-aware raw step) lowers and agrees with the unmeshed engine on a
    host mesh."""
    from repro.compat import make_mesh
    mesh = make_mesh((jax.device_count(), 1), ("data", "model"))
    model, tc, eng = _make_engine(epochs=2, batch=32)
    eng_mesh = FitEngine(model, tc, FitConfig(epochs=2, batch_size=32),
                         mesh=mesh)
    x, y = _data(100)
    key = jax.random.key(2)
    p_plain, l_plain = eng.fit(key, x, y)
    p_mesh, l_mesh = eng_mesh.fit(key, x, y)
    np.testing.assert_allclose(np.asarray(l_mesh), np.asarray(l_plain),
                               rtol=1e-6, atol=1e-6)
    for a, b in zip(compat.tree_leaves(p_plain),
                    compat.tree_leaves(p_mesh)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# async handle
# ---------------------------------------------------------------------------


def test_submit_fit_matches_sync():
    _, _, eng = _make_engine()
    x, y = _data(90)
    key = jax.random.key(9)
    p_sync, l_sync = eng.fit(key, x, y)
    fut = eng.submit_fit(key, x, y)
    p_async, l_async = fut.result()
    assert fut.done()
    assert _leaves_equal(p_sync, p_async)
    np.testing.assert_array_equal(np.asarray(l_sync), np.asarray(l_async))


# ---------------------------------------------------------------------------
# LiveTask + campaign integration
# ---------------------------------------------------------------------------


def _live_task(x, y, **kw):
    from repro.core import LiveTask
    return LiveTask(features=x, groundtruth=y, num_classes=10, epochs=3,
                    seed=4, sweep_page=256, score_microbatch=256, **kw)


@pytest.fixture(scope="module")
def small_pool():
    from repro.data.synth import make_classification
    return make_classification(700, num_classes=10, dim=16,
                               difficulty=0.3, seed=4)


def test_live_task_fused_matches_hostloop_oracle(small_pool):
    """LiveTask.train through the fused engine == the per-step host-loop
    oracle path, bit-exactly (same task seed -> same permutations)."""
    x, y = small_pool
    fused, oracle = _live_task(x, y), _live_task(x, y, fit_fused=False)
    idx = np.arange(200)
    c_f = fused.train(idx, y[:200])
    c_o = oracle.train(idx, y[:200])
    assert c_f == c_o   # nominal cost: c_u * n on both paths
    assert _leaves_equal(fused._params, oracle._params)


def test_live_task_resident_matches_upload(small_pool):
    x, y = small_pool
    a, b = _live_task(x, y), _live_task(x, y, fit_resident=True)
    idx1 = np.arange(150)
    idx2 = np.arange(260)           # append-only growth
    for t in (a, b):
        t.train(idx1, y[idx1])
        t.train(idx2, y[idx2])
    assert _leaves_equal(a._params, b._params)
    # non-append update forces a resident rebuild, still exact
    idx3 = np.concatenate([np.arange(100), np.arange(300, 400)])
    a.train(idx3, y[idx3])
    b.train(idx3, y[idx3])
    assert _leaves_equal(a._params, b._params)


def _campaign(x, y, *, fit_async, max_iters=3, **task_kw):
    from repro.core import AMAZON, MCALCampaign, MCALConfig
    task = _live_task(x, y, **task_kw)
    camp = MCALCampaign(task, AMAZON,
                        MCALConfig(seed=4, max_iters=max_iters,
                                   delta0_frac=0.02, fit_async=fit_async))
    camp.bootstrap()
    while not camp.done:
        camp.iteration()
    return camp


def test_async_fit_campaign_matches_sync(small_pool):
    """fit_async defers each retrain + measurement onto the engine
    worker; the folded records must be identical to the synchronous
    campaign — acquisitions, eps history, ledger, commit labels."""
    x, y = small_pool
    sync = _campaign(x, y, fit_async=False)
    async_ = _campaign(x, y, fit_async=True)
    np.testing.assert_array_equal(sync.pool.B_idx, async_.pool.B_idx)
    assert sync.eps_hist == async_.eps_hist
    assert sync.train_sizes == async_.train_sizes
    assert sync.train_costs == async_.train_costs
    assert [r.cstar for r in sync.history] == \
        [r.cstar for r in async_.history]
    assert [r.training_spent for r in sync.history] == \
        [r.training_spent for r in async_.history]
    a, b = sync.commit(), async_.commit()
    assert a.total_cost == pytest.approx(b.total_cost, rel=1e-12)
    np.testing.assert_array_equal(a.labels, b.labels)
    np.testing.assert_array_equal(a.machine_mask, b.machine_mask)


def test_async_fit_state_dict_folds_pending(small_pool):
    """state_dict during an in-flight async retrain folds it first — the
    checkpoint is indistinguishable from a synchronous campaign's."""
    x, y = small_pool
    from repro.core import AMAZON, MCALCampaign, MCALConfig

    def boot(fit_async):
        camp = MCALCampaign(_live_task(x, y), AMAZON,
                            MCALConfig(seed=4, delta0_frac=0.02,
                                       fit_async=fit_async))
        camp.bootstrap()   # leaves a pending fit in async mode
        return camp

    sd_async = boot(True).state_dict()
    sd_sync = boot(False).state_dict()
    assert sd_async["train_sizes"] == sd_sync["train_sizes"]
    assert sd_async["eps_hist"] == sd_sync["eps_hist"]
    assert sd_async["ledger"] == sd_sync["ledger"]


def test_async_fit_arch_selection_matches_sync(small_pool):
    """Architecture selection with fit_async retrains every candidate
    concurrently; shared-ledger payments land at submit time, so the
    winner, every candidate's history, and the shared ledger must be
    identical to the synchronous run."""
    from repro.core import AMAZON, MCALConfig, select_architecture

    x, y = small_pool

    def run(fit_async):
        tasks = {
            "small": _live_task(x, y, hidden=32),
            "big": _live_task(x, y, hidden=64),
        }
        cfg = MCALConfig(seed=4, max_iters=4, delta0_frac=0.02,
                         fit_async=fit_async)
        return select_architecture(tasks, AMAZON, cfg,
                                   max_explore_iters=3)

    (w_s, res_s, hist_s) = run(False)
    (w_a, res_a, hist_a) = run(True)
    assert w_s == w_a
    for name in hist_s:
        assert [r.cstar for r in hist_s[name]] == \
            [r.cstar for r in hist_a[name]]
        assert [r.training_spent for r in hist_s[name]] == \
            [r.training_spent for r in hist_a[name]]
        assert [r.human_spent for r in hist_s[name]] == \
            [r.human_spent for r in hist_a[name]]
    assert res_s.total_cost == pytest.approx(res_a.total_cost, rel=1e-12)
    np.testing.assert_array_equal(res_s.labels, res_a.labels)


def test_warm_executables_serve_dispatch_exactly():
    """warm() keeps the AOT executables and fit() dispatches them (jit's
    own cache is NOT populated by lower().compile()): a warmed engine
    must produce bit-identical results through the compiled path."""
    _, _, eng = _make_engine(epochs=2, batch=32)
    x, y = _data(120)
    key = jax.random.key(11)
    p_ref, l_ref = eng.fit(key, x, y)
    keys = eng.cache_keys()

    _, _, warmed = _make_engine(epochs=2, batch=32)
    assert warmed.warm(keys) == len(keys)
    assert set(warmed._compiled) == set(keys)   # executables retained
    p_w, l_w = warmed.fit(key, x, y)            # served by the AOT path
    assert _leaves_equal(p_ref, p_w)
    np.testing.assert_array_equal(np.asarray(l_ref), np.asarray(l_w))
