"""Fault-injection harness + resilient runtime (tier-1).

The contract under test: chaos is REPLAYABLE (whether invocation ``c``
of site ``s`` faults is a pure function of the plan seed), recovery is
TRANSPARENT (a chaos run whose retries succeed produces a decision
stream, ledger, and labels bit-identical to its fault-free sibling),
and terminal faults are CONTAINED (a retry-exhausted tenant is
quarantined; its fleet siblings commit unperturbed).

Layers:

* **plan/injector/retry units** — pure schedule decisions, counter
  advancement, deterministic backoff jitter;
* **annotation resilience** — charge-exactly-once retries through the
  request path, including at the budget edge;
* **worker resilience** — crashed broker jobs re-dispatch in place;
  hung jobs surface as :class:`StragglerTimeout`, not a hang;
* **crash-safe autosave** — an injected kill leaves a sidecar the next
  invocation resumes bit-identically;
* **chaos acceptance** — an async noisy adaptive-DS campaign under a
  seeded plan completes and diffs clean against a fault-free sibling;
* **fleet quarantine acceptance** — N=4, one tenant's annotation
  backend dies: it quarantines, the other three commit diff-clean
  against a fleet that never contained the victim.
"""
import os
import threading
import types

import numpy as np
import pytest

from repro.annotation import make_annotation_service
from repro.annotation.service import BudgetExceeded
from repro.core import AMAZON, MCALCampaign, MCALConfig, make_emulated_task
from repro.core.worker import SerialWorker
from repro.faults import (AnnotationTimeout, FaultInjector, FaultPlan,
                          FaultRule, InjectedKill, InjectedWorkerCrash,
                          RetryExhausted, RetryPolicy, StragglerTimeout,
                          TransientAnnotationError, hash01)
from repro.faults.errors import FaultError, TransientError
from repro.trace import TraceStore, diff, read_trace

# ---------------------------------------------------------------------------
# plan: pure, seeded, counter-keyed
# ---------------------------------------------------------------------------


def test_hash01_is_pure_and_uniformish():
    a = hash01(7, "annotation.request", 3)
    assert a == hash01(7, "annotation.request", 3)
    assert 0.0 <= a < 1.0
    draws = {hash01(7, "annotation.request", c) for c in range(64)}
    assert len(draws) == 64                     # counters decorrelate
    assert hash01(7, "worker.fit-engine", 3) != a     # sites decorrelate
    assert hash01(8, "annotation.request", 3) != a    # seeds decorrelate


def test_fault_rule_rejects_unknown_kind():
    with pytest.raises(ValueError):
        FaultRule("annotation.request", "meteor")


def test_plan_decide_is_pure_and_at_wins_over_rate():
    rules = (FaultRule("s", "transient", rate=0.5),
             FaultRule("s", "crash", at=(3,)))
    p1, p2 = FaultPlan(seed=11, rules=rules), FaultPlan(seed=11,
                                                        rules=list(rules))
    decisions = [p1.decide("s", c) for c in range(128)]
    assert decisions == [p2.decide("s", c) for c in range(128)]
    assert decisions[3].kind == "crash"         # explicit schedule wins
    fired = sum(1 for d in decisions if d is not None and d.kind
                == "transient")
    assert 32 <= fired <= 96                    # ~rate, deterministic
    assert p1.decide("other-site", 3) is None


def test_plan_after_and_cumulative_rate_partition():
    p = FaultPlan(seed=2, rules=(
        FaultRule("s", "transient", rate=0.3, after=10),
        FaultRule("s", "timeout", rate=0.3, after=10)))
    assert all(p.decide("s", c) is None for c in range(10))
    kinds = {d.kind for c in range(10, 200)
             if (d := p.decide("s", c)) is not None}
    # ONE shared uniform draw partitioned by cumulative rate: both rules
    # fire, and a given counter fires at most one of them
    assert kinds == {"transient", "timeout"}


def test_injector_counters_advance_and_fault_maps_to_exception():
    inj = FaultInjector(FaultPlan(seed=0, rules=(
        FaultRule("s", "transient", at=(1,)),
        FaultRule("k", "kill", at=(0,)),
        FaultRule("c", "crash", at=(0,)),
        FaultRule("o", "oserror", at=(0,)))))
    assert inj.check("s") is None               # counter 0: clean
    with pytest.raises(TransientAnnotationError):
        inj.check("s")                          # counter 1: fires
    assert inj.check("s") is None
    assert inj.counters()["s"] == 3 and inj.fired == 1
    with pytest.raises(InjectedWorkerCrash):
        inj.check("c")
    assert issubclass(InjectedWorkerCrash, TransientError)   # retryable
    with pytest.raises(OSError):
        inj.check("o")
    # kills unwind PAST `except Exception` recovery (emulated preemption)
    with pytest.raises(InjectedKill):
        inj.check("k")
    assert not issubclass(InjectedKill, Exception)


def test_injector_latency_respects_deadline_and_emits(tmp_path):
    inj = FaultInjector(FaultPlan(seed=0, time_scale=0.0, rules=(
        FaultRule("s", "latency", at=(0, 1), duration=5.0),)))
    p = str(tmp_path / "t.jsonl")
    with TraceStore(p, "camp") as tr:
        inj.attach_trace(tr)
        f = inj.check("s")                      # no deadline: just waits
        assert f is not None and f.rule.kind == "latency"
        with pytest.raises(AnnotationTimeout):
            inj.check("s", timeout=0.1)         # 5s spike > 0.1s deadline
    ev = [e for e in read_trace(p) if e.kind == "fault_injected"]
    assert [e.payload["counter"] for e in ev] == [0, 1]
    assert all(e.payload["site"] == "s" and e.payload["fault"] == "latency"
               for e in ev)


# ---------------------------------------------------------------------------
# retry policy: bounded, deterministic, transient-only
# ---------------------------------------------------------------------------


def test_retry_succeeds_after_transients_and_notifies():
    pol = RetryPolicy(max_attempts=4, seed=5, sleep_scale=0.0)
    calls, seen = [], []
    def fn():
        calls.append(1)
        if len(calls) < 3:
            raise TransientAnnotationError("flaky")
        return "ok"
    assert pol.call(fn, site="s", notify=lambda a, e, d:
                    seen.append((a, d))) == "ok"
    assert len(calls) == 3 and [a for a, _ in seen] == [0, 1]
    # deterministic jitter: an identical policy reports identical delays
    assert [d for _, d in seen] == [RetryPolicy(max_attempts=4, seed=5)
                                    .backoff("s", 0, a) for a in (0, 1)]
    assert seen[1][1] > 0.0


def test_retry_exhaustion_chains_last_transient():
    pol = RetryPolicy(max_attempts=3, sleep_scale=0.0)
    n = []
    def fn():
        n.append(1)
        raise TransientAnnotationError("still down")
    with pytest.raises(RetryExhausted) as ei:
        pol.call(fn, site="s")
    assert len(n) == 3
    assert isinstance(ei.value.__cause__, TransientAnnotationError)
    assert isinstance(ei.value, FaultError)     # terminal -> quarantine


def test_retry_passes_non_transient_through_untouched():
    pol = RetryPolicy(max_attempts=4, sleep_scale=0.0)
    n = []
    def fn():
        n.append(1)
        raise ValueError("a bug, not weather")
    with pytest.raises(ValueError):
        pol.call(fn, site="s")
    assert len(n) == 1


def test_backoff_is_bounded_and_jitter_free_when_disabled():
    pol = RetryPolicy(base_delay=0.1, multiplier=2.0, max_delay=0.3,
                      jitter=0.0)
    assert [pol.backoff("s", 0, a) for a in range(4)] == \
        pytest.approx([0.1, 0.2, 0.3, 0.3])


# ---------------------------------------------------------------------------
# annotation resilience: retries charge exactly once
# ---------------------------------------------------------------------------

_GT = np.random.default_rng(17).integers(0, 3, 64).astype(np.int64)


def _svc(**kw):
    base = dict(n_workers=5, noise=0.2, repeats=3, seed=0)
    base.update(kw)
    return make_annotation_service(3, **base)


def test_annotation_retry_is_transparent_and_charges_once(tmp_path):
    reqs = [np.arange(8), np.arange(8, 20), np.arange(20, 25)]
    clean = _svc()
    want = [clean.annotate(i, _GT[i]) for i in reqs]

    chaotic = _svc()
    with TraceStore(str(tmp_path / "t.jsonl"), "camp") as tr:
        chaotic.attach_trace(tr)
        # attempt-counters 0 and 2 fail: every batch recovers on its
        # next attempt (no two consecutive counters fire)
        chaotic.attach_faults(
            FaultInjector(FaultPlan(rules=(
                FaultRule("annotation.request", "transient", at=(0, 2)),))),
            RetryPolicy(sleep_scale=0.0))
        got = [chaotic.annotate(i, _GT[i]) for i in reqs]
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)
    # the retried batches replayed the identical worker schedule and
    # were charged exactly once: both ledgers match bit-for-bit
    assert chaotic.ledger.snapshot() == clean.ledger.snapshot()
    assert chaotic.request_cursor == clean.request_cursor
    retries = [e for e in read_trace(str(tmp_path / "t.jsonl"))
               if e.kind == "retry"]
    assert len(retries) == 2
    assert all(e.payload["site"] == "annotation.request"
               and e.payload["error"] == "TransientAnnotationError"
               and e.payload["delay"] > 0.0 for e in retries)


def test_annotation_retry_at_budget_edge_charges_nothing_extra():
    # budget fits the first batch exactly (8 labels x 3 votes x $0.04)
    svc = _svc(budget=8 * 3 * 0.04)
    svc.attach_faults(
        FaultInjector(FaultPlan(rules=(
            FaultRule("annotation.request", "transient", at=(0,)),))),
        RetryPolicy(sleep_scale=0.0))
    labels = svc.annotate(np.arange(8), _GT[:8])     # retried, then fits
    assert labels.shape == (8,)
    spent = svc.ledger.human
    assert spent == pytest.approx(8 * 3 * 0.04)
    # the next batch is refused BEFORE any charge — BudgetExceeded is
    # not a transient, so the retry layer does not spin on it
    with pytest.raises(BudgetExceeded):
        svc.annotate(np.arange(8, 16), _GT[8:16])
    assert svc.ledger.human == pytest.approx(spent)
    assert svc.request_cursor == 1              # refused batch: no cursor


def test_session_fault_override_leaves_siblings_clean():
    svc = _svc()
    a, b = svc.session("a"), svc.session("b")
    solo = _svc()
    want_b = solo.session("b-solo").annotate(np.arange(6), _GT[:6])
    a.attach_faults(
        FaultInjector(FaultPlan(rules=(
            FaultRule("annotation.request", "transient", rate=1.0),))),
        RetryPolicy(max_attempts=2, sleep_scale=0.0))
    with pytest.raises(RetryExhausted):
        a.annotate(np.arange(6), _GT[:6])
    got_b = b.annotate(np.arange(6), _GT[:6])   # sibling: untouched
    np.testing.assert_array_equal(got_b, want_b)
    assert a.votes_bought == 0 and a.request_cursor == 0
    assert b.votes_bought == 18
    svc.close()


# ---------------------------------------------------------------------------
# worker resilience: crashed jobs re-dispatch, hung jobs time out
# ---------------------------------------------------------------------------


def test_worker_crash_redispatches_in_place():
    w = SerialWorker("pool-sweep")
    w.attach_faults(
        FaultInjector(FaultPlan(rules=(
            FaultRule("worker.pool-sweep", "crash", at=(0,)),))),
        RetryPolicy(sleep_scale=0.0))
    assert w.submit(lambda: 7).result(timeout=5) == 7
    assert w.redispatches == 1
    assert w.submit(lambda: 8).result(timeout=5) == 8   # keeps draining
    assert w.close(timeout=5) is True


def test_worker_crash_without_retry_surfaces_at_result():
    w = SerialWorker("fit-engine")
    w.attach_faults(FaultInjector(FaultPlan(rules=(
        FaultRule("worker.fit-engine", "crash", at=(0,)),))))
    with pytest.raises(InjectedWorkerCrash):
        w.submit(lambda: 7).result(timeout=5)
    assert w.submit(lambda: 9).result(timeout=5) == 9
    assert w.redispatches == 0
    assert w.close(timeout=5) is True


def test_sweep_future_deadline_raises_straggler_timeout():
    from repro.serving.sweep import SweepFuture
    gate = threading.Event()
    w = SerialWorker("t")
    fut = SweepFuture(w.submit(gate.wait), label="sweep[margin]")
    with pytest.raises(StragglerTimeout) as ei:
        fut.result(timeout=0.05)
    assert "sweep[margin]" in str(ei.value)
    assert isinstance(ei.value, FaultError)     # terminal -> quarantine
    gate.set()
    assert w.close(timeout=5) is True
    assert fut.result(timeout=5) is True        # the job itself finished


# ---------------------------------------------------------------------------
# crash-safe autosave: an injected kill resumes bit-identically
# ---------------------------------------------------------------------------


def _emulated_run(trace_path, *, autosave_path="", faults=None):
    from repro.launch.label import run_campaign
    task = make_emulated_task("cifar10", "resnet18", seed=0,
                              pool_size=4000, sweep_page=512)
    return run_campaign(task, AMAZON, MCALConfig(seed=0),
                        trace_path=str(trace_path), campaign_id="camp",
                        autosave_path=str(autosave_path), faults=faults)


def test_injected_kill_autosaves_and_resumes_bit_identically(tmp_path):
    save = tmp_path / "autosave.json"
    t_chaos = tmp_path / "chaos.jsonl"
    killer = FaultInjector(FaultPlan(rules=(
        FaultRule("campaign.iteration", "kill", at=(1,)),)))
    with pytest.raises(InjectedKill):
        _emulated_run(t_chaos, autosave_path=save, faults=killer)
    assert os.path.exists(save)                 # the sidecar landed

    # the next invocation (fresh process: fresh task, NO plan — counters
    # restart, so the resumed leg must not re-fire the kill) resumes
    # from the sidecar and completes
    res, camp = _emulated_run(t_chaos, autosave_path=save)
    assert res is not None and not os.path.exists(save)   # spent

    t_clean = tmp_path / "clean.jsonl"
    want, _ = _emulated_run(t_clean)
    assert res.decision == want.decision
    assert res.ledger == want.ledger            # bit-identical money
    assert res.total_cost == want.total_cost
    # the interrupted-and-resumed decision stream IS the uninterrupted
    # one (autosave/resume markers are observability kinds)
    assert diff(str(t_chaos), str(t_clean)) is None
    kinds = {e.kind for e in read_trace(str(t_chaos))}
    assert "autosave" in kinds and "resume" in kinds


# ---------------------------------------------------------------------------
# concurrent-round error aggregation (no campaigns: surgical units)
# ---------------------------------------------------------------------------


def _fake_tenant(tid):
    return types.SimpleNamespace(tenant_id=tid, quarantined=False)


def test_run_round_aggregates_concurrent_tenant_errors():
    from repro.launch.orchestrator import CampaignOrchestrator
    orch = CampaignOrchestrator([], controller=None, concurrent=True)
    def boom(exc):
        def run():
            raise exc
        return run
    e1, e2 = ValueError("t0 died"), KeyError("t2 died")
    jobs = [(_fake_tenant("t0"), boom(e1)),
            (_fake_tenant("t1"), lambda: None),
            (_fake_tenant("t2"), boom(e2))]
    with pytest.raises(ValueError) as ei:
        orch._run_round(jobs)
    # the primary is the first failure in FLEET order (deterministic,
    # not completion order) and carries every sibling failure
    assert ei.value is e1
    assert ei.value.sibling_errors == (e2,)
    if hasattr(e1, "__notes__"):                # 3.11+
        assert any("t2" in n and "KeyError" in n for n in e1.__notes__)


def test_run_round_quarantines_fault_errors_instead_of_raising():
    from repro.launch.orchestrator import CampaignOrchestrator
    seen = []
    ctl = types.SimpleNamespace(
        quarantine=lambda t, e, phase="iteration":
            (seen.append((t.tenant_id, type(e).__name__, phase)) or True))
    orch = CampaignOrchestrator([], controller=ctl, concurrent=True)
    def die():
        raise RetryExhausted("annotation backend gone")
    orch._run_round([(_fake_tenant("t0"), lambda: None),
                     (_fake_tenant("t1"), die)])
    assert seen == [("t1", "RetryExhausted", "iteration")]


def test_label_cli_exposes_resilience_flags():
    from repro.launch.label import build_parser
    args = build_parser().parse_args(
        ["--sweep-timeout", "1.5", "--fit-timeout", "30",
         "--autosave", "side.json", "--chaos", "--chaos-seed", "9"])
    assert args.sweep_timeout == pytest.approx(1.5)
    assert args.fit_timeout == pytest.approx(30.0)
    assert args.autosave == "side.json" and args.chaos
    assert args.chaos_seed == 9
    bare = build_parser().parse_args([])
    assert bare.sweep_timeout is None and bare.fit_timeout is None
    assert not bare.chaos and bare.chaos_seed is None


# ---------------------------------------------------------------------------
# chaos acceptance: async campaign under a seeded plan == fault-free
# ---------------------------------------------------------------------------


def _live_task(annotation=None):
    from repro.core.task import LiveTask
    from repro.data.synth import make_classification
    x, y = make_classification(96, num_classes=3, difficulty=0.3, seed=0)
    return LiveTask(features=x, groundtruth=y, num_classes=3, epochs=2,
                    score_microbatch=32, sweep_page=32, seed=0,
                    annotation=annotation)


def _chaos_campaign(trace_path, faults=None, retry=None):
    svc = make_annotation_service(3, n_workers=5, noise=0.25, repeats=3,
                                  max_repeats=5, adaptive=True,
                                  aggregator="ds", seed=0)
    task = _live_task(annotation=svc)
    cfg = MCALConfig(max_iters=2, delta0_frac=0.1, test_frac=0.2,
                     sweep_async=True, fit_async=True,
                     label_quality=svc.expected_quality())
    camp = MCALCampaign(task, AMAZON, cfg)
    trace = TraceStore(str(trace_path), "camp")
    camp.attach_trace(trace)
    if faults is not None:
        camp.attach_faults(faults, retry)
    try:
        res = camp.run()
    finally:
        camp.close()
        trace.close()
    return res


def test_chaos_campaign_diffs_clean_against_fault_free(tmp_path):
    """THE acceptance property: transient annotation failures, one
    broker-job crash per engine family, and one torn trace write — the
    campaign completes, and nothing about its decisions, labels, or
    money is distinguishable from the run where none of it happened."""
    inj = FaultInjector(FaultPlan(seed=7, time_scale=0.0, rules=(
        # attempt-counters 0/3/7 fail; no two consecutive, so every
        # batch recovers within one retry
        FaultRule("annotation.request", "transient", at=(0, 3, 7)),
        FaultRule("worker.pool-sweep", "crash", at=(0,)),
        FaultRule("worker.fit-engine", "crash", at=(0,)),
        FaultRule("trace.flush", "oserror", at=(0,)),)))
    t_chaos, t_clean = tmp_path / "chaos.jsonl", tmp_path / "clean.jsonl"
    res = _chaos_campaign(t_chaos, inj,
                          RetryPolicy(seed=7, sleep_scale=0.0))
    want = _chaos_campaign(t_clean)
    assert inj.fired >= 4                       # every family actually hit
    assert {"annotation.request", "worker.pool-sweep", "worker.fit-engine",
            "trace.flush"} <= set(inj.counters())
    assert res.decision == want.decision
    assert res.ledger == want.ledger
    assert res.total_cost == want.total_cost
    assert res.measured_error == want.measured_error
    assert diff(str(t_chaos), str(t_clean)) is None
    ev = read_trace(str(t_chaos))
    assert any(e.kind == "fault_injected" for e in ev)
    assert any(e.kind == "retry" for e in ev)


# ---------------------------------------------------------------------------
# fleet quarantine acceptance: N=4, one tenant's backend dies
# ---------------------------------------------------------------------------


def test_fleet_quarantines_dead_tenant_and_commits_survivors(tmp_path):
    from repro.core.tenant import TenantSpec
    from repro.launch.orchestrator import build_fleet
    from repro.data.synth import make_classification
    x, y = make_classification(320, num_classes=3, difficulty=0.3, seed=0)
    engine_kw = dict(epochs=2, score_microbatch=128, sweep_page=128)

    def specs(ids):
        ann = make_annotation_service(3, n_workers=5, noise=0.2,
                                      repeats=3, seed=0)
        q = ann.expected_quality()
        return ann, [TenantSpec(t, priority=i, seed=int(t[1:]),
                                cfg=MCALConfig(max_iters=2,
                                               delta0_frac=0.1,
                                               test_frac=0.2,
                                               seed=int(t[1:]),
                                               label_quality=q))
                     for i, t in enumerate(ids)]

    d1 = str(tmp_path / "fleet")
    ann, sp = specs(["t0", "t1", "t2", "t3"])
    orch = build_fleet(x, y, sp, service=AMAZON, trace_dir=d1,
                       concurrent=True, annotation_service=ann,
                       engine_kw=engine_kw)
    victim = orch.tenants[1]
    # kill ONLY t1's annotation backend after its first batch: the
    # session-level override leaves its siblings' request paths clean
    victim.campaign.task.annotation.attach_faults(
        FaultInjector(FaultPlan(rules=(
            FaultRule("annotation.request", "transient", rate=1.0,
                      after=1),))),
        RetryPolicy(max_attempts=2, sleep_scale=0.0))
    try:
        results = orch.run()
    finally:
        orch.close()
    assert set(results) == {"t0", "t2", "t3"}   # the victim never commits
    assert victim.quarantined and victim.done
    assert "RetryExhausted" in victim.quarantine_error

    done = [e for e in read_trace(os.path.join(d1, "t1.jsonl"))
            if e.kind == "done"]
    assert done and done[-1].payload["reason"] == "quarantined"
    qev = [e for e in read_trace(os.path.join(d1, "fleet.jsonl"))
           if e.kind == "quarantine"]
    assert qev and qev[-1].payload["tenant"] == "t1"

    # the survivors never noticed: bit-identical to a fleet that never
    # contained the victim at all
    d2 = str(tmp_path / "solo")
    ann2, sp2 = specs(["t0", "t2", "t3"])
    orch2 = build_fleet(x, y, sp2, service=AMAZON, trace_dir=d2,
                        concurrent=False, annotation_service=ann2,
                        engine_kw=engine_kw)
    try:
        want = orch2.run()
    finally:
        orch2.close()
    for tid in ("t0", "t2", "t3"):
        d = diff(os.path.join(d1, f"{tid}.jsonl"),
                 os.path.join(d2, f"{tid}.jsonl"))
        assert d is None, f"{tid} perturbed by the quarantine: {d}"
        assert results[tid].decision == want[tid].decision
        assert results[tid].total_cost == pytest.approx(
            want[tid].total_cost)
