"""Campaign health engine, SLO enforcement, and regression observatory.

Three layers under test:

* the pure judgment machinery — SLO specs/verdicts, hysteresis cells
  (flap -> one alert + one clear), detector math — via the
  ``tick_samples`` seam, no jax anywhere;
* live integration — a solo campaign and fleets with the engine
  attached: alert events ride the trace without contaminating the
  decision stream (diff clean vs the monitor-off sibling), SLO
  enforcement drives the downgrade cascade deterministically
  (byte-equal alert sequences across identical runs);
* the tooling — ``report --health``, the zero-span burn-rate guard,
  and ``benchmarks/regress.py`` over synthetic and real history.
"""
import json
import os

import pytest

from repro.obs import (ALERT_KINDS, HealthConfig, HealthEngine, SLOSpec,
                       alert_sequence, evaluate_slo, hist_quantile)


# ---------------------------------------------------------------- SLO spec

def test_slo_spec_rejects_unknown_clause():
    with pytest.raises(ValueError, match="unknown SLO clause"):
        SLOSpec.from_dict({"cost_per_label_max": 0.1, "latencyy": 1.0})


def test_slo_spec_rejects_nonpositive():
    with pytest.raises(ValueError, match="must be positive"):
        SLOSpec.from_dict({"cost_per_label_max": 0.0})


def test_slo_spec_load_and_clauses(tmp_path):
    p = tmp_path / "slo.json"
    p.write_text(json.dumps({"cost_per_label_max": 0.15,
                             "projected_quality_min": 0.8}))
    spec = SLOSpec.load(str(p))
    assert spec.cost_per_label_max == 0.15
    assert spec.iteration_p95_max is None
    # evaluation order is fixed regardless of JSON key order
    assert spec.clauses() == ["cost_per_label", "projected_quality"]


def test_evaluate_slo_verdicts():
    spec = SLOSpec(cost_per_label_max=0.1, iteration_p95_max=2.0,
                   projected_quality_min=0.9)
    obs = {"tenant": "t0", "cost_per_label": 0.5, "iteration_p95": 3.0,
           "projected_quality": 0.5}
    v = evaluate_slo(spec, obs)
    assert [x["slo"] for x in v] == ["cost_per_label", "iteration_p95",
                                    "projected_quality"]
    by = {x["slo"]: x for x in v}
    assert by["cost_per_label"]["enforceable"] is True
    assert by["projected_quality"]["enforceable"] is True
    # wall-clock latency alerts but never drives the cascade
    assert by["iteration_p95"]["enforceable"] is False


def test_evaluate_slo_skips_unmeasured():
    spec = SLOSpec(cost_per_label_max=0.1, iteration_p95_max=2.0,
                   projected_quality_min=0.9)
    # nothing measurable yet (no labels, metrics off, no fits) -> no
    # breaches, not "everything breached"
    assert evaluate_slo(spec, {"tenant": "", "cost_per_label": None,
                               "iteration_p95": None,
                               "projected_quality": None}) == []
    assert evaluate_slo(None, {"tenant": ""}) == []


def test_hist_quantile():
    h = {"buckets": [0.1, 1.0, 10.0], "counts": [5, 4, 1],
         "count": 10, "sum": 4.0, "min": 0.01, "max": 7.5}
    assert hist_quantile(h, 0.5) == 0.1
    assert hist_quantile(h, 0.95) == 10.0
    assert hist_quantile({"buckets": [], "counts": [], "count": 0},
                         0.5) is None


# ------------------------------------------------- hysteresis cells (pure)

class _Sink:
    """Minimal trace duck-type: record emitted events."""

    def __init__(self):
        self.events = []

    def emit(self, kind, **payload):
        self.events.append((kind, payload))


def _drift_sample(observed, tenant=""):
    return {"tenant": tenant, "spent": 0.0, "budget": None, "done": False,
            "assumed_residual": 0.1, "observed_residual": observed}


def test_flapping_metric_one_alert_one_clear():
    """The headline dedup/hysteresis contract: a metric flapping across
    its threshold every tick produces ONE alert; only sustained health
    clears it (one alert_clear)."""
    tr = _Sink()
    eng = HealthEngine(config=HealthConfig(drift_tol=0.05), trace=tr)
    for observed in (0.2, 0.1, 0.2, 0.1, 0.2, 0.1):   # flap 3x
        eng.tick_samples([_drift_sample(observed)])
    for _ in range(2):                                # sustained health
        eng.tick_samples([_drift_sample(0.1)])
    kinds = [k for k, _ in tr.events]
    assert kinds == ["alert", "alert_clear"]
    assert eng.counts()["alerts_raised"] == 1
    assert eng.counts()["alerts_cleared"] == 1
    assert eng.active() == []


def test_sustained_breach_emits_once():
    tr = _Sink()
    eng = HealthEngine(config=HealthConfig(), trace=tr)
    for _ in range(5):
        eng.tick_samples([_drift_sample(0.3)])
    assert [k for k, _ in tr.events] == ["alert"]
    assert eng.active() == [("", "annotator_drift")]


def test_up_ticks_delays_raise():
    tr = _Sink()
    eng = HealthEngine(config=HealthConfig(up_ticks=2), trace=tr)
    eng.tick_samples([_drift_sample(0.3)])
    assert tr.events == []               # one breach is not yet an alert
    eng.tick_samples([_drift_sample(0.3)])
    assert [k for k, _ in tr.events] == ["alert"]


def test_burn_eta_math_and_payload():
    tr = _Sink()
    eng = HealthEngine(config=HealthConfig(burn_horizon=3.0), trace=tr)

    def tick(spent):
        eng.tick_samples([{"tenant": "t", "spent": spent, "budget": 10.0,
                           "done": False, "assumed_residual": 0.0}])

    tick(2.0)    # burn 2, remaining 8, eta 4 -> healthy
    assert tr.events == []
    tick(5.0)    # burn 3, remaining 5, eta 1.67 -> fires (warn)
    assert len(tr.events) == 1
    kind, p = tr.events[0]
    assert kind == "alert" and p["detector"] == "budget_burn"
    assert p["severity"] == "warn"
    assert p["eta_rounds"] == pytest.approx(5.0 / 3.0)
    tick(9.0)    # still firing: deduplicated, no second event
    assert len(tr.events) == 1


def test_burn_skips_uncapped_and_done():
    tr = _Sink()
    eng = HealthEngine(trace=tr)
    eng.tick_samples([{"tenant": "t", "spent": 99.0, "budget": None,
                       "done": False, "assumed_residual": 0.0}])
    eng.tick_samples([{"tenant": "t", "spent": 99.0, "budget": 1.0,
                       "done": True, "assumed_residual": 0.0}])
    assert tr.events == []


def test_telemetry_detectors_first_sample_is_baseline():
    """cache_storm / fault_pressure / queue_saturation judge counter
    DELTAS — the first sample only establishes the baseline (startup
    compiles are not a storm), queues are judged immediately."""
    tr = _Sink()
    eng = HealthEngine(config=HealthConfig(cache_miss_burst=8.0,
                                           queue_depth_max=64.0), trace=tr)
    eng.tick_samples([{"tenant": "", "counters":
                       {"pack_cache_misses_total": 50.0,
                        "pack_cache_hits_total": 0.0}, "queues": {}}])
    assert tr.events == []               # baseline, not a 50-miss storm
    eng.tick_samples([{"tenant": "", "counters":
                       {"pack_cache_misses_total": 62.0,
                        "pack_cache_hits_total": 3.0},
                       "queues": {"sweep": {"depth": 100.0}}}])
    by = {p["detector"]: (k, p) for k, p in tr.events}
    assert by["cache_storm"][0] == "alert"
    assert by["cache_storm"][1]["misses"] == 12.0
    assert by["queue_saturation"][1]["depth"] == 100.0
    # one straggler/quarantine is instant critical fault pressure
    eng.tick_samples([{"tenant": "", "counters":
                       {"pack_cache_misses_total": 62.0,
                        "pack_cache_hits_total": 3.0,
                        "straggler_timeouts_total": 1.0}, "queues": {}}])
    fp = [p for k, p in tr.events if p.get("detector") == "fault_pressure"]
    assert fp and fp[0]["severity"] == "critical"


def test_slo_breach_stream_raises_and_clears():
    tr = _Sink()
    eng = HealthEngine(SLOSpec(cost_per_label_max=0.1), trace=tr)

    def tick(cpl):
        eng.tick_samples([{"tenant": "a", "spent": 1.0, "budget": None,
                           "done": False, "assumed_residual": 0.0,
                           "cost_per_label": cpl}])

    tick(0.5)
    tick(0.5)
    tick(0.05)
    tick(0.05)
    kinds = [k for k, _ in tr.events]
    assert kinds == ["slo_breach", "alert_clear"]
    assert eng.counts()["slo_breaches"] == 1
    assert tr.events[0][1]["limit"] == 0.1


# ------------------------------------- controller enforcement (FakeTenant)

class FakeTenant:
    """Controller-facing duck-type of :class:`repro.core.tenant.Tenant`
    (the test_orchestrator pattern) — ledger hand-set, downgrade
    semantics mirrored."""

    def __init__(self, tenant_id, priority=0, allocation=None,
                 spent=0.0, ask=0.0, shrinkable=False):
        self.tenant_id = tenant_id
        self.priority = priority
        self.allocation = allocation
        self.paused = False
        self.votes_shrunk = False
        self.forced = False
        self._spent = float(spent)
        self._ask = float(ask)
        self._shrinkable = shrinkable

    @property
    def spent(self):
        return self._spent

    @property
    def done(self):
        return self.forced

    @property
    def running(self):
        return not self.forced

    def next_spend(self):
        if self.forced or self.paused:
            return 0.0
        return self._ask * (0.5 if self.votes_shrunk else 1.0)

    def apply_downgrade(self, action):
        if not self.running:
            return False
        if action == "pause":
            if self.paused:
                return False
            self.paused = True
            return True
        if action == "shrink_votes":
            if self.votes_shrunk or not self._shrinkable:
                return False
            self.votes_shrunk = True
            return True
        if action == "force_commit":
            self.forced = True
            return True
        raise ValueError(action)


def _breach(tenant, slo="cost_per_label", enforceable=True):
    return {"tenant": tenant, "slo": slo, "value": 1.0, "limit": 0.1,
            "enforceable": enforceable}


def test_enforce_slo_strike_escalation():
    """Per-tenant strikes escalate one cascade step per breached
    rebalance: pause, then shrink_votes, then force_commit."""
    from repro.core.tenant import FleetController

    t = FakeTenant("a", ask=1.0, shrinkable=True)
    ctl = FleetController([t], slo_enforce=True)
    a1 = ctl._enforce_slo([_breach("a")])
    assert [d["action"] for d in a1] == ["pause"] and t.paused
    t.paused = False                     # rebalance lifts the pause
    a2 = ctl._enforce_slo([_breach("a")])
    assert [d["action"] for d in a2] == ["shrink_votes"] and t.votes_shrunk
    a3 = ctl._enforce_slo([_breach("a")])
    assert [d["action"] for d in a3] == ["force_commit"] and t.forced
    assert a3[0]["slo"] == "cost_per_label"
    # a dead tenant takes no further action
    assert ctl._enforce_slo([_breach("a")]) == []


def test_enforce_slo_skips_advisory_and_walks_cascade_order():
    from repro.core.tenant import FleetController

    lo = FakeTenant("lo", priority=0, ask=1.0)
    hi = FakeTenant("hi", priority=1, ask=1.0)
    ctl = FleetController([hi, lo], slo_enforce=True)
    # advisory (wall-clock) breaches never downgrade anyone
    assert ctl._enforce_slo([_breach("lo", slo="iteration_p95",
                                     enforceable=False)]) == []
    # both breach: walk order is (priority asc, tenant_id asc)
    applied = ctl._enforce_slo([_breach("hi"), _breach("lo")])
    assert [d["tenant"] for d in applied] == ["lo", "hi"]
    assert lo.paused and hi.paused


# ------------------------------------------------ solo campaign (live jax)

POOL = 2000


def _solo_campaign(trace_path, health):
    from repro.annotation import make_annotation_service
    from repro.core import AMAZON, MCALConfig, make_emulated_task
    from repro.core.mcal import MCALCampaign
    from repro.trace import TraceStore

    ann = make_annotation_service(
        10, noise=0.2, repeats=3, max_repeats=5, adaptive=True,
        aggregator="ds", pricing=AMAZON, seed=0)
    task = make_emulated_task("cifar10", "resnet18", seed=0,
                              pool_size=POOL)
    task.annotation = ann
    cfg = MCALConfig(seed=0, delta0_frac=0.1,
                     label_quality=ann.expected_quality())
    camp = MCALCampaign(task, AMAZON, cfg)
    with TraceStore(trace_path, "health-solo") as tr:
        camp.attach_trace(tr)
        if health is not None:
            camp.attach_health(health)
        return camp.run()


@pytest.fixture(scope="module")
def solo_runs(tmp_path_factory):
    """A noisy solo campaign twice: monitor-off and monitored with a
    breachable SLO (tiny cost-per-label ceiling -> judgment work on
    every iteration)."""
    d = tmp_path_factory.mktemp("health_solo")
    off, on = str(d / "off.jsonl"), str(d / "on.jsonl")
    res_off = _solo_campaign(off, None)
    eng = HealthEngine(SLOSpec(cost_per_label_max=0.02,
                               projected_quality_min=0.99))
    res_on = _solo_campaign(on, eng)
    return {"off": off, "on": on, "res_off": res_off, "res_on": res_on,
            "engine": eng}


def test_solo_health_attached_diff_clean(solo_runs):
    """Attached vs detached: alert events are OBSERVABILITY_KINDS, the
    decision stream (and the committed cost) is byte-identical."""
    from repro.trace import diff
    assert diff(solo_runs["off"], solo_runs["on"]) is None
    assert (solo_runs["res_on"].total_cost
            == solo_runs["res_off"].total_cost)


def test_solo_health_alerts_fired_and_sequenced(solo_runs):
    eng = solo_runs["engine"]
    assert eng.counts()["alerts_raised"] > 0
    assert eng.counts()["slo_breaches"] > 0
    seq = alert_sequence(solo_runs["on"])
    assert seq, "judgment stream missing from the trace"
    assert all(s["state"] in ("raise", "clear", "breach") for s in seq)
    assert any(s["detector"] == "slo:cost_per_label" for s in seq)
    ticks = [s["tick"] for s in seq]
    assert ticks == sorted(ticks)
    assert alert_sequence(solo_runs["off"]) == []


def test_report_health_panel_solo(solo_runs, capsys):
    from repro.launch import report
    report.main([solo_runs["on"], "--health"])
    out = capsys.readouterr().out
    assert "== health ==" in out
    assert "slo:cost_per_label" in out
    report.main([solo_runs["on"], "--json", "--health"])
    blob = json.loads(capsys.readouterr().out)
    assert blob["health"]["alerts_raised"] > 0
    assert blob["health"]["slo_breaches"] > 0


def test_report_health_panel_empty_without_engine(solo_runs, capsys):
    from repro.launch import report
    report.main([solo_runs["off"], "--health"])
    out = capsys.readouterr().out
    assert "no health events" in out


# ------------------------------------------------------- fleets (live jax)

N_TENANTS = 4
ENGINE_KW = dict(epochs=2, score_microbatch=128, sweep_page=128)


def _fleet(trace_dir, specs, *, health=None, slo_enforce=False,
           global_budget=None):
    from repro.core import AMAZON
    from repro.data.synth import make_classification
    from repro.launch.orchestrator import build_fleet

    x, y = make_classification(400, num_classes=4, difficulty=0.3, seed=0)
    orch = build_fleet(x, y, specs, service=AMAZON, trace_dir=trace_dir,
                       concurrent=False, health=health,
                       slo_enforce=slo_enforce,
                       global_budget=global_budget, engine_kw=ENGINE_KW)
    try:
        orch.run()
    finally:
        orch.close()


def _specs(budget=None):
    from repro.core import MCALConfig
    from repro.core.tenant import TenantSpec
    return [TenantSpec(f"t{i}", priority=i % 2, seed=i, budget=budget,
                       cfg=MCALConfig(seed=i, max_iters=2,
                                      delta0_frac=0.1, test_frac=0.2))
            for i in range(N_TENANTS)]


@pytest.fixture(scope="module")
def slo_fleet_pair(tmp_path_factory):
    """Two identical over-SLO fleets with enforcement on: every tenant
    breaches a tiny cost-per-label ceiling, so the engine both alerts
    and drives the cascade."""
    dirs = []
    for tag in ("a", "b"):
        d = str(tmp_path_factory.mktemp(f"slo_fleet_{tag}"))
        _fleet(d, _specs(),
               health=HealthEngine(SLOSpec(cost_per_label_max=0.001)),
               slo_enforce=True)
        dirs.append(d)
    return dirs


def test_slo_enforcement_deterministic_byte_equal(slo_fleet_pair):
    """The SLO-breach determinism contract: identical over-SLO fleets
    emit byte-equal alert sequences AND identical downgrade walks."""
    from repro.core.tenant import downgrade_sequence
    a, b = slo_fleet_pair
    sa = json.dumps(alert_sequence(os.path.join(a, "fleet.jsonl")))
    sb = json.dumps(alert_sequence(os.path.join(b, "fleet.jsonl")))
    assert sa == sb
    assert sa != "[]"
    ga = downgrade_sequence(os.path.join(a, "fleet.jsonl"))
    gb = downgrade_sequence(os.path.join(b, "fleet.jsonl"))
    assert ga == gb
    assert ga, "enforcement never reached the cascade"


def test_slo_enforcement_downgrades_carry_slo_and_terminate(slo_fleet_pair):
    """SLO downgrades are tagged with the breached clause (pause is the
    first strike for every tenant), and a fleet where EVERYONE breaches
    still terminates: all-paused is a stall, which the orchestrator
    resolves by forcing the rest out — so every tenant ends in
    force_commit, not an infinite pause loop."""
    from repro.trace.store import read_trace
    events = [e for e in read_trace(
        os.path.join(slo_fleet_pair[0], "fleet.jsonl"))
        if e.kind == "downgrade"]
    slo_events = [e for e in events if "slo" in e.payload]
    assert {e.payload["slo"] for e in slo_events} == {"cost_per_label"}
    assert ({e.payload["tenant"] for e in slo_events
             if e.payload["action"] == "pause"}
            == {f"t{i}" for i in range(N_TENANTS)})
    forced = {e.payload["tenant"] for e in events
              if e.payload["action"] == "force_commit"}
    assert forced == {f"t{i}" for i in range(N_TENANTS)}


def test_slo_alerts_ride_fleet_trace_not_tenant_traces(slo_fleet_pair):
    from repro.trace.store import read_trace
    for i in range(N_TENANTS):
        events = read_trace(os.path.join(slo_fleet_pair[0],
                                         f"t{i}.jsonl"))
        assert not [e for e in events if e.kind in ALERT_KINDS]


@pytest.fixture(scope="module")
def budget_fleet_runs(tmp_path_factory):
    """The acceptance scenario: an over-budget fleet (global ceiling
    between spent and projected, so the EXISTING budget cascade fires)
    with the health engine armed (--slo-enforce on, SLO contracted but
    not breached) — twice monitored, once monitor-off."""
    spec_kw = dict(budget=20.0)
    fleet_kw = dict(slo_enforce=True, global_budget=21.0)

    def eng():
        return HealthEngine(SLOSpec(cost_per_label_max=100.0))

    d1 = str(tmp_path_factory.mktemp("budget_fleet_on1"))
    _fleet(d1, _specs(**spec_kw), health=eng(), **fleet_kw)
    d2 = str(tmp_path_factory.mktemp("budget_fleet_on2"))
    _fleet(d2, _specs(**spec_kw), health=eng(), **fleet_kw)
    d3 = str(tmp_path_factory.mktemp("budget_fleet_off"))
    _fleet(d3, _specs(**spec_kw), global_budget=21.0)
    return d1, d2, d3


def test_over_budget_fleet_alerts_deterministic(budget_fleet_runs):
    d1, d2, _ = budget_fleet_runs
    s1 = json.dumps(alert_sequence(os.path.join(d1, "fleet.jsonl")))
    s2 = json.dumps(alert_sequence(os.path.join(d2, "fleet.jsonl")))
    assert s1 == s2
    seq = json.loads(s1)
    assert any(s["detector"] == "budget_burn" for s in seq), seq


def test_over_budget_fleet_cascade_and_diff_clean(budget_fleet_runs):
    """Monitoring an over-budget fleet changes NOTHING about its
    decisions: the budget cascade fires identically, and every
    per-tenant decision stream diffs clean against the monitor-off
    sibling."""
    from repro.core.tenant import downgrade_sequence
    from repro.trace import diff
    d1, _, d3 = budget_fleet_runs
    assert downgrade_sequence(os.path.join(d1, "fleet.jsonl"))
    assert (downgrade_sequence(os.path.join(d1, "fleet.jsonl"))
            == downgrade_sequence(os.path.join(d3, "fleet.jsonl")))
    for i in range(N_TENANTS):
        assert diff(os.path.join(d1, f"t{i}.jsonl"),
                    os.path.join(d3, f"t{i}.jsonl")) is None


def test_report_health_panel_fleet(budget_fleet_runs, capsys):
    from repro.launch import report
    d1 = budget_fleet_runs[0]
    report.main([d1, "--health"])
    out = capsys.readouterr().out
    assert "== health ==" in out
    assert "budget_burn" in out


# ------------------------------------------------ report burn-rate guard

def _write_trace(path, events):
    """Hand-write a JSONL trace (controlled timestamps)."""
    with open(path, "w") as f:
        for i, (kind, ts, payload) in enumerate(events):
            f.write(json.dumps({"seq": i, "campaign": "c", "kind": kind,
                                "ts": ts, "payload": payload}) + "\n")


def _charge(total):
    return {"ledger": "campaign", "human": total, "training": 0.0,
            "human_labels": 10, "human_votes": 10, "total": total}


def test_report_burn_guard_zero_span(tmp_path):
    """Charges landing within the same wall-clock instant (resume
    replay, single-burst acquisition) must not divide by ~0: the burn
    block reports None and the text view omits it instead of printing
    inf/NaN."""
    from repro.launch.report import render, summarize
    p = str(tmp_path / "t.jsonl")
    t0 = 1700000000.0
    _write_trace(p, [
        ("campaign_begin", t0, {"config": {}, "runtime": {},
                                "pool_size": 10}),
        ("charge", t0, _charge(1.0)),
        ("charge", t0 + 1e-5, _charge(2.0)),
    ])
    s = summarize(p)
    assert s["burn"]["per_second"] is None
    assert s["burn"]["recent_per_second"] is None
    out = render(s)
    assert "burn rate" not in out
    assert "inf" not in out and "nan" not in out.lower()


def test_report_burn_normal_span(tmp_path):
    from repro.launch.report import render, summarize
    p = str(tmp_path / "t.jsonl")
    t0 = 1700000000.0
    _write_trace(p, [
        ("campaign_begin", t0, {"config": {}, "runtime": {},
                                "pool_size": 10}),
        ("charge", t0, _charge(1.0)),
        ("charge", t0 + 4.0, _charge(3.0)),
    ])
    s = summarize(p)
    assert s["burn"]["per_second"] == pytest.approx(0.5)
    assert "burn rate" in render(s)


# -------------------------------------------------- regression observatory

def _bench_record(run, ts, gates):
    return {"run": run, "mode": "smoke", "timestamp": ts, "jax": "0",
            "backend": "cpu", "device_count": 1, "rows": [],
            "gates": gates, "errors": []}


def _write_history(d, records):
    for rec in records:
        with open(os.path.join(d, f"BENCH_{rec['run']}.json"), "w") as f:
            json.dump(rec, f)


def test_regress_flags_synthetic_regression(tmp_path, capsys):
    from benchmarks import regress
    d = str(tmp_path)
    _write_history(d, [
        _bench_record("r1", "2026-01-01T00:00:00Z", {"fit": 2.0, "ok": 5.0}),
        _bench_record("r2", "2026-01-02T00:00:00Z", {"fit": 2.1, "ok": 5.0}),
        _bench_record("r3", "2026-01-03T00:00:00Z", {"fit": 1.0, "ok": 5.1}),
    ])
    report = regress.evaluate(regress.load_history(d))
    by = {g["gate"]: g for g in report["gates"]}
    # 1.0 vs median(2.0, 2.1)=2.05 -> ratio ~0.49 < 0.70 -> fail
    assert by["fit"]["verdict"] == "fail"
    assert by["fit"]["baseline"] == pytest.approx(2.05)
    assert by["ok"]["verdict"] == "ok"
    assert report["status"] == "fail"
    assert regress.main(["--history", d]) == 1
    assert regress.main(["--history", d, "--warn-only"]) == 0
    out = capsys.readouterr().out
    assert "! fit" in out


def test_regress_warn_new_and_missing_verdicts(tmp_path):
    from benchmarks import regress
    d = str(tmp_path)
    _write_history(d, [
        _bench_record("r1", "2026-01-01T00:00:00Z", {"a": 2.0, "gone": 3.0}),
        _bench_record("r2", "2026-01-02T00:00:00Z", {"a": 1.7, "new": 9.0}),
    ])
    by = {g["gate"]: g for g in
          regress.evaluate(regress.load_history(d))["gates"]}
    assert by["a"]["verdict"] == "warn"          # 0.85 ratio
    assert by["new"]["verdict"] == "new"         # no prior series
    assert by["gone"]["verdict"] == "missing"    # dropped out of latest
    assert regress.main(["--history", d]) == 0   # warn never fails


def test_regress_insufficient_history(tmp_path):
    from benchmarks import regress
    d = str(tmp_path)
    _write_history(d, [_bench_record("only", "2026-01-01T00:00:00Z",
                                     {"a": 1.0})])
    assert regress.evaluate(regress.load_history(d))["status"] \
        == "insufficient-history"
    assert regress.main(["--history", d]) == 0


def test_regress_passes_on_real_history():
    """The in-tree trajectory must never fail its own observatory (it
    may warn — CI smoke shapes are noisy)."""
    from benchmarks import regress
    records = regress.load_history()
    assert len(records) >= 2
    report = regress.evaluate(records)
    assert report["status"] in ("ok", "warn"), report
    assert regress.main([]) == 0


def test_run_check_history_is_jax_free(tmp_path, monkeypatch, capsys):
    """`benchmarks.run --check-history` must judge without importing
    jax — the observatory has to work on a box that can't run the
    benchmarks."""
    import builtins
    import benchmarks.run as run_mod

    real_import = builtins.__import__

    def guard(name, *a, **kw):
        assert not name.startswith("jax"), "--check-history imported jax"
        return real_import(name, *a, **kw)

    monkeypatch.setattr(builtins, "__import__", guard)
    monkeypatch.setattr("sys.argv", ["run", "--check-history"])
    with pytest.raises(SystemExit) as exc:
        run_mod.main()
    assert exc.value.code == 0
    assert "regression observatory" in capsys.readouterr().out


# ------------------------------------------------------------ CLI guards

def test_orchestrator_slo_enforce_requires_spec(tmp_path, monkeypatch):
    from repro.launch import orchestrator
    cfg = tmp_path / "tenants.json"
    cfg.write_text(json.dumps([{"tenant_id": "t0"}]))
    monkeypatch.setattr("sys.argv", ["orchestrator", "--tenants",
                                     str(cfg), "--pool", "200",
                                     "--slo-enforce"])
    with pytest.raises(SystemExit, match="--slo-enforce requires"):
        orchestrator.main()
