"""Cost models (paper Eqn. 4) + ledger.

Property-style cases run from a seeded deterministic grid so the suite is
self-contained; when ``hypothesis`` happens to be installed the same
properties are additionally fuzzed.
"""
import numpy as np
import pytest

from repro.core.cost import (AMAZON, SATYAM, CostLedger, LabelingService,
                             TrainCostModel, schedule_sizes)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False


def test_eqn4_closed_form_matches_schedule_sum():
    cm = TrainCostModel(c_u=0.004, exponent=1)
    for B, delta in [(10000, 500), (16000, 1000), (7000, 700)]:
        sizes = schedule_sizes(0, B, delta)
        assert cm.cost_from_scratch(B, delta) == pytest.approx(
            0.004 * float(np.sum(sizes)), rel=1e-9)
        # paper formula: 1/2 c_u B (B/delta + 1)
        assert cm.cost_from_scratch(B, delta) == pytest.approx(
            0.5 * 0.004 * B * (B / delta + 1), rel=1e-9)


def test_cubic_variant():
    cm = TrainCostModel(c_u=1e-7, exponent=2)
    sizes = schedule_sizes(0, 4000, 1000)
    assert cm.cost_from_scratch(4000, 1000) == pytest.approx(
        1e-7 * float(np.sum(sizes.astype(float) ** 2)))


def _check_grow_cost_consistency(start, gap, delta):
    """cost_to_grow == sum of per-iteration costs of the actual schedule."""
    cm = TrainCostModel(c_u=0.01, exponent=1)
    end = start + gap
    m = int(np.ceil(gap / delta))
    sizes = np.minimum(start + delta * np.arange(1, m + 1), end)
    assert cm.cost_to_grow(start, end, delta) == pytest.approx(
        0.01 * float(np.sum(sizes)), rel=1e-9)


def _grow_cases(n=40, seed=0):
    rng = np.random.default_rng(seed)
    cases = [(0, 1, 100), (0, 20000, 100), (5000, 1, 5000),
             (5000, 20000, 5000), (0, 100, 100), (1234, 999, 1000)]
    while len(cases) < n:
        cases.append((int(rng.integers(0, 5001)),
                      int(rng.integers(1, 20001)),
                      int(rng.integers(100, 5001))))
    return cases


@pytest.mark.parametrize("start,gap,delta", _grow_cases())
def test_grow_cost_consistency(start, gap, delta):
    _check_grow_cost_consistency(start, gap, delta)


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(start=st.integers(0, 5000), gap=st.integers(1, 20000),
           delta=st.integers(100, 5000))
    def test_property_grow_cost_consistency(start, gap, delta):
        _check_grow_cost_consistency(start, gap, delta)


def test_fit_recovers_cu():
    cm = TrainCostModel(exponent=1)
    sizes = [1000, 2000, 4000]
    costs = [4.0, 8.0, 16.0]
    cm.fit(sizes, costs)
    assert cm.c_u == pytest.approx(0.004)


def test_ledger():
    led = CostLedger()
    led.pay_human(100, AMAZON)
    led.pay_human(100, SATYAM)
    led.pay_training(1.5)
    assert led.human == pytest.approx(100 * 0.04 + 100 * 0.003)
    assert led.total == pytest.approx(led.human + 1.5)
    assert led.human_labels == 200
    assert led.human_votes == 200   # one vote per label by default


def test_pay_human_zero_is_free():
    led = CostLedger()
    assert led.pay_human(0, AMAZON) == 0.0
    assert led.pay_human(0, AMAZON, repeats=7) == 0.0
    assert led.human == 0.0 and led.human_labels == 0
    assert led.human_votes == 0


def test_pay_human_repeats_multiplies_pricing():
    led = CostLedger()
    c = led.pay_human(100, AMAZON, repeats=3)
    assert c == pytest.approx(300 * 0.04)
    assert led.human_labels == 100 and led.human_votes == 300
    # exact vote counts (adaptive policies) override uniform repeats
    led.pay_human(10, AMAZON, votes=37)
    assert led.human_votes == 337
    assert led.human == pytest.approx(337 * 0.04)
    # top-up rounds buy votes for already-counted labels
    led.pay_votes(13, AMAZON)
    assert led.human_labels == 110 and led.human_votes == 350
    assert led.human == pytest.approx(350 * 0.04)


TIERED = LabelingService("tiered", 0.05,
                         tiers=((0, 0.05), (100, 0.02), (1000, 0.01)))


@pytest.mark.parametrize("n,start,expect", [
    (0, 0, 0.0),
    (100, 0, 100 * 0.05),             # exactly up to the boundary
    (101, 0, 100 * 0.05 + 0.02),      # one request past it
    (50, 75, 25 * 0.05 + 25 * 0.02),  # straddling mid-batch
    (10, 100, 10 * 0.02),             # starting exactly on the boundary
    (2000, 0, 100 * 0.05 + 900 * 0.02 + 1000 * 0.01),  # across both
    (5, 5000, 5 * 0.01),              # deep in the last tier
])
def test_tier_boundaries(n, start, expect):
    assert TIERED.cost(n, start=start) == pytest.approx(expect)


def test_tiered_ledger_threads_cumulative_volume():
    """Tier discounts apply against the CUMULATIVE request count — two
    50-vote batches price like one 100-vote batch."""
    led = CostLedger()
    led.pay_human(60, TIERED)
    led.pay_human(60, TIERED)
    assert led.human == pytest.approx(TIERED.cost(120))
    assert led.human == pytest.approx(100 * 0.05 + 20 * 0.02)


def test_untier_service_cost_ignores_start():
    assert AMAZON.cost(10, start=999999) == pytest.approx(10 * 0.04)


def test_service_scaled_prices_repeats():
    eff = AMAZON.scaled(3.0)
    assert eff.price_per_label == pytest.approx(0.12)
    assert AMAZON.scaled(1.0) is AMAZON


def test_tiers_must_be_sorted():
    with pytest.raises(AssertionError):
        LabelingService("bad", 0.05, tiers=((100, 0.02), (0, 0.05)))


def test_ledger_as_dict_roundtrip():
    led = CostLedger()
    led.pay_human(100, TIERED, repeats=3)
    led.pay_training(2.5)
    back = CostLedger.from_dict(led.as_dict())
    assert back == led
    # snapshot = as_dict + derived total (the report shape)
    assert led.snapshot() == dict(led.as_dict(), total=led.total)
    # pre-annotation checkpoints lack human_votes: one vote per label
    legacy = {"human": 4.0, "training": 1.0, "human_labels": 100}
    old = CostLedger.from_dict(legacy)
    assert old.human_votes == 100


def test_ledger_roundtrips_through_campaign_state_dict():
    """The ledger (votes included) survives campaign state_dict /
    load_state_dict — the persistence path preempted noisy-oracle
    campaigns rely on."""
    import json

    from repro.core import AMAZON, MCALCampaign, MCALConfig, \
        make_emulated_task

    def fresh():
        return MCALCampaign(
            make_emulated_task("cifar10", "resnet18", seed=0,
                               pool_size=2000, sweep_page=512),
            AMAZON, MCALConfig(seed=0, max_iters=2))

    ref = fresh()
    ref.bootstrap()
    ref.iteration()
    blob = json.loads(json.dumps(ref.state_dict()))
    assert set(blob["ledger"]) == {"human", "training", "human_labels",
                                   "human_votes"}
    resumed = fresh()
    resumed.load_state_dict(blob)
    assert resumed.pool.ledger == ref.pool.ledger
