"""Cost models (paper Eqn. 4) + ledger.

Property-style cases run from a seeded deterministic grid so the suite is
self-contained; when ``hypothesis`` happens to be installed the same
properties are additionally fuzzed.
"""
import numpy as np
import pytest

from repro.core.cost import (AMAZON, SATYAM, CostLedger, LabelingService,
                             TrainCostModel, schedule_sizes)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False


def test_eqn4_closed_form_matches_schedule_sum():
    cm = TrainCostModel(c_u=0.004, exponent=1)
    for B, delta in [(10000, 500), (16000, 1000), (7000, 700)]:
        sizes = schedule_sizes(0, B, delta)
        assert cm.cost_from_scratch(B, delta) == pytest.approx(
            0.004 * float(np.sum(sizes)), rel=1e-9)
        # paper formula: 1/2 c_u B (B/delta + 1)
        assert cm.cost_from_scratch(B, delta) == pytest.approx(
            0.5 * 0.004 * B * (B / delta + 1), rel=1e-9)


def test_cubic_variant():
    cm = TrainCostModel(c_u=1e-7, exponent=2)
    sizes = schedule_sizes(0, 4000, 1000)
    assert cm.cost_from_scratch(4000, 1000) == pytest.approx(
        1e-7 * float(np.sum(sizes.astype(float) ** 2)))


def _check_grow_cost_consistency(start, gap, delta):
    """cost_to_grow == sum of per-iteration costs of the actual schedule."""
    cm = TrainCostModel(c_u=0.01, exponent=1)
    end = start + gap
    m = int(np.ceil(gap / delta))
    sizes = np.minimum(start + delta * np.arange(1, m + 1), end)
    assert cm.cost_to_grow(start, end, delta) == pytest.approx(
        0.01 * float(np.sum(sizes)), rel=1e-9)


def _grow_cases(n=40, seed=0):
    rng = np.random.default_rng(seed)
    cases = [(0, 1, 100), (0, 20000, 100), (5000, 1, 5000),
             (5000, 20000, 5000), (0, 100, 100), (1234, 999, 1000)]
    while len(cases) < n:
        cases.append((int(rng.integers(0, 5001)),
                      int(rng.integers(1, 20001)),
                      int(rng.integers(100, 5001))))
    return cases


@pytest.mark.parametrize("start,gap,delta", _grow_cases())
def test_grow_cost_consistency(start, gap, delta):
    _check_grow_cost_consistency(start, gap, delta)


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(start=st.integers(0, 5000), gap=st.integers(1, 20000),
           delta=st.integers(100, 5000))
    def test_property_grow_cost_consistency(start, gap, delta):
        _check_grow_cost_consistency(start, gap, delta)


def test_fit_recovers_cu():
    cm = TrainCostModel(exponent=1)
    sizes = [1000, 2000, 4000]
    costs = [4.0, 8.0, 16.0]
    cm.fit(sizes, costs)
    assert cm.c_u == pytest.approx(0.004)


def test_ledger():
    led = CostLedger()
    led.pay_human(100, AMAZON)
    led.pay_human(100, SATYAM)
    led.pay_training(1.5)
    assert led.human == pytest.approx(100 * 0.04 + 100 * 0.003)
    assert led.total == pytest.approx(led.human + 1.5)
    assert led.human_labels == 200
