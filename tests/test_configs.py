"""Guard the assigned architecture configs against drift: every published
dimension from the assignment table is pinned here."""
import pytest

from repro.configs import ARCH_IDS, LONG_CONTEXT_OK, cells, get_config, get_smoke

# (layers, d_model, heads, kv, d_ff, vocab) per the assignment
PUBLISHED = {
    "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
    "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
    "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
    "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
    "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
    "mamba2-1.3b": (48, 2048, None, None, 0, 50280),
    "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
    "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
    "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
    "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
}

EXTRAS = {
    "zamba2-2.7b": {"ssm_state": 64},
    "mamba2-1.3b": {"ssm_state": 128},
    "kimi-k2-1t-a32b": {"num_experts": 384, "experts_per_token": 8},
    "dbrx-132b": {"num_experts": 16, "experts_per_token": 4},
    "gemma3-4b": {"local_global_ratio": 5},
    "qwen2-1.5b": {"qkv_bias": True},
    "qwen1.5-4b": {"qkv_bias": True},
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_published_dimensions(arch):
    cfg = get_config(arch)
    nl, d, h, kv, ff, v = PUBLISHED[arch]
    assert cfg.num_layers == nl
    assert cfg.d_model == d
    if h is not None:
        assert cfg.num_heads == h and cfg.num_kv_heads == kv
    if ff:
        assert cfg.d_ff == ff
    # ragged vocabs are padded up (<= 256) for shardability — documented
    # in the config files (whisper 51865->51872, internvl2 92553->92672)
    assert v <= cfg.vocab_size < v + 256
    for k, val in EXTRAS.get(arch, {}).items():
        assert getattr(cfg, k) == val, (arch, k)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_is_same_family_but_small(arch):
    full, smoke = get_config(arch), get_smoke(arch)
    assert smoke.family == full.family
    assert smoke.num_layers <= 6 and smoke.d_model <= 128
    assert smoke.vocab_size <= 1024


def test_long_context_cells():
    """long_500k runs exactly for the sub-quadratic archs."""
    assert LONG_CONTEXT_OK == {"zamba2-2.7b", "mamba2-1.3b", "gemma3-4b"}
    total = sum(len(cells(a)) for a in ARCH_IDS)
    assert total == 33  # 10 archs x 3 shapes + 3 long_500k
