"""Multi-tenant orchestrator: shared engines, fleet budgets, isolation.

Three layers:

* **acceptance** — an N=8 concurrent fleet over ONE shared engine bundle
  produces per-tenant decision streams bit-identical to the same fleet
  run serially (``trace.replay.diff`` clean per tenant), and an
  over-ceiling fleet executes the downgrade cascade deterministically
  (equal ``downgrade_sequence``s, fleet traces diff-clean under
  ``FLEET_KINDS``);
* **controller units** — redistribution and cascade ordering over
  hand-set ledgers (no campaigns, pure accounting);
* **session isolation** — interleaved ``submit``s from two sessions of
  ONE shared AnnotationService keep per-tenant charges and vote streams
  bit-identical to each session running alone, including across a
  preempt/resume of one session.
"""
import os

import numpy as np
import pytest

from repro.core import MCALConfig
from repro.core.tenant import (DOWNGRADE_ACTIONS, FLEET_KINDS,
                               FleetController, TenantSpec,
                               downgrade_sequence)
from repro.trace.replay import diff
from repro.trace.store import read_trace

POOL = 320
CLASSES = 3
ENGINE_KW = dict(epochs=2, score_microbatch=128, sweep_page=128)


def _data(n=POOL, seed=0):
    from repro.data.synth import make_classification
    return make_classification(n, num_classes=CLASSES, difficulty=0.3,
                               seed=seed)


def _cfg(**kw):
    base = dict(max_iters=2, delta0_frac=0.1, test_frac=0.2)
    base.update(kw)
    return MCALConfig(**base)


def _run_fleet(tmpdir, specs, *, concurrent, global_budget=None,
               annotation=None):
    from repro.core import AMAZON
    from repro.launch.orchestrator import build_fleet
    x, y = _data()
    orch = build_fleet(x, y, specs, service=AMAZON,
                       global_budget=global_budget, trace_dir=tmpdir,
                       concurrent=concurrent,
                       annotation_service=annotation,
                       engine_kw=ENGINE_KW)
    try:
        results = orch.run()
    finally:
        orch.close()
    return results, orch


# ---------------------------------------------------------------------------
# acceptance: N=8 concurrent == N=8 serial, per-tenant, bit-for-bit
# ---------------------------------------------------------------------------


def test_concurrent_fleet_matches_serial_n8(tmp_path):
    specs = [TenantSpec(f"t{i}", priority=i % 3, seed=i,
                        cfg=_cfg(seed=i, eps_target=0.05 + 0.01 * (i % 4)))
             for i in range(8)]
    d1, d2 = str(tmp_path / "conc"), str(tmp_path / "serial")
    res_c, orch_c = _run_fleet(d1, specs, concurrent=True)
    res_s, orch_s = _run_fleet(d2, specs, concurrent=False)

    assert set(res_c) == {s.tenant_id for s in specs} == set(res_s)
    for s in specs:
        d = diff(os.path.join(d1, f"{s.tenant_id}.jsonl"),
                 os.path.join(d2, f"{s.tenant_id}.jsonl"))
        assert d is None, f"{s.tenant_id} diverged: {d}"
        assert res_c[s.tenant_id].decision == res_s[s.tenant_id].decision
        assert res_c[s.tenant_id].total_cost == \
            pytest.approx(res_s[s.tenant_id].total_cost)

    # the whole fleet shared ONE compile cache — matched-shape tenants
    # never compiled per-tenant programs (8 tenants, one engine bundle)
    assert orch_c.engines.compiled_count() > 0
    assert orch_c.engines.compiled_count() == orch_s.engines.compiled_count()


def test_shared_engines_refuse_mismatched_shapes():
    from repro.core.task import LiveTask
    from repro.launch.orchestrator import SharedEngines
    x, y = _data()
    with SharedEngines.build(x.shape[1], CLASSES, **ENGINE_KW) as eng:
        with pytest.raises(AssertionError):
            LiveTask(features=x[:, :-1], groundtruth=y,
                     num_classes=CLASSES, engines=eng)
        with pytest.raises(AssertionError):
            LiveTask(features=x, groundtruth=y, num_classes=CLASSES,
                     engines=eng, fit_resident=True)


# ---------------------------------------------------------------------------
# acceptance: the downgrade cascade is deterministic and replayable
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def cascade_runs(tmp_path_factory):
    """The same over-ceiling fleet twice: per-tenant budgets too small,
    a global ceiling the asks breach, a shared annotation service so
    shrink_votes has repeats to halve."""
    from repro.annotation import make_annotation_service

    def run(d):
        ann = make_annotation_service(CLASSES, n_workers=5, noise=0.2,
                                      repeats=3, seed=0)
        quality = ann.expected_quality()
        specs = [TenantSpec(f"t{i}", priority=i, budget=6.0, seed=i,
                            cfg=_cfg(max_iters=3, seed=i,
                                     label_quality=quality))
                 for i in range(3)]
        res, orch = _run_fleet(d, specs, concurrent=True,
                               global_budget=14.0, annotation=ann)
        return res

    d1 = str(tmp_path_factory.mktemp("cascade1"))
    d2 = str(tmp_path_factory.mktemp("cascade2"))
    return d1, run(d1), d2, run(d2)


def test_cascade_is_deterministic(cascade_runs):
    d1, res1, d2, res2 = cascade_runs
    seq1 = downgrade_sequence(os.path.join(d1, "fleet.jsonl"))
    seq2 = downgrade_sequence(os.path.join(d2, "fleet.jsonl"))
    assert seq1, "the ceiling never bound — no cascade to compare"
    assert seq1 == seq2
    # the fleet's full budget decision stream replays too
    assert diff(os.path.join(d1, "fleet.jsonl"),
                os.path.join(d2, "fleet.jsonl"),
                kinds=FLEET_KINDS) is None
    # relief order is least-destructive first, least-critical first
    rank = {a: i for i, a in enumerate(DOWNGRADE_ACTIONS)}
    per_round = {}
    for ev in seq1:
        per_round.setdefault(ev["round"], []).append(ev)
    for evs in per_round.values():
        assert [rank[e["action"]] for e in evs] == \
            sorted(rank[e["action"]] for e in evs)


def test_forced_tenants_finish_with_fleet_ceiling_reason(cascade_runs):
    d1, res1, _d2, _res2 = cascade_runs
    forced = {e["tenant"] for e in downgrade_sequence(
        os.path.join(d1, "fleet.jsonl")) if e["action"] == "force_commit"}
    for tid in forced:
        events = read_trace(os.path.join(d1, f"{tid}.jsonl"))
        done = [e for e in events if e.kind == "done"]
        assert done and done[-1].payload["reason"] == "fleet_ceiling"
        # a forced tenant still COMMITS (Pyrrhus-style: keep what you
        # have) — its result exists and is priced
        assert res1[tid].decision in ("hybrid", "human_all")


def test_fleet_report_structure(cascade_runs):
    from repro.launch.orchestrator import fleet_report, render_fleet
    d1, _res1, _d2, _res2 = cascade_runs
    rep = fleet_report(d1)
    assert set(rep["tenants"]) == {"t0", "t1", "t2"}
    fl = rep["fleet"]
    assert fl["ceiling"] == 14.0 and fl["rounds"] >= 1
    assert fl["downgrades"] and fl["final"] is not None
    assert fl["final"]["total"] == pytest.approx(
        sum(t["total"] for t in fl["final"]["tenants"].values()))
    text = render_fleet(rep)
    assert "ceiling" in text and "t0" in text and "force_commit" in text


# ---------------------------------------------------------------------------
# controller units: hand-set ledgers, no campaigns
# ---------------------------------------------------------------------------


class FakeTenant:
    """The controller-facing duck-type of :class:`repro.core.tenant.
    Tenant` with the ledger hand-set — mirrors the real downgrade
    semantics (pause zeroes the ask for one round, shrink halves it
    once, force ends the tenant)."""

    def __init__(self, tenant_id, priority=0, allocation=None,
                 spent=0.0, ask=0.0, shrinkable=False):
        self.tenant_id = tenant_id
        self.priority = priority
        self.allocation = allocation
        self.paused = False
        self.votes_shrunk = False
        self.forced = False
        self._spent = float(spent)
        self._ask = float(ask)
        self._shrinkable = shrinkable

    @property
    def spent(self):
        return self._spent

    @property
    def done(self):
        return self.forced

    @property
    def running(self):
        return not self.forced

    def next_spend(self):
        if self.forced or self.paused:
            return 0.0
        return self._ask * (0.5 if self.votes_shrunk else 1.0)

    def apply_downgrade(self, action):
        if not self.running:
            return False
        if action == "pause":
            if self.paused:
                return False
            self.paused = True
            return True
        if action == "shrink_votes":
            if self.votes_shrunk or not self._shrinkable:
                return False
            self.votes_shrunk = True
            return True
        if action == "force_commit":
            self.forced = True
            return True
        raise ValueError(action)


def test_redistribute_surplus_to_highest_priority_first():
    lo = FakeTenant("lo", priority=0, allocation=10.0, spent=2.0, ask=0.0)
    mid = FakeTenant("mid", priority=1, allocation=5.0, spent=5.0, ask=10.0)
    hi = FakeTenant("hi", priority=2, allocation=5.0, spent=5.0, ask=3.0)
    ctl = FleetController([lo, mid, hi], global_budget=None)
    ctl.rebalance()
    # lo's 8.0 surplus: hi (most critical over-asker) topped up to its
    # full 3.0 need first, mid gets the remaining 5.0 of its 10.0 need
    assert lo.allocation == pytest.approx(2.0)
    assert hi.allocation == pytest.approx(8.0)
    assert mid.allocation == pytest.approx(10.0)
    # nobody was downgraded — there is no ceiling
    assert not any(t.paused or t.votes_shrunk or t.forced
                   for t in (lo, mid, hi))


def test_redistribute_takes_done_tenants_leftover():
    done = FakeTenant("done", priority=9, allocation=10.0, spent=4.0)
    done.forced = True          # finished: its leftover 6.0 is surplus
    ask = FakeTenant("ask", priority=0, allocation=1.0, spent=1.0, ask=4.0)
    ctl = FleetController([done, ask], global_budget=None)
    ctl.rebalance()
    assert done.allocation == pytest.approx(4.0)
    assert ask.allocation == pytest.approx(5.0)


def test_uncapped_tenants_sit_out_redistribution():
    free = FakeTenant("free", allocation=None, spent=0.0, ask=100.0)
    rich = FakeTenant("rich", allocation=10.0, spent=0.0, ask=0.0)
    ctl = FleetController([free, rich], global_budget=None)
    ctl.rebalance()
    assert free.allocation is None and rich.allocation == pytest.approx(0.0)


def test_cascade_pauses_least_critical_first_and_stops():
    a = FakeTenant("a", priority=2, spent=4.0, ask=2.0)
    b = FakeTenant("b", priority=0, spent=4.0, ask=2.0)
    c = FakeTenant("c", priority=1, spent=4.0, ask=2.0)
    # projected 18 vs ceiling 16: pausing ONE lowest-priority tenant fits
    ctl = FleetController([a, b, c], global_budget=16.0)
    summary = ctl.rebalance()
    assert b.paused and not a.paused and not c.paused
    assert [d["tenant"] for d in summary["downgrades"]] == ["b"]
    assert ctl.projected() <= 16.0


def test_cascade_tie_breaks_on_tenant_id():
    a = FakeTenant("a", priority=0, spent=4.0, ask=2.0)
    b = FakeTenant("b", priority=0, spent=4.0, ask=2.0)
    ctl = FleetController([b, a], global_budget=10.0)
    summary = ctl.rebalance()
    assert a.paused and not b.paused
    assert [d["tenant"] for d in summary["downgrades"]] == ["a"]


def test_cascade_escalates_through_all_three_actions(tmp_path):
    from repro.trace import TraceStore
    trace = TraceStore(str(tmp_path / "fleet.jsonl"), "fleet")
    a = FakeTenant("a", priority=1, spent=6.0, ask=2.0, shrinkable=True)
    b = FakeTenant("b", priority=0, spent=6.0, ask=2.0, shrinkable=True)
    # ceiling below the SPENT total: no amount of pausing or shrinking
    # can fit — the cascade must escalate to force_commit for everyone
    ctl = FleetController([a, b], global_budget=10.0, trace=trace)
    summary = ctl.rebalance()
    actions = [(d["action"], d["tenant"]) for d in summary["downgrades"]]
    assert actions == [("pause", "b"), ("pause", "a"),
                       ("shrink_votes", "b"), ("shrink_votes", "a"),
                       ("force_commit", "b"), ("force_commit", "a")]
    assert not a.running and not b.running
    trace.close()
    # the trace round-trips the exact sequence
    assert [(d["action"], d["tenant"]) for d in
            downgrade_sequence(str(tmp_path / "fleet.jsonl"))] == actions


def test_pause_lifts_at_next_rebalance():
    a = FakeTenant("a", priority=1, spent=4.0, ask=2.0)
    b = FakeTenant("b", priority=0, spent=4.0, ask=2.0)
    ctl = FleetController([a, b], global_budget=10.0)
    ctl.rebalance()
    assert b.paused
    ctl.global_budget = 100.0   # ceiling no longer binds
    ctl.rebalance()
    assert not b.paused and not a.paused


def test_resolve_stall_forces_everyone_least_critical_first(tmp_path):
    from repro.trace import TraceStore
    trace = TraceStore(str(tmp_path / "fleet.jsonl"), "fleet")
    a = FakeTenant("a", priority=1, spent=1.0)
    b = FakeTenant("b", priority=0, spent=1.0)
    ctl = FleetController([a, b], global_budget=1.0, trace=trace)
    ctl.resolve_stall()
    assert not a.running and not b.running
    trace.close()
    assert [d["tenant"] for d in
            downgrade_sequence(str(tmp_path / "fleet.jsonl"))] == ["b", "a"]


def test_tenant_spec_from_dict():
    s = TenantSpec.from_dict({"tenant_id": "t7", "priority": 3,
                              "budget": 12.5, "seed": 4,
                              "cfg": {"eps_target": 0.1, "max_iters": 5}})
    assert s.tenant_id == "t7" and s.priority == 3
    assert s.budget == pytest.approx(12.5) and s.seed == 4
    assert s.cfg.eps_target == pytest.approx(0.1) and s.cfg.max_iters == 5
    d = TenantSpec.from_dict({"tenant_id": "bare"})
    assert d.priority == 0 and d.budget is None and d.cfg == MCALConfig()
    with pytest.raises(TypeError):    # unknown cfg keys are rejected
        TenantSpec.from_dict({"tenant_id": "x", "cfg": {"nope": 1}})
    with pytest.raises(AssertionError):   # duplicate ids are rejected
        FleetController([FakeTenant(i) for i in ("a", "a")])


# ---------------------------------------------------------------------------
# satellite: session ledger isolation through ONE shared service
# ---------------------------------------------------------------------------

ISO_CLASSES = 4
ISO_POOL = 64


def _iso_service():
    from repro.annotation import make_annotation_service
    return make_annotation_service(ISO_CLASSES, n_workers=7, noise=0.35,
                                   repeats=3, seed=0)


def _iso_requests(seed, n_batches=6):
    rng = np.random.default_rng(seed)
    return [np.sort(rng.choice(ISO_POOL, size=int(rng.integers(3, 9)),
                               replace=False)).astype(np.int64)
            for _ in range(n_batches)]


_ISO_GT = np.random.default_rng(99).integers(
    0, ISO_CLASSES, ISO_POOL).astype(np.int64)


def _solo_labels(reqs):
    """The same request history against a PRIVATE service (same pool
    seed): the bit-exact baseline any shared-service session must
    match."""
    svc = _iso_service()
    sess = svc.session("solo")
    labels = [sess.annotate(i, _ISO_GT[i]) for i in reqs]
    svc.close()
    return labels, sess.votes_bought, sess.labels_bought


def test_interleaved_sessions_do_not_cross_talk():
    reqs_a, reqs_b = _iso_requests(1), _iso_requests(2)
    svc = _iso_service()
    a, b = svc.session("a"), svc.session("b")
    got_a, got_b = [], []
    # interleave through the BROKER (one worker thread serializes every
    # batch) — a's and b's requests alternate in service-arrival order
    for ra, rb in zip(reqs_a, reqs_b):
        fa = a.submit(ra, _ISO_GT[ra])
        fb = b.submit(rb, _ISO_GT[rb])
        got_a.append(fa.result())
        got_b.append(fb.result())
    svc.close()

    solo_a, votes_a, labels_a = _solo_labels(reqs_a)
    solo_b, votes_b, labels_b = _solo_labels(reqs_b)
    for got, want in zip(got_a, solo_a):
        np.testing.assert_array_equal(got, want)
    for got, want in zip(got_b, solo_b):
        np.testing.assert_array_equal(got, want)
    # per-session charges are each session's own requests, and they
    # partition the shared service ledger exactly
    assert a.votes_bought == votes_a and b.votes_bought == votes_b
    assert a.labels_bought == labels_a and b.labels_bought == labels_b
    assert a.votes_bought + b.votes_bought == svc.votes_bought


def test_session_preempt_resume_does_not_perturb_sibling():
    reqs_a, reqs_b = _iso_requests(3), _iso_requests(4)
    svc = _iso_service()
    a, b = svc.session("a"), svc.session("b")
    got_a, got_b = [], []
    for i, (ra, rb) in enumerate(zip(reqs_a, reqs_b)):
        if i == len(reqs_a) // 2:
            # preempt tenant A mid-fleet: persist its session, drop it,
            # resume into a FRESH session on the same live service
            state = a.state_dict()
            a = svc.session("a-resumed")
            a.load_state_dict(state)
        got_a.append(a.annotate(ra, _ISO_GT[ra]))
        got_b.append(b.annotate(rb, _ISO_GT[rb]))
    svc.close()

    solo_a, votes_a, _ = _solo_labels(reqs_a)
    solo_b, votes_b, _ = _solo_labels(reqs_b)
    for got, want in zip(got_a, solo_a):   # A resumed bit-identically
        np.testing.assert_array_equal(got, want)
    for got, want in zip(got_b, solo_b):   # ...and B never noticed
        np.testing.assert_array_equal(got, want)
    assert a.votes_bought == votes_a and b.votes_bought == votes_b


def test_shrunk_session_policy_is_tenant_local():
    from repro.annotation.service import RepeatPolicy
    svc = _iso_service()
    a, b = svc.session("a"), svc.session("b")
    a.set_policy(RepeatPolicy(repeats=1, aggregator="majority"))
    idx = np.arange(8)
    a.annotate(idx, _ISO_GT[idx])
    b.annotate(idx, _ISO_GT[idx])
    svc.close()
    assert a.votes_bought == 8          # shrunk: 1 vote/label
    assert b.votes_bought == 24         # sibling untouched: 3 votes/label
    assert b.policy.repeats == 3
