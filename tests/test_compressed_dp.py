"""Compressed-DP train step: learns, and tracks the uncompressed
trajectory (error feedback keeps int8 gradient reduction unbiased).
Cross-device behaviour checked on a real 4-device mesh in a subprocess."""
import json
import os
import subprocess
import sys

import numpy as np

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke
from repro.configs.base import TrainConfig
from repro.models.registry import get_model
from repro.training.compressed_dp import (init_ef_state,
                                          make_compressed_dp_train_step)
from repro.training.train_loop import init_train_state, make_train_step

from repro.compat import make_mesh
mesh = make_mesh((4,), ("data",), axis_types=True)
cfg = get_smoke("qwen2-1.5b")
model = get_model(cfg)
tc = TrainConfig(learning_rate=1e-2, schedule="constant")
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)),
                               jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)),
                               jnp.int32)}

# uncompressed reference (single device semantics, same global batch)
ref_step = make_train_step(model, tc)
ref_state = init_train_state(model, tc, jax.random.key(0))
ref = []
for _ in range(5):
    ref_state, m = ref_step(ref_state, batch)
    ref.append(float(m["loss"]))

# compressed DP over 4 devices
step = make_compressed_dp_train_step(model, tc, mesh, compress_axis="data")
state = init_train_state(model, tc, jax.random.key(0))
ef = init_ef_state(state["params"])
comp = []
carry = (state, ef)
with mesh:
    for _ in range(5):
        carry, m = step(carry, batch)
        comp.append(float(m["loss"]))
print(json.dumps({"ref": ref, "comp": comp}))
"""


def test_compressed_dp_tracks_reference():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    ref, comp = out["ref"], out["comp"]
    assert all(np.isfinite(ref)) and all(np.isfinite(comp))
    assert comp[-1] < comp[0], out          # it learns
    for a, b in zip(ref, comp):             # and tracks the exact reduction
        assert abs(a - b) < 0.05 * abs(a) + 0.05, out
