"""Device-resident pool-scoring engine vs the seed host-path oracle."""
import numpy as np
import pytest

import jax

from repro.configs.base import ModelConfig
from repro.core import scoring
from repro.core import selection as sel
from repro.models.registry import get_model


@pytest.fixture(scope="module")
def mlp_setup():
    cfg = ModelConfig(name="score-probe", family="mlp", num_layers=2,
                      d_model=64, num_classes=10, input_dim=32,
                      dtype="float32", remat="none")
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    x = np.random.default_rng(0).normal(size=(5000, 32)).astype(np.float32)
    ref = scoring.score_pool_reference(model, params, x)
    return model, params, x, ref


def test_engine_matches_reference_oracle(mlp_setup):
    model, params, x, (ref_stats, ref_feats) = mlp_setup
    eng = scoring.PoolScoringEngine(
        model, scoring.ScoringConfig(microbatch=1024))
    stats, feats = eng.score_host(params, x)
    np.testing.assert_allclose(stats.margin, ref_stats.margin, atol=1e-5)
    np.testing.assert_allclose(stats.entropy, ref_stats.entropy, atol=1e-5)
    np.testing.assert_allclose(stats.max_logprob, ref_stats.max_logprob,
                               atol=1e-5)
    np.testing.assert_array_equal(stats.top1, ref_stats.top1)
    np.testing.assert_allclose(feats, ref_feats, atol=1e-5)


@pytest.mark.parametrize("mode", ["chunked", "pallas"])
def test_head_modes_match_dense(mlp_setup, mode):
    model, params, x, (ref_stats, _) = mlp_setup
    eng = scoring.PoolScoringEngine(
        model, scoring.ScoringConfig(microbatch=512, head_mode=mode,
                                     vocab_chunk=8, pallas_bv=128))
    stats, _ = eng.score_host(params, x[:1024])
    np.testing.assert_allclose(stats.margin, ref_stats.margin[:1024],
                               atol=1e-5)
    np.testing.assert_allclose(stats.entropy, ref_stats.entropy[:1024],
                               atol=1e-5)
    np.testing.assert_array_equal(stats.top1, ref_stats.top1[:1024])


@pytest.mark.parametrize("n", [1, 7, 1000, 1024, 1025, 4999])
def test_ragged_pool_sizes_trim_correctly(mlp_setup, n):
    model, params, x, (ref_stats, _) = mlp_setup
    eng = scoring.PoolScoringEngine(
        model, scoring.ScoringConfig(microbatch=1024))
    stats, feats = eng.score_host(params, x[:n])
    assert stats.margin.shape == (n,) and feats.shape[0] == n
    np.testing.assert_allclose(stats.margin, ref_stats.margin[:n], atol=1e-5)


@pytest.mark.parametrize("metric", scoring.UNCERTAINTY_METRICS)
def test_topk_matches_host_selection_on_tie_free_scores(mlp_setup, metric):
    """Identical top-k SET as the host argpartition path (tie-free pool:
    continuous random logits make exact score ties measure-zero)."""
    model, params, x, (ref_stats, _) = mlp_setup
    eng = scoring.PoolScoringEngine(
        model, scoring.ScoringConfig(microbatch=1024))
    k = 64
    idx = eng.top_k(params, x, k, metric)
    host_scores = sel.uncertainty_scores(metric, ref_stats)
    host_top = np.argpartition(-host_scores, k - 1)[:k]
    assert set(idx.tolist()) == set(host_top.tolist())
    # and the device result is sorted most-uncertain-first
    dev_scores = host_scores[idx]
    assert np.all(np.diff(dev_scores) <= 1e-12)


def test_rank_confident_matches_host_ranking(mlp_setup):
    """Same ordering as the host L(.) ranking applied to the engine's own
    statistics (fp-identical inputs, so the orders must agree exactly)."""
    model, params, x, _ = mlp_setup
    eng = scoring.PoolScoringEngine(
        model, scoring.ScoringConfig(microbatch=1024))
    order = eng.rank_confident(params, x[:2000])
    stats, _ = eng.score_host(params, x[:2000])
    host_order = sel.rank_for_machine_labeling(stats)
    np.testing.assert_array_equal(order, host_order)


@pytest.mark.parametrize("mode", ["dense", "chunked"])
@pytest.mark.parametrize("n", [512, 1000, 1537])
def test_feature_emission_matches_reference(mlp_setup, mode, n):
    """Features from the engine sweep match the host-forward reference to
    1e-5 across head modes, including a non-divisible microbatch tail
    (512 divides evenly; 1000 and 1537 leave ragged tails)."""
    model, params, x, (_, ref_feats) = mlp_setup
    eng = scoring.PoolScoringEngine(
        model, scoring.ScoringConfig(microbatch=512, head_mode=mode,
                                     vocab_chunk=8))
    feats = eng.pool_features(params, x[:n])
    assert isinstance(feats, jax.Array)   # device-resident, no host trip
    assert feats.shape == (n, ref_feats.shape[1])
    np.testing.assert_allclose(np.asarray(feats), ref_feats[:n], atol=1e-5)


def test_feature_emission_consistent_with_score(mlp_setup):
    """pool_features and score emit the same features from the same
    compiled sweep."""
    model, params, x, _ = mlp_setup
    eng = scoring.PoolScoringEngine(
        model, scoring.ScoringConfig(microbatch=512))
    _, feats_score = eng.score(params, x[:700])
    feats_only = eng.pool_features(params, x[:700])
    np.testing.assert_array_equal(np.asarray(feats_only),
                                  np.asarray(feats_score))


def test_with_features_disabled(mlp_setup):
    """with_features=False: stats still match, the feature slot is
    zero-width, and pool_features refuses loudly."""
    model, params, x, (ref_stats, _) = mlp_setup
    eng = scoring.PoolScoringEngine(
        model, scoring.ScoringConfig(microbatch=512, with_features=False))
    stats, feats = eng.score_host(params, x[:1000])
    assert feats.shape == (1000, 0)
    np.testing.assert_allclose(stats.margin, ref_stats.margin[:1000],
                               atol=1e-5)
    with pytest.raises(ValueError):
        eng.pool_features(params, x[:1000])


def test_stats_from_confidence_packing():
    conf = np.asarray([0.9, 0.1, 0.5])
    top1 = np.asarray([1, 2, 3])
    stats = scoring.stats_from_confidence(conf, num_classes=10, top1=top1)
    np.testing.assert_array_equal(stats.top1, top1)
    assert np.all(stats.max_logprob < 0)
    # more confident -> larger margin, smaller entropy, larger max_logprob
    assert stats.margin[0] > stats.margin[1]
    assert stats.entropy[0] < stats.entropy[1]
    assert stats.max_logprob[0] > stats.max_logprob[1]
