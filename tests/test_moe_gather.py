"""Int8 expert-gather (moe_gather_dtype) correctness: forward close to the
bf16 path, backward EXACT all-gather transpose — checked on a real 4-device
(data=2, model=2) mesh in a subprocess (device count must be set before
jax initializes)."""
import json
import os
import subprocess
import sys

import numpy as np

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ModelConfig, TrainConfig
from repro.configs import input_pspecs, input_specs
from repro.configs.base import ShapeConfig
from repro.models.registry import get_model
from repro.training.train_loop import init_train_state, make_sharded_train_step

from repro.compat import make_mesh
mesh = make_mesh((2, 2), ("data", "model"), axis_types=True)
base = ModelConfig(name="m", family="moe", num_layers=2, d_model=32,
                   num_heads=4, num_kv_heads=2, head_dim=8, d_ff=16,
                   vocab_size=128, num_experts=4, experts_per_token=2,
                   sharding="fsdp_tp", remat="none", dtype="float32")
shape = ShapeConfig("t", seq_len=16, global_batch=4, kind="train")
tc = TrainConfig(learning_rate=1e-2, schedule="constant")
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, 128, (4, 16)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, 128, (4, 16)), jnp.int32)}

out = {}
for mode in ("bf16", "int8"):
    cfg = base.replace(moe_gather_dtype=mode)
    model = get_model(cfg)
    bp = input_pspecs(cfg, shape, mesh, "fsdp_tp")
    step, _, _ = make_sharded_train_step(model, tc, mesh, "fsdp_tp", bp)
    state = init_train_state(model, tc, jax.random.key(0))
    losses = []
    for _ in range(4):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    out[mode] = losses
print(json.dumps(out))
"""


def test_int8_gather_trains_like_bf16():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    bf16, int8 = out["bf16"], out["int8"]
    assert all(np.isfinite(bf16)) and all(np.isfinite(int8))
    # both configurations must actually learn
    assert bf16[-1] < bf16[0] and int8[-1] < int8[0], out
    # int8 weight gathers perturb the forward slightly; training must track
    # the bf16 trajectory closely (exact backward via custom_vjp transpose)
    for a, b in zip(bf16, int8):
        assert abs(a - b) < 0.15 * abs(a) + 0.05, out
