"""Joint (|B|, theta) search + delta adaptation vs brute force.

Property-style cases run from a seeded deterministic grid so the suite is
self-contained; when ``hypothesis`` happens to be installed the same
properties are additionally fuzzed.
"""
import numpy as np
import pytest

from repro.core.cost import AMAZON, LabelingService, TrainCostModel
from repro.core.powerlaw import PowerLaw
from repro.core.search import adapt_delta, budget_search, joint_search

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False

THETAS = tuple(round(0.1 * i, 1) for i in range(1, 11))


def _laws(alpha, gamma, k, q):
    return {t: PowerLaw(alpha=alpha * t ** q, gamma=gamma, k=k)
            for t in THETAS}


def _brute_force(pool, test, cur, spent, laws, cm, delta, svc, eps):
    best = (pool * svc.price_per_label + spent, cur, 0.0)
    for B in range(cur, pool - test + 1, delta):
        grow = cm.cost_to_grow(cur, B, delta)
        for t, law in laws.items():
            S = t * (pool - test - B)
            if S / pool * law.predict(B) > eps:
                continue
            c = (pool - S) * svc.price_per_label + spent + grow
            if c < best[0]:
                best = (c, B, t)
    return best


def _check_joint_search_matches_brute_force(alpha, gamma, q, cu, cur_frac):
    pool, test = 20_000, 1_000
    cur = int(cur_frac * pool)
    delta = 500
    cur = (cur // delta) * delta or delta
    laws = _laws(alpha, gamma, 2e5, q)
    cm = TrainCostModel(c_u=cu, exponent=1)
    spent = cm.cost_from_scratch(cur, delta)
    res = joint_search(pool_size=pool, test_size=test, current_B=cur,
                       spent=spent, laws=laws, cost_model=cm, delta=delta,
                       service=AMAZON, eps_target=0.05)
    bf_cost, bf_B, bf_t = _brute_force(pool, test, cur, spent, laws, cm,
                                       delta, AMAZON, 0.05)
    assert res.cost == pytest.approx(bf_cost, rel=1e-6)
    if res.theta_opt > 0:
        assert res.B_opt == bf_B and res.theta_opt == pytest.approx(bf_t)


def _search_cases(n=25, seed=0):
    rng = np.random.default_rng(seed)
    cases = [(1.0, 0.2, 0.5, 1e-4, 0.01),    # corners of the strategy box
             (30.0, 0.7, 4.0, 1e-2, 0.3),
             (1.0, 0.7, 4.0, 1e-4, 0.3),
             (30.0, 0.2, 0.5, 1e-2, 0.01),
             (8.0, 0.45, 1.5, 4e-3, 0.1)]
    while len(cases) < n:
        cases.append((float(rng.uniform(1.0, 30.0)),
                      float(rng.uniform(0.2, 0.7)),
                      float(rng.uniform(0.5, 4.0)),
                      float(10.0 ** rng.uniform(-4, -2)),
                      float(rng.uniform(0.01, 0.3))))
    return [tuple(round(v, 6) for v in c) for c in cases]


@pytest.mark.parametrize("alpha,gamma,q,cu,cur_frac", _search_cases())
def test_joint_search_matches_brute_force(alpha, gamma, q, cu, cur_frac):
    _check_joint_search_matches_brute_force(alpha, gamma, q, cu, cur_frac)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(alpha=st.floats(1.0, 30.0), gamma=st.floats(0.2, 0.7),
           q=st.floats(0.5, 4.0), cu=st.floats(1e-4, 1e-2),
           cur_frac=st.floats(0.01, 0.3))
    def test_property_joint_search_matches_brute_force(alpha, gamma, q, cu,
                                                       cur_frac):
        _check_joint_search_matches_brute_force(alpha, gamma, q, cu, cur_frac)


def test_search_falls_back_to_human_all():
    laws = {t: PowerLaw(alpha=50.0, gamma=0.01) for t in THETAS}  # hopeless
    cm = TrainCostModel(c_u=0.05, exponent=1)
    res = joint_search(pool_size=10_000, test_size=500, current_B=500,
                       spent=25.0, laws=laws, cost_model=cm, delta=500,
                       service=AMAZON, eps_target=0.05)
    assert res.theta_opt == 0.0
    assert res.cost == pytest.approx(10_000 * 0.04 + 25.0)


def test_budget_search_respects_budget():
    laws = _laws(10.0, 0.5, 2e5, 1.5)
    cm = TrainCostModel(c_u=0.004, exponent=1)
    res = budget_search(pool_size=20_000, test_size=1_000, current_B=1_000,
                        spent=10.0, laws=laws, cost_model=cm, delta=500,
                        service=AMAZON, budget=500.0)
    assert res.cost <= 500.0 + 1e-6 or not res.feasible


def test_adapt_delta_prefers_fewest_retrains_within_slack():
    cm = TrainCostModel(c_u=0.004, exponent=1)
    d = adapt_delta(current_B=3_500, B_opt=6_000, cstar=994.0, spent=56.0,
                    pool_size=50_000, test_size=2_500,
                    machine_labeled=29_050, cost_model=cm, service=AMAZON,
                    beta=0.05)
    assert d == 2_500  # N = 1 jump fits inside (1 + beta) * C*
    assert adapt_delta(current_B=6_000, B_opt=6_000, cstar=1.0, spent=0.0,
                       pool_size=50_000, test_size=2_500, machine_labeled=0,
                       cost_model=cm, service=AMAZON) == 0
