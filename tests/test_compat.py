"""The version-adaptive JAX shim, exercised on BOTH CI matrix legs.

Everything here runs on the 0.4.37 floor and on recent jax — the same
test asserts whichever behaviour the installed version should produce,
probing via the compat module's own feature detection.  Tests that only
make sense on one side use a compat SKIP (never an xfail): a skip states
"this API legitimately does not exist here", an xfail would claim the
test is expected to break.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import compat

HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")


def test_version_tuple_parsed():
    assert len(compat.JAX_VERSION) >= 2
    assert compat.JAX_VERSION >= (0, 4, 37)


def test_tree_family_roundtrip():
    tree = {"a": jnp.arange(3), "b": [jnp.zeros(2), jnp.ones(1)]}
    leaves, treedef = compat.tree_flatten(tree)
    assert len(leaves) == 3
    rebuilt = compat.tree_unflatten(treedef, leaves)
    assert compat.tree_structure(rebuilt) == treedef
    doubled = compat.tree_map(lambda x: x * 2, tree)
    np.testing.assert_array_equal(doubled["a"], np.asarray([0, 2, 4]))


def test_tree_flatten_with_path_spellings():
    """flatten_with_path + keystr — the 0.4.x gap that motivated the shim."""
    tree = {"w": jnp.ones(2), "b": jnp.zeros(1)}
    flat = compat.tree_flatten_with_path(tree)[0]
    keys = sorted(compat.keystr(path) for path, _ in flat)
    assert keys == ["['b']", "['w']"]
    named = compat.tree_map_with_path(
        lambda path, x: compat.keystr(path), tree)
    assert named == {"w": "['w']", "b": "['b']"}


def test_make_mesh_tolerates_axis_types_everywhere():
    """axis_types=True must construct a mesh on every supported version —
    dropped on 0.4.x, defaulted Auto types on newer jax."""
    mesh = compat.make_mesh((1,), ("data",), axis_types=True)
    assert mesh.axis_names == ("data",)
    assert mesh.devices.size == 1


@pytest.mark.skipif(not HAS_AXIS_TYPES,
                    reason="jax < AxisType: explicit axis types do not "
                           "exist on this version (compat skip)")
def test_default_axis_types_modern():
    types = compat.default_axis_types(2)
    assert types == (jax.sharding.AxisType.Auto,) * 2


@pytest.mark.skipif(HAS_AXIS_TYPES,
                    reason="jax >= AxisType: legacy None-default only "
                           "applies below it (compat skip)")
def test_default_axis_types_legacy():
    assert compat.default_axis_types(2) is None


def test_shard_map_normalizes_replication_kwarg():
    """Callers use the modern check_vma spelling; the shim must translate
    for 0.4.x (check_rep) and pass through on newer jax."""
    mesh = compat.make_mesh((1,), ("data",))
    P = compat.PartitionSpec

    @compat.shard_map(mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                      check_vma=False)
    def double(x):
        return x * 2

    np.testing.assert_array_equal(double(jnp.arange(4.0)),
                                  np.arange(4.0) * 2)


def test_cost_analysis_dict_normalizes_shapes():
    """List-of-dicts (0.4.x), plain dict (newer), None, and empty list all
    normalize to one flat dict."""

    class Fake:
        def __init__(self, ret):
            self._ret = ret

        def cost_analysis(self):
            return self._ret

    assert compat.cost_analysis_dict(Fake([{"flops": 2.0}])) == \
        {"flops": 2.0}
    assert compat.cost_analysis_dict(Fake({"flops": 3.0})) == {"flops": 3.0}
    assert compat.cost_analysis_dict(Fake(None)) == {}
    assert compat.cost_analysis_dict(Fake([])) == {}


def test_cost_analysis_dict_on_real_compiled():
    """Whatever shape the installed jax returns, the shim yields a dict."""
    compiled = jax.jit(lambda x: x @ x).lower(
        jnp.ones((8, 8), jnp.float32)).compile()
    cost = compat.cost_analysis_dict(compiled)
    assert isinstance(cost, dict)
