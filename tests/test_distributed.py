"""Distributed runtime: checkpoint/restore + re-shard, compression EF,
straggler detection, loader sharding."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed import checkpoint as ckpt
from repro.distributed.compression import (compressed_psum, init_ef_state,
                                           quantize_ef, tree_compressed_psum)
from repro.distributed.straggler import StragglerMonitor
from repro.launch.mesh import make_host_mesh

from repro.compat import shard_map as _sm


def test_checkpoint_roundtrip_and_latest():
    tree = {"w": jnp.arange(24.0).reshape(4, 6),
            "opt": [{"m": jnp.ones((3,))}], "step": jnp.int32(3)}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 3, tree, extra={"note": "x"})
        ckpt.save(d, 7, tree)
        assert ckpt.latest_step(d) == 7
        restored, manifest = ckpt.restore(d, 7, tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_no_torn_dirs():
    tree = {"w": jnp.ones((2, 2))}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, tree)
        # a leftover tmp dir (simulated crash) must not be visible
        os.makedirs(os.path.join(d, "step_0000000002.tmp"))
        assert ckpt.latest_step(d) == 1


def test_checkpoint_elastic_reshard():
    """Restore a checkpoint onto a mesh with explicit shardings."""
    mesh = make_host_mesh()
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    sh = {"w": NamedSharding(mesh, P("data", None))}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 0, tree)
        restored, _ = ckpt.restore(d, 0, tree, shardings=sh)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))
        assert restored["w"].sharding == sh["w"]


def test_quantize_ef_error_feedback_unbiased():
    """EF: accumulated compressed updates converge to the true sum."""
    rng = np.random.default_rng(0)
    g = rng.normal(size=(64,)).astype(np.float32)
    residual = jnp.zeros((64,), jnp.float32)
    total = np.zeros((64,), np.float32)
    for _ in range(50):
        q, scale, residual = quantize_ef(jnp.asarray(g), residual)
        total += np.asarray(q, np.float32) * float(scale)
    np.testing.assert_allclose(total / 50, g, atol=float(np.max(np.abs(g)))
                               / 120)


def test_compressed_psum_shardmap():
    mesh = make_host_mesh()
    g = {"a": jnp.asarray(np.random.default_rng(1).normal(size=(16, 4)),
                          jnp.float32)}
    ef = init_ef_state(g)

    def body(gl, efl):
        return tree_compressed_psum(gl, efl, "data")

    out, new_ef = _sm(body, mesh=mesh, in_specs=(P(), P()),
                      out_specs=(P(), P()))(g, ef)
    err = np.max(np.abs(np.asarray(out["a"]) - np.asarray(g["a"])))
    assert err <= float(np.max(np.abs(np.asarray(g["a"])))) / 100


def test_straggler_monitor():
    events = []
    m = StragglerMonitor(min_samples=8, k_mad=4.0,
                         on_straggler=events.append)
    for i in range(20):
        m.observe(0.10 + 0.002 * (i % 3))
    ev = m.observe(0.5)
    assert ev is not None and events and events[-1].duration == 0.5
    assert m.observe(0.11) is None  # back to normal


def test_sharded_loader_epoch():
    from repro.data.loader import ShardedLoader
    data = {"x": np.arange(40).reshape(40, 1), "y": np.arange(40)}
    loader = ShardedLoader(data, global_batch=8, mesh=None, seed=0)
    seen = []
    for b in loader.epoch():
        assert b["x"].shape == (8, 1)
        seen.extend(np.asarray(b["y"]).tolist())
    assert len(seen) == 40 and set(seen) == set(range(40))
