"""ServeEngine across model families: generation runs, shapes hold, and
greedy decode matches the full-forward argmax at the first step (exercises
the per-family prefill-cache -> decode-cache loading)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models.registry import get_model
from repro.serving.engine import ServeEngine

FAMS = ["qwen2-1.5b", "mamba2-1.3b", "zamba2-2.7b", "whisper-tiny",
        "kimi-k2-1t-a32b"]


def _batch(cfg, B, T, rng):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)),
                                   jnp.int32)}
    if cfg.family == "audio":
        batch["audio_frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_tokens, cfg.d_model)),
            jnp.float32)
    if cfg.family == "vlm" and cfg.frontend_tokens:
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_tokens, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", FAMS)
def test_generate_matches_forward_first_token(arch):
    cfg = get_smoke(arch)
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    B, T, G = 2, 16, 4
    batch = _batch(cfg, B, T, rng)
    eng = ServeEngine(model, params, max_seq=T + G + 8, batch_size=B)
    out = eng.generate(batch, steps=G)
    assert out.shape == (B, G)
    assert (np.asarray(out) >= 0).all() and \
        (np.asarray(out) < cfg.vocab_size).all()
    hidden = model.forward(params, batch)
    logits = model.logits(params, hidden[:, -1:, :])
    want = np.argmax(np.asarray(logits[:, 0], np.float32), axis=-1)
    np.testing.assert_array_equal(np.asarray(out[:, 0]), want)


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "gemma3-4b"])  # untied + tied
def test_score_matches_forward_stats(arch):
    """ServeEngine.score (the machine-labeling step) == ScoreStats of the
    materialized last-position logits (fp32 head, the scoring convention)."""
    from repro.core.scoring import resolve_head_weight
    from repro.models.layers import score_stats_from_logits

    cfg = get_smoke(arch)
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(1)
    batch = _batch(cfg, 2, 12, rng)
    eng = ServeEngine(model, params, max_seq=24, batch_size=2)
    stats = eng.score(batch)
    hidden = model.forward(params, batch)
    h = hidden[:, -1, :].astype(jnp.float32)
    w = resolve_head_weight(cfg, params).astype(jnp.float32)
    ref = score_stats_from_logits(jnp.einsum("bd,dv->bv", h, w))
    np.testing.assert_allclose(np.asarray(stats.margin),
                               np.asarray(ref.margin), atol=1e-5)
    np.testing.assert_allclose(np.asarray(stats.entropy),
                               np.asarray(ref.entropy), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(stats.top1),
                                  np.asarray(ref.top1))
