"""Selection metrics M(.) / L(.) (paper §3.3).

Property-style cases run from a seeded deterministic grid so the suite is
self-contained; when ``hypothesis`` happens to be installed the same
properties are additionally fuzzed.
"""
import numpy as np
import pytest

from repro.core import selection as sel
from repro.models.layers import ScoreStats

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False


def _stats(margin, entropy=None, maxlp=None):
    n = len(margin)
    return ScoreStats(
        margin=np.asarray(margin, float),
        entropy=np.asarray(entropy if entropy is not None else np.zeros(n)),
        max_logprob=np.asarray(maxlp if maxlp is not None else -np.ones(n)),
        top1=np.zeros(n, np.int64))


def test_margin_selects_most_uncertain():
    stats = _stats(margin=[5.0, 0.1, 3.0, 0.2])
    cand = np.asarray([10, 11, 12, 13])
    pick = sel.select_for_training("margin", 2, stats=stats, candidates=cand)
    assert set(pick) == {11, 13}


def test_l_ranking_most_confident_first():
    stats = _stats(margin=[0.5, 4.0, 2.0])
    order = sel.rank_for_machine_labeling(stats)
    assert list(order) == [1, 2, 0]


def test_entropy_and_least_confidence():
    stats = _stats(margin=[1, 1, 1], entropy=[0.1, 2.0, 1.0],
                   maxlp=[-0.01, -3.0, -1.0])
    cand = np.arange(3)
    assert sel.select_for_training("entropy", 1, stats=stats,
                                   candidates=cand)[0] == 1
    assert sel.select_for_training("least_confidence", 1, stats=stats,
                                   candidates=cand)[0] == 1


def _check_selection_permutation_invariant(margins, k):
    """The selected SET is invariant to candidate permutation."""
    k = min(k, len(margins))
    stats = _stats(margins)
    cand = np.arange(len(margins))
    a = set(sel.select_for_training("margin", k, stats=stats, candidates=cand))
    rng = np.random.default_rng(0)
    perm = rng.permutation(len(margins))
    stats_p = _stats(np.asarray(margins)[perm])
    b = set(sel.select_for_training("margin", k, stats=stats_p,
                                    candidates=cand[perm]))
    assert a == b


def _margin_cases(n=30, seed=2):
    rng = np.random.default_rng(seed)
    cases = []
    while len(cases) < n:
        m = int(rng.integers(5, 41))
        margins = rng.permutation(np.round(np.linspace(0, 10, m)
                                           + rng.uniform(0, 0.01, m), 6))
        cases.append(([float(v) for v in margins], int(rng.integers(1, 6))))
    return cases


@pytest.mark.parametrize("margins,k", _margin_cases())
def test_selection_permutation_invariant(margins, k):
    _check_selection_permutation_invariant(margins, k)


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.floats(0.0, 10.0), min_size=5, max_size=40,
                    unique=True),
           st.integers(1, 5))
    def test_property_selection_permutation_invariant(margins, k):
        _check_selection_permutation_invariant(margins, k)


def test_kcenter_spreads():
    """k-center must cover both clusters; uncertainty would not see them."""
    rng = np.random.default_rng(0)
    a = rng.normal(0, 0.1, size=(50, 2))
    b = rng.normal(5, 0.1, size=(50, 2)) + 5
    feats = np.concatenate([a, b])
    rows = sel.k_center_greedy(feats, 2)
    assert (rows[0] < 50) != (rows[1] < 50)


def _brute_force_curve(stats, correct, thetas, metric="margin"):
    """Per-theta recount from first principles: stable-sort the scores,
    take the clamped top-m slice, count errors — no shared cumsum."""
    scores = sel.uncertainty_scores(metric, stats)
    order = np.argsort(scores, kind="stable")
    wrong = (~np.asarray(correct, bool))[order]
    n = len(wrong)
    out = []
    for th in thetas:
        m = min(max(int(round(th * n)), 1), n)
        out.append(float(np.mean(wrong[:m])))
    return np.asarray(out, np.float64)


# thetas exercising the clamp at both ends: 0 and tiny round to m=1,
# 1.0 is exact, and >1.0 (plus rounding slop) must clamp to m=n.
CLAMP_THETAS = (0.0, 1e-9, 0.007, 0.5, 1.0, 1.004, 1.37)


def _curve_cases(n_cases=12, seed=7):
    rng = np.random.default_rng(seed)
    cases = []
    for metric in sel.UNCERTAINTY_METRICS:
        for n in (1, 7, 40, 400):
            cases.append((int(rng.integers(0, 2 ** 31)), n, metric))
    rng.shuffle(cases)
    return cases[:n_cases] + [(0, 1, "margin"), (1, 400, "entropy")]


@pytest.mark.parametrize("seed,n,metric", _curve_cases())
def test_error_curve_matches_brute_force_recount(seed, n, metric):
    """Property grid: the cumsum-based curve equals a per-theta recount,
    for every metric, across the clamp-exercising theta set — including
    quantized scores that force stable-sort tie handling."""
    rng = np.random.default_rng(seed)
    # quantized scores -> deliberate exact ties in the ranking
    margin = np.round(rng.uniform(0, 3, n), 1)
    entropy = np.round(rng.uniform(0, 2, n), 1)
    maxlp = -np.round(rng.uniform(0.01, 3, n), 1)
    stats = _stats(margin, entropy=entropy, maxlp=maxlp)
    correct = rng.uniform(size=n) < 0.7
    curve = sel.machine_label_error_curve(stats, correct, CLAMP_THETAS,
                                          metric)
    expect = _brute_force_curve(stats, correct, CLAMP_THETAS, metric)
    np.testing.assert_allclose(curve, expect, rtol=0, atol=1e-12)


def test_error_curve_theta_clamping():
    """theta=0 / tiny clamp up to the single most-confident sample;
    theta >= 1 (and >1 from rounding) clamp down to the full set."""
    n = 10
    margin = np.linspace(5, 0.5, n)       # row 0 most confident
    correct = np.zeros(n, bool)
    correct[0] = True                      # only the top-1 row is right
    stats = _stats(margin)
    curve = sel.machine_label_error_curve(
        stats, correct, [0.0, 1e-9, 1.0, 1.7])
    assert curve[0] == 0.0 and curve[1] == 0.0      # m clamped to 1
    assert curve[2] == curve[3] == pytest.approx(0.9)  # m clamped to n


def test_error_curve_stable_tie_ranking():
    """Equal scores keep input order (stable sort): with all margins tied,
    the top-theta slice is exactly the input prefix."""
    n = 8
    stats = _stats(np.full(n, 2.0))
    order = sel.rank_for_machine_labeling(stats)
    np.testing.assert_array_equal(order, np.arange(n))  # ties -> input order
    correct = np.asarray([1, 1, 0, 1, 0, 0, 1, 0], bool)
    curve = sel.machine_label_error_curve(stats, correct, [0.25, 0.5, 1.0])
    np.testing.assert_allclose(curve, [0.0, 0.25, 0.5])


def test_error_curve_monotone_under_perfect_ranking():
    """With margin perfectly anti-correlated with error, the top-theta
    error curve is non-decreasing in theta."""
    n = 400
    margin = np.linspace(2, 0, n)
    correct = np.ones(n, bool)
    correct[-n // 4:] = False  # errors concentrated at low margin
    stats = _stats(margin)
    curve = sel.machine_label_error_curve(stats, correct,
                                          [0.25, 0.5, 0.75, 1.0])
    assert np.all(np.diff(curve) >= -1e-12)
    assert curve[0] == 0.0 and curve[-1] == pytest.approx(0.25)
