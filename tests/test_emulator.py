"""Emulator invariants: the measured error curves must follow the
generating truncated power-law family (MCAL's measurement machinery is
only as meaningful as this holds)."""
import numpy as np
import pytest

from repro.core.emulator import EmulatedTask, make_emulated_task
from repro.core.powerlaw import PowerLaw
from repro.core.selection import machine_label_error_curve


def _measured_curve(task, B, thetas, seed=0):
    rng = np.random.default_rng(seed)
    T_idx = rng.choice(task.pool_size, 4000, replace=False)
    train = rng.choice(np.setdiff1d(np.arange(task.pool_size), T_idx), B,
                       replace=False)
    task.train(train, task.human_label(train))
    stats, _ = task.score(T_idx)
    correct = task.eval_correct(T_idx, task.human_label(T_idx))
    return machine_label_error_curve(stats, correct, thetas)


def test_full_pool_error_follows_law():
    task = make_emulated_task("cifar10", "resnet18", seed=0)
    law = task.law
    for B in (2000, 8000, 20000):
        curve = _measured_curve(task, B, [1.0])
        want = float(law.predict(B))
        assert curve[0] == pytest.approx(want, rel=0.15), (B, curve[0], want)


def test_theta_concentration_exponent():
    """eps_theta ~ eps_full * theta^q by construction."""
    task = make_emulated_task("cifar10", "resnet18", seed=1)
    thetas = [0.25, 0.5, 1.0]
    curve = _measured_curve(task, 8000, thetas, seed=1)
    q = task.q
    for th, e in zip(thetas, curve):
        want = float(task.law.predict(8000)) * th ** q
        assert e == pytest.approx(want, rel=0.3, abs=5e-3), (th, e, want)


def test_deterministic_per_B():
    """Scoring/prediction draws are stable for a fixed trained size."""
    t1 = make_emulated_task("fashion", "resnet18", seed=3)
    t2 = make_emulated_task("fashion", "resnet18", seed=3)
    idx = np.arange(500)
    for t in (t1, t2):
        t.train(np.arange(1000, 3000), t.human_label(np.arange(1000, 3000)))
    np.testing.assert_array_equal(t1.predict(idx), t2.predict(idx))
    s1, _ = t1.score(idx)
    s2, _ = t2.score(idx)
    np.testing.assert_allclose(np.asarray(s1.margin), np.asarray(s2.margin))


def test_training_cost_is_linear_in_B():
    task = make_emulated_task("cifar100", "resnet50", seed=0)
    c1 = task.train(np.arange(1000), task.labels_gt[:1000])
    c2 = task.train(np.arange(2000), task.labels_gt[:2000])
    assert c2 == pytest.approx(2 * c1)
