"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.margin_head import margin_head
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels import ref
from repro.models.layers import score_stats_from_logits
from repro.models.mamba2 import ssd_chunked

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("T,D,V,bt,bv", [
    (128, 64, 512, 64, 256),
    (200, 48, 1000, 64, 128),    # ragged T and V
    (65, 32, 257, 32, 128),      # tiny + prime-ish V
    (256, 128, 4096, 128, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_margin_head_sweep(T, D, V, bt, bv, dtype):
    h = jnp.asarray(RNG.normal(size=(T, D)), dtype)
    w = jnp.asarray(RNG.normal(size=(D, V)) * 0.1, dtype)
    m, e, mlp, t1 = margin_head(h, w, bt=bt, bv=bv, interpret=True)
    rm, re, rmlp, rt1 = ref.margin_head_ref(h, w)
    tol = 5e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(m), np.asarray(rm), atol=tol,
                               rtol=tol)
    np.testing.assert_allclose(np.asarray(e), np.asarray(re), atol=tol * 10,
                               rtol=tol * 10)
    np.testing.assert_allclose(np.asarray(mlp), np.asarray(rmlp), atol=tol,
                               rtol=tol)
    if dtype == jnp.float32:
        assert (np.asarray(t1) == np.asarray(rt1)).all()


@pytest.mark.parametrize("B,H,Hk,Tq,Tk,hd,causal,window", [
    (2, 4, 2, 128, 128, 32, True, 0),
    (1, 4, 4, 96, 96, 16, True, 0),
    (2, 8, 2, 64, 64, 32, True, 24),     # sliding window
    (1, 2, 1, 50, 130, 16, False, 0),    # cross-attention shape
    (1, 6, 3, 33, 77, 8, True, 0),       # ragged
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, Hk, Tq, Tk, hd, causal, window, dtype):
    q = jnp.asarray(RNG.normal(size=(B, H, Tq, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Hk, Tk, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Hk, Tk, hd)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, bq=32,
                          bk=32, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 5e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol)


@pytest.mark.parametrize("B,T,H,hd,N,C", [
    (2, 128, 4, 16, 32, 64),
    (1, 96, 2, 8, 16, 32),      # ragged T vs chunk
    (2, 64, 8, 32, 64, 64),
    (1, 256, 4, 64, 128, 128),
])
def test_ssd_scan_sweep(B, T, H, hd, N, C):
    xh = jnp.asarray(RNG.normal(size=(B, T, H, hd)), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.normal(size=(B, T, H))) * 0.5 + 0.01,
                     jnp.float32)
    A = jnp.asarray(np.abs(RNG.normal(size=(H,))) * 0.5 + 0.1, jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, T, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(B, T, N)), jnp.float32)
    y, h = ssd_scan(xh, dt, A, Bm, Cm, chunk=C, interpret=True)
    yr, hr = ssd_chunked(xh, dt, A, Bm, Cm, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-3,
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=2e-3,
                               atol=2e-3)


def test_ops_dispatch():
    """ops.score_head must agree between forced kernel and forced ref."""
    from repro.kernels import ops
    h = jnp.asarray(RNG.normal(size=(64, 32)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(32, 300)) * 0.1, jnp.float32)
    a = ops.score_head(h, w, force_pallas=True)
    b = ops.score_head(h, w, force_pallas=False)
    np.testing.assert_allclose(np.asarray(a.margin), np.asarray(b.margin),
                               atol=1e-4)
    assert (np.asarray(a.top1) == np.asarray(b.top1)).all()


# -- REPRO_USE_PALLAS normalization ------------------------------------------
# regression for the silent-fallback bug: unrecognized spellings used to
# fall through to False, quietly running the jnp reference path on a
# host that had asked for kernels.

@pytest.mark.parametrize("raw", [
    "1", "true", "yes", "on", "TRUE", "Yes", "ON", " true ", "\ton\t",
])
def test_use_pallas_truthy(monkeypatch, raw):
    from repro.kernels import ops
    monkeypatch.setenv("REPRO_USE_PALLAS", raw)
    assert ops.use_pallas() is True


@pytest.mark.parametrize("raw", [
    "0", "false", "no", "off", "FALSE", "No", "OFF", " false ", "\toff\t",
])
def test_use_pallas_falsy(monkeypatch, raw):
    from repro.kernels import ops
    monkeypatch.setenv("REPRO_USE_PALLAS", raw)
    assert ops.use_pallas() is False


@pytest.mark.parametrize("raw", [None, "", "  ", "auto", "AUTO", " Auto "])
def test_use_pallas_auto_follows_backend(monkeypatch, raw):
    """Unset, exported-but-empty, and every 'auto' spelling all mean the
    same thing: kernels iff the backend is a TPU."""
    from repro.kernels import ops
    if raw is None:
        monkeypatch.delenv("REPRO_USE_PALLAS", raising=False)
    else:
        monkeypatch.setenv("REPRO_USE_PALLAS", raw)
    assert ops.use_pallas() is (jax.default_backend() == "tpu")


@pytest.mark.parametrize("raw", ["ture", "2", "enable", "y", "n", "none"])
def test_use_pallas_rejects_unrecognized(monkeypatch, raw):
    from repro.kernels import ops
    monkeypatch.setenv("REPRO_USE_PALLAS", raw)
    with pytest.raises(ValueError, match="REPRO_USE_PALLAS"):
        ops.use_pallas()
