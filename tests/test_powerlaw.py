"""Truncated power-law model (paper Eqn. 3): fit recovery + properties.

Property-style cases run from a seeded deterministic grid so the suite is
self-contained; when ``hypothesis`` happens to be installed the same
properties are additionally fuzzed.
"""
import numpy as np
import pytest

from repro.core.powerlaw import EPS_FLOOR, PowerLaw, fit_power_law, required_size

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False

SIZES = np.asarray([200, 500, 1000, 2000, 4000, 8000, 16000, 32000], float)


def test_exact_recovery_noiseless():
    true = PowerLaw(alpha=4.0, gamma=0.45, k=2e4)
    fit = fit_power_law(SIZES, true.predict(SIZES))
    np.testing.assert_allclose(fit.alpha, true.alpha, rtol=1e-6)
    np.testing.assert_allclose(fit.gamma, true.gamma, rtol=1e-6)
    np.testing.assert_allclose(fit.k, true.k, rtol=1e-5)


def test_plain_power_law_recovery():
    true = PowerLaw(alpha=2.0, gamma=0.3)
    fit = fit_power_law(SIZES, true.predict(SIZES), truncated=False)
    np.testing.assert_allclose(fit.alpha, 2.0, rtol=1e-6)
    np.testing.assert_allclose(fit.gamma, 0.3, rtol=1e-6)
    assert np.isinf(fit.k)


def test_noisy_recovery_within_tolerance():
    rng = np.random.default_rng(0)
    true = PowerLaw(alpha=9.0, gamma=0.5, k=2e5)
    errs = true.predict(SIZES) * np.exp(rng.normal(0, 0.05, len(SIZES)))
    fit = fit_power_law(SIZES, errs)
    pred = fit.predict(50_000)
    assert abs(pred - true.predict(50_000)) / true.predict(50_000) < 0.4


def test_truncated_beats_plain_at_extrapolation():
    rng = np.random.default_rng(1)
    true = PowerLaw(alpha=4.0, gamma=0.4, k=3e4)  # strong falloff
    rel_t, rel_p = [], []
    for s in range(10):
        rng = np.random.default_rng(s)
        errs = true.predict(SIZES) * np.exp(rng.normal(0, 0.03, len(SIZES)))
        t = fit_power_law(SIZES, errs, truncated=True).predict(60_000)
        p = fit_power_law(SIZES, errs, truncated=False).predict(60_000)
        tgt = true.predict(60_000)
        rel_t.append(abs(t - tgt) / tgt)
        rel_p.append(abs(p - tgt) / tgt)
    assert np.mean(rel_t) < np.mean(rel_p)


def test_degenerate_few_points():
    one = fit_power_law([1000], [0.2])
    assert one.predict(5000) == pytest.approx(0.2)
    two = fit_power_law([1000, 4000], [0.2, 0.1])
    assert two.gamma >= 0
    assert two.predict(8000) <= 0.11


def test_eps_floor():
    fit = fit_power_law(SIZES, np.zeros_like(SIZES))
    assert np.all(fit.predict(SIZES) >= EPS_FLOOR / 10)


def _check_fit_recovers_family(alpha, gamma, logk):
    """Noiseless members of the family are fixed points of the fit."""
    true = PowerLaw(alpha=alpha, gamma=gamma, k=10.0 ** logk)
    y = true.predict(SIZES)
    if np.any(y < EPS_FLOOR * 10):  # floor clips the signal; skip
        return
    fit = fit_power_law(SIZES, y)
    np.testing.assert_allclose(fit.predict(SIZES), y, rtol=1e-4)


def _family_cases(n=60, seed=0):
    rng = np.random.default_rng(seed)
    cases = [(0.1, 0.0, 3.5), (50.0, 1.0, 7.0), (1.0, 0.5, 5.0),
             (0.1, 1.0, 3.5), (50.0, 0.0, 7.0), (10.0, 0.3, 4.2)]
    while len(cases) < n:
        cases.append((float(rng.uniform(0.1, 50)),
                      float(rng.uniform(0.0, 1.0)),
                      float(rng.uniform(3.5, 7.0))))
    return [tuple(round(v, 6) for v in c) for c in cases]


@pytest.mark.parametrize("alpha,gamma,logk", _family_cases())
def test_fit_recovers_family(alpha, gamma, logk):
    _check_fit_recovers_family(alpha, gamma, logk)


def _check_prediction_monotone_nonincreasing(errs):
    """Fitted family is always monotone non-increasing in n."""
    sizes = SIZES[: len(errs)]
    fit = fit_power_law(sizes, errs)
    grid = np.linspace(sizes[0], sizes[-1] * 4, 64)
    pred = fit.predict(grid)
    assert np.all(np.diff(pred) <= 1e-12)


def _err_list_cases(n=40, seed=1):
    rng = np.random.default_rng(seed)
    cases = [
        [0.9, 0.9, 0.9, 0.9],                       # flat
        [0.9, 0.5, 0.3, 0.2, 0.15, 0.12, 0.11, 0.1],  # clean decay
        [0.01, 0.9, 0.01, 0.9],                     # adversarial zig-zag
        [0.5, 0.6, 0.7, 0.8],                       # increasing (fit must clip)
    ]
    while len(cases) < n:
        m = int(rng.integers(4, 9))
        cases.append([float(v) for v in
                      np.round(rng.uniform(0.01, 0.9, m), 6)])
    return cases


@pytest.mark.parametrize("errs", _err_list_cases())
def test_prediction_monotone_nonincreasing(errs):
    _check_prediction_monotone_nonincreasing(errs)


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(alpha=st.floats(0.1, 50), gamma=st.floats(0.0, 1.0),
           logk=st.floats(3.5, 7.0))
    def test_property_fit_recovers_family(alpha, gamma, logk):
        _check_fit_recovers_family(alpha, gamma, logk)

    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.floats(0.01, 0.9), min_size=4, max_size=8))
    def test_property_prediction_monotone_nonincreasing(errs):
        _check_prediction_monotone_nonincreasing(errs)


def test_required_size_bisection():
    law = PowerLaw(alpha=4.0, gamma=0.5, k=1e6)
    n = required_size(law, 0.05)
    assert law.predict(n) <= 0.05 <= law.predict(n * 0.9)
    assert required_size(law, 1e-12, n_max=1e6) == np.inf
