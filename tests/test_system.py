"""End-to-end system tests: live MCAL over a real JAX classifier, the
fault-tolerant trainer, the serving engine, and the sharded train step on
the host mesh."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import TrainConfig
from repro.core import AMAZON, LiveTask, MCALConfig, run_mcal
from repro.data.synth import make_classification, make_lm_tokens
from repro.models.registry import get_model


def test_live_mcal_end_to_end():
    """A real MLP classifier trained by the framework's own train loop
    labels a synthetic pool within the error bound, cheaper than humans."""
    x, y = make_classification(3000, num_classes=10, dim=32,
                               difficulty=0.25, seed=0)
    task = LiveTask(features=x, groundtruth=y, num_classes=10, epochs=30,
                    c_u_nominal=2e-4, seed=0)
    res = run_mcal(task, AMAZON, MCALConfig(seed=0, delta0_frac=0.02,
                                            max_iters=25))
    assert res.measured_error <= 0.05 + 0.01
    assert res.total_cost < 3000 * 0.04
    assert res.S_size > 0  # actually machine-labeled something


def test_trainer_checkpoints_and_resumes():
    cfg = get_smoke("qwen2-1.5b")
    model = get_model(cfg)
    tc = TrainConfig(learning_rate=1e-2, schedule="constant", total_steps=8)
    toks = make_lm_tokens(64, 33, cfg.vocab_size, seed=0)
    data = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    from repro.data.loader import ShardedLoader
    from repro.training.trainer import Trainer, TrainerConfig

    with tempfile.TemporaryDirectory() as d:
        tcfg = TrainerConfig(ckpt_dir=d, ckpt_every=2, max_steps=4,
                             log_every=0)
        tr = Trainer(model, tc, tcfg, mesh=None, seed=0,
                     log_fn=lambda *_: None)
        loader = ShardedLoader(data, 8, seed=0)

        def batches():
            while True:
                yield from loader.epoch()

        tr.fit(batches())
        assert tr.step == 4
        # simulate preemption: new trainer resumes from step 4
        tcfg2 = TrainerConfig(ckpt_dir=d, ckpt_every=2, max_steps=6,
                              log_every=0)
        tr2 = Trainer(model, tc, tcfg2, mesh=None, seed=1,
                      log_fn=lambda *_: None)
        assert tr2.step == 4
        tr2.fit(batches())
        assert tr2.step == 6


def test_serve_engine_greedy_matches_forward():
    cfg = get_smoke("qwen2-1.5b")
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    B, T = 2, 16
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)),
                                   jnp.int32)}
    from repro.serving.engine import ServeEngine
    eng = ServeEngine(model, params, max_seq=T + 8, batch_size=B)
    out = eng.generate(batch, steps=3)
    assert out.shape == (B, 3)
    # first generated token == argmax of the full forward at position T-1
    hidden = model.forward(params, batch)
    logits = model.logits(params, hidden[:, -1:, :])
    want = np.argmax(np.asarray(logits[:, 0]), axis=-1)
    np.testing.assert_array_equal(np.asarray(out[:, 0]), want)


def test_sharded_train_step_on_host_mesh():
    """The pjit path lowers + runs on whatever devices exist (1 CPU)."""
    from repro.configs import input_pspecs, input_specs
    from repro.configs.base import ShapeConfig
    from repro.launch.mesh import make_host_mesh
    from repro.training.train_loop import make_sharded_train_step

    cfg = get_smoke("qwen2-1.5b").replace(sharding="fsdp_tp")
    model = get_model(cfg)
    mesh = make_host_mesh()
    shape = ShapeConfig("t", seq_len=32, global_batch=4, kind="train")
    tc = TrainConfig(learning_rate=1e-2, schedule="constant")
    bp = input_pspecs(cfg, shape, mesh, "fsdp_tp")
    step, ab_state, state_sh = make_sharded_train_step(
        model, tc, mesh, "fsdp_tp", bp)
    # real execution
    from repro.training.train_loop import init_train_state
    state = init_train_state(model, tc, jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)),
                                   jnp.int32)}
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))


def test_label_pool_persistence():
    from repro.data.pool import HUMAN, MACHINE, TEST, TRAIN, LabelPool
    p = LabelPool(100)
    p.mark(np.arange(5), TEST, labels=np.arange(5))
    p.mark(np.arange(5, 20), TRAIN, labels=np.zeros(15, np.int64))
    assert p.counts()["unlabeled"] == 80
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "pool.npz")
        p.save(path)
        q = LabelPool.load(path)
        np.testing.assert_array_equal(p.state, q.state)
        np.testing.assert_array_equal(p.labels, q.labels)
