"""Roofline analytic model validated against XLA cost_analysis on small
UNROLLED configs (scan bodies are counted once by HloCostAnalysis, so the
validation must unroll — see launch/roofline.py docstring)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, cells, get_config
from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.compat import cost_analysis_dict
from repro.launch.roofline import analyze_cell, forward_flops, param_counts
from repro.models.registry import get_model


def _xla_flops(cfg, B, T, train: bool):
    model = get_model(cfg)
    if train:
        from repro.training.train_loop import init_train_state, make_train_step
        tc = TrainConfig()
        step = make_train_step(model, tc, jit=True)
        state = jax.eval_shape(
            lambda: init_train_state(model, tc, jax.random.key(0)))
        batch = {"tokens": jax.ShapeDtypeStruct((B, T), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((B, T), jnp.int32)}
        lowered = step.lower(state, batch)
    else:
        params = model.abstract_params()
        fn = jax.jit(lambda p, t: model.forward(p, {"tokens": t}))
        lowered = fn.lower(params, jax.ShapeDtypeStruct((B, T), jnp.int32))
    return cost_analysis_dict(lowered.compile()).get("flops", 0.0)


@pytest.mark.parametrize("nl,d,h,ff,v", [(4, 256, 4, 1024, 1024),
                                         (2, 128, 2, 512, 512)])
def test_forward_flops_matches_xla_unrolled(nl, d, h, ff, v):
    cfg = ModelConfig(name="probe", num_layers=nl, d_model=d, num_heads=h,
                      num_kv_heads=h, d_ff=ff, vocab_size=v,
                      scan_layers=False, remat="none", dtype="float32")
    B, T = 4, 128
    got = _xla_flops(cfg, B, T, train=False)
    # forward + full-seq logits head
    want = forward_flops(cfg, B * T, (T + 1) / 2, with_head_tokens=0)
    # XLA counts the body matmuls; allow 20% for fusions/softmax/etc.
    assert got == pytest.approx(want, rel=0.2), (got, want)


def test_train_flops_roughly_3x_forward_no_remat():
    cfg = ModelConfig(name="probe", num_layers=2, d_model=128, num_heads=2,
                      num_kv_heads=2, d_ff=512, vocab_size=512,
                      scan_layers=False, remat="none", dtype="float32")
    B, T = 4, 128
    fwd = _xla_flops(cfg, B, T, train=False)
    train = _xla_flops(cfg, B, T, train=True)
    assert 2.0 <= train / fwd <= 4.0, train / fwd


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_counts_match_model(arch):
    cfg = get_config(arch)
    pc = param_counts(cfg)
    exact = get_model(cfg).param_count()
    assert pc.total == pytest.approx(exact, rel=0.02), (pc.total, exact)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_roofline_table_well_formed(arch):
    cfg = get_config(arch)
    for shape in cells(arch):
        for mesh in ("single", "multi"):
            r = analyze_cell(cfg, shape, mesh)
            assert r.compute_s > 0 and r.memory_s > 0
            assert np.isfinite(r.collective_s)
            assert 0 < r.useful_ratio <= 1.05, (arch, shape.name,
                                                r.useful_ratio)
            assert r.dominant in ("compute", "memory", "collective")
