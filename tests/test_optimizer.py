"""AdamW vs reference math + memory-lever variants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.training import optimizer as opt


def _np_adamw(p, g, m, v, t, lr, tc):
    m = tc.beta1 * m + (1 - tc.beta1) * g
    v = tc.beta2 * v + (1 - tc.beta2) * g * g
    mh = m / (1 - tc.beta1 ** t)
    vh = v / (1 - tc.beta2 ** t)
    upd = mh / (np.sqrt(vh) + tc.eps)
    if p.ndim >= 2:
        upd = upd + tc.weight_decay * p
    return p - lr * upd, m, v


def test_adamw_matches_reference_math():
    tc = TrainConfig(weight_decay=0.01)
    rng = np.random.default_rng(0)
    p0 = rng.normal(size=(8, 16)).astype(np.float32)
    params = {"w": jnp.asarray(p0)}
    slots = opt.init_slots([params["w"]], tc)
    m = np.zeros_like(p0)
    v = np.zeros_like(p0)
    p_ref = p0.copy()
    for t in range(1, 4):
        g = rng.normal(size=p0.shape).astype(np.float32)
        params, slots = opt.adamw_update(
            params, {"w": jnp.asarray(g)}, slots, jnp.int32(t - 1),
            jnp.float32(1e-2), tc)
        p_ref, m, v = _np_adamw(p_ref, g, m, v, t, 1e-2, tc)
    np.testing.assert_allclose(np.asarray(params["w"]), p_ref, rtol=2e-5,
                               atol=2e-6)


def test_factored_second_moment_close_to_full():
    """Adafactor-style v must track full v within a modest factor."""
    tc_full = TrainConfig()
    tc_fac = TrainConfig(factored_second_moment=True)
    rng = np.random.default_rng(1)
    p = {"w": jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)}
    sf = opt.init_slots([p["w"]], tc_full)
    sa = opt.init_slots([p["w"]], tc_fac)
    pf, pa = p, p
    for t in range(5):
        g = {"w": jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)}
        pf, sf = opt.adamw_update(pf, g, sf, jnp.int32(t), jnp.float32(1e-2),
                                  tc_full)
        pa, sa = opt.adamw_update(pa, g, sa, jnp.int32(t), jnp.float32(1e-2),
                                  tc_fac)
    # same direction, bounded deviation
    d_full = np.asarray(pf["w"]) - np.asarray(p["w"])
    d_fac = np.asarray(pa["w"]) - np.asarray(p["w"])
    cos = np.sum(d_full * d_fac) / (
        np.linalg.norm(d_full) * np.linalg.norm(d_fac))
    assert cos > 0.9
    assert "vr" in sa[0] and "vc" in sa[0] and "v" not in sa[0]


def test_int8_moment_roundtrip():
    tc = TrainConfig(moment_dtype="int8")
    rng = np.random.default_rng(2)
    p = {"w": jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)}
    slots = opt.init_slots([p["w"]], tc)
    assert slots[0]["m_q"].dtype == jnp.int8
    g = {"w": jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)}
    p2, slots = opt.adamw_update(p, g, slots, jnp.int32(0), jnp.float32(1e-2),
                                 tc)
    m_true = 0.1 * np.asarray(g["w"])
    m_q = np.asarray(opt.dequantize_int8(
        {"q": slots[0]["m_q"], "scale": slots[0]["m_scale"]}))
    np.testing.assert_allclose(m_q, m_true, atol=float(np.max(np.abs(m_true)))
                               / 100)


def test_clip_by_global_norm():
    g = {"a": jnp.ones((3,)) * 3.0, "b": jnp.ones((4,)) * 4.0}
    clipped, norm = opt.clip_by_global_norm(g, 1.0)
    total = np.sqrt(sum(np.sum(np.asarray(l) ** 2)
                        for l in jax.tree.leaves(clipped)))
    assert norm == pytest.approx(np.sqrt(9 * 3 + 16 * 4))
    assert total == pytest.approx(1.0, rel=1e-5)


def test_slot_spec_shapes_match_init():
    tc = TrainConfig(moment_dtype="int8", factored_second_moment=True)
    shape = (12, 24, 48)
    spec = opt.slot_spec(shape, (None, None, None), tc)
    assert spec["vr"][0] == (12, 24) and spec["vc"][0] == (12, 48)
    slots = opt.init_slots([jnp.zeros(shape)], tc)
    for k, (sh, dt, _) in spec.items():
        assert slots[0][k].shape == sh and slots[0][k].dtype == dt
