"""MCAL driver: emulated end-to-end campaigns, invariants, variants."""
import json

import numpy as np
import pytest

from repro.core import (AMAZON, SATYAM, MCALCampaign, MCALConfig,
                        make_emulated_task, run_mcal, select_architecture)
from repro.core.baselines import run_naive_al
from repro.core.emulator import DATASETS


@pytest.mark.parametrize("ds", ["fashion", "cifar10", "cifar100"])
@pytest.mark.parametrize("seed", [0, 1])
def test_campaign_meets_error_and_beats_human(ds, seed):
    task = make_emulated_task(ds, "resnet18", seed=seed)
    res = run_mcal(task, AMAZON, MCALConfig(seed=seed))
    assert res.measured_error <= 0.05 + 0.005, res.measured_error
    assert res.total_cost < task.pool_size * 0.04
    # every sample got a label
    assert (res.labels >= 0).all()


def test_campaign_beats_naive_al():
    """The paper's headline: cheaper than AL at ANY tested delta."""
    mcal = run_mcal(make_emulated_task("cifar10", "resnet18", seed=0),
                    AMAZON, MCALConfig(seed=0))
    for d in (0.033, 0.067, 0.10):
        al = run_naive_al(make_emulated_task("cifar10", "resnet18", seed=0),
                          AMAZON, d)
        assert mcal.total_cost <= al.cost * 1.001, (d, al.cost)


def test_imagenet_bails_out_with_bounded_tax():
    task = make_emulated_task("imagenet", "efficientnet-b0", seed=0)
    res = run_mcal(task, AMAZON, MCALConfig(seed=0))
    human_all = task.pool_size * 0.04
    assert res.decision == "human_all"
    assert res.ledger["training"] <= 0.15 * human_all
    assert res.measured_error == 0.0  # everything human-labeled


def test_budget_variant_spends_within_budget_and_error_decreases():
    errs = []
    for budget in (600.0, 1200.0):
        task = make_emulated_task("cifar10", "resnet18", seed=0)
        res = run_mcal(task, AMAZON, MCALConfig(seed=0, budget=budget))
        assert res.total_cost <= budget * 1.001
        errs.append(res.measured_error)
    assert errs[1] <= errs[0]


def test_arch_selection_picks_res18():
    tasks = {a: make_emulated_task("cifar10", a, seed=0)
             for a in ("cnn18", "resnet18", "resnet50")}
    winner, res, hist = select_architecture(tasks, AMAZON, MCALConfig(seed=0))
    assert winner == "resnet18"
    assert res.measured_error <= 0.055
    assert set(hist) == set(tasks)


def test_satyam_cheaper_labels_still_meet_constraint():
    task = make_emulated_task("cifar10", "resnet18", seed=3)
    res = run_mcal(task, SATYAM, MCALConfig(seed=3))
    assert res.measured_error <= 0.055
    assert res.total_cost < task.pool_size * 0.003


def test_campaign_checkpoint_resume_mid_loop():
    """Preempt after a few iterations; the resumed campaign must finish
    with identical economics (deterministic emulator)."""
    cfg = MCALConfig(seed=0)

    def fresh():
        return MCALCampaign(make_emulated_task("cifar10", "resnet18", seed=0),
                            AMAZON, cfg)

    ref = fresh()
    ref.bootstrap()
    for _ in range(3):
        ref.iteration()
    blob = json.dumps(ref.state_dict())  # must be JSON-serializable

    resumed = fresh()
    resumed.load_state_dict(json.loads(blob))
    while not ref.done:
        ref.iteration()
    while not resumed.done:
        resumed.iteration()
    a, b = ref.commit(), resumed.commit()
    assert a.total_cost == pytest.approx(b.total_cost, rel=1e-9)
    assert a.S_size == b.S_size and a.B_size == b.B_size


def test_resume_after_bailout_keeps_decision():
    """Regression: state_dict used to drop decision/B_opt/theta_opt/
    freeze_delta — a campaign resumed after bail-out forgot it chose
    human_all and would happily keep iterating."""
    ref = MCALCampaign(make_emulated_task("imagenet", "efficientnet-b0",
                                          seed=0), AMAZON, MCALConfig(seed=0))
    ref.bootstrap()
    while not ref.done:
        ref.iteration()
    assert ref.decision == "human_all"
    blob = json.dumps(ref.state_dict())

    resumed = MCALCampaign(make_emulated_task("imagenet", "efficientnet-b0",
                                              seed=0), AMAZON,
                           MCALConfig(seed=0))
    resumed.load_state_dict(json.loads(blob))
    assert resumed.done and resumed.decision == "human_all"
    assert resumed.B_opt == ref.B_opt
    assert resumed.theta_opt == ref.theta_opt
    assert resumed.freeze_delta == ref.freeze_delta
    a, b = ref.commit(), resumed.commit()
    assert a.decision == b.decision == "human_all"
    assert a.total_cost == pytest.approx(b.total_cost, rel=1e-9)
    assert b.measured_error == 0.0


def test_kcenter_campaign_resume_picks_identical_candidates():
    """k-center anchor state is rebuilt from B_idx on load (one feature
    sweep), so a resumed kcenter campaign must pick the identical
    candidate sequence as the uninterrupted one."""
    cfg = MCALConfig(seed=0, metric="kcenter", max_iters=6)

    def fresh():
        return MCALCampaign(
            make_emulated_task("cifar10", "resnet18", seed=0,
                               pool_size=4000), AMAZON, cfg)

    ref = fresh()
    ref.bootstrap()
    for _ in range(2):
        ref.iteration()
    blob = json.dumps(ref.state_dict())

    resumed = fresh()
    resumed.load_state_dict(json.loads(blob))
    assert resumed._anchor_feats is not None   # rebuilt on load
    while not ref.done:
        ref.iteration()
    while not resumed.done:
        resumed.iteration()
    np.testing.assert_array_equal(ref.pool.B_idx, resumed.pool.B_idx)
    a, b = ref.commit(), resumed.commit()
    assert a.total_cost == pytest.approx(b.total_cost, rel=1e-9)
    assert a.S_size == b.S_size


def test_async_sweep_campaign_matches_sync():
    """sweep_async overlaps the M(.) sweep with the host-side fits/search;
    prefix-stable rankings make it acquisition-identical to the
    synchronous campaign."""
    from repro.core import LiveTask
    from repro.data.synth import make_classification

    x, y = make_classification(800, num_classes=10, dim=16,
                               difficulty=0.3, seed=4)

    def run_campaign(sweep_async):
        task = LiveTask(features=x, groundtruth=y, num_classes=10,
                        epochs=3, seed=4, sweep_page=256,
                        score_microbatch=256)
        camp = MCALCampaign(task, AMAZON,
                            MCALConfig(seed=4, max_iters=3,
                                       delta0_frac=0.02,
                                       sweep_async=sweep_async))
        camp.bootstrap()
        while not camp.done:
            camp.iteration()
        return camp

    sync, async_ = run_campaign(False), run_campaign(True)
    np.testing.assert_array_equal(sync.pool.B_idx, async_.pool.B_idx)
    a, b = sync.commit(), async_.commit()
    assert a.total_cost == pytest.approx(b.total_cost, rel=1e-9)
    np.testing.assert_array_equal(a.labels, b.labels)


def test_relaxed_eps_saves_more():
    t5 = run_mcal(make_emulated_task("cifar10", "resnet18", seed=0), AMAZON,
                  MCALConfig(seed=0, eps_target=0.05))
    t10 = run_mcal(make_emulated_task("cifar10", "resnet18", seed=0), AMAZON,
                   MCALConfig(seed=0, eps_target=0.10))
    assert t10.total_cost <= t5.total_cost * 1.02
    assert t10.measured_error <= 0.10 + 0.005
