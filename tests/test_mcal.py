"""MCAL driver: emulated end-to-end campaigns, invariants, variants."""
import json

import numpy as np
import pytest

from repro.core import (AMAZON, SATYAM, MCALCampaign, MCALConfig,
                        make_emulated_task, run_mcal, select_architecture)
from repro.core.baselines import run_naive_al
from repro.core.emulator import DATASETS


@pytest.mark.parametrize("ds", ["fashion", "cifar10", "cifar100"])
@pytest.mark.parametrize("seed", [0, 1])
def test_campaign_meets_error_and_beats_human(ds, seed):
    task = make_emulated_task(ds, "resnet18", seed=seed)
    res = run_mcal(task, AMAZON, MCALConfig(seed=seed))
    assert res.measured_error <= 0.05 + 0.005, res.measured_error
    assert res.total_cost < task.pool_size * 0.04
    # every sample got a label
    assert (res.labels >= 0).all()


def test_campaign_beats_naive_al():
    """The paper's headline: cheaper than AL at ANY tested delta."""
    mcal = run_mcal(make_emulated_task("cifar10", "resnet18", seed=0),
                    AMAZON, MCALConfig(seed=0))
    for d in (0.033, 0.067, 0.10):
        al = run_naive_al(make_emulated_task("cifar10", "resnet18", seed=0),
                          AMAZON, d)
        assert mcal.total_cost <= al.cost * 1.001, (d, al.cost)


def test_imagenet_bails_out_with_bounded_tax():
    task = make_emulated_task("imagenet", "efficientnet-b0", seed=0)
    res = run_mcal(task, AMAZON, MCALConfig(seed=0))
    human_all = task.pool_size * 0.04
    assert res.decision == "human_all"
    assert res.ledger["training"] <= 0.15 * human_all
    assert res.measured_error == 0.0  # everything human-labeled


def test_budget_variant_spends_within_budget_and_error_decreases():
    errs = []
    for budget in (600.0, 1200.0):
        task = make_emulated_task("cifar10", "resnet18", seed=0)
        res = run_mcal(task, AMAZON, MCALConfig(seed=0, budget=budget))
        assert res.total_cost <= budget * 1.001
        errs.append(res.measured_error)
    assert errs[1] <= errs[0]


def test_arch_selection_picks_res18():
    tasks = {a: make_emulated_task("cifar10", a, seed=0)
             for a in ("cnn18", "resnet18", "resnet50")}
    winner, res, hist = select_architecture(tasks, AMAZON, MCALConfig(seed=0))
    assert winner == "resnet18"
    assert res.measured_error <= 0.055
    assert set(hist) == set(tasks)


def test_satyam_cheaper_labels_still_meet_constraint():
    task = make_emulated_task("cifar10", "resnet18", seed=3)
    res = run_mcal(task, SATYAM, MCALConfig(seed=3))
    assert res.measured_error <= 0.055
    assert res.total_cost < task.pool_size * 0.003


def test_campaign_checkpoint_resume_mid_loop():
    """Preempt after a few iterations; the resumed campaign must finish
    with identical economics (deterministic emulator)."""
    cfg = MCALConfig(seed=0)

    def fresh():
        return MCALCampaign(make_emulated_task("cifar10", "resnet18", seed=0),
                            AMAZON, cfg)

    ref = fresh()
    ref.bootstrap()
    for _ in range(3):
        ref.iteration()
    blob = json.dumps(ref.state_dict())  # must be JSON-serializable

    resumed = fresh()
    resumed.load_state_dict(json.loads(blob))
    while not ref.done:
        ref.iteration()
    while not resumed.done:
        resumed.iteration()
    a, b = ref.commit(), resumed.commit()
    assert a.total_cost == pytest.approx(b.total_cost, rel=1e-9)
    assert a.S_size == b.S_size and a.B_size == b.B_size


def test_resume_after_bailout_keeps_decision():
    """Regression: state_dict used to drop decision/B_opt/theta_opt/
    freeze_delta — a campaign resumed after bail-out forgot it chose
    human_all and would happily keep iterating."""
    ref = MCALCampaign(make_emulated_task("imagenet", "efficientnet-b0",
                                          seed=0), AMAZON, MCALConfig(seed=0))
    ref.bootstrap()
    while not ref.done:
        ref.iteration()
    assert ref.decision == "human_all"
    blob = json.dumps(ref.state_dict())

    resumed = MCALCampaign(make_emulated_task("imagenet", "efficientnet-b0",
                                              seed=0), AMAZON,
                           MCALConfig(seed=0))
    resumed.load_state_dict(json.loads(blob))
    assert resumed.done and resumed.decision == "human_all"
    assert resumed.B_opt == ref.B_opt
    assert resumed.theta_opt == ref.theta_opt
    assert resumed.freeze_delta == ref.freeze_delta
    a, b = ref.commit(), resumed.commit()
    assert a.decision == b.decision == "human_all"
    assert a.total_cost == pytest.approx(b.total_cost, rel=1e-9)
    assert b.measured_error == 0.0


def test_kcenter_campaign_resume_picks_identical_candidates():
    """k-center anchor state is rebuilt from B_idx on load (one feature
    sweep), so a resumed kcenter campaign must pick the identical
    candidate sequence as the uninterrupted one."""
    cfg = MCALConfig(seed=0, metric="kcenter", max_iters=6)

    def fresh():
        return MCALCampaign(
            make_emulated_task("cifar10", "resnet18", seed=0,
                               pool_size=4000), AMAZON, cfg)

    ref = fresh()
    ref.bootstrap()
    for _ in range(2):
        ref.iteration()
    blob = json.dumps(ref.state_dict())

    resumed = fresh()
    resumed.load_state_dict(json.loads(blob))
    assert resumed._anchor_feats is not None   # rebuilt on load
    while not ref.done:
        ref.iteration()
    while not resumed.done:
        resumed.iteration()
    np.testing.assert_array_equal(ref.pool.B_idx, resumed.pool.B_idx)
    a, b = ref.commit(), resumed.commit()
    assert a.total_cost == pytest.approx(b.total_cost, rel=1e-9)
    assert a.S_size == b.S_size


def test_async_sweep_campaign_matches_sync():
    """sweep_async overlaps the M(.) sweep with the host-side fits/search;
    prefix-stable rankings make it acquisition-identical to the
    synchronous campaign."""
    from repro.core import LiveTask
    from repro.data.synth import make_classification

    x, y = make_classification(800, num_classes=10, dim=16,
                               difficulty=0.3, seed=4)

    def run_campaign(sweep_async):
        task = LiveTask(features=x, groundtruth=y, num_classes=10,
                        epochs=3, seed=4, sweep_page=256,
                        score_microbatch=256)
        camp = MCALCampaign(task, AMAZON,
                            MCALConfig(seed=4, max_iters=3,
                                       delta0_frac=0.02,
                                       sweep_async=sweep_async))
        camp.bootstrap()
        while not camp.done:
            camp.iteration()
        return camp

    sync, async_ = run_campaign(False), run_campaign(True)
    np.testing.assert_array_equal(sync.pool.B_idx, async_.pool.B_idx)
    a, b = sync.commit(), async_.commit()
    assert a.total_cost == pytest.approx(b.total_cost, rel=1e-9)
    np.testing.assert_array_equal(a.labels, b.labels)


def test_relaxed_eps_saves_more():
    t5 = run_mcal(make_emulated_task("cifar10", "resnet18", seed=0), AMAZON,
                  MCALConfig(seed=0, eps_target=0.05))
    t10 = run_mcal(make_emulated_task("cifar10", "resnet18", seed=0), AMAZON,
                   MCALConfig(seed=0, eps_target=0.10))
    assert t10.total_cost <= t5.total_cost * 1.02
    assert t10.measured_error <= 0.10 + 0.005


def test_state_dict_persists_fitted_models_and_resumes_without_refit():
    """Checkpoints carry the fitted per-theta power laws + the training
    cost model; a resumed campaign's first search() consumes them from
    the restored memo cache — zero refits — and they equal a fresh fit
    of the same history."""
    import repro.core.mcal as mcal_mod

    ref = MCALCampaign(make_emulated_task("cifar10", "resnet18", seed=0),
                       AMAZON, MCALConfig(seed=0))
    ref.bootstrap()
    for _ in range(3):
        ref.iteration()
    blob = json.loads(json.dumps(ref.state_dict()))  # strict-JSON trip
    assert blob["fitted"] is not None
    assert set(blob["fitted"]["laws"]) == {str(t) for t in ref.cfg.thetas}
    assert blob["fitted"]["cost_model"]["c_u"] > 0

    resumed = MCALCampaign(make_emulated_task("cifar10", "resnet18",
                                              seed=0), AMAZON,
                           MCALConfig(seed=0))
    resumed.load_state_dict(blob)
    calls = []
    orig = mcal_mod.fit_power_law

    def counting(*a, **k):
        calls.append(1)
        return orig(*a, **k)

    mcal_mod.fit_power_law = counting
    try:
        res_laws, res_cm = resumed._fit_models()
    finally:
        mcal_mod.fit_power_law = orig
    assert not calls, "resumed campaign refit its power laws"
    fresh_laws, fresh_cm = ref._fit_models()
    assert res_cm.c_u == pytest.approx(fresh_cm.c_u)
    for t in ref.cfg.thetas:
        assert res_laws[t].alpha == pytest.approx(fresh_laws[t].alpha)
        assert res_laws[t].gamma == pytest.approx(fresh_laws[t].gamma)
        assert (res_laws[t].k == pytest.approx(fresh_laws[t].k)
                or (np.isinf(res_laws[t].k) and np.isinf(fresh_laws[t].k)))
    # the cache invalidates as soon as the history grows
    resumed.iteration()           # acquires + measures -> history grows
    resumed._fit_models()         # next consumer refits at the new key
    assert resumed._fit_models_cache[0][0] == len(resumed.train_sizes)


def test_state_dict_persists_engine_pack_keys():
    """Live-task checkpoints round-trip the scoring + fit engines'
    pack-shape compile-cache keys, and load_state_dict prewarms them."""
    from repro.core import LiveTask
    from repro.data.synth import make_classification

    x, y = make_classification(600, num_classes=10, dim=16,
                               difficulty=0.3, seed=1)

    def fresh():
        task = LiveTask(features=x, groundtruth=y, num_classes=10,
                        epochs=2, seed=1, sweep_page=256,
                        score_microbatch=256)
        return MCALCampaign(task, AMAZON,
                            MCALConfig(seed=1, delta0_frac=0.02))

    ref = fresh()
    ref.bootstrap()
    ref.iteration()
    blob = json.loads(json.dumps(ref.state_dict()))
    keys = blob["pack_keys"]
    assert keys and keys["scoring"] and keys["fit"]

    resumed = fresh()
    resumed.load_state_dict(blob)
    got = resumed.task.pack_cache_keys()
    assert {tuple(k) for k in keys["fit"]} <= \
        {tuple(k) for k in got["fit"]}
    assert {tuple(k) for k in keys["scoring"]} <= \
        {tuple(k) for k in got["scoring"]}


def test_commit_sweep_cursor_resumes_identically():
    """A commit L(.) sweep preempted mid-pool resumes from its
    SweepCheckpoint bit-identically: same machine labels, same cost."""
    from repro.core import LiveTask
    from repro.data.synth import make_classification
    from repro.serving.sweep import SweepCheckpoint

    x, y = make_classification(900, num_classes=10, dim=16,
                               difficulty=0.25, seed=2)

    def finished_campaign():
        task = LiveTask(features=x, groundtruth=y, num_classes=10,
                        epochs=3, seed=2, sweep_page=128,
                        score_microbatch=128)
        camp = MCALCampaign(task, AMAZON,
                            MCALConfig(seed=2, max_iters=3,
                                       delta0_frac=0.02))
        camp.bootstrap()
        while not camp.done:
            camp.iteration()
        return camp

    plain = finished_campaign().commit()

    # cut cursors every page, "preempt" after the second cut, resume from
    # a JSON round-trip of the captured cursor
    camp = finished_campaign()
    cuts = []

    class Preempted(Exception):
        pass

    def capture(ck):
        cuts.append(ck.to_json())
        if len(cuts) == 2:
            raise Preempted

    camp.sweep_checkpoint_every = 1
    camp.on_sweep_checkpoint = capture
    with pytest.raises(Preempted):
        camp.commit()

    resumed = finished_campaign()
    resumed.resume_sweep_checkpoint = SweepCheckpoint.from_json(cuts[-1])
    res = resumed.commit()
    np.testing.assert_array_equal(res.labels, plain.labels)
    np.testing.assert_array_equal(res.machine_mask, plain.machine_mask)
    assert res.total_cost == pytest.approx(plain.total_cost, rel=1e-12)


def test_emulated_commit_sweep_cursor_resumes_identically():
    """The emulated (replay) task honours the same cursor kwargs."""
    task = make_emulated_task("cifar10", "resnet18", seed=0,
                              pool_size=4000, sweep_page=256)
    idx = np.arange(1000, 3500)
    order_full, top1_full = task.machine_label_sweep(idx)

    cuts = []
    task.machine_label_sweep(idx, checkpoint_every=3,
                             on_checkpoint=lambda ck: cuts.append(ck))
    assert len(cuts) >= 2
    from repro.serving.sweep import SweepCheckpoint
    mid = SweepCheckpoint.from_json(cuts[1].to_json())
    order_res, top1_res = task.machine_label_sweep(idx, checkpoint=mid)
    np.testing.assert_array_equal(order_res, order_full)
    np.testing.assert_array_equal(top1_res, top1_full)
