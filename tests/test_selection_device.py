"""Oracle-grid harness: the device k-center engine vs the host oracle.

The contract (documented in ``repro.core.selection_device``) is EXACT
chosen-index agreement with ``selection.k_center_greedy`` — the same
sequence, not a set-overlap score.  To make that sound rather than a
float-rounding lottery, every grid case uses integer-valued float32
features small enough that all squared distances are exactly representable
in fp32, so the host's direct ``sum((x - c)^2)`` and the device's MXU
expansion ``||x||^2 - 2 x.c + ||c||^2`` produce bit-equal distances and
both argmax walks (first-occurrence tie-break) are identical — including
through duplicate-row ties and anchor-seeded starts.

The grid sweeps (N, d, k, n_anchors, n_duplicates) plus block sizes that
force both the fused single-tile sweep and the ``lax.map`` multi-tile
sweep, the pow2-bucketed k padding, and the Pallas pairwise-distance
kernel path (interpret mode, the repo's off-TPU convention).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import selection as sel
from repro.core.selection_device import (KCenterConfig,
                                         k_center_greedy_device)
from repro.kernels import ops, ref
from repro.kernels.pairwise_dist import pairwise_sqdist


def _case(seed, N, d, k, n_anchors, n_dups):
    """Integer-valued fp32 features (exact distances), optional duplicate
    rows and anchors, all from one seeded generator."""
    rng = np.random.default_rng(seed)
    X = rng.integers(0, 8, size=(N, d)).astype(np.float32)
    if n_dups:
        src = rng.integers(0, N, size=n_dups)
        dst = rng.integers(0, N, size=n_dups)
        X[dst] = X[src]
    A = (rng.integers(0, 8, size=(n_anchors, d)).astype(np.float32)
         if n_anchors else None)
    return X, A


GRID = [
    # (seed, N, d, k, n_anchors, n_dups)
    (0, 5, 3, 1, 0, 0),
    (1, 5, 3, 5, 0, 3),          # k == N with duplicate rows
    (2, 33, 4, 7, 0, 0),
    (3, 33, 4, 7, 5, 0),         # anchor-seeded start
    (4, 64, 8, 16, 0, 32),       # heavy duplication
    (5, 100, 16, 13, 9, 20),
    (6, 257, 8, 31, 3, 50),      # non-pow2 everything
    (7, 1025, 32, 5, 17, 100),
    (8, 2048, 64, 33, 1, 0),     # single anchor
    (9, 300, 2, 40, 8, 150),     # low-d, mostly duplicates
]


@pytest.mark.parametrize("seed,N,d,k,n_anchors,n_dups", GRID)
def test_exact_agreement_with_host_oracle(seed, N, d, k, n_anchors, n_dups):
    X, A = _case(seed, N, d, k, n_anchors, n_dups)
    host = sel.k_center_greedy(X, k, anchors=A)
    dev = k_center_greedy_device(X, k, anchors=A)
    np.testing.assert_array_equal(dev, host)


@pytest.mark.parametrize("block", [16, 64, 1024])
def test_multi_tile_sweep_matches_oracle(block):
    """Small block sizes force the lax.map tiled sweep (and tiled anchor
    init); the chosen sequence must not depend on the tiling."""
    X, A = _case(11, 517, 8, 23, 6, 40)
    host = sel.k_center_greedy(X, 23, anchors=A)
    dev = k_center_greedy_device(X, 23, anchors=A,
                                 cfg=KCenterConfig(block=block))
    np.testing.assert_array_equal(dev, host)


def test_k_bucketing_is_prefix_stable():
    """k is padded to the next pow2 and trimmed — greedy selection is
    prefix-stable, so every k must return a prefix of the k=N run."""
    X, _ = _case(12, 120, 6, 0, 0, 10)
    full = k_center_greedy_device(X, 120)
    for k in (1, 3, 5, 17, 64, 100):
        np.testing.assert_array_equal(k_center_greedy_device(X, k),
                                      full[:k])


def test_all_duplicate_pool_tie_breaking():
    """Every row identical: both engines must walk the same (degenerate)
    first-index tie-break sequence."""
    X = np.tile(np.asarray([[3.0, 1.0, 2.0]], np.float32), (17, 1))
    host = sel.k_center_greedy(X, 6)
    dev = k_center_greedy_device(X, 6)
    np.testing.assert_array_equal(dev, host)


def test_two_point_tie_prefers_first_index():
    """Two equidistant farthest points: the lower index must win on both
    engines (argmax first-occurrence)."""
    X = np.asarray([[0.0, 0.0], [4.0, 0.0], [0.0, 4.0], [1.0, 1.0]],
                   np.float32)
    host = sel.k_center_greedy(X, 3)
    dev = k_center_greedy_device(X, 3)
    np.testing.assert_array_equal(dev, host)
    assert dev[0] == 0 and dev[1] == 1  # row 1 ties row 2, lower index wins


def test_anchors_suppress_covered_region():
    """With an anchor sitting on cluster A, the first device pick must come
    from cluster B — and still match the host oracle exactly."""
    rng = np.random.default_rng(13)
    a = rng.integers(0, 3, size=(40, 4)).astype(np.float32)
    b = rng.integers(20, 23, size=(40, 4)).astype(np.float32)
    X = np.concatenate([a, b])
    anchor = a[:1]
    host = sel.k_center_greedy(X, 4, anchors=anchor)
    dev = k_center_greedy_device(X, 4, anchors=anchor)
    np.testing.assert_array_equal(dev, host)
    assert dev[0] >= 40  # farthest from the anchored cluster


def test_k_clamped_and_empty():
    X, _ = _case(14, 9, 3, 0, 0, 0)
    assert k_center_greedy_device(X, 0).shape == (0,)
    assert sel.k_center_greedy(X, 0).shape == (0,)  # host twin agrees
    np.testing.assert_array_equal(k_center_greedy_device(X, 50),
                                  sel.k_center_greedy(X, 50))  # k > N clamps


def test_accepts_device_resident_features():
    """The engine consumes the scoring sweep's device arrays directly."""
    X, A = _case(15, 130, 8, 9, 4, 0)
    host = sel.k_center_greedy(X, 9, anchors=A)
    dev = k_center_greedy_device(jnp.asarray(X), 9, anchors=A)
    np.testing.assert_array_equal(dev, host)


# -- the Pallas pairwise-distance kernel path --------------------------------


@pytest.mark.parametrize("N,M,D", [(5, 3, 4), (64, 16, 8), (130, 9, 33),
                                   (257, 128, 16)])
def test_pairwise_kernel_matches_reference(N, M, D):
    rng = np.random.default_rng(N * 1000 + M)
    x = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(M, D)).astype(np.float32))
    kern = pairwise_sqdist(x, c, bn=32, bm=8, interpret=True)
    np.testing.assert_allclose(np.asarray(kern),
                               np.asarray(ref.pairwise_sqdist_ref(x, c)),
                               atol=1e-5)
    assert kern.shape == (N, M) and np.all(np.asarray(kern) >= 0.0)


def test_pairwise_ops_wrapper_gates_kernel():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(40, 8)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(7, 8)).astype(np.float32))
    on = ops.pairwise_sqdist(x, c, force_pallas=True)
    off = ops.pairwise_sqdist(x, c, force_pallas=False)
    np.testing.assert_allclose(np.asarray(on), np.asarray(off), atol=1e-5)


@pytest.mark.parametrize("seed,N,d,k,n_anchors,n_dups",
                         [(3, 33, 4, 7, 5, 0), (5, 100, 16, 13, 9, 20),
                          (6, 257, 8, 31, 3, 50)])
def test_kernel_anchor_path_matches_oracle(seed, N, d, k, n_anchors,
                                           n_dups):
    """Anchor initialization through the Pallas kernel (interpret mode)
    must preserve the exact-agreement contract."""
    X, A = _case(seed, N, d, k, n_anchors, n_dups)
    host = sel.k_center_greedy(X, k, anchors=A)
    dev = k_center_greedy_device(
        X, k, anchors=A, cfg=KCenterConfig(use_kernel=True))
    np.testing.assert_array_equal(dev, host)


# -- wiring: LiveTask + MCAL campaign take the device path -------------------


def test_live_task_kcenter_campaign_uses_device_path(monkeypatch):
    """A kcenter MCAL campaign over an engine-backed LiveTask routes M(.)
    through kcenter_candidates (device features + device greedy loop)
    with anchors covering the full labeled set B under the CURRENT
    classifier (rebuilt each training round), and completes."""
    from repro.core import LiveTask, MCALCampaign, MCALConfig
    from repro.core.cost import AMAZON
    from repro.data.synth import make_classification

    x, y = make_classification(400, num_classes=4, dim=8, difficulty=0.3,
                               seed=3)
    task = LiveTask(features=x, groundtruth=y, num_classes=4, epochs=4,
                    seed=3)
    calls = []
    orig = LiveTask.kcenter_candidates
    monkeypatch.setattr(
        LiveTask, "kcenter_candidates",
        lambda self, k, cand, anchors=None:
        calls.append((len(cand), len(anchors))) or
        orig(self, k, cand, anchors=anchors))
    camp = MCALCampaign(task, AMAZON,
                        MCALConfig(metric="kcenter", seed=3,
                                   delta0_frac=0.02, max_iters=3))
    camp.bootstrap()
    sizes = [len(camp.pool.B_idx)]
    camp.iteration()
    sizes.append(len(camp.pool.B_idx))
    camp.iteration()
    assert len(calls) >= 2          # device path taken each acquisition
    # each acquisition's anchors cover exactly the labeled set B at that
    # point (features under the then-current classifier)
    assert [a for _, a in calls[:2]] == sizes[:2]
    # the per-round anchor cache is rebuildable from B_idx alone
    feats = camp._anchor_features()
    assert feats.shape == (len(camp.pool.B_idx), task.hidden)
