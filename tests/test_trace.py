"""Campaign event bus + append-only trace store (tier-1).

The trace IS the campaign: replaying the JSONL event stream must
reconstruct the full decision trajectory — iteration records, running
ledger, decisions, the committed result — bit-identically with ZERO
engine recompute, across sync/async engine variants, noisy annotation,
and preempt/resume hops.  ``diff`` must localize the first real
divergence and stay silent on scheduling-only differences.
"""
import json
import os

import numpy as np
import pytest

from repro.core import AMAZON, MCALCampaign, MCALConfig, make_emulated_task
from repro.trace import (ALL_KINDS, OBSERVABILITY_KINDS, REPLAY_KINDS,
                         TraceError, TraceEvent, TraceStore, diff,
                         read_trace, replay, sanitize)

# ---------------------------------------------------------------------------
# store level: schema round-trip, tolerance rules, resume truncation
# ---------------------------------------------------------------------------


def test_event_json_round_trip_with_numpy_payload():
    e = TraceEvent(seq=3, campaign="c", kind="charge", ts=1.5,
                   payload={"n": np.int64(7), "cost": np.float32(0.25),
                            "ok": np.bool_(True), "idx": np.arange(3)})
    d = json.loads(e.to_json())
    e2 = TraceEvent.from_dict(d)
    assert (e2.seq, e2.campaign, e2.kind, e2.ts) == (3, "c", "charge", 1.5)
    assert e2.payload == {"n": 7, "cost": 0.25, "ok": True, "idx": [0, 1, 2]}


def test_event_rejects_non_finite_payload():
    e = TraceEvent(seq=0, campaign="c", kind="x", ts=0.0,
                   payload={"bad": float("nan")})
    with pytest.raises(ValueError):
        e.to_json()


def test_sanitize_makes_payloads_strict_json():
    out = sanitize({"nan": float("nan"), "inf": np.inf,
                    "f": np.float64(2.0), "i": np.int32(3),
                    "b": np.bool_(False),
                    "nest": [{"k": -np.inf}, (1.0, 2.0)],
                    "arr": np.array([1.5, np.nan])})
    assert out == {"nan": None, "inf": None, "f": 2.0, "i": 3, "b": False,
                   "nest": [{"k": None}, [1.0, 2.0]], "arr": [1.5, None]}
    json.dumps(out, allow_nan=False)   # must not raise


def test_store_buffers_then_flushes_monotone_seq(tmp_path):
    p = str(tmp_path / "t.jsonl")
    with TraceStore(p, "camp", flush_every=100) as tr:
        tr.emit("campaign_begin", config={"seed": 0})
        tr.emit("charge", total=1.0)
        assert tr.next_seq == 2
        assert read_trace(p) == []          # buffered: not on disk yet
        tr.flush()
        assert [e.seq for e in read_trace(p)] == [0, 1]
        tr.emit("done", reason="x")
    ev = read_trace(p)                      # close() flushed the tail
    assert [e.seq for e in ev] == [0, 1, 2]
    assert all(e.campaign == "camp" for e in ev)
    assert read_trace(p, campaign="other") == []


def test_read_tolerates_truncated_final_line_only(tmp_path):
    p = str(tmp_path / "t.jsonl")
    with TraceStore(p, "camp") as tr:
        for i in range(4):
            tr.emit("charge", total=float(i))
    with open(p, "a") as f:
        f.write('{"seq": 4, "campaign": "camp", "ki')   # mid-write tail
    assert [e.seq for e in read_trace(p)] == [0, 1, 2, 3]

    lines = open(p).read().splitlines()
    lines[1] = lines[1][:20]                            # mid-file garbage
    open(p, "w").write("\n".join(lines) + "\n")
    with pytest.raises(TraceError):
        read_trace(p)


def test_resume_truncates_tail_and_continues_without_gaps(tmp_path):
    p = str(tmp_path / "t.jsonl")
    with TraceStore(p, "camp") as tr:
        for i in range(6):
            tr.emit("charge", total=float(i))
    # checkpoint was cut at next_seq=4: events 4-5 are post-checkpoint
    # work the resumed campaign redoes — resume drops them and re-appends
    with TraceStore.resume(p, 4) as tr:
        assert tr.campaign == "camp" and tr.next_seq == 4
        tr.emit("charge", total=99.0)
        tr.emit("done", reason="resumed")
    ev = read_trace(p)
    assert [e.seq for e in ev] == [0, 1, 2, 3, 4, 5]
    assert ev[4].payload["total"] == 99.0 and ev[5].kind == "done"
    # a cursor pointing past the flushed file is corruption, not a resume
    with pytest.raises(TraceError):
        TraceStore.resume(p, 100)


def test_torn_write_recovers_without_duplicate_or_gapped_seqs(tmp_path):
    """A flush that dies mid-write (injected OSError after half the
    payload) keeps the buffer; the next flush truncates the torn tail
    and rewrites it — readers never see duplicate or gapped seqs."""
    from repro.faults import FaultInjector, FaultPlan, FaultRule
    p = str(tmp_path / "t.jsonl")
    tr = TraceStore(p, "camp", flush_every=1000)
    tr.attach_faults(FaultInjector(FaultPlan(rules=(
        FaultRule("trace.flush", "oserror", at=(0,)),))))
    for i in range(4):
        tr.emit("charge", total=float(i))
    tr.flush()                       # torn: half the payload, then OSError
    assert tr.write_errors == 1
    assert os.path.getsize(p) > 0    # the torn tail IS on disk...
    assert len(read_trace(p)) < 4    # ...but short, ending mid-line
    tr.emit("charge", total=4.0)     # emitting into a torn store is safe
    tr.flush()                       # recovery: truncate + full rewrite
    tr.close()
    ev = read_trace(p)
    assert [e.seq for e in ev] == [0, 1, 2, 3, 4]
    assert [e.payload["total"] for e in ev] == [0.0, 1.0, 2.0, 3.0, 4.0]
    assert tr.write_errors == 1


def test_store_down_hard_warns_but_never_raises_into_emitters(tmp_path):
    """Every flush failing (the volume is gone): emit/flush stay silent
    — losing a campaign to its own audit log would invert the
    dependency — and close() warns about the lost tail."""
    from repro.faults import FaultInjector, FaultPlan, FaultRule
    p = str(tmp_path / "t.jsonl")
    tr = TraceStore(p, "camp", flush_every=1)   # flush on every emit
    tr.attach_faults(FaultInjector(FaultPlan(rules=(
        FaultRule("trace.flush", "oserror", rate=1.0),))))
    for i in range(3):
        tr.emit("charge", total=float(i))       # 3 failed flushes, no raise
    assert tr.write_errors == 3
    with pytest.warns(RuntimeWarning, match="unflushed"):
        tr.close()


# ---------------------------------------------------------------------------
# campaign level: replay-equals-live, diff, resume append-only
# ---------------------------------------------------------------------------


def _traced_run(path, seed, campaign="camp", cfg=None):
    task = make_emulated_task("cifar10", "resnet18", seed=0,
                              pool_size=4000, sweep_page=512)
    cfg = cfg or MCALConfig(seed=seed)
    camp = MCALCampaign(task, AMAZON, cfg)
    with TraceStore(str(path), campaign) as tr:
        camp.attach_trace(tr)
        res = camp.run()
    return res, camp


@pytest.fixture(scope="module")
def traces(tmp_path_factory):
    """Three traced emulated campaigns: two seed-0 siblings (must diff
    clean) and one seed-1 (must diverge at the config)."""
    d = tmp_path_factory.mktemp("traces")
    runs = {}
    for name, seed in (("a0", 0), ("b0", 0), ("a1", 1)):
        p = d / f"{name}.jsonl"
        res, camp = _traced_run(p, seed, campaign=f"cifar10-s{seed}")
        runs[name] = (str(p), res, camp)
    return runs


@pytest.mark.parametrize("run", ["a0", "a1"])
def test_replay_equals_live_with_zero_recompute(traces, run):
    path, res, camp = traces[run]
    rp = replay(path)
    assert rp.result is not None and rp.decision == res.decision
    assert rp.total_cost == res.total_cost               # bit-identical
    assert rp.votes == res.ledger["human_votes"]
    assert rp.pool_size == len(res.labels)
    assert len(rp.history) == len(res.history)
    for got, want in zip(rp.history, res.history):
        assert got.to_dict() == want.to_dict()
    assert rp.result.to_dict(with_history=False) == \
        res.to_dict(with_history=False)
    # structural contract: known kinds, one begin, commit is flushed last
    kinds = [e.kind for e in rp.events]
    assert set(kinds) <= ALL_KINDS
    assert kinds.count("campaign_begin") == 1
    assert kinds[-1] == "commit"


def test_diff_is_none_for_identical_siblings(traces):
    assert diff(traces["a0"][0], traces["b0"][0]) is None


def test_diff_localizes_injected_seed_divergence(traces):
    d = diff(traces["a0"][0], traces["a1"][0])
    assert d is not None and d.index == 0
    assert d.kind_a == d.kind_b == "campaign_begin"
    assert "config" in d.fields
    assert "diverge at event #0" in d.describe()


def test_diff_reports_truncated_trace_as_end(traces, tmp_path):
    src = traces["a0"][0]
    cut = str(tmp_path / "cut.jsonl")
    lines = [l for l in open(src).read().splitlines() if l.strip()]
    open(cut, "w").write("\n".join(lines[:-1]) + "\n")   # drop the commit
    d = diff(src, cut)
    assert d is not None and d.kind_b == "<end>"
    assert "ends" in d.describe()


def test_replay_rejects_sequence_gap(traces, tmp_path):
    src = traces["a0"][0]
    bad = str(tmp_path / "gap.jsonl")
    lines = [l for l in open(src).read().splitlines() if l.strip()]
    open(bad, "w").write("\n".join(lines[:3] + lines[4:]) + "\n")
    with pytest.raises(TraceError):
        replay(bad)


def test_noisy_adaptive_campaign_replays_and_snapshots(tmp_path):
    """The annotation broker's decision stream (service-ledger charges)
    and telemetry (vote rounds, adaptive top-ups, per-worker accuracy
    snapshots) all land in one trace; replay reproduces the economics."""
    from repro.annotation import make_annotation_service

    task = make_emulated_task("cifar10", "resnet18", seed=0,
                              pool_size=4000, sweep_page=512)
    task.annotation = make_annotation_service(
        task.num_classes, n_workers=5, noise=0.2, repeats=2,
        max_repeats=4, adaptive=True, aggregator="ds", pricing=AMAZON,
        seed=0)
    cfg = MCALConfig(seed=0,
                     label_quality=task.annotation.expected_quality())
    p = str(tmp_path / "noisy.jsonl")
    camp = MCALCampaign(task, AMAZON, cfg)
    with TraceStore(p, "noisy-s0") as tr:
        camp.attach_trace(tr)
        res = camp.run()

    rp = replay(p)
    assert rp.total_cost == res.total_cost
    assert rp.votes == camp.pool.ledger.human_votes
    kinds = {e.kind for e in rp.events}
    assert {"vote_round", "topup", "annotator_snapshot"} <= kinds
    assert any(c["ledger"] == "service" for c in rp.charges)
    snaps = [e for e in rp.events if e.kind == "annotator_snapshot"]
    assert all(len(e.payload["worker_accuracy"]) == 5 for e in snaps)


def test_async_sweep_and_fit_siblings_diff_clean(tmp_path):
    """sweep_async + fit_async change scheduling, provably not
    decisions: the decision streams must be identical event-for-event
    (diff None), with only observability events differing."""
    from repro.core import LiveTask
    from repro.data.synth import make_classification

    x, y = make_classification(800, num_classes=10, dim=16,
                               difficulty=0.3, seed=4)

    def run(name, sweep_async, fit_async):
        task = LiveTask(features=x, groundtruth=y, num_classes=10,
                        epochs=3, seed=4, sweep_page=256,
                        score_microbatch=256)
        camp = MCALCampaign(task, AMAZON,
                            MCALConfig(seed=4, max_iters=3,
                                       delta0_frac=0.02,
                                       sweep_async=sweep_async,
                                       fit_async=fit_async))
        p = str(tmp_path / f"{name}.jsonl")
        with TraceStore(p, name) as tr:
            camp.attach_trace(tr)
            camp.bootstrap()
            while not camp.done:
                camp.iteration()
            res = camp.commit()
        return p, res

    p_sync, r_sync = run("sync", False, False)
    p_async, r_async = run("async", True, True)
    assert diff(p_sync, p_async) is None
    assert replay(p_async).total_cost == r_sync.total_cost
    assert r_async.total_cost == pytest.approx(r_sync.total_cost,
                                               rel=1e-9)
    # the async trace DOES carry its own scheduling telemetry
    async_kinds = {e.kind for e in read_trace(p_async)}
    assert {"fit_submit", "fit_done"} <= async_kinds


def test_preempted_campaign_trace_is_append_only(tmp_path):
    """The acceptance scenario: a campaign preempted and resumed N times
    (state checkpoint embeds the trace cursor) yields ONE trace with no
    gaps, no duplicate seqs, a single campaign_begin — and its decision
    stream diffs clean against the uninterrupted run's."""
    from repro.launch.label import run_campaign

    cfg = MCALConfig(seed=0)

    def task():
        return make_emulated_task("cifar10", "resnet18", seed=0,
                                  pool_size=4000, sweep_page=512)

    cont = str(tmp_path / "cont.jsonl")
    res_cont, _ = run_campaign(task(), AMAZON, cfg, trace_path=cont,
                               campaign_id="cifar10-s0")

    prem = str(tmp_path / "prem.jsonl")
    state = str(tmp_path / "state.json")
    res, hops = None, 0
    while res is None:
        res, camp = run_campaign(task(), AMAZON, cfg, state_path=state,
                                 iters_per_run=2, trace_path=prem,
                                 campaign_id="cifar10-s0")
        hops += 1
        assert hops < 50
    assert hops > 1 and not os.path.exists(state)

    ev = read_trace(prem)
    assert [e.seq for e in ev] == list(range(len(ev)))   # no gaps/dups
    kinds = [e.kind for e in ev]
    assert kinds.count("campaign_begin") == 1
    assert kinds.count("resume") == hops - 1
    assert kinds.count("state_save") >= hops - 1
    assert diff(cont, prem) is None
    rp = replay(prem)
    assert rp.total_cost == res_cont.total_cost
    assert rp.total_cost == res.total_cost
    assert len(rp.history) == len(res_cont.history)


def test_noisy_async_preempted_campaign_replays_bit_identically(tmp_path):
    """The PR's acceptance criterion verbatim: a NOISY (adaptive
    Dawid-Skene annotation) ASYNC (sweep_async + fit_async) campaign,
    preempted and resumed, replays bit-identically to its live records
    and ledger — and diffs clean against its uninterrupted sibling."""
    from repro.annotation import make_annotation_service
    from repro.core import LiveTask
    from repro.data.synth import make_classification
    from repro.launch.label import run_campaign

    x, y = make_classification(800, num_classes=10, dim=16,
                               difficulty=0.3, seed=4)

    def task():
        t = LiveTask(features=x, groundtruth=y, num_classes=10,
                     epochs=3, seed=4, sweep_page=256,
                     score_microbatch=256)
        t.annotation = make_annotation_service(
            10, n_workers=5, noise=0.15, repeats=2, max_repeats=4,
            adaptive=True, aggregator="ds", pricing=AMAZON, seed=0)
        return t

    cfg = MCALConfig(seed=4, max_iters=3, delta0_frac=0.02,
                     eps_target=0.15, sweep_async=True, fit_async=True,
                     label_quality=task().annotation.expected_quality())

    cont = str(tmp_path / "cont.jsonl")
    res_cont, camp_cont = run_campaign(task(), AMAZON, cfg,
                                       trace_path=cont,
                                       campaign_id="live-s4")

    prem = str(tmp_path / "prem.jsonl")
    state = str(tmp_path / "state.json")
    res, hops = None, 0
    while res is None:
        res, camp = run_campaign(task(), AMAZON, cfg, state_path=state,
                                 iters_per_run=1, trace_path=prem,
                                 campaign_id="live-s4")
        hops += 1
        assert hops < 20
    assert hops > 1 and not os.path.exists(state)

    ev = read_trace(prem)
    assert [e.seq for e in ev] == list(range(len(ev)))
    assert [e.kind for e in ev].count("campaign_begin") == 1
    assert diff(cont, prem) is None
    rp = replay(prem)
    assert rp.total_cost == res.total_cost == res_cont.total_cost
    assert rp.decision == res_cont.decision
    assert len(rp.history) == len(res_cont.history)
    for got, want in zip(rp.history, res_cont.history):
        assert got.to_dict() == want.to_dict()
    assert rp.votes == camp_cont.pool.ledger.human_votes
    assert rp.votes > rp.ledger["human_labels"]   # repeats really bought


def test_state_dict_version_gate(traces):
    """Satellite: state blobs carry a schema version; a blob from a
    FUTURE version is rejected instead of being half-loaded."""
    from repro.core.mcal import STATE_VERSION

    _, _, camp = traces["a0"]
    sd = json.loads(json.dumps(camp.state_dict()))
    assert sd["version"] == STATE_VERSION
    assert sd["trace"] is not None          # cursor embedded while traced

    task = make_emulated_task("cifar10", "resnet18", seed=0,
                              pool_size=4000, sweep_page=512)
    fresh = MCALCampaign(task, AMAZON, MCALConfig(seed=0))
    with pytest.raises(ValueError, match="version"):
        fresh.load_state_dict(dict(sd, version=STATE_VERSION + 1))


def test_result_and_record_shared_serialization(traces):
    """Satellite: MCALResult/IterationRecord own their dict round-trip
    (the same code path the commit/iteration trace events use)."""
    from repro.core.mcal import IterationRecord, MCALResult

    _, res, _ = traces["a0"]
    for rec in res.history:
        assert IterationRecord.from_dict(rec.to_dict()).to_dict() == \
            rec.to_dict()
    d = res.to_dict()
    r2 = MCALResult.from_dict(d)
    assert r2.to_dict() == d
    assert r2.total_cost == res.total_cost
    assert len(r2.labels) == len(res.labels)
