"""Token-routing EP (a2a) MoE == replicate+psum MoE, on a real (2,2) mesh.

Capacity factor is set high so no copies are dropped — then the two routes
must agree numerically (same experts, same weights, different wire)."""
import json
import os
import subprocess
import sys

import numpy as np

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ModelConfig
from repro.models.transformer import moe_block, moe_specs
from repro.models.param import init_params

from repro.compat import make_mesh
mesh = make_mesh((2, 2), ("data", "model"), axis_types=True)
base = ModelConfig(name="m", family="moe", num_layers=1, d_model=32,
                   num_heads=4, num_kv_heads=2, d_ff=16, vocab_size=64,
                   num_experts=4, experts_per_token=2,
                   moe_capacity_factor=8.0, dtype="float32")
specs = moe_specs(base, 1)
params = init_params(specs, jax.random.key(0))
params = jax.tree.map(lambda a: a[0], params)  # unstack the layer dim

rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(2, 8, 32)) * 0.5, jnp.float32)

results = {}
with mesh:
    ref = moe_block(base.replace(moe_route="replicate_psum"), params, x,
                    mesh=mesh)
    # the a2a route with F-gathered experts must be exact ("psum" FFN is
    # invalid with data-sharded tokens by construction — see _expert_ffn)
    for gd in ("bf16", "int8"):
        out = moe_block(base.replace(moe_route="a2a", moe_ffn_mode="gather",
                                     moe_gather_dtype=gd),
                        params, x, mesh=mesh)
        key = f"a2a_{gd}"
        tol_scale = 1.0 if gd == "bf16" else 50.0  # int8 weights are lossy
        results[key] = float(jnp.max(jnp.abs(out - ref))) / tol_scale
    solo = moe_block(base, params, x, mesh=None)
    results["ref_vs_solo"] = float(jnp.max(jnp.abs(ref - solo)))
print(json.dumps(results))
"""


def test_a2a_matches_replicate_psum():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    for key, err in out.items():
        assert err < 1e-4, (key, err, out)
