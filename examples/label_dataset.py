"""End-to-end live labeling campaign — the paper's system, for real.

    PYTHONPATH=src python examples/label_dataset.py
    PYTHONPATH=src python examples/label_dataset.py --noisy
    PYTHONPATH=src python examples/label_dataset.py --trace run.jsonl
    PYTHONPATH=src python examples/label_dataset.py --slo examples/slo.json

Everything is live: a JAX MLP classifier is (re)trained by the framework's
own train loop on every MCAL iteration, the pool is scored with the
margin head, human labels are charged to the ledger, and the final hybrid
labeling is validated against the oracle.  Takes a few minutes on CPU
(dozens of real training runs).

Default mode keeps the paper's assumption (human labels are perfect and
cost one request each).  ``--noisy`` replaces that oracle with the
annotation-service runtime: a seeded pool of imperfect annotators
(including a spammer), Dawid-Skene EM aggregation on device, an
adaptive-repeats policy (extra votes only for items whose aggregated
posterior is still unsure — Liao et al.'s good practice), every vote
charged at the service rate, and the campaign folding the residual
aggregated-label error into its accuracy target.

``--trace run.jsonl`` additionally records the campaign's full event
stream (every charge, fit, search, acquisition, iteration, commit) to an
append-only trace — watch it live with ``python -m repro.launch.report
run.jsonl --watch 2``, replay it without recompute via ``python -m
repro.launch.label --trace-replay run.jsonl``, or diff it against a
sibling run with ``--trace-diff``.  The full launcher
(``repro.launch.label``) takes the same ``--trace PATH`` flag.

``--metrics`` additionally instruments every engine hot path with the
runtime metrics layer (``repro.obs``): spans, compile-cache counters,
queue gauges.  With ``--trace`` the metric events interleave into the
trace (replay/diff ignore them) and the panel renders with
``python -m repro.launch.report run.jsonl --metrics``; either way a
per-span breakdown prints at the end.  The full launcher spells it
``--metrics PATH`` (plus ``--prom`` / ``--profile``).

``--chaos`` runs the campaign under seeded fault injection
(``repro.faults``): a flaky annotation backend (transient failures +
latency spikes), one broker-job crash per engine family, and one torn
trace write — all recovered by the resilience layer (bounded seeded-
jitter retries, in-place job re-dispatch, torn-tail truncation), so the
result is bit-identical to the fault-free run.  Combine with
``--noisy`` (the annotation-service request path is the busiest fault
site) and ``--trace`` (the torn-write site, plus ``fault_injected`` /
``retry`` events land in the trace for ``repro.launch.report``'s fault-
pressure line).  The injected-fault and
retry counts print at the end; the full launcher spells it ``--chaos``
(+ ``--chaos-seed``), alongside ``--autosave PATH`` (crash-safe
resume sidecar) and ``--sweep-timeout`` / ``--fit-timeout``
(straggler wall budgets).

``--slo examples/slo.json`` runs the campaign under the streaming
health engine (``repro.obs.health``): the declarative SLO contract is
judged at every iteration alongside the full detector suite (budget
burn, annotator drift, power-law fit quality), and hysteresis-gated
``alert`` / ``slo_breach`` events ride the trace when ``--trace`` is
also given — render them with ``python -m repro.launch.report
run.jsonl --health`` (add ``--watch 2`` for a live alert panel).
Judgment counts print at the end; the full launcher spells it
``--slo SPEC.json`` too (plus ``repro.launch.orchestrator``'s
``--slo-enforce``, where breach verdicts drive the fleet's downgrade
cascade).
"""
import sys

import numpy as np

from repro.core import AMAZON, LiveTask, MCALConfig, run_mcal
from repro.data.synth import make_classification

NOISY = "--noisy" in sys.argv
METRICS = "--metrics" in sys.argv
CHAOS = "--chaos" in sys.argv
TRACE = (sys.argv[sys.argv.index("--trace") + 1]
         if "--trace" in sys.argv else "")
SLO = (sys.argv[sys.argv.index("--slo") + 1]
       if "--slo" in sys.argv else "")
POOL, CLASSES, DIM = 6_000, 10, 32

print(f"generating a {POOL:,}-sample / {CLASSES}-class pool "
      f"(25% hard tail) ...")
x, y = make_classification(POOL, num_classes=CLASSES, dim=DIM,
                           difficulty=0.3, hard_frac=0.25, seed=0)

annotation = None
eps_target = 0.05
if NOISY:
    from repro.annotation import make_annotation_service
    annotation = make_annotation_service(
        CLASSES, n_workers=5, noise=0.15, spammer_frac=0.2,
        repeats=2, max_repeats=4, adaptive=True, confidence=0.9,
        aggregator="ds", pricing=AMAZON, seed=0)
    eps_target = 0.15     # leave budget for the annotators' residual
    q = annotation.calibrate()   # measured on a synthetic seeded batch
    print(f"noisy annotation service: 5 workers (1 spammer), "
          f"adaptive 2-4 votes/label, Dawid-Skene aggregation")
    print(f"calibrated label quality: residual error "
          f"~{q.residual_error:.1%}, ~{q.avg_repeats:.2f} votes/label")

task = LiveTask(features=x, groundtruth=y, num_classes=CLASSES,
                hidden=64, depth=2, epochs=30, c_u_nominal=2e-4, seed=0,
                annotation=annotation)

print("running MCAL (real training per iteration) ...")
cfg = MCALConfig(eps_target=eps_target, delta0_frac=0.02, max_iters=25,
                 seed=0, label_quality=q if annotation else None)
metrics = None
if METRICS:
    from repro.obs import MetricsRegistry
    metrics = MetricsRegistry()
faults = retry = None
if CHAOS:
    from repro.faults import FaultInjector, FaultPlan, RetryPolicy
    faults = FaultInjector(FaultPlan.standard_transient(0))
    retry = RetryPolicy(seed=0)
    print("chaos mode: standard transient fault plan injected "
          "(flaky annotation backend, one crash per engine broker, "
          "one torn trace write)")
health = None
if SLO:
    from repro.obs import HealthEngine, SLOSpec
    spec = SLOSpec.load(SLO)
    health = HealthEngine(spec)
    print(f"health engine armed: SLO contract {spec.to_dict()} "
          f"judged every iteration (+ burn/drift/fit detectors)")
if TRACE:
    from repro.trace import TraceStore
    with TraceStore(TRACE, "example-live-s0") as tr:
        if metrics is not None:
            metrics.attach_trace(tr)
        result = run_mcal(task, AMAZON, cfg, trace=tr, metrics=metrics,
                          faults=faults, retry=retry, health=health)
        if metrics is not None:
            metrics.emit_snapshot(scope="example")
    print(f"trace          : {TRACE} (replay: python -m "
          f"repro.launch.label --trace-replay {TRACE}"
          + (f"; panel: python -m repro.launch.report {TRACE} --metrics)"
             if metrics is not None else ")"))
else:
    result = run_mcal(task, AMAZON, cfg, metrics=metrics,
                      faults=faults, retry=retry, health=health)

human_all = POOL * AMAZON.price_per_label
bound = eps_target
if NOISY:
    human_all *= cfg.label_quality.avg_repeats
    bound = eps_target + cfg.label_quality.residual_error
print(f"\ndecision       : {result.decision}")
print(f"trained on     : {result.B_size:,} human labels "
      f"({result.B_size / POOL:.1%})")
print(f"machine-labeled: {result.S_size:,} ({result.S_size / POOL:.1%}) "
      f"at theta={result.theta_final:.2f}")
print(f"measured error : {result.measured_error:.2%} "
      f"(achievable bound {bound:.0%})")
print(f"cost           : ${result.total_cost:.2f} "
      f"(human-only ${human_all:.0f}; "
      f"{1 - result.total_cost / human_all:.1%} saved)")
print(f"ledger         : {result.ledger}")
if NOISY:
    print(f"annotation     : {annotation.votes_bought:,} votes for "
          f"{result.ledger['human_labels']:,} labels "
          f"(avg {annotation.avg_repeats():.2f}/label); "
          f"worker accuracy "
          f"{np.round(annotation.worker_accuracy(), 2).tolist()}")
if faults is not None:
    print(f"chaos          : {faults.fired} faults injected across "
          f"{sum(faults.counters().values()):,} seam ticks "
          f"({', '.join(sorted(faults.counters()))}) — all recovered")
if health is not None:
    c = health.counts()
    act = ", ".join(c["active"]) or "none"
    print(f"health         : {c['alerts_raised']} alerts raised / "
          f"{c['alerts_cleared']} cleared, {c['slo_breaches']} SLO "
          f"breaches over {c['ticks']} ticks (active: {act})"
          + (f" — panel: python -m repro.launch.report {TRACE} --health"
             if TRACE else ""))
if metrics is not None:
    snap = metrics.snapshot()
    spans = sorted((h for h in snap["histograms"]
                    if h["name"] == "span_seconds"),
                   key=lambda h: -h["sum"])
    parts = [f"{h['labels'].get('name', '?')} x{h['count']} "
             f"({h['sum']:.1f}s)" for h in spans[:5]]
    print("metrics        : " + ", ".join(parts))
assert result.measured_error <= bound + 0.01, "error bound violated!"
