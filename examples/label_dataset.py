"""End-to-end live labeling campaign — the paper's system, for real.

    PYTHONPATH=src python examples/label_dataset.py

Everything is live: a JAX MLP classifier is (re)trained by the framework's
own train loop on every MCAL iteration, the pool is scored with the
margin head, human labels are simulated as ground truth and charged to the
ledger, and the final hybrid labeling is validated against the oracle.
Takes a few minutes on CPU (dozens of real training runs).
"""
import numpy as np

from repro.core import AMAZON, LiveTask, MCALConfig, run_mcal
from repro.data.synth import make_classification

POOL, CLASSES, DIM = 6_000, 10, 32

print(f"generating a {POOL:,}-sample / {CLASSES}-class pool "
      f"(25% hard tail) ...")
x, y = make_classification(POOL, num_classes=CLASSES, dim=DIM,
                           difficulty=0.3, hard_frac=0.25, seed=0)
task = LiveTask(features=x, groundtruth=y, num_classes=CLASSES,
                hidden=64, depth=2, epochs=30, c_u_nominal=2e-4, seed=0)

print("running MCAL (real training per iteration) ...")
result = run_mcal(task, AMAZON,
                  MCALConfig(eps_target=0.05, delta0_frac=0.02,
                             max_iters=25, seed=0))

human_only = POOL * AMAZON.price_per_label
print(f"\ndecision       : {result.decision}")
print(f"trained on     : {result.B_size:,} human labels "
      f"({result.B_size / POOL:.1%})")
print(f"machine-labeled: {result.S_size:,} ({result.S_size / POOL:.1%}) "
      f"at theta={result.theta_final:.2f}")
print(f"measured error : {result.measured_error:.2%} (bound 5%)")
print(f"cost           : ${result.total_cost:.2f} "
      f"(human-only ${human_only:.0f}; "
      f"{1 - result.total_cost / human_only:.1%} saved)")
print(f"ledger         : {result.ledger}")
assert result.measured_error <= 0.06, "error bound violated!"
