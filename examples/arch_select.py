"""Architecture selection + budget-constrained labeling (paper §4).

    PYTHONPATH=src python examples/arch_select.py

1. MCAL explores CNN18 / ResNet18 / ResNet50 over a SHARED label pool until
   the per-architecture cost predictions stabilize, then continues only the
   cheapest one (labels are bought once; every candidate's training spend is
   the exploration tax).
2. The budget variant flips the optimization: minimize labeling error
   subject to a hard dollar budget.
"""
from repro.core import (AMAZON, MCALConfig, make_emulated_task, run_mcal,
                        select_architecture)

print("=== architecture selection on emulated CIFAR-10 ===")
tasks = {a: make_emulated_task("cifar10", a, seed=0)
         for a in ("cnn18", "resnet18", "resnet50")}
winner, result, histories = select_architecture(tasks, AMAZON,
                                                MCALConfig(seed=0))
print(f"winner          : {winner}")
print(f"total cost      : ${result.total_cost:,.0f} "
      f"(incl. ${result.ledger['training']:.0f} exploration tax)")
print(f"measured error  : {result.measured_error:.2%}")
for name, hist in histories.items():
    cs = hist[-1].cstar if hist else float("nan")
    print(f"  {name:10s} explored {len(hist):2d} iterations, "
          f"final C* estimate ${cs:,.0f}")

print("\n=== budget-constrained variant ===")
for budget in (600.0, 1000.0, 1500.0):
    task = make_emulated_task("cifar10", "resnet18", seed=0)
    res = run_mcal(task, AMAZON, MCALConfig(seed=0, budget=budget))
    print(f"budget ${budget:6,.0f} -> spent ${res.total_cost:7,.0f}, "
          f"error {res.measured_error:.2%}, machine-labeled {res.S_size:,}")
