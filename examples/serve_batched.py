"""Batched serving + pool scoring with the margin head.

    PYTHONPATH=src python examples/serve_batched.py

Serves a (reduced-config) qwen2-family LM with batched requests through the
ServeEngine (prefill -> KV-cache decode), then scores a token pool with the
fused margin/entropy head — the inference jobs MCAL runs at datacenter
scale when the classifier is an LLM.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.kernels import ops
from repro.models import transformer as tf
from repro.models.registry import get_model
from repro.serving.engine import ServeEngine

cfg = get_smoke("qwen2-1.5b")
model = get_model(cfg)
params = model.init(jax.random.key(0))
rng = np.random.default_rng(0)

# --- batched generation ----------------------------------------------------
B, T, GEN = 8, 32, 16
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)),
                               jnp.int32)}
engine = ServeEngine(model, params, max_seq=T + GEN + 8, batch_size=B)
t0 = time.perf_counter()
out = engine.generate(batch, GEN)
jax.block_until_ready(out)
dt = time.perf_counter() - t0
print(f"generated {B}x{GEN} tokens in {dt:.2f}s "
      f"({B * GEN / dt:.0f} tok/s on CPU)")

# --- pool scoring via the fused margin head ---------------------------------
hidden = model.forward(params, batch)
w = tf.lm_head_weight(cfg, params)
stats = ops.score_head(hidden.reshape(-1, cfg.d_model), w)
print(f"scored {stats.margin.size} positions: "
      f"margin p5={float(jnp.percentile(stats.margin, 5)):.3f} "
      f"p95={float(jnp.percentile(stats.margin, 95)):.3f}, "
      f"mean entropy={float(stats.entropy.mean()):.3f} nats")
print("lowest-margin (most uncertain) positions would be routed to humans;"
      "\nhighest-margin positions are machine-labeled — MCAL's L(.)/M(.).")
