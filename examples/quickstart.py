"""Quickstart: label an emulated CIFAR-10 pool with MCAL at minimum cost.

    PYTHONPATH=src python examples/quickstart.py

Runs in seconds: MCAL learns the truncated power-law error model on the
fly, jointly picks (|B|, theta), and labels the whole 50k pool ~3x cheaper
than the $2,000 human-only bill while keeping labeling error under 5%.
"""
from repro.core import AMAZON, MCALConfig, make_emulated_task, run_mcal

task = make_emulated_task("cifar10", "resnet18", seed=0)
result = run_mcal(task, AMAZON, MCALConfig(eps_target=0.05, seed=0))

X = task.pool_size
print(f"pool size            : {X:,}")
print(f"decision             : {result.decision}")
print(f"human-labeled (train): {result.B_size:,} ({result.B_size / X:.1%})")
print(f"machine-labeled      : {result.S_size:,} ({result.S_size / X:.1%})")
print(f"measured label error : {result.measured_error:.2%} (bound: 5%)")
print(f"total cost           : ${result.total_cost:,.0f}"
      f"  (human-only: ${X * AMAZON.price_per_label:,.0f})")
print(f"savings              : "
      f"{1 - result.total_cost / (X * AMAZON.price_per_label):.1%}")
print("\nper-iteration trace (C* = predicted optimal cost):")
for rec in result.history:
    print(f"  it {rec.i:2d}  |B|={rec.B_size:6,}  delta={rec.delta:6,}  "
          f"C*=${rec.cstar:7,.0f}  B_opt={rec.B_opt:6,}  "
          f"theta*={rec.theta_opt:.2f}")
