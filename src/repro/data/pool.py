"""Unlabeled-pool bookkeeping for labeling campaigns.

A thin, explicit state machine over sample indices: every sample is in
exactly one of {unlabeled, test, train(B), machine(S), residual-human}.
The MCAL driver keeps richer per-iteration state; this class is the
serving-side view used by the launch/label CLI and the checkpointable
campaign state (a campaign can be preempted and resumed mid-loop).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Optional

import numpy as np

UNLABELED, TEST, TRAIN, MACHINE, HUMAN = 0, 1, 2, 3, 4
_STATE_NAMES = {0: "unlabeled", 1: "test", 2: "train", 3: "machine", 4: "human"}


@dataclasses.dataclass
class LabelPool:
    size: int

    def __post_init__(self):
        self.state = np.zeros(self.size, np.int8)
        self.labels = np.full(self.size, -1, np.int64)

    # -- transitions --------------------------------------------------------
    def mark(self, idx: np.ndarray, state: int,
             labels: Optional[np.ndarray] = None):
        idx = np.asarray(idx, np.int64)
        self.state[idx] = state
        if labels is not None:
            self.labels[idx] = labels

    def indices(self, state: int) -> np.ndarray:
        return np.nonzero(self.state == state)[0]

    @property
    def unlabeled(self) -> np.ndarray:
        return self.indices(UNLABELED)

    def counts(self) -> Dict[str, int]:
        return {_STATE_NAMES[s]: int(np.sum(self.state == s))
                for s in _STATE_NAMES}

    # -- persistence (campaign fault tolerance) -----------------------------
    def save(self, path: str):
        tmp = path + ".tmp"
        np.savez(tmp, state=self.state, labels=self.labels)
        os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)

    @classmethod
    def load(cls, path: str) -> "LabelPool":
        z = np.load(path)
        p = cls(size=len(z["state"]))
        p.state = z["state"]
        p.labels = z["labels"]
        return p
