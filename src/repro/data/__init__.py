from repro.data import loader, pool, synth  # noqa: F401
from repro.data.synth import make_classification, make_lm_tokens  # noqa: F401
from repro.data.pool import LabelPool  # noqa: F401
