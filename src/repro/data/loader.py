"""Sharded batching + host->device pipeline.

``ShardedLoader`` feeds the distributed train step: host numpy arrays are
cut into global batches, each placed as one global array with the batch dim
sharded over ("pod", "data") via ``jax.make_array_from_callback`` — each
device receives only its shard, so the host never materializes per-device
copies.  A one-deep prefetch overlaps host slicing with device compute.

On a single CPU device this degrades to plain device_put, so the same loop
drives tests and the production launcher.
"""
from __future__ import annotations

import collections
from typing import Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_sharding(mesh: Mesh, ndim: int) -> NamedSharding:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    spec = (axes if len(axes) > 1 else (axes[0] if axes else None),) + \
        (None,) * (ndim - 1)
    return NamedSharding(mesh, P(*spec))


def device_put_global(array: np.ndarray, mesh: Optional[Mesh]):
    if mesh is None:
        return jax.device_put(array)
    sh = batch_sharding(mesh, array.ndim)
    return jax.make_array_from_callback(
        array.shape, sh, lambda idx: array[idx])


class ShardedLoader:
    def __init__(self, data: Dict[str, np.ndarray], global_batch: int,
                 mesh: Optional[Mesh] = None, seed: int = 0,
                 drop_last: bool = True, prefetch: int = 1):
        sizes = {k: len(v) for k, v in data.items()}
        assert len(set(sizes.values())) == 1, sizes
        self.data = data
        self.n = next(iter(sizes.values()))
        self.global_batch = global_batch
        self.mesh = mesh
        self.rng = np.random.default_rng(seed)
        self.drop_last = drop_last
        self.prefetch = prefetch

    def _host_batches(self) -> Iterator[Dict[str, np.ndarray]]:
        order = self.rng.permutation(self.n)
        nb = self.n // self.global_batch if self.drop_last else \
            -(-self.n // self.global_batch)
        for b in range(nb):
            sel = order[b * self.global_batch:(b + 1) * self.global_batch]
            if len(sel) < self.global_batch:
                sel = np.concatenate(
                    [sel, order[: self.global_batch - len(sel)]])
            yield {k: v[sel] for k, v in self.data.items()}

    def epoch(self) -> Iterator[Dict]:
        """One epoch of device-resident global batches (1-deep prefetch)."""
        queue = collections.deque()
        for host_batch in self._host_batches():
            queue.append({k: device_put_global(v, self.mesh)
                          for k, v in host_batch.items()})
            if len(queue) > self.prefetch:
                yield queue.popleft()
        while queue:
            yield queue.popleft()
