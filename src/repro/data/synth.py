"""Synthetic datasets with controllable difficulty.

``make_classification`` builds the feature-vector pools MCAL's live
campaigns label: class centroids on a hypersphere + anisotropic Gaussian
noise; ``difficulty`` in [0, 1) scales the noise/margin ratio so the
achievable classifier error spans the paper's easy (Fashion-like) to hard
(CIFAR-100-like) regimes.  A fraction of samples is drawn with boosted
noise ("hard tail") so uncertainty ranking has real structure to find.

``make_lm_tokens`` builds deterministic pseudo-corpora for LM-arch training
smoke paths (Zipf-ish unigram draws + a copy task so loss is learnable).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


def make_classification(
    n: int,
    num_classes: int = 10,
    dim: int = 32,
    difficulty: float = 0.3,
    hard_frac: float = 0.25,
    seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (features (n, dim) f32, labels (n,) i64)."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(num_classes, dim))
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    labels = rng.integers(0, num_classes, n)
    # per-dimension sigma scaled by sqrt(32/dim) so the noise-to-margin
    # ratio (and thus Bayes error) is dimension-independent
    base_sigma = (0.1 + 0.5 * difficulty) * np.sqrt(32.0 / dim)
    x = centers[labels] + rng.normal(size=(n, dim)) * base_sigma
    # the "hard tail" lies NEAR DECISION BOUNDARIES (between two class
    # centers) — hard but LEARNABLE, so uncertainty-ranked acquisition has
    # informative structure to exploit (pure-noise tails make active
    # learning lose to random: a classic AL failure mode)
    hard = rng.random(n) < hard_frac
    other = (labels + rng.integers(1, num_classes, n)) % num_classes
    lam = rng.uniform(0.25, 0.48, n)
    boundary = (1 - lam[:, None]) * centers[labels] + \
        lam[:, None] * centers[other] + \
        rng.normal(size=(n, dim)) * (base_sigma * 0.6)
    x[hard] = boundary[hard]
    return x.astype(np.float32), labels.astype(np.int64)


def make_lm_tokens(
    n_seq: int,
    seq_len: int,
    vocab_size: int,
    seed: int = 0,
    copy_prefix: int = 8,
) -> np.ndarray:
    """(n_seq, seq_len) i32 token ids: Zipf unigrams with the first
    ``copy_prefix`` tokens repeated mid-sequence (learnable structure)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab_size + 1)
    p = 1.0 / ranks
    p /= p.sum()
    toks = rng.choice(vocab_size, size=(n_seq, seq_len), p=p)
    if seq_len >= 2 * copy_prefix + 2:
        mid = seq_len // 2
        toks[:, mid:mid + copy_prefix] = toks[:, :copy_prefix]
    return toks.astype(np.int32)
