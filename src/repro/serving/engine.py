"""Batched serving engine: prefill + decode with sharded KV/SSM caches.

MCAL's machine-labeling pass is an inference job over the whole remaining
pool; this engine is that job's runtime.  It also provides the
``serve_step`` the multi-pod dry-run lowers for the decode_* / long_*
shape cells: one new token against a KV cache of ``seq_len``.

Sharding: cache batch over ("pod", "data"), heads over "model"; for
long-context cells the cache sequence dim is sharded over the mesh and
``decode_attention``'s softmax lowers to partial stats + a small
all-reduce (distributed flash-decode) under the SPMD partitioner.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shd
from repro.models import transformer as tf
from repro.models.registry import Model


def make_prefill_step(model: Model, mesh=None, policy: str = "tp"):
    """jitted (params, batch) -> (last_logits, cache)."""

    def step(params, batch):
        hidden, cache = model.prefill(params, batch, mesh=mesh)
        logits = model.logits(params, hidden[:, -1:, :])
        return logits, cache

    if mesh is None:
        return jax.jit(step)
    ab_p, lg_p = model.abstract_params(), model.logical_axes()
    p_sh = shd.tree_named(mesh, shd.tree_pspecs(ab_p, lg_p, mesh, policy))
    return jax.jit(step, in_shardings=(p_sh, None))


def make_scoring_step(model: Model, mesh=None, policy: str = "tp",
                      head_mode: str = "auto"):
    """jitted (params, batch) -> last-position :class:`ScoreStats`.

    MCAL's machine-labeling pass over the remaining pool is this step
    swept batch-by-batch: forward + vocab head fused into packed
    uncertainty statistics (margin/entropy/max-logprob/top1) without
    materializing (B, V) logits in HBM for large vocabularies.
    """
    from repro.core.scoring import head_stats, resolve_head_weight

    def step(params, batch):
        hidden = model.forward(params, batch, mesh=mesh)
        h = hidden[:, -1, :].astype(jnp.float32)
        w = resolve_head_weight(model.cfg, params)
        return head_stats(h, w.astype(jnp.float32), mode=head_mode)

    if mesh is None:
        return jax.jit(step)
    ab_p, lg_p = model.abstract_params(), model.logical_axes()
    p_sh = shd.tree_named(mesh, shd.tree_pspecs(ab_p, lg_p, mesh, policy))
    return jax.jit(step, in_shardings=(p_sh, None))


def make_decode_step(model: Model, mesh=None, policy: str = "tp",
                     donate_cache: bool = True):
    """jitted (params, cache, tokens, cache_len) -> (logits, new_cache)."""

    def step(params, cache, tokens, cache_len):
        return model.decode_step(params, cache, tokens, cache_len, mesh=mesh)

    if mesh is None:
        return jax.jit(step, donate_argnums=(1,) if donate_cache else ())
    ab_p, lg_p = model.abstract_params(), model.logical_axes()
    p_sh = shd.tree_named(mesh, shd.tree_pspecs(ab_p, lg_p, mesh, policy))
    return jax.jit(step, in_shardings=(p_sh, None, None, None),
                   donate_argnums=(1,) if donate_cache else ())


class ServeEngine:
    """Minimal batched generation/scoring loop over a fixed-size cache."""

    def __init__(self, model: Model, params: Dict, max_seq: int,
                 batch_size: int, mesh=None, policy: str = "tp"):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.batch_size = batch_size
        self.mesh = mesh
        self._prefill = make_prefill_step(model, mesh, policy)
        self._decode = make_decode_step(model, mesh, policy)
        self._score = make_scoring_step(model, mesh, policy)
        self._sweep_runners: Dict[int, Any] = {}

    def prefill(self, batch: Dict) -> Tuple[jax.Array, Dict, int]:
        logits, cache = self._prefill(self.params, batch)
        T = batch["tokens"].shape[1]
        full = self.model.init_cache(self.batch_size, self.max_seq)
        full = _load_cache(self.model.cfg, full, cache)
        return logits, full, T

    def score(self, batch: Dict):
        """Last-position ScoreStats for one batch (MCAL machine-labeling
        pass — :meth:`score_pool` sweeps the remaining pool through
        this)."""
        return self._score(self.params, batch)

    def _sweep_runner(self, page_rows: int):
        from repro.serving.sweep import (PoolSweepRunner, ServeSweepAdapter,
                                         SweepConfig)
        runner = self._sweep_runners.get(page_rows)
        if runner is None:
            runner = PoolSweepRunner(ServeSweepAdapter(self._score),
                                     SweepConfig(page_rows=page_rows))
            self._sweep_runners[page_rows] = runner
        return runner

    def score_pool(self, pool_batch: Dict, *, page_rows: Optional[int] = None,
                   sink=None, checkpoint=None):
        """MCAL's machine-labeling pass at pool scale: stream an
        arbitrary-size row-aligned token pool (``tokens`` plus any per-row
        extras) through the jit'd scoring step as paged, double-buffered
        work (``serving.sweep``).  Default deliverable is the packed
        last-position :class:`ScoreStats` trimmed to the pool size
        (device-resident); pass a sweep sink (``TopKSink`` /
        ``RankTop1Sink``) to fold the pool without materializing pool-wide
        stats, and/or a ``SweepCheckpoint`` to resume a preempted sweep
        mid-pool."""
        from repro.serving.sweep import StatsSink
        runner = self._sweep_runner(page_rows or self.batch_size)
        return runner.run(self.params, pool_batch, sink or StatsSink(),
                          checkpoint=checkpoint)

    def score_pool_async(self, pool_batch: Dict, *,
                         page_rows: Optional[int] = None, sink=None,
                         checkpoint=None):
        """:meth:`score_pool` as a ``SweepFuture`` — the sweep streams on
        the runner's worker thread; ``result()`` is the synchronization
        point."""
        from repro.serving.sweep import StatsSink
        runner = self._sweep_runner(page_rows or self.batch_size)
        return runner.submit(self.params, pool_batch, sink or StatsSink(),
                             checkpoint=checkpoint)

    def generate(self, batch: Dict, steps: int,
                 sampler: str = "greedy") -> jax.Array:
        logits, cache, pos = self.prefill(batch)
        toks = []
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        for i in range(steps):
            toks.append(tok)
            logits, cache = self._decode(self.params, cache, tok,
                                         jnp.int32(pos + i))
            tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        return jnp.concatenate(toks, axis=1)


def _load_cache(cfg: ModelConfig, full, prefix):
    """Copy a prefill cache into the zero-initialized max_seq cache."""
    if cfg.family == "ssm":
        return prefix
    if cfg.family == "hybrid":
        out = dict(full)
        out["ssm"] = prefix["ssm"]
        out["attn"] = {
            k: jax.lax.dynamic_update_slice(
                full["attn"][k], prefix["attn"][k].astype(full["attn"][k].dtype),
                (0,) * full["attn"][k].ndim)
            for k in ("k", "v")}
        return out
    if cfg.family == "audio":
        out = {k: jax.lax.dynamic_update_slice(
            full[k], prefix[k].astype(full[k].dtype), (0,) * full[k].ndim)
            for k in ("k", "v")}
        out["xk"], out["xv"] = prefix["xk"], prefix["xv"]
        return out
    return {k: jax.lax.dynamic_update_slice(
        full[k], prefix[k].astype(full[k].dtype), (0,) * full[k].ndim)
        for k in ("k", "v")}
