"""Halo-exchange windowed attention for sequence-sharded serving.

For sliding-window layers (window w) with activations sequence-sharded
over a mesh axis, full K/V gathers are wasted wire: a query in shard s
only attends to its own shard plus the last w tokens of shard s-1.  This
primitive exchanges exactly that halo with one collective_permute
(w tokens instead of the whole sequence — gemma3's local layers need
1,024 of 32,768 tokens: a 32x wire reduction per local layer, EXPERIMENTS
§Perf Cell B it-2).

Requirements: T divisible by the axis size, window <= T/axis_size.
Global (full-attention) layers still use the gathered path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.compat import shard_map as _shard_map

from repro.models.layers import blockwise_attention


def halo_window_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                          window: int, mesh, axis: str = "model",
                          batch_axes=("data",),
                          scale: Optional[float] = None) -> jax.Array:
    """q/k/v: (B, T, H|Hk, hd), T sharded over ``axis``; causal sliding-
    window attention with a one-hop halo exchange."""
    B, T, H, hd = q.shape
    n = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    assert T % n == 0 and window <= T // n, (T, n, window)
    Hk = k.shape[2]

    def body(ql, kl, vl):
        # ql/kl/vl: (B_loc, T_loc, heads, hd) — this shard's slice
        idx = jax.lax.axis_index(axis)
        T_loc = ql.shape[1]
        # halo: last `window` keys/values of the PREVIOUS shard
        perm = [(i, i + 1) for i in range(n - 1)]
        halo_k = jax.lax.ppermute(kl[:, -window:], axis, perm)
        halo_v = jax.lax.ppermute(vl[:, -window:], axis, perm)
        # shard 0 has no predecessor: mask its halo out via positions
        kk = jnp.concatenate([halo_k, kl], axis=1)
        vv = jnp.concatenate([halo_v, vl], axis=1)
        # relative frame: q[j] at window + j, keys at 0..T_loc+window-1;
        # shard 0 has no predecessor -> its (zero-filled) halo is masked
        kv_start = jnp.where(idx == 0, window, 0)
        out = blockwise_attention(
            ql, kk, vv, causal=True, window=window,
            q_offset=window, kv_start=kv_start,
            kv_chunk=min(1024, kk.shape[1]), scale=scale)
        return out

    bspec = tuple(a for a in batch_axes
                  if a in mesh.axis_names)
    bspec = bspec if len(bspec) > 1 else (bspec[0] if bspec else None)
    spec_q = P(bspec, axis, None, None)
    return _shard_map(
        body, mesh=mesh,
        in_specs=(spec_q, spec_q, spec_q),
        out_specs=spec_q,
        check_vma=False,
    )(q, k, v)
