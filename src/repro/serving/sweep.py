"""Streaming pool-sweep runtime: paged, double-buffered scoring over the
remaining pool with async overlap and resumable cursors.

MCAL's commit step and every L(.)/M(.) pass are one inference job over the
WHOLE remaining pool (millions of samples at paper scale).  The scoring
engine (``core.scoring``) made one pool pass a single jit-compiled program,
but it still device-materializes the entire pool buffer at once and hands
pool-wide statistics back to the host.  This module is the production
runtime around that program:

* the pool stays on host and streams through the jit'd scoring step as
  **pages** — each page padded/reshaped with the exact pow2 bucketing of
  ``PoolScoringEngine._pack`` (``scoring.pack_shape``), so pages reuse the
  engine's compile cache and per-row statistics are computed by the same
  compiled program as an unpaged sweep;
* pages are **double-buffered**: the host→device transfer of page i+1 is
  enqueued while page i's compute is in flight (JAX async dispatch), and
  the page buffer is donated to the scoring step where the backend
  supports donation — peak device memory is O(page), not O(pool);
* each page folds into a pluggable **sink** that keeps its running state
  device-resident, so pool-wide statistics never materialize on the host:
    - :class:`TopKSink`       M(.): top-k uncertainty reservoir
                              (``lax.top_k`` over reservoir + page),
    - :class:`RankTop1Sink`   L(.)/commit: streaming confidence-rank +
                              top1 accumulator (one score field + the
                              machine label per row is ALL that reaches
                              the host),
    - :class:`FeatureSink`    k-center anchors: device-resident (N, D)
                              pooled-feature emitter,
    - :class:`StatsSink`      packed ScoreStats (the generic deliverable,
                              ``ServeEngine.score_pool``'s default);
* the sweep carries a **resumable cursor**: :meth:`PoolSweepRunner.run_until`
  stops mid-pool and returns a JSON-serializable :class:`SweepCheckpoint`
  (page index + folded sink state); :meth:`PoolSweepRunner.run` accepts it
  and continues bit-identically to an uninterrupted sweep — preempted
  paper-scale sweeps restart mid-pool instead of re-scoring from row 0;
* :meth:`PoolSweepRunner.submit` returns a :class:`SweepFuture` — the
  sweep runs on the runner's worker thread while the caller keeps
  dispatching other work (``MCALCampaign.iteration`` launches the M(.)
  sweep and overlaps the host-side power-law fits + joint search,
  synchronizing only when the acquisition is consumed).

Oracle-test contract (tests/test_sweep.py)
------------------------------------------

Every sink must agree EXACTLY with its host/engine oracle: the top-k
reservoir with ``PoolScoringEngine.top_k`` (``lax.top_k`` over the full
pool), the streaming rank with ``selection.rank_for_machine_labeling``
over full-pool stats, the feature emitter with
``PoolScoringEngine.pool_features`` — including ragged final pages and a
mid-pool checkpoint/resume.  Two conventions make that sound (the same
reasoning as the k-center engine's contract):

* pages pack with ``scoring.pack_shape`` so every row is computed inside a
  microbatch of the SAME shape as the unpaged engine sweep — the compiled
  per-microbatch program is identical, hence per-row statistics are
  bit-equal across pagings;
* ties break by FIRST global index on both sides: the reservoir
  concatenates its (lower-index) state ahead of the page before
  ``lax.top_k`` (which prefers earlier positions on equal values), and the
  rank sink's host fold is the same stable argsort as the oracle.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import time
from types import SimpleNamespace
from typing import Any, Callable, Dict, List, Optional, Tuple

from concurrent.futures import TimeoutError as FuturesTimeout

from repro.core.worker import SerialWorker

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import selection as sel
from repro.core.scoring import (next_pow2, pack_shape, uncertainty_from_stats)
from repro.models.layers import ScoreStats

# score field each L(.)/M(.) metric actually consumes — the ONLY per-row
# float the rank sink ships to the host
_METRIC_FIELD = {"margin": "margin", "entropy": "entropy",
                 "least_confidence": "max_logprob"}


# ---------------------------------------------------------------------------
# config / cursor / async handle
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SweepConfig:
    page_rows: int = 8192   # rows per page (keep a pow2 multiple of the
                            # engine microbatch so full pages share one
                            # compiled program)
    prefetch: int = 2       # pages in flight: 2 = double-buffered (the
                            # transfer of page i+1 overlaps page i compute)


@dataclasses.dataclass
class SweepCheckpoint:
    """Resumable sweep cursor: the next page to score + the folded sink
    state, JSON-serializable so campaign checkpoints can embed it."""

    next_page: int
    n: int                  # pool rows the cursor was cut against
    page_rows: int
    sink_kind: str
    sink_state: Dict

    def to_json(self) -> str:
        # strict JSON: sinks encode non-finite sentinels themselves (e.g.
        # TopKSink's None slots) — a NaN/inf reaching here is a sink bug
        return json.dumps(dataclasses.asdict(self), allow_nan=False)

    @classmethod
    def from_json(cls, blob: str) -> "SweepCheckpoint":
        return cls(**json.loads(blob))


class SweepFuture:
    """Async sweep handle (:meth:`PoolSweepRunner.submit`).  ``result()``
    is the synchronization point — the fold the caller eventually needs.

    This is the ONE worker-handle type every async runtime shares: the
    fit engine re-exports it as ``FitFuture`` and the annotation broker
    as ``AnnotationFuture`` — hardening (cancellation semantics, mapped
    results, timeout behaviour) lands here once for all three."""

    def __init__(self, future, map_result: Optional[Callable] = None,
                 label: str = ""):
        self._future = future
        self._map = map_result
        self._label = label
        self._done_value: Any = None
        self._mapped = False

    def done(self) -> bool:
        return self._future.done()

    def cancel(self) -> bool:
        return self._future.cancel()

    def result(self, timeout: Optional[float] = None):
        """The fold.  With a ``timeout`` (seconds) the wait is a wall
        budget: a job still running when it expires raises
        :class:`~repro.faults.errors.StragglerTimeout` — the straggler
        detection the campaign's ``sweep_timeout``/``fit_timeout``
        knobs (and the launchers' ``--sweep-timeout``/``--fit-timeout``)
        arm.  The job itself keeps running on its daemon worker; the
        future stays valid for a later (longer) wait."""
        if not self._mapped:
            try:
                out = self._future.result(timeout)
            except FuturesTimeout:
                from repro.faults.errors import StragglerTimeout
                raise StragglerTimeout(
                    f"{self._label or 'worker job'} still running after "
                    f"its {timeout:g}s wall budget") from None
            self._done_value = self._map(out) if self._map else out
            self._mapped = True
        return self._done_value


# ---------------------------------------------------------------------------
# sinks — device-resident page folds
# ---------------------------------------------------------------------------
#
# Sink contract: ``init(n) -> state``; ``fold(state, stats, feats, offset,
# nvalid) -> state`` consumes one page's PACKED statistics (padded rows
# beyond ``nvalid`` must be ignored; ``offset`` is the page's global row
# offset) without forcing a host sync; ``finalize(state, n)`` produces the
# deliverable; ``serialize``/``deserialize`` round-trip the folded state
# through JSON for the sweep cursor.


@functools.partial(jax.jit, static_argnames=("metric",))
def _topk_fold(scores, idx, stats, offset, nvalid, metric):
    page = uncertainty_from_stats(stats, metric).astype(jnp.float32)
    rows = jnp.arange(page.shape[0])
    page = jnp.where(rows < nvalid, page, -jnp.inf)
    gidx = (offset + rows).astype(jnp.int32)
    # reservoir state first: its (earlier) global indices keep winning ties,
    # matching full-pool lax.top_k's first-index preference
    vals, pos = jax.lax.top_k(jnp.concatenate([scores, page]),
                              scores.shape[0])
    return vals, jnp.concatenate([idx, gidx])[pos]


class TopKSink:
    """M(.) sink: device top-k uncertainty reservoir.  Finalizes to the
    (k,) global row indices, sorted most-uncertain-first — exactly
    ``PoolScoringEngine.top_k`` without ever materializing pool-wide
    scores."""

    kind = "topk"

    def __init__(self, k: int, metric: str = "margin"):
        if metric not in _METRIC_FIELD:
            raise ValueError(f"unknown uncertainty metric {metric!r}")
        self.k = k
        self.metric = metric

    def init(self, n: int):
        k = max(min(self.k, n), 0)
        return (jnp.full((k,), -jnp.inf, jnp.float32),
                jnp.zeros((k,), jnp.int32))

    def fold(self, state, stats, feats, offset: int, nvalid: int):
        return _topk_fold(state[0], state[1], stats, offset, nvalid,
                          self.metric)

    def finalize(self, state, n: int) -> np.ndarray:
        return np.asarray(state[1], np.int64)

    def serialize(self, state) -> Dict:
        # unfilled reservoir slots hold -inf sentinels; store them as None
        # so the cursor stays strict-JSON (RFC 8259 has no -Infinity)
        scores = [None if not np.isfinite(v) else float(v)
                  for v in np.asarray(state[0], np.float64)]
        return {"k": self.k, "metric": self.metric, "scores": scores,
                "idx": np.asarray(state[1], np.int64).tolist()}

    def deserialize(self, blob: Dict):
        if blob["metric"] != self.metric or blob["k"] != self.k:
            raise ValueError(
                f"checkpoint folded TopKSink(k={blob['k']}, "
                f"metric={blob['metric']!r}); cannot resume into "
                f"TopKSink(k={self.k}, metric={self.metric!r})")
        scores = np.asarray([-np.inf if v is None else v
                             for v in blob["scores"]], np.float32)
        return (jnp.asarray(scores),
                jnp.asarray(np.asarray(blob["idx"], np.int32)))


class RankTop1Sink:
    """L(.)/commit sink: streaming confidence rank + top1 accumulator.

    Folds keep per-page device slices (no host sync on the sweep's hot
    path); finalize ships ONE score field + the top1 label per row and
    runs the oracle's own stable argsort — the machine-labeling prefix and
    its labels from a single pool pass, with none of the other statistics
    or features ever leaving the device."""

    kind = "rank"

    def __init__(self, metric: str = "margin"):
        if metric not in _METRIC_FIELD:
            raise ValueError(f"unknown uncertainty metric {metric!r}")
        self.metric = metric
        self._field = _METRIC_FIELD[metric]

    def init(self, n: int) -> List:
        return []

    def fold(self, state, stats, feats, offset: int, nvalid: int):
        state.append((getattr(stats, self._field)[:nvalid],
                      stats.top1[:nvalid]))
        return state

    def finalize(self, state, n: int) -> Tuple[np.ndarray, np.ndarray]:
        if state:
            field = np.concatenate([np.asarray(f) for f, _ in state])
            top1 = np.concatenate([np.asarray(t, np.int64) for _, t in state])
        else:
            field = np.zeros((0,), np.float32)
            top1 = np.zeros((0,), np.int64)
        scores = sel.uncertainty_scores(
            self.metric, SimpleNamespace(**{self._field: field}))
        return np.argsort(scores, kind="stable"), top1

    def serialize(self, state) -> Dict:
        field = (np.concatenate([np.asarray(f) for f, _ in state])
                 if state else np.zeros((0,), np.float32))
        top1 = (np.concatenate([np.asarray(t, np.int64) for _, t in state])
                if state else np.zeros((0,), np.int64))
        return {"metric": self.metric,
                "field": np.asarray(field, np.float64).tolist(),
                "dtype": str(field.dtype),
                "top1": top1.tolist()}

    def deserialize(self, blob: Dict) -> List:
        if blob["metric"] != self.metric:
            raise ValueError(
                f"checkpoint folded RankTop1Sink({blob['metric']!r}); "
                f"cannot resume into RankTop1Sink({self.metric!r})")
        return [(np.asarray(blob["field"], np.dtype(blob["dtype"])),
                 np.asarray(blob["top1"], np.int64))]


class FeatureSink:
    """k-center sink: device-resident (N, D) pooled-feature emitter — the
    paged twin of ``PoolScoringEngine.pool_features`` (the greedy
    farthest-point engine consumes the result without a host trip).

    Cursor caveat: serializing this sink's state materializes every folded
    feature row into the JSON blob (O(rows_swept * D) host floats) — fine
    for anchor-scale sweeps (|B| rows), disproportionate mid-pool at paper
    scale; a binary sidecar for feature cursors is the roadmap follow-on.
    """

    kind = "features"

    def init(self, n: int) -> List:
        return []

    def fold(self, state, stats, feats, offset: int, nvalid: int):
        if feats is None or feats.shape[-1] == 0:
            raise ValueError(
                "sweep adapter emits no features; build the scoring engine "
                "with ScoringConfig(with_features=True)")
        state.append(feats[:nvalid])
        return state

    def finalize(self, state, n: int) -> jax.Array:
        if not state:
            return jnp.zeros((0, 0), jnp.float32)
        return jnp.concatenate(state, axis=0)

    def serialize(self, state) -> Dict:
        feats = (np.asarray(jnp.concatenate(state, axis=0), np.float64)
                 if state else np.zeros((0, 0)))
        return {"feats": feats.tolist()}

    def deserialize(self, blob: Dict) -> List:
        feats = np.asarray(blob["feats"], np.float32)
        return [jnp.asarray(feats)] if feats.size else []


class StatsSink:
    """Generic sink: packed :class:`ScoreStats` for the whole pool, pages
    concatenated device-side and trimmed to the true pool size
    (``ServeEngine.score_pool``'s default deliverable)."""

    kind = "stats"
    _FIELDS = ("margin", "entropy", "max_logprob", "top1")

    def init(self, n: int) -> List:
        return []

    def fold(self, state, stats, feats, offset: int, nvalid: int):
        state.append(ScoreStats(*(getattr(stats, f)[:nvalid]
                                  for f in self._FIELDS)))
        return state

    def finalize(self, state, n: int) -> ScoreStats:
        if not state:
            z = jnp.zeros((0,), jnp.float32)
            return ScoreStats(z, z, z, jnp.zeros((0,), jnp.int32))
        return ScoreStats(*(jnp.concatenate([getattr(s, f) for s in state])
                            for f in self._FIELDS))

    def serialize(self, state) -> Dict:
        packed = self.finalize(state, -1)
        return {f: np.asarray(getattr(packed, f), np.float64).tolist()
                for f in self._FIELDS}

    def deserialize(self, blob: Dict) -> List:
        if not blob["margin"]:
            return []
        return [ScoreStats(
            margin=jnp.asarray(np.asarray(blob["margin"], np.float32)),
            entropy=jnp.asarray(np.asarray(blob["entropy"], np.float32)),
            max_logprob=jnp.asarray(np.asarray(blob["max_logprob"],
                                               np.float32)),
            top1=jnp.asarray(np.asarray(blob["top1"], np.int32)))]


SINKS = {s.kind: s for s in (TopKSink, RankTop1Sink, FeatureSink, StatsSink)}


# ---------------------------------------------------------------------------
# adapters — how a page becomes device work
# ---------------------------------------------------------------------------


class EngineSweepAdapter:
    """Feeds pages through a :class:`~repro.core.scoring.PoolScoringEngine`'s
    jit-compiled packed scoring step.  Pages pad/reshape on HOST with the
    engine's own pow2 bucketing (``scoring.pack_shape``) before the async
    device transfer, so every page reuses the engine's compile cache and
    per-row statistics are bit-equal to an unpaged engine sweep."""

    def __init__(self, engine):
        self.engine = engine

    def length(self, pool) -> int:
        return int(pool.shape[0])

    def put(self, pool, lo: int, hi: int):
        page = np.asarray(pool[lo:hi])
        n = hi - lo
        n_mb, mb = pack_shape(n, self.engine.cfg.microbatch)
        pad = n_mb * mb - n
        if pad:
            page = np.concatenate(
                [page, np.zeros((pad,) + page.shape[1:], page.dtype)])
        return jax.device_put(
            page.reshape((n_mb, mb) + page.shape[1:])), n

    def score(self, params, page):
        return self.engine.score_pages(params, page)


class ServeSweepAdapter:
    """Feeds pages of a row-aligned token-batch dict (``tokens`` plus any
    per-row extras: ``audio_frames``, ``patch_embeds``) through a serving
    scoring step (``ServeEngine._score``).  Ragged tail pages pad to the
    next pow2 batch so the step compiles O(log page) programs."""

    def __init__(self, score_step):
        self._step = score_step

    def length(self, pool: Dict) -> int:
        return int(next(iter(pool.values())).shape[0])

    def put(self, pool: Dict, lo: int, hi: int):
        n = hi - lo
        b = max(next_pow2(n), 8)
        page = {}
        for key, v in pool.items():
            a = np.asarray(v[lo:hi])
            if b != n:
                a = np.concatenate(
                    [a, np.zeros((b - n,) + a.shape[1:], a.dtype)])
            page[key] = jax.device_put(a)
        return page, n

    def score(self, params, page):
        return self._step(params, page), None


class HostTaskAdapter:
    """Pages an arbitrary host ``score(idx_page) -> (stats, feats)``
    callable (e.g. ``EmulatedTask.score``) through the same runtime, so
    paper-scale emulated replays share the cursor/sink machinery without a
    device in the loop.  The "pool" is the global index array itself."""

    def __init__(self, score_fn: Callable):
        self._score = score_fn

    def length(self, pool) -> int:
        return int(len(pool))

    def put(self, pool, lo: int, hi: int):
        return pool[lo:hi], hi - lo

    def score(self, params, page):
        return self._score(page)


# ---------------------------------------------------------------------------
# the runner
# ---------------------------------------------------------------------------


class PoolSweepRunner:
    """Streams an arbitrary-size pool through a scoring step as paged,
    double-buffered, sink-folded device work (module docstring has the
    full design).  One runner per (adapter, page size); a runner is
    reusable across parameter sets and pools."""

    def __init__(self, adapter, cfg: SweepConfig = SweepConfig()):
        assert cfg.page_rows > 0
        self.adapter = adapter
        self.cfg = cfg
        self._exec: Optional[SerialWorker] = None
        # campaign event bus (observability only: page cursors + sink
        # finalizations; emits may come from the runner's worker thread)
        self.trace = None
        # runtime metrics (repro.obs.MetricsRegistry); None = free no-op
        self.metrics = None
        # resilience seam: chaos injector + broker re-dispatch policy,
        # handed to the lazy SerialWorker (site ``worker.pool-sweep``)
        self.faults = None
        self.retry = None

    def attach_faults(self, faults, retry=None) -> None:
        """Wire the fault injector (and optional re-dispatch policy)
        into the runner's broker: every submitted job ticks the
        ``worker.pool-sweep`` site, and transient crashes re-dispatch."""
        self.faults = faults
        if retry is not None:
            self.retry = retry
        if self._exec is not None:
            self._exec.attach_faults(faults, retry)

    def _emit(self, kind: str, **payload) -> None:
        if self.trace is not None:
            self.trace.emit(kind, **payload)

    def n_pages(self, n: int) -> int:
        return -(-n // self.cfg.page_rows)

    # -- synchronous sweeps -------------------------------------------------

    def run(self, params, pool, sink, *,
            checkpoint: Optional[SweepCheckpoint] = None,
            checkpoint_every: int = 0,
            on_checkpoint: Optional[Callable] = None):
        """Sweep the whole pool (resuming from ``checkpoint`` if given)
        and return the sink's finalized deliverable.  With
        ``checkpoint_every``/``on_checkpoint``, a resumable cursor is cut
        every N pages and handed to the callback before sweeping on —
        callers persist it so a preempted sweep restarts mid-pool.  The
        live sink state is threaded through the cuts (serialization
        happens only for the callback's cursor, never round-trips back),
        and no cursor is cut after the final page (there is nothing left
        to resume)."""
        if self.metrics is not None:
            with self.metrics.span("sweep", sink=sink.kind):
                return self._run_sync(params, pool, sink,
                                      checkpoint=checkpoint,
                                      checkpoint_every=checkpoint_every,
                                      on_checkpoint=on_checkpoint)
        return self._run_sync(params, pool, sink, checkpoint=checkpoint,
                              checkpoint_every=checkpoint_every,
                              on_checkpoint=on_checkpoint)

    def _run_sync(self, params, pool, sink, *,
                  checkpoint: Optional[SweepCheckpoint] = None,
                  checkpoint_every: int = 0,
                  on_checkpoint: Optional[Callable] = None):
        n = self.adapter.length(pool)
        n_pages = self.n_pages(n)
        start, state = self._restore(sink, n, checkpoint)
        if checkpoint_every and on_checkpoint is not None:
            page = start
            while page < n_pages:
                stop = min(page + checkpoint_every, n_pages)
                state = self._sweep(params, pool, sink, state, page,
                                    stop, n)
                page = stop
                if page < n_pages:
                    self._emit("sweep_cut", next_page=int(page),
                               n=int(n), sink=sink.kind)
                    on_checkpoint(SweepCheckpoint(
                        next_page=page, n=n,
                        page_rows=self.cfg.page_rows, sink_kind=sink.kind,
                        sink_state=sink.serialize(state)))
        else:
            state = self._sweep(params, pool, sink, state, start,
                                n_pages, n)
        self._emit("sweep_done", n=int(n), pages=int(n_pages),
                   resumed_from=int(start), sink=sink.kind)
        return sink.finalize(state, n)

    def run_until(self, params, pool, sink, stop_page: int, *,
                  checkpoint: Optional[SweepCheckpoint] = None
                  ) -> SweepCheckpoint:
        """Sweep up to (not including) ``stop_page`` and cut a resumable
        cursor.  Feeding it back into :meth:`run` continues bit-identically
        to an uninterrupted sweep."""
        n = self.adapter.length(pool)
        start, state = self._restore(sink, n, checkpoint)
        stop = min(stop_page, self.n_pages(n))
        state = self._sweep(params, pool, sink, state, start, stop, n)
        self._emit("sweep_cut", next_page=int(stop), n=int(n),
                   sink=sink.kind)
        return SweepCheckpoint(next_page=stop, n=n,
                               page_rows=self.cfg.page_rows,
                               sink_kind=sink.kind,
                               sink_state=sink.serialize(state))

    # -- async handle --------------------------------------------------------

    def submit(self, params, pool, sink, *,
               checkpoint: Optional[SweepCheckpoint] = None,
               map_result: Optional[Callable] = None) -> SweepFuture:
        """Launch :meth:`run` on the runner's worker thread; the caller
        overlaps its own (host or device) work and synchronizes at
        ``result()`` — the fold."""
        return SweepFuture(
            self._executor().submit(self.run, params, pool, sink,
                                    checkpoint=checkpoint),
            map_result, label=f"sweep[{sink.kind}]")

    def submit_call(self, fn: Callable, *args, **kw) -> SweepFuture:
        """Run an arbitrary callable on the sweep worker (composite jobs
        like feature-sweep + device k-center that end in a sweep)."""
        return SweepFuture(self._executor().submit(fn, *args, **kw),
                           label="sweep[call]")

    # -- lifecycle -----------------------------------------------------------

    def close(self) -> None:
        """Idempotent runner shutdown: join the sweep worker thread (a
        no-op if no sweep was ever submitted).  ``submit`` afterwards
        raises — synchronous ``run`` calls remain valid."""
        if self._exec is not None:
            self._exec.close()

    def __enter__(self) -> "PoolSweepRunner":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- internals -----------------------------------------------------------

    def _executor(self) -> SerialWorker:
        if self._exec is None:
            self._exec = SerialWorker("pool-sweep", retry=self.retry,
                                      faults=self.faults)
            self._exec.metrics = self.metrics
        return self._exec

    def _restore(self, sink, n: int,
                 ckpt: Optional[SweepCheckpoint]) -> Tuple[int, Any]:
        if ckpt is None:
            return 0, sink.init(n)
        if ckpt.sink_kind != sink.kind:
            raise ValueError(f"checkpoint folded a {ckpt.sink_kind!r} sink; "
                             f"cannot resume into {sink.kind!r}")
        if ckpt.n != n or ckpt.page_rows != self.cfg.page_rows:
            raise ValueError(
                f"checkpoint cursor (n={ckpt.n}, page_rows={ckpt.page_rows})"
                f" does not match this sweep (n={n}, "
                f"page_rows={self.cfg.page_rows})")
        return ckpt.next_page, sink.deserialize(ckpt.sink_state)

    def _sweep(self, params, pool, sink, state, start: int, stop: int,
               n: int):
        P = self.cfg.page_rows
        m = self.metrics
        clock = time.perf_counter
        queue: List = []
        nxt = start
        depth = max(self.cfg.prefetch, 1)

        def put_page(i: int):
            # h2d submit latency (the transfer itself overlaps compute)
            t0 = clock() if m is not None else 0.0
            out = self.adapter.put(pool, i * P, min((i + 1) * P, n))
            if m is not None:
                m.observe("sweep_put_seconds", clock() - t0)
            return out

        while nxt < stop and len(queue) < depth:
            queue.append(put_page(nxt))
            nxt += 1
        for p in range(start, stop):
            page, nvalid = queue.pop(0)
            t0 = clock() if m is not None else 0.0
            stats, feats = self.adapter.score(params, page)  # async dispatch
            if m is not None:
                # dispatch-side latency only: device compute stays async
                # and overlaps the next page's h2d below
                m.observe("sweep_score_submit_seconds", clock() - t0)
            if nxt < stop:   # h2d of the next page overlaps this compute
                queue.append(put_page(nxt))
                nxt += 1
            t0 = clock() if m is not None else 0.0
            state = sink.fold(state, stats, feats, p * P, nvalid)
            if m is not None:
                # fold blocks on page i's results: the overlap window
                m.observe("sweep_fold_seconds", clock() - t0)
                m.inc("sweep_pages_total")
                m.inc("sweep_rows_total", float(nvalid))
        return state
