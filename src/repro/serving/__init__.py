from repro.serving.engine import ServeEngine, make_decode_step, make_prefill_step  # noqa: F401
from repro.serving.sweep import (  # noqa: F401
    EngineSweepAdapter, FeatureSink, HostTaskAdapter, PoolSweepRunner,
    RankTop1Sink, ServeSweepAdapter, StatsSink, SweepCheckpoint, SweepConfig,
    SweepFuture, TopKSink)
