"""Campaign event bus + append-only trace store.

Public API:
    TraceStore / TraceEvent / read_trace   append-only JSONL event log
    replay(path) -> ReplayedCampaign       full trajectory, zero recompute
    diff(a, b) -> TraceDiff | None         first-divergence analysis
    REPLAY_KINDS / OBSERVABILITY_KINDS     the emit-site contract
"""
from repro.trace.replay import (ALL_KINDS, OBSERVABILITY_KINDS,
                                REPLAY_KINDS, ReplayedCampaign, TraceDiff,
                                diff, replay)
from repro.trace.store import (TraceError, TraceEvent, TraceStore,
                               iter_trace, read_trace, sanitize)
