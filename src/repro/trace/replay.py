"""Replay and first-divergence diff over campaign traces.

Every emitter behind the trace is deterministic and checkpointable, so a
campaign's trace IS its trajectory: :func:`replay` reconstructs the full
run — iteration records, running ledger, decisions, the committed result
— from the event log alone, bit-identical to the live campaign's
``MCALResult`` and with ZERO engine recompute (no training, no scoring,
no annotation requests; the only work is JSON parsing).

Event kinds split into two classes:

* **decision events** (:data:`REPLAY_KINDS`) — the deterministic stream
  every sibling run of the same campaign policy must produce identically:
  config, bootstrap, every ledger charge, every measurement, every
  power-law fit, every joint-search outcome, every acquisition, every
  iteration record, the termination reason, and the commit.  Replay reads
  only these, and :func:`diff` compares only these — so a sync campaign
  and its ``--sweep-async``/``--fit-async`` sibling diff clean even
  though their raw streams interleave worker-thread events differently.
* **observability events** (:data:`OBSERVABILITY_KINDS`) — scheduling
  and quality telemetry (sweep cursor cuts, fit submit/fold timestamps,
  vote rounds and adaptive top-ups, annotator-quality snapshots, state
  saves, resumes).  ``launch/report.py`` renders these; replay and diff
  ignore them, because their count and interleaving legitimately vary
  with runtime mode and preemption.

:func:`diff` normalizes the one intentional sibling difference out of the
decision stream — ``campaign_begin``'s ``runtime`` block (the async
flags) — and returns the FIRST event where two traces disagree, with the
differing payload fields named.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.trace.store import TraceError, TraceEvent, read_trace

# the deterministic decision stream (replay input, diff domain)
REPLAY_KINDS = frozenset({
    "campaign_begin", "bootstrap", "charge", "measure", "powerlaw_fit",
    "search", "acquisition", "iteration", "done", "commit",
})

# telemetry: counts/interleavings vary with runtime mode and preemption
# (metric_span / metric_snapshot are the repro.obs metrics stream — they
# interleave with the decision events but never enter replay or diff)
OBSERVABILITY_KINDS = frozenset({
    "state_save", "resume", "vote_round", "topup", "annotator_snapshot",
    "sweep_cut", "sweep_done", "fit_submit", "fit_done",
    "metric_span", "metric_snapshot",
    # the resilience layer's telemetry (repro.faults): injected faults,
    # retry re-issues, and fleet quarantine decisions ride the trace but
    # never enter replay/diff — a chaos run whose retries all succeed
    # diffs CLEAN against its fault-free sibling (the bench_faults /
    # test_faults acceptance invariant)
    "fault_injected", "retry", "quarantine", "autosave",
    # the health engine's judgment stream (repro.obs.health): raised /
    # cleared alerts and SLO breach verdicts are observations ABOUT the
    # decision stream, never part of it — a monitored campaign diffs
    # clean against its monitor-off sibling
    "alert", "alert_clear", "slo_breach",
})

ALL_KINDS = REPLAY_KINDS | OBSERVABILITY_KINDS


@dataclasses.dataclass
class ReplayedCampaign:
    """A campaign trajectory reconstructed from its trace alone.

    ``history`` holds live-equal ``IterationRecord`` objects, ``ledger``
    the final campaign ledger snapshot (with ``total``), ``result`` the
    committed ``MCALResult`` (None for a trace cut before commit —
    preempted or still running).  ``charges`` is the full charge stream
    (campaign AND service ledgers) for audit/burn-rate analysis.
    """

    campaign: str
    config: Dict
    runtime: Dict
    pool_size: int
    history: List                       # List[IterationRecord]
    ledger: Dict
    charges: List[Dict]
    decision: Optional[str]
    done_reason: Optional[str]
    result: Optional[object]            # MCALResult | None
    events: List[TraceEvent]

    @property
    def total_cost(self) -> float:
        return float(self.ledger.get("total", 0.0))

    @property
    def votes(self) -> int:
        return int(self.ledger.get("human_votes", 0))


def replay(path: str, *, campaign: Optional[str] = None
           ) -> ReplayedCampaign:
    """Reconstruct a campaign's trajectory from its trace — records,
    ledger, decisions, committed result — without touching a single
    engine.  Validates the trace structurally on the way: contiguous
    monotone sequence numbers and monotone-non-decreasing campaign
    ledger balances."""
    # lazy: replay needs the record dataclasses, not the engines — but
    # repro.core.mcal transitively imports jax, and trace READERS (the
    # report CLI, --trace-replay) should not pay that until they ask
    # for reconstructed records
    from repro.core.mcal import IterationRecord, MCALResult

    events = read_trace(path, campaign=campaign)
    if not events:
        raise TraceError(f"{path}: empty trace")
    for prev, e in zip(events, events[1:]):
        if e.seq != prev.seq + 1:
            raise TraceError(
                f"{path}: sequence gap {prev.seq} -> {e.seq} — the trace "
                f"was corrupted or mixes campaigns")

    config: Dict = {}
    runtime: Dict = {}
    pool_size = 0
    history: List = []
    charges: List[Dict] = []
    ledger: Dict = {"human": 0.0, "training": 0.0, "human_labels": 0,
                    "human_votes": 0, "total": 0.0}
    decision: Optional[str] = None
    done_reason: Optional[str] = None
    result = None

    for e in events:
        p = e.payload
        if e.kind == "campaign_begin":
            config = dict(p.get("config", {}))
            runtime = dict(p.get("runtime", {}))
            pool_size = int(p.get("pool_size", 0))
        elif e.kind == "charge":
            charges.append(dict(p, seq=e.seq, ts=e.ts))
            if p.get("ledger") == "campaign":
                if p["total"] < ledger["total"] - 1e-9:
                    raise TraceError(
                        f"{path}: campaign ledger regressed at seq "
                        f"{e.seq} (${ledger['total']:.4f} -> "
                        f"${p['total']:.4f})")
                ledger = {k: p[k] for k in ("human", "training",
                                            "human_labels", "human_votes",
                                            "total")}
        elif e.kind == "iteration":
            history.append(IterationRecord.from_dict(p))
        elif e.kind == "done":
            done_reason = str(p.get("reason", ""))
        elif e.kind == "commit":
            result = MCALResult.from_dict(dict(p, history=[]))
            result.history = history
            decision = result.decision
            ledger = dict(result.ledger)

    return ReplayedCampaign(
        campaign=events[0].campaign, config=config, runtime=runtime,
        pool_size=pool_size, history=history, ledger=ledger,
        charges=charges, decision=decision, done_reason=done_reason,
        result=result, events=events)


@dataclasses.dataclass
class TraceDiff:
    """The first divergence between two traces' decision streams.
    ``index`` counts FILTERED events (position in the compared streams);
    ``fields`` names the differing payload keys when the kinds agree.
    A kind of ``"<end>"`` means that trace ran out of events first."""

    index: int
    kind_a: str
    kind_b: str
    seq_a: int
    seq_b: int
    payload_a: Dict
    payload_b: Dict
    fields: List[str]

    def describe(self) -> str:
        if "<end>" in (self.kind_a, self.kind_b):
            short, tail = (("a", self.kind_b) if self.kind_a == "<end>"
                           else ("b", self.kind_a))
            return (f"traces diverge at event #{self.index}: trace "
                    f"{short} ends, the other continues with {tail!r}")
        if self.kind_a != self.kind_b:
            return (f"traces diverge at event #{self.index}: "
                    f"{self.kind_a!r} (seq {self.seq_a}) vs "
                    f"{self.kind_b!r} (seq {self.seq_b})")
        return (f"traces diverge at event #{self.index} "
                f"({self.kind_a!r}, seq {self.seq_a}/{self.seq_b}): "
                f"fields {', '.join(self.fields)}")


def _normalized(e: TraceEvent):
    payload = dict(e.payload)
    if e.kind == "campaign_begin":
        # the one intentional sibling difference: sync vs async execution
        # mode changes scheduling, provably not decisions — normalize it
        # out so --sweep-async/--fit-async siblings diff clean
        payload.pop("runtime", None)
    return e.kind, payload


def diff(path_a: str, path_b: str, *,
         kinds: Sequence[str] = REPLAY_KINDS) -> Optional[TraceDiff]:
    """First divergence between two traces' ``kinds``-filtered streams
    (None when they agree).  Wall-clock timestamps, sequence numbers,
    campaign ids, and observability events never count as divergence —
    only decision kinds and payloads do."""
    kinds = frozenset(kinds)
    ev_a = [e for e in read_trace(path_a) if e.kind in kinds]
    ev_b = [e for e in read_trace(path_b) if e.kind in kinds]
    for i, (a, b) in enumerate(zip(ev_a, ev_b)):
        ka, pa = _normalized(a)
        kb, pb = _normalized(b)
        if ka == kb and pa == pb:
            continue
        fields = (sorted(k for k in set(pa) | set(pb)
                         if pa.get(k) != pb.get(k)) if ka == kb else [])
        return TraceDiff(index=i, kind_a=ka, kind_b=kb, seq_a=a.seq,
                         seq_b=b.seq, payload_a=pa, payload_b=pb,
                         fields=fields)
    if len(ev_a) != len(ev_b):
        i = min(len(ev_a), len(ev_b))
        a = ev_a[i] if i < len(ev_a) else None
        b = ev_b[i] if i < len(ev_b) else None
        return TraceDiff(
            index=i,
            kind_a=a.kind if a else "<end>", kind_b=b.kind if b else "<end>",
            seq_a=a.seq if a else -1, seq_b=b.seq if b else -1,
            payload_a=dict(a.payload) if a else {},
            payload_b=dict(b.payload) if b else {}, fields=[])
    return None
