"""Append-only campaign trace store: the event log every decision site
emits through.

One :class:`TraceStore` is one campaign's audit trail — an append-only
JSONL file where every event carries a monotone sequence number and the
campaign id::

    {"seq": 17, "campaign": "cifar10-resnet18-s0", "kind": "iteration",
     "ts": 1754650000.123, "payload": {...}}

Design contract (what makes replay/diff sound):

* **append-only, monotone seq** — events are never rewritten; ``seq``
  increases by exactly 1 per event, so a gap or duplicate is corruption
  by definition (``replay`` validates this);
* **buffered off the hot path** — ``emit`` appends to an in-memory
  buffer under a lock (safe for the async sweep/fit worker threads) and
  only touches the file every ``flush_every`` events or on an explicit
  :meth:`flush` (campaign checkpoints flush BEFORE the state file is
  written, so a persisted trace cursor always points inside the file);
* **wall-clock ``ts`` is observability metadata only** — replay and diff
  ignore it, so sibling runs of a deterministic campaign produce
  byte-comparable *decision* streams even though their timestamps differ;
* **strict JSON** — payloads must be finite (``allow_nan=False``);
  emitters encode non-finite sentinels themselves (the same convention
  as ``SweepCheckpoint``), so a NaN reaching the store is an emitter bug;
* **resume truncates, never forks** — a preempted campaign restarts from
  a state checkpoint whose embedded trace cursor (``next_seq``) marks the
  last event the checkpoint knew about; :meth:`TraceStore.resume` drops
  any events written after that cut (work the resumed campaign will
  redo and re-emit) and continues appending at ``next_seq`` — the
  resumed trace has no gaps and no duplicate sequence numbers.

Readers (:func:`read_trace`) tolerate a truncated FINAL line — the
normal state of a trace that is being written right now (the live report
renders from exactly such files) or that lost its tail in a crash.
Garbage anywhere else is real corruption and raises.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
import warnings
from typing import Dict, Iterator, List, Optional

import numpy as np


class TraceError(RuntimeError):
    """A structurally corrupt trace (mid-file garbage, seq regression,
    or a resume cursor pointing past the end of the file)."""


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One decision/charge/measurement event.  ``payload`` is the
    kind-specific dict; ``ts`` is wall-clock metadata that replay and
    diff ignore."""

    seq: int
    campaign: str
    kind: str
    ts: float
    payload: Dict

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), allow_nan=False,
                          default=_np_default)

    @classmethod
    def from_dict(cls, d: Dict) -> "TraceEvent":
        return cls(seq=int(d["seq"]), campaign=str(d["campaign"]),
                   kind=str(d["kind"]), ts=float(d["ts"]),
                   payload=dict(d["payload"]))


def _np_default(o):
    """json fallback: numpy scalars/arrays emitted by decision sites."""
    if isinstance(o, np.integer):
        return int(o)
    if isinstance(o, np.floating):
        return float(o)
    if isinstance(o, np.bool_):
        return bool(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(f"trace payload value {o!r} is not JSON-serializable")


class TraceStore:
    """Append-only JSONL event writer for one campaign.

    ``TraceStore(path, campaign=...)`` starts a FRESH trace (truncating
    any existing file — a new campaign is a new trail);
    :meth:`TraceStore.resume` reopens a preempted campaign's trace at
    its checkpointed cursor.  ``emit`` is thread-safe: the async sweep,
    fit-engine, and annotation workers all emit through the campaign's
    one store and sequence numbers stay monotone.
    """

    def __init__(self, path: str, campaign: str = "campaign", *,
                 flush_every: int = 256, _next_seq: int = 0,
                 _append: bool = False):
        self.path = str(path)
        self.campaign = str(campaign)
        self.flush_every = max(int(flush_every), 1)
        self._seq = int(_next_seq)
        self._buf: List[str] = []
        self._lock = threading.Lock()
        self._f = open(self.path, "a" if _append else "w")
        # -- torn-write tolerance -------------------------------------------
        # byte offset of the last DURABLE event boundary: a flush that
        # dies mid-write (OSError) may leave a torn tail past it; the
        # next flush truncates back to this offset and rewrites the kept
        # buffer, so the file never carries duplicate or gapped seqs
        self._end_pos = os.path.getsize(self.path) if _append else 0
        self._torn: Optional[int] = None
        self.write_errors = 0          # flushes that hit an OSError
        self._faults = None            # chaos injector (attach_faults)

    def attach_faults(self, faults) -> None:
        """Wire the chaos seam: every buffer flush ticks the
        ``trace.flush`` fault site (an injected fault emulates a torn
        write: half the payload reaches the file, then OSError)."""
        self._faults = faults

    # -- writing -----------------------------------------------------------
    @property
    def next_seq(self) -> int:
        """The sequence number the NEXT event will carry — the trace
        cursor campaign checkpoints embed (flush first: a persisted
        cursor must point inside the file, not inside the buffer)."""
        with self._lock:
            return self._seq

    def emit(self, kind: str, **payload) -> None:
        """Append one event (buffered; flushed every ``flush_every``
        events).  Payload values must be JSON-finite."""
        with self._lock:
            e = TraceEvent(seq=self._seq, campaign=self.campaign,
                           kind=kind, ts=time.time(), payload=payload)
            self._buf.append(e.to_json())
            self._seq += 1
            if len(self._buf) >= self.flush_every:
                self._flush_locked()

    def flush(self) -> None:
        with self._lock:
            self._flush_locked()

    def _flush_locked(self) -> None:
        """One buffer flush, torn-write tolerant: an OSError mid-write
        (a full disk, a flaky volume, an injected fault) KEEPS the
        buffer and remembers the last durable byte offset; the next
        flush truncates the torn tail and rewrites the whole kept buffer
        — readers never see duplicate or gapped sequence numbers, only
        (at worst) one truncated final line, which ``read_trace``
        already tolerates.  Write faults are recorded in
        ``write_errors``; they are deliberately NOT raised into the
        emitting decision site (losing a campaign to its own audit log
        would invert the dependency)."""
        if self._f.closed:
            return
        try:
            if self._torn is not None:
                # the torn write left the position past the durable
                # boundary: rewind (append-mode writes re-seek to EOF,
                # which the truncate puts exactly at the boundary)
                self._f.seek(self._torn)
                self._f.truncate(self._torn)
                self._torn = None
            if self._buf:
                payload = "\n".join(self._buf) + "\n"
                if self._faults is not None and \
                        self._faults.tick("trace.flush",
                                          emit=False) is not None:
                    # emulate the torn write (emit=False: we hold the
                    # store lock — a fault_injected emit would deadlock)
                    self._f.write(payload[:max(len(payload) // 2, 1)])
                    self._f.flush()
                    raise OSError("injected trace-write fault")
                self._f.write(payload)
                self._f.flush()
                # ensure_ascii JSON + "\n" joins: byte length == length
                self._end_pos += len(payload)
                self._buf.clear()
            else:
                self._f.flush()
        except OSError:
            self.write_errors += 1
            self._torn = self._end_pos

    def close(self) -> None:
        with self._lock:
            self._flush_locked()
            if self._buf or self._torn is not None:
                # one recovery attempt for a store that went down dirty
                self._flush_locked()
            if self._buf or self._torn is not None:
                warnings.warn(
                    f"trace {self.path}: closed with {len(self._buf)} "
                    f"unflushed events after {self.write_errors} write "
                    f"errors — the tail of this trace is lost",
                    RuntimeWarning, stacklevel=2)
            if not self._f.closed:
                self._f.close()

    def __enter__(self) -> "TraceStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- resume ------------------------------------------------------------
    @classmethod
    def resume(cls, path: str, next_seq: int, *,
               campaign: Optional[str] = None,
               flush_every: int = 256) -> "TraceStore":
        """Reopen a preempted campaign's trace at its checkpointed
        cursor: keep events with ``seq < next_seq`` (the prefix the state
        checkpoint was cut against), truncate anything written after the
        cut (work the resumed campaign redoes and re-emits), and continue
        appending at ``next_seq`` — no gaps, no duplicate sequence
        numbers.  The campaign id is recovered from the kept prefix
        unless overridden."""
        next_seq = int(next_seq)
        keep_bytes, last_seq, seen_campaign = 0, -1, campaign
        with open(path, "rb") as f:
            for raw in f:
                line = raw.decode("utf-8", errors="replace").strip()
                if not line:
                    keep_bytes += len(raw)
                    continue
                try:
                    d = json.loads(line)
                except json.JSONDecodeError:
                    break   # truncated tail from the crash: drop it
                if int(d["seq"]) >= next_seq:
                    break
                last_seq = int(d["seq"])
                if seen_campaign is None:
                    seen_campaign = str(d["campaign"])
                keep_bytes += len(raw)
        if last_seq != next_seq - 1:
            raise TraceError(
                f"trace {path} ends at seq {last_seq} but the checkpoint "
                f"cursor expects events through seq {next_seq - 1} — the "
                f"trace was not flushed before the state file was written")
        with open(path, "r+b") as f:
            f.truncate(keep_bytes)
        return cls(path, campaign=seen_campaign or "campaign",
                   flush_every=flush_every, _next_seq=next_seq,
                   _append=True)


def sanitize(obj):
    """Deep-copy ``obj`` with non-finite floats replaced by ``None`` —
    the strict-JSON escape hatch for emitters whose numeric fields may
    legitimately be +/-inf (unfitted power laws, infeasible searches).
    Also normalizes numpy scalars so sanitized payloads compare equal
    across live and replayed streams."""
    if isinstance(obj, dict):
        return {k: sanitize(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [sanitize(v) for v in obj]
    if isinstance(obj, np.bool_):
        return bool(obj)
    if isinstance(obj, (float, np.floating)):
        f = float(obj)
        return f if np.isfinite(f) else None
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.ndarray):
        return sanitize(obj.tolist())
    return obj


def read_trace(path: str, *, campaign: Optional[str] = None
               ) -> List[TraceEvent]:
    """Read a trace file into events.  A truncated FINAL line (the file
    is mid-write, or a crash cut the tail) is tolerated and dropped;
    garbage anywhere else raises :class:`TraceError`.  ``campaign``
    filters to one campaign id (traces are single-campaign today, but a
    reader should not have to assume that)."""
    events: List[TraceEvent] = []
    with open(path) as f:
        lines = f.read().split("\n")
    for i, line in enumerate(lines):
        if not line.strip():
            continue
        try:
            d = json.loads(line)
        except json.JSONDecodeError:
            if any(l.strip() for l in lines[i + 1:]):
                raise TraceError(
                    f"{path}:{i + 1}: corrupt mid-file event line")
            break   # truncated final line: the mid-write tail
        events.append(TraceEvent.from_dict(d))
    if campaign is not None:
        events = [e for e in events if e.campaign == campaign]
    return events


def iter_trace(path: str) -> Iterator[TraceEvent]:
    """Iterator form of :func:`read_trace` (same tolerance rules)."""
    yield from read_trace(path)
