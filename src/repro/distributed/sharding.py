"""Logical-axis sharding rules (MaxText-style, divisibility-aware).

Every parameter is annotated with logical axis names; a policy maps logical
axes to mesh axes.  ``logical_to_pspec`` drops any assignment that does not
divide evenly into the mesh (e.g. qwen2's 12 query heads over a 16-way
"model" axis fall back to replication) so the same model code lowers on any
mesh without per-arch special cases.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple, Union

import jax

from repro import compat
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical axis vocabulary ------------------------------------------------
#   embed   : d_model dim of weights
#   heads   : query-head dim
#   kv      : kv-head dim
#   mlp     : ffn hidden dim
#   vocab   : vocabulary dim
#   expert  : MoE expert dim
#   expert_mlp : per-expert ffn hidden dim (2nd shard axis for giant MoE)
#   layers  : stacked scan dim (never sharded)
#   conv    : ssm conv kernel dim (never sharded)
#   state   : ssm state dim (never sharded)
#   batch   : activation batch
#   seq     : activation sequence
#   act_embed : activation d_model

AxisAssign = Union[None, str, Tuple[str, ...]]

POLICIES: Dict[str, Dict[str, AxisAssign]] = {
    # Pure tensor parallel: weights replicated over "data"/"pod".
    "tp": {
        "embed": None,
        "heads": "model",
        "kv": "model",
        "mlp": "model",
        "vocab": "model",
        "expert": "model",
        "expert_mlp": "data",
        "layers": None,
        "conv": None,
        "state": None,
        "ssm_heads": "model",
        "batch": ("pod", "data"),
        "seq": None,
        "act_embed": None,
        "act_seq_train": "model",
        "cache_seq": ("model", "data", "pod"),
        "cache_batch": ("pod", "data"),
    },
    # FSDP x TP: weights additionally sharded over "data" on the non-TP dim.
    "fsdp_tp": {
        "embed": "data",
        "heads": "model",
        "kv": "model",
        "mlp": "model",
        "vocab": "model",
        "expert": "model",
        "expert_mlp": "data",
        "layers": None,
        "conv": None,
        "state": None,
        "ssm_heads": "model",
        "batch": ("pod", "data"),
        "seq": None,
        "act_embed": None,
        "act_seq_train": "model",
        "cache_seq": ("model", "data", "pod"),
        "cache_batch": ("pod", "data"),
    },
    # fsdp_tp with SEQUENCE-SHARDED activations: the scan carries (the
    # memory term that forces deep grad accumulation on giant models)
    # shrink by the "model" size, so accum drops to 1 and per-microbatch
    # weight re-gathers stop multiplying (EXPERIMENTS §Perf Cell C it-2).
    # Attention K/V gathers over "model" and the MoE uses the a2a route.
    "fsdp_tp_seq": {
        "embed": "data",
        "heads": None,          # tokens are seq-sharded, not head-sharded
        "kv": None,
        "mlp": "model",
        "vocab": "model",
        "expert": "model",
        "expert_mlp": "data",
        "layers": None,
        "conv": None,
        "state": None,
        "ssm_heads": None,
        "batch": ("pod", "data"),
        "seq": "model",
        "act_embed": None,
        "act_seq_train": "model",
        "cache_seq": ("model", "data", "pod"),
        "cache_batch": ("pod", "data"),
    },
    # Pure ZeRO-3 data parallelism over the WHOLE mesh: no tensor
    # parallelism, batch sharded over every axis, weights sharded over
    # (data, model) jointly on one dim and re-gathered per use.  The right
    # regime for small-d_model models where 16-way TP's activation
    # all-reduces dwarf compute (see EXPERIMENTS.md §Perf, mamba2 train).
    "fsdp": {
        "embed": ("data", "model"),   # ragged vocabs shard on D instead
        "heads": None,
        "kv": None,
        "mlp": ("data", "model"),
        "vocab": ("data", "model"),
        "expert": ("data", "model"),
        "expert_mlp": None,
        "layers": None,
        "conv": None,
        "state": None,
        "ssm_heads": None,
        "batch": ("pod", "data", "model"),
        "seq": None,
        "act_embed": None,
        "act_seq_train": None,
        "cache_seq": ("model",),
        "cache_batch": ("pod", "data"),
    },
    # Serving with replicated weights + sequence-sharded activations:
    # zero weight-movement; attention K/V gathers over "model" are the only
    # collective (and local/sliding-window layers touch just a halo).  The
    # right regime for prefill/pool-scoring of models whose full weights
    # fit one chip (see EXPERIMENTS.md §Perf, gemma3 prefill).
    "seq_serve": {
        "embed": None,
        "heads": None,
        "kv": None,
        "mlp": None,
        "vocab": None,
        "expert": None,
        "expert_mlp": None,
        "layers": None,
        "conv": None,
        "state": None,
        "ssm_heads": None,
        "batch": ("pod", "data"),
        "seq": "model",
        "act_embed": None,
        "act_seq_train": None,
        "cache_seq": "model",
        "cache_batch": ("pod", "data"),
    },
}


def mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _assign_size(assign: AxisAssign, sizes: Dict[str, int]) -> int:
    if assign is None:
        return 1
    if isinstance(assign, str):
        return sizes.get(assign, 1)
    return math.prod(sizes.get(a, 1) for a in assign)


def _filter_assign(assign: AxisAssign, sizes: Dict[str, int]) -> AxisAssign:
    """Drop mesh axes absent from the mesh (e.g. 'pod' on single-pod)."""
    if assign is None:
        return None
    if isinstance(assign, str):
        return assign if assign in sizes else None
    kept = tuple(a for a in assign if a in sizes)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def logical_to_pspec(
    shape: Sequence[int],
    logical: Sequence[Optional[str]],
    mesh: Mesh,
    policy: str,
) -> P:
    """Build a PartitionSpec for ``shape`` annotated with logical axes.

    Any logical->mesh assignment whose mesh size does not evenly divide the
    corresponding dim is dropped (replicated); a mesh axis is used at most
    once per spec.
    """
    rules = POLICIES[policy]
    sizes = mesh_axis_sizes(mesh)
    used: set = set()
    out = []
    for dim, ax in zip(shape, logical):
        assign = rules.get(ax) if ax else None
        if assign is None:
            out.append(None)
            continue
        names = (assign,) if isinstance(assign, str) else tuple(assign)
        # greedy: keep each mesh axis that exists, is unused, and divides
        kept = []
        prod = 1
        for n in names:
            if n in sizes and n not in used and dim % (prod * sizes[n]) == 0 and sizes[n] > 1:
                kept.append(n)
                prod *= sizes[n]
        if not kept:
            out.append(None)
            continue
        used.update(kept)
        out.append(tuple(kept) if len(kept) > 1 else kept[0])
    return P(*out)


def tree_pspecs(abstract_tree, logical_tree, mesh: Mesh, policy: str):
    """Map a pytree of ShapeDtypeStructs + matching logical-axes tree
    (tuples of logical names) to a pytree of PartitionSpecs."""
    return compat.tree_map(
        lambda leaf, logical: logical_to_pspec(leaf.shape, logical, mesh, policy),
        abstract_tree,
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(i, (str, type(None))) for i in x),
    )


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def tree_named(mesh: Mesh, spec_tree):
    return compat.tree_map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def tree_size_bytes(tree) -> int:
    return sum(
        math.prod(l.shape) * l.dtype.itemsize for l in compat.tree_leaves(tree)
    )


def constrain(x, mesh, policy: str, *logical: str):
    """with_sharding_constraint by logical axis names (one per dim).

    The SPMD partitioner loses batch sharding inside scanned + rematted
    blocks unless activations are pinned (MaxText-style); every model
    block calls this at its boundaries.  No-op when mesh is None.
    Assignments that don't divide the dim fall back to replication via
    ``logical_to_pspec``.
    """
    if mesh is None:
        return x
    spec = logical_to_pspec(x.shape, logical, mesh, policy)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))
