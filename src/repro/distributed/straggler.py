"""Straggler mitigation: step-time outlier detection + mitigation hooks.

At thousands of chips the p99 step time is set by the slowest participant.
The monitor keeps a rolling window of measured step times, flags outliers
by median + k*MAD (robust to the warmup tail), and invokes a mitigation
callback — in production that callback triggers the hot-spare pod swap /
re-mesh (checkpoint -> drop the slow host -> restore onto the spare via
``checkpoint.restore`` with new shardings); in tests it records the event.

Detection is host-side and out of the jit path: it reads wall-clock
timings the trainer already collects, so it adds zero device overhead.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Deque, List, Optional


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration: float
    median: float
    threshold: float


class StragglerMonitor:
    def __init__(self, window: int = 64, k_mad: float = 5.0,
                 min_samples: int = 16,
                 on_straggler: Optional[Callable[[StragglerEvent], None]] = None):
        self.window: Deque[float] = collections.deque(maxlen=window)
        self.k_mad = k_mad
        self.min_samples = min_samples
        self.on_straggler = on_straggler
        self.events: List[StragglerEvent] = []
        self._t0: Optional[float] = None
        self._step = 0

    # -- timing context ------------------------------------------------------
    def start(self):
        self._t0 = time.perf_counter()

    def stop(self) -> Optional[StragglerEvent]:
        assert self._t0 is not None, "start() before stop()"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        return self.observe(dt)

    def observe(self, duration: float) -> Optional[StragglerEvent]:
        self._step += 1
        event = None
        if len(self.window) >= self.min_samples:
            med = _median(self.window)
            mad = _median([abs(x - med) for x in self.window]) or 1e-9
            thresh = med + self.k_mad * mad
            if duration > thresh:
                event = StragglerEvent(self._step, duration, med, thresh)
                self.events.append(event)
                if self.on_straggler:
                    self.on_straggler(event)
        self.window.append(duration)
        return event


def _median(xs) -> float:
    s = sorted(xs)
    n = len(s)
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])
