"""Gradient compression: int8 quantization with error feedback.

At 512+ chips the slow axis is the inter-pod link; compressing the
data-parallel gradient reduction 4x (bf16 -> int8) on that axis cuts the
collective roofline term proportionally.  Error feedback keeps the scheme
unbiased over time: the per-device quantization residual is added back to
the next step's gradient before quantizing (Seide et al.-style EF).

``compressed_psum`` is the shard_map building block: quantize per shard ->
integer all-reduce (psum of int32 to avoid overflow) -> dequantize with the
max-scale, residual returned to the caller.  ``ef_state`` mirrors the grad
pytree; kept in the train state when ``TrainConfig.grad_compression ==
"int8_ef"``.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro import compat


def quantize_ef(g: jax.Array, residual: jax.Array
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """-> (q int8, scale f32 scalar, new_residual)."""
    gf = g.astype(jnp.float32) + residual
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_residual = gf - q.astype(jnp.float32) * scale
    return q, scale, new_residual


def compressed_psum(g: jax.Array, residual: jax.Array, axis_name: str
                    ) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback int8 all-reduce over ``axis_name`` (inside shard_map).

    Every participant quantizes with its own scale; scales are maxed across
    the axis and the int32 sum is dequantized with the shared scale, so the
    wire format is int8 payload + one f32 scalar.
    """
    q, scale, new_residual = quantize_ef(g, residual)
    scale_max = jax.lax.pmax(scale, axis_name)
    # requantize against the shared scale so the integer sum is exact
    q_shared = jnp.clip(
        jnp.round(q.astype(jnp.float32) * (scale / scale_max)),
        -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q_shared, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    mean = total.astype(jnp.float32) * scale_max / n
    return mean.astype(g.dtype), new_residual


def init_ef_state(grads) -> Dict:
    return compat.tree_map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def tree_compressed_psum(grads, ef_state, axis_name: str):
    flat_g, treedef = compat.tree_flatten(grads)
    flat_r = compat.tree_leaves(ef_state)
    outs = [compressed_psum(g, r, axis_name) for g, r in zip(flat_g, flat_r)]
    new_g = compat.tree_unflatten(treedef, [o[0] for o in outs])
    new_r = compat.tree_unflatten(treedef, [o[1] for o in outs])
    return new_g, new_r
