"""Sharded, atomic, resharding-capable checkpoints (fault tolerance).

Layout: one directory per step; each pytree leaf becomes ``<leaf-id>.npy``
plus a ``manifest.json`` mapping tree paths -> files + dtypes + shapes +
step metadata.  Writes go to ``<dir>.tmp`` and are published with one
atomic ``os.replace`` so a preempted writer can never leave a torn
checkpoint; ``latest_step`` scans only published directories.

Elastic re-mesh: ``restore`` takes target shardings (any mesh size) and
reassembles each leaf via ``jax.make_array_from_callback`` — the saved
layout is mesh-agnostic (full logical arrays), so a 512-chip checkpoint
restores onto 256 or 1024 chips unchanged.  On multi-host deployments each
leaf callback reads only the file ranges its addressable shards need
(np.load with mmap), so restore traffic is O(local bytes), not O(model).

The MCAL campaign driver persists its own loop state (power-law history,
ledger, pool bitmap) through ``save_json`` so a preempted labeling campaign
resumes mid-loop.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import compat
import numpy as np


def _leaf_files(tree) -> Dict[str, Any]:
    leaves = compat.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in leaves}


def save(ckpt_dir: str, step: int, tree, extra: Optional[Dict] = None):
    """Atomically write ``tree`` under ``ckpt_dir/step_<n>``."""
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    for i, (key, leaf) in enumerate(_leaf_files(tree).items()):
        arr = np.asarray(jax.device_get(leaf))
        dtype_name = str(arr.dtype)
        if arr.dtype == jnp.bfloat16:  # npy has no native bf16: store bits
            arr = arr.view(np.uint16)
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": dtype_name}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like_tree,
            shardings=None) -> Any:
    """Restore into the structure of ``like_tree`` (abstract or concrete).

    ``shardings``: optional matching pytree of NamedShardings — the elastic
    re-mesh path; leaves are materialized shard-by-shard on the new mesh.
    """
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    paths = compat.tree_flatten_with_path(like_tree)[0]
    sh_leaves = (compat.tree_leaves(
        shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
        if shardings is not None else [None] * len(paths))
    assert len(sh_leaves) == len(paths), (len(sh_leaves), len(paths))
    out = []
    for (path, like), sh in zip(paths, sh_leaves):
        key = jax.tree_util.keystr(path)
        meta = manifest["leaves"][key]
        arr = np.load(os.path.join(d, meta["file"]), mmap_mode="r")
        if meta["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16.dtype)
        dtype = like.dtype if hasattr(like, "dtype") else arr.dtype
        if sh is None:
            out.append(jnp.asarray(np.asarray(arr), dtype=dtype))
        else:
            out.append(jax.make_array_from_callback(
                tuple(meta["shape"]), sh,
                lambda idx, a=arr, dt=dtype: np.asarray(a[idx]).astype(dt)))
    structure = compat.tree_structure(like_tree)
    return compat.tree_unflatten(structure, out), manifest


def save_json(path: str, obj: Dict):
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f)
    os.replace(tmp, path)


def load_json(path: str) -> Optional[Dict]:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)
