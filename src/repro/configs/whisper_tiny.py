"""whisper-tiny — encoder-decoder audio backbone (conv frontend STUB).
[arXiv:2212.04356; unverified]  4L d_model=384 6H (kv=6) d_ff=1536
vocab=51865.  LayerNorm + GELU + learned positions.  ``input_specs``
supplies precomputed frame embeddings (B, 1500, 384).  Vocab padded
51865 -> 51872 for even sharding.  max_seq_len covers the decode_32k cell
(the assigned shapes exceed Whisper's native 448-token decoder — shapes are
the spec)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,
    encoder_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51872,   # 51865 padded to a multiple of 16
    norm="layernorm",
    act="gelu",
    pos_embed="learned",
    encoder_tokens=1500,
    max_seq_len=32768,
    sharding="fsdp_tp",
    remat="layer",
    logits_chunk=16384,
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="audio",
    num_layers=2,
    encoder_layers=2,
    d_model=48,
    num_heads=3,
    num_kv_heads=3,
    head_dim=16,
    d_ff=96,
    vocab_size=128,
    norm="layernorm",
    act="gelu",
    pos_embed="learned",
    encoder_tokens=16,
    max_seq_len=128,
    remat="none",
)
