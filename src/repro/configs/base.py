"""Config system for the repro framework.

A single frozen dataclass describes every supported architecture family
(dense / ssm / hybrid / moe / vlm / audio).  Configs are plain data: models,
sharding rules and the launcher all consume them.  Each assigned architecture
lives in ``src/repro/configs/<id>.py`` exposing ``CONFIG`` (full size, used
only via ShapeDtypeStruct in the dry-run) and ``SMOKE`` (reduced, actually
instantiated in tests).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp

DTYPES = {
    "bfloat16": jnp.bfloat16,
    "float32": jnp.float32,
    "float16": jnp.float16,
}


@dataclass(frozen=True)
class ModelConfig:
    # identity -----------------------------------------------------------
    name: str = "model"
    family: str = "dense"  # dense | ssm | hybrid | moe | vlm | audio
    # backbone -----------------------------------------------------------
    num_layers: int = 2
    d_model: int = 128
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0            # 0 -> d_model // num_heads
    d_ff: int = 256
    vocab_size: int = 256
    act: str = "swiglu"          # swiglu | gelu
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    pos_embed: str = "rope"      # rope | learned (whisper)
    max_seq_len: int = 4096
    # attention pattern ---------------------------------------------------
    sliding_window: int = 0      # 0 -> full causal
    local_global_ratio: int = 0  # N -> every (N+1)-th layer is global (gemma3: 5)
    # ssm (mamba2 / hybrid) ------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_kernel: int = 4
    ssm_chunk: int = 128          # SSD chunk length
    # hybrid (zamba2-style shared attention block) -------------------------
    shared_attn_every: int = 0   # 0 -> no shared attention block
    # moe -------------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    num_shared_experts: int = 0   # kimi-style always-on shared expert(s)
    moe_gather_dtype: str = "bf16"  # "int8" halves the ZeRO-3 expert-shard
                                    # all-gather wire (lossy; see §Perf)
    moe_route: str = "replicate_psum"  # | "a2a" (token-routing EP, §Perf)
    moe_ffn_mode: str = "gather"       # | "psum" (local-F partial sums)
    # enc-dec (whisper) ------------------------------------------------------
    encoder_layers: int = 0
    encoder_tokens: int = 0      # stub frontend output length (1500 for whisper)
    # vlm -------------------------------------------------------------------
    frontend: str = ""           # "" | vit_stub | conv_stub
    frontend_tokens: int = 0     # patch tokens prepended to the text sequence
    # numerics / performance ------------------------------------------------
    dtype: str = "bfloat16"
    remat: str = "layer"         # none | layer | chunk
    remat_chunk: int = 0         # layers per remat chunk when remat == "chunk"
    scan_layers: bool = True
    logits_chunk: int = 0        # 0 -> materialize logits; else chunked CE/score
    # sharding --------------------------------------------------------------
    sharding: str = "fsdp_tp"    # tp | fsdp_tp
    seq_shard_train: bool = True # shard train activations' seq dim over "model"
    # classifier head for MCAL labeling tasks --------------------------------
    num_classes: int = 0         # 0 -> plain LM head over vocab
    input_dim: int = 0           # mlp family: feature-vector input width

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_num_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def jnp_dtype(self):
        return DTYPES[self.dtype]

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell: what gets lowered in the dry-run."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


@dataclass(frozen=True)
class TrainConfig:
    """Optimizer / schedule / runtime knobs."""

    learning_rate: float = 3e-4
    weight_decay: float = 0.01
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    # memory levers for giant models
    moment_dtype: str = "float32"     # float32 | bfloat16 | int8
    factored_second_moment: bool = False
    # schedule: the paper trains 200 epochs with 10x LR drops at 80/120/160/180
    schedule: str = "paper_steps"     # paper_steps | cosine | constant
    warmup_steps: int = 0
    total_steps: int = 1000
    # distributed tricks
    grad_compression: str = "none"    # none | int8_ef
    grad_accum: int = 1
    accum_dtype: str = "float32"      # grad-accumulation carry dtype;
                                      # bfloat16 halves the carry for
                                      # >=100B models (f32 carry alone is
                                      # 16 GB/chip for a 1T model @ 256)
