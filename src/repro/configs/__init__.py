"""Architecture registry: one module per assigned architecture.

``get_config(arch_id)`` / ``get_smoke(arch_id)`` resolve the full and
reduced configs; ``input_specs`` builds ShapeDtypeStruct stand-ins for every
model input of a given (config x shape) cell (dry-run pattern: weak-type
correct, shardable, no device allocation).
"""
from __future__ import annotations

import importlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, SHAPES_BY_NAME

ARCH_IDS = (
    "zamba2-2.7b",
    "phi3-medium-14b",
    "gemma3-4b",
    "qwen2-1.5b",
    "qwen1.5-4b",
    "mamba2-1.3b",
    "internvl2-26b",
    "kimi-k2-1t-a32b",
    "dbrx-132b",
    "whisper-tiny",
)

# long_500k needs sub-quadratic attention; pure full-attention archs skip it
# (see DESIGN.md §Arch-applicability / shape-cell skips).
LONG_CONTEXT_OK = {"zamba2-2.7b", "mamba2-1.3b", "gemma3-4b"}


def _module(arch_id: str):
    name = arch_id.replace("-", "_").replace(".", "p")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).CONFIG


def get_smoke(arch_id: str) -> ModelConfig:
    return _module(arch_id).SMOKE


def cells(arch_id: str):
    """The (shape) cells defined for this arch (applies long_500k skip)."""
    out = []
    for s in SHAPES:
        if s.name == "long_500k" and arch_id not in LONG_CONTEXT_OK:
            continue
        out.append(s)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                grad_accum: int = 1) -> Dict:
    """ShapeDtypeStruct stand-ins for the token-side step inputs.

    ``grad_accum > 1`` pre-splits train batches to (A, B//A, ...) — the
    microbatch scan dim is leading and never sharded.
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def tok(b, s):
        return jax.ShapeDtypeStruct((b, s), i32)

    if shape.kind == "decode":
        specs = {"tokens": tok(B, 1)}
    else:
        if cfg.family == "vlm" and cfg.frontend_tokens:
            t = S - cfg.frontend_tokens
            specs = {
                "tokens": tok(B, t),
                "patch_embeds": jax.ShapeDtypeStruct(
                    (B, cfg.frontend_tokens, cfg.d_model), jnp.float32),
            }
        elif cfg.family == "audio":
            specs = {
                "tokens": tok(B, S),
                "audio_frames": jax.ShapeDtypeStruct(
                    (B, cfg.encoder_tokens, cfg.d_model), jnp.float32),
            }
        else:
            specs = {"tokens": tok(B, S)}
        if shape.kind == "train":
            lt = specs["tokens"].shape[1]
            specs["labels"] = tok(B, lt)
            if grad_accum > 1:
                assert B % grad_accum == 0, (B, grad_accum)
                specs = {
                    k: jax.ShapeDtypeStruct(
                        (grad_accum, v.shape[0] // grad_accum) + v.shape[1:],
                        v.dtype)
                    for k, v in specs.items()}
    return specs


def input_pspecs(cfg: ModelConfig, shape: ShapeConfig, mesh, policy: str,
                 grad_accum: int = 1):
    """PartitionSpecs matching input_specs (batch over pod+data; the
    leading microbatch dim, when present, is unsharded)."""
    from repro.distributed.sharding import logical_to_pspec
    specs = input_specs(cfg, shape, grad_accum)
    accum = grad_accum > 1 and shape.kind == "train"
    out = {}
    for k, v in specs.items():
        logical = [None] * len(v.shape)
        logical[1 if accum else 0] = "batch"
        out[k] = logical_to_pspec(v.shape, logical, mesh, policy)
    return out
