"""qwen2-1.5b — dense GQA with QKV bias.
[arXiv:2407.10671; hf]  28L d_model=1536 12H (kv=2) d_ff=8960 vocab=151936."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    sharding="fsdp_tp",
    remat="layer",
    logits_chunk=16384,
)

SMOKE = ModelConfig(
    name="qwen2-smoke",
    family="dense",
    num_layers=3,
    d_model=48,
    num_heads=3,
    num_kv_heads=1,
    head_dim=16,
    d_ff=96,
    vocab_size=256,
    qkv_bias=True,
    remat="none",
)
