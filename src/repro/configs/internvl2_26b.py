"""internvl2-26b — VLM: InternViT frontend (STUB) + InternLM2 backbone.
[arXiv:2404.16821; hf]  48L d_model=6144 48H (kv=8) d_ff=16384 vocab=92553.
The ViT frontend is a stub: ``input_specs`` supplies precomputed patch
embeddings (B, 1024, d_model).  Vocab padded 92553 -> 92672 (multiple of
256) for even sharding; padding ids are never produced."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92672,   # 92553 padded to a multiple of 256
    frontend="vit_stub",
    frontend_tokens=1024,
    sharding="fsdp_tp",
    remat="layer",
    logits_chunk=16384,
)

SMOKE = ModelConfig(
    name="internvl2-smoke",
    family="vlm",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    frontend="vit_stub",
    frontend_tokens=8,
    remat="none",
)
