"""dbrx-132b — fine-grained MoE, 16 experts top-4.
[hf:databricks/dbrx-base; unverified]  40L d_model=6144 48H (kv=8)
d_ff=10752 (per expert) vocab=100352."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    num_experts=16,
    experts_per_token=4,
    moe_capacity_factor=1.25,
    sharding="fsdp_tp",
    seq_shard_train=False,
    remat="layer",
    logits_chunk=16384,
)

SMOKE = ModelConfig(
    name="dbrx-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=64,
    vocab_size=256,
    num_experts=4,
    experts_per_token=2,
    seq_shard_train=False,
    remat="none",
)
