"""kimi-k2-1t-a32b — trillion-param MoE, 384 experts top-8 + 1 shared expert.
[arXiv:2501.kimi2; unverified]  61L d_model=7168 64H (kv=8) d_ff=2048
(per expert) vocab=163840.

Simplification recorded in DESIGN.md: Kimi K2's dense first layer is modeled
as MoE like the rest (param delta ~0.03%); attention follows the assigned
GQA spec.  Expert weights are sharded expert->"model" and F->"data"
(ZeRO-3 gather on use) so the ~2 TB of expert weights fit 256 x 16 GB."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=2048,
    vocab_size=163840,
    num_experts=384,
    experts_per_token=8,
    num_shared_experts=1,
    moe_capacity_factor=1.25,
    sharding="fsdp_tp",
    seq_shard_train=False,   # MoE tokens stay batch-sharded (see moe_block)
    remat="layer",
    logits_chunk=16384,
)

SMOKE = ModelConfig(
    name="kimi-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=32,
    vocab_size=256,
    num_experts=8,
    experts_per_token=2,
    num_shared_experts=1,
    seq_shard_train=False,
    remat="none",
)
