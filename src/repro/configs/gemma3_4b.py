"""gemma3-4b — dense GQA, 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]  34L d_model=2560 8H (kv=4)
d_ff=10240 vocab=262144.  Every 6th layer is global; local layers use a
1024-token sliding window.  Tied embeddings (the 262k vocab dominates)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    tie_embeddings=True,
    sliding_window=1024,
    local_global_ratio=5,
    rope_theta=1_000_000.0,
    sharding="fsdp_tp",
    remat="layer",
    logits_chunk=16384,
)

SMOKE = ModelConfig(
    name="gemma3-smoke",
    family="dense",
    num_layers=6,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    tie_embeddings=True,
    sliding_window=8,
    local_global_ratio=5,
    remat="none",
)
