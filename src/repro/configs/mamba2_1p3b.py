"""mamba2-1.3b — attention-free SSD (state-space duality).
[arXiv:2405.21060; unverified]  48L d_model=2048 vocab=50280, ssm_state=128.
d_inner = 2*d_model = 4096, head_dim 64 -> 64 ssm heads."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=1,          # attention-free
    num_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv_kernel=4,
    sharding="fsdp_tp",
    remat="layer",
    logits_chunk=16384,
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    num_layers=4,
    d_model=64,
    num_heads=1,
    num_kv_heads=1,
    d_ff=0,
    vocab_size=256,
    ssm_state=8,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_conv_kernel=4,
    ssm_chunk=16,
    remat="none",
)
