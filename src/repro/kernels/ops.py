"""jit'd public wrappers over the Pallas kernels.

``use_pallas(...)`` gates kernel vs. jnp-reference per call site:
the kernels are written for TPU (Mosaic) and validated on CPU in
interpret mode; ``interpret`` is selected automatically from the backend.
The model layers call these entry points, so swapping kernel<->ref is a
flag, never a code change.
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import margin_head as _mh
from repro.kernels import pairwise_dist as _pd
from repro.kernels import ssd_scan as _ssd
from repro.kernels import ref as _ref
from repro.models.layers import ScoreStats


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# case-insensitive REPRO_USE_PALLAS vocabularies; anything outside them
# is a hard error — a typo like "ture" or an unsupported spelling used
# to fall through to False silently, running the jnp reference path on a
# host that asked for kernels
_PALLAS_TRUTHY = frozenset({"1", "true", "yes", "on"})
_PALLAS_FALSY = frozenset({"0", "false", "no", "off"})


def use_pallas() -> bool:
    env = os.environ.get("REPRO_USE_PALLAS", "auto")
    val = env.strip().lower()
    if val in ("", "auto"):       # "" = exported-but-empty: unset intent
        return jax.default_backend() == "tpu"
    if val in _PALLAS_TRUTHY:
        return True
    if val in _PALLAS_FALSY:
        return False
    raise ValueError(
        f"REPRO_USE_PALLAS={env!r} is not a recognized setting: use one "
        f"of {sorted(_PALLAS_TRUTHY)} / {sorted(_PALLAS_FALSY)} / 'auto'")


def score_head(hidden: jax.Array, w_vocab: jax.Array, *,
               force_pallas: Optional[bool] = None) -> ScoreStats:
    """Pool-scoring statistics for MCAL's M(.)/L(.).  hidden: (..., D)."""
    lead = hidden.shape[:-1]
    h2 = hidden.reshape(-1, hidden.shape[-1])
    on = use_pallas() if force_pallas is None else force_pallas
    if on:
        m, e, mlp, t1 = _mh.margin_head(h2, w_vocab, interpret=_interpret())
    else:
        m, e, mlp, t1 = _ref.margin_head_ref(h2, w_vocab)
    return ScoreStats(
        margin=m.reshape(lead), entropy=e.reshape(lead),
        max_logprob=mlp.reshape(lead), top1=t1.reshape(lead))


def pairwise_sqdist(x: jax.Array, c: jax.Array, *,
                    force_pallas: Optional[bool] = None) -> jax.Array:
    """(N, D) x (M, D) -> (N, M) squared distances for k-center M(.)."""
    on = use_pallas() if force_pallas is None else force_pallas
    if on:
        return _pd.pairwise_sqdist(x, c, interpret=_interpret())
    return _ref.pairwise_sqdist_ref(x, c)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int = 0,
              scale: Optional[float] = None,
              force_pallas: Optional[bool] = None) -> jax.Array:
    """Model-layout attention (B, T, H, hd) x (B, Tk, Hk, hd)."""
    on = use_pallas() if force_pallas is None else force_pallas
    if on:
        out = _fa.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal, window=window,
            scale=scale, interpret=_interpret())
        return out.transpose(0, 2, 1, 3)
    from repro.models.layers import blockwise_attention
    return blockwise_attention(q, k, v, causal=causal, window=window,
                               scale=scale, kv_chunk=min(1024, k.shape[1]))


def ssd(xh, dt, A, Bm, Cm, *, chunk: int = 128,
        force_pallas: Optional[bool] = None):
    on = use_pallas() if force_pallas is None else force_pallas
    if on:
        return _ssd.ssd_scan(xh, dt, A, Bm, Cm, chunk=chunk,
                             interpret=_interpret())
    return _ref.ssd_scan_ref(xh, dt, A, Bm, Cm, chunk=chunk)
