"""Mamba2 SSD (state-space duality) chunked-scan Pallas kernel.

TPU adaptation of the SSD algorithm: instead of the CUDA implementation's
warp-level selective scan, each chunk is processed as dense MXU matmuls
(the quadratic intra-chunk term + two skinny state matmuls) and the
inter-chunk recurrence is carried through VMEM scratch across the chunk
grid dimension — the state never round-trips to HBM.

Grid: (B, T // C) with the chunk index innermost.  All per-chunk einsums
are phrased as 2-D matmuls (heads folded into rows) so Mosaic maps them
onto the 128x128 MXU.

The jnp oracle is ``repro.models.mamba2.ssd_chunked`` (ref.py re-exports).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(xh_ref, dt_ref, A_ref, B_ref, C_ref, y_ref, hfin_ref, h_sc, *,
            C: int, H: int, hd: int, N: int):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        h_sc[:] = jnp.zeros_like(h_sc)

    xh = xh_ref[0].astype(jnp.float32)        # (C, H, hd)
    dt = dt_ref[0].astype(jnp.float32)        # (C, H)
    A = A_ref[:].astype(jnp.float32)          # (H,)
    Bc = B_ref[0].astype(jnp.float32)         # (C, N)
    Cc = C_ref[0].astype(jnp.float32)         # (C, N)

    la = -(dt * A[None, :])                   # (C, H) log decay
    cum = jnp.cumsum(la, axis=0)              # inclusive l_t
    xd = xh * dt[..., None]                   # (C, H, hd)

    # intra-chunk: Y[t] = sum_{s<=t} (C_t . B_s) exp(l_t - l_s) x_s
    t_pos = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    s_pos = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    tril = t_pos >= s_pos
    diff = cum[:, None, :] - cum[None, :, :]              # (C, C, H)
    Lmat = jnp.exp(jnp.where(tril[:, :, None], diff, NEG_INF))
    scores = jnp.dot(Cc, Bc.T, preferred_element_type=jnp.float32)  # (C, C)
    W = scores[:, :, None] * Lmat                         # (t, s, H)
    Wh = W.transpose(2, 0, 1)                             # (H, t, s)
    xdh = xd.transpose(1, 0, 2)                           # (H, s, hd)
    y_intra = jax.lax.dot_general(
        Wh, xdh, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)               # (H, t, hd)
    y_intra = y_intra.transpose(1, 0, 2)                  # (t, H, hd)

    # chunk state summary: S[h,d,n] = sum_s exp(l_last - l_s) xd[s,h,d] B[s,n]
    decay_end = jnp.exp(cum[-1:, :] - cum)                # (C, H)
    z = (xd * decay_end[..., None]).transpose(1, 2, 0)    # (H, hd, C)
    S = jnp.dot(z.reshape(H * hd, C), Bc,
                preferred_element_type=jnp.float32)       # (H*hd, N)

    # inter-chunk: y_inter[t] = exp(l_t) * C_t . h_prev
    h_prev = h_sc[:]                                      # (H*hd, N)
    y_inter = jnp.dot(Cc, h_prev.T,
                      preferred_element_type=jnp.float32)  # (C, H*hd)
    y_inter = y_inter.reshape(C, H, hd) * jnp.exp(cum)[..., None]

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    gamma = jnp.exp(cum[-1, :])                           # (H,)
    g = jnp.broadcast_to(gamma[:, None, None], (H, hd, 1)).reshape(H * hd, 1)
    h_sc[:] = g * h_prev + S

    @pl.when(ci == nc - 1)
    def _emit():
        hfin_ref[0] = h_sc[:].reshape(H, hd, N)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(xh: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
             Cm: jax.Array, *, chunk: int = 128,
             interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD.  xh: (B, T, H, hd); dt: (B, T, H); A: (H,);
    Bm/Cm: (B, T, N).  Returns (y (B, T, H, hd), h_final (B, H, hd, N)).

    T is padded to a chunk multiple with dt = 0 (exact: unit decay, zero
    state update).
    """
    Bsz, T, H, hd = xh.shape
    N = Bm.shape[-1]
    C = min(chunk, T)
    pad = (-T) % C
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Tp = T + pad
    nc = Tp // C

    grid = (Bsz, nc)
    y, hfin = pl.pallas_call(
        functools.partial(_kernel, C=C, H=H, hd=hd, N=N),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, C, H, hd), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, C, H), lambda b, c: (b, c, 0)),
            pl.BlockSpec((H,), lambda b, c: (0,)),
            pl.BlockSpec((1, C, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, C, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, C, H, hd), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, H, hd, N), lambda b, c: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, Tp, H, hd), xh.dtype),
            jax.ShapeDtypeStruct((Bsz, H, hd, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((H * hd, N), jnp.float32)],
        interpret=interpret,
    )(xh, dt, A, Bm, Cm)
    return y[:, :T], hfin
