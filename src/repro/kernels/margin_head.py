"""Fused vocab-projection + online top-2 / logsumexp / entropy Pallas kernel.

MCAL's pool-scoring hot spot: ranking millions of unlabeled samples by
margin / entropy / least-confidence requires the final projection
``hidden @ W_vocab`` over vocabularies up to 262k.  Materializing the
(T, V) logits in HBM is O(T*V) memory traffic; this kernel keeps logits as
MXU-aligned VMEM tiles only and carries per-token running statistics
(max, sum-exp, sum x*exp — fp32) across the vocab-tile grid dimension —
the online-softmax trick applied to MCAL's L(.)/M(.) metrics.  HBM traffic
drops from O(T*V) to O(T*D + D*V + T).

Grid: (T tiles, V tiles), V innermost so the scratch carry is sequential.
Per grid step: one (bt, D) x (D, bv) MXU matmul + row reductions.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(h_ref, w_ref, margin_ref, ent_ref, mlp_ref, top1_ref,
            m_sc, s_sc, u_sc, v1_sc, v2_sc, i1_sc, *, V: int, bv: int):
    vi = pl.program_id(1)
    nv = pl.num_programs(1)

    @pl.when(vi == 0)
    def _init():
        m_sc[:] = jnp.full_like(m_sc, NEG_INF)
        s_sc[:] = jnp.zeros_like(s_sc)
        u_sc[:] = jnp.zeros_like(u_sc)
        v1_sc[:] = jnp.full_like(v1_sc, NEG_INF)
        v2_sc[:] = jnp.full_like(v2_sc, NEG_INF)
        i1_sc[:] = jnp.zeros_like(i1_sc)

    x = jnp.dot(h_ref[:], w_ref[:], preferred_element_type=jnp.float32)
    col = vi * bv + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    valid = col < V
    x = jnp.where(valid, x, NEG_INF)

    # online logsumexp + sum(x * e^x) (entropy numerator)
    m_old, s_old, u_old = m_sc[:], s_sc[:], u_sc[:]
    cm = jnp.max(x, axis=-1)
    m_new = jnp.maximum(m_old, cm)
    corr = jnp.exp(m_old - m_new)
    e = jnp.exp(x - m_new[:, None])
    s_sc[:] = s_old * corr + jnp.sum(e, axis=-1)
    u_sc[:] = u_old * corr + jnp.sum(jnp.where(valid, x, 0.0) * e, axis=-1)
    m_sc[:] = m_new

    # online top-2 merge: tile top-2 vs carried top-2
    c1 = jnp.max(x, axis=-1)
    a1 = jnp.argmax(x, axis=-1)  # local tile index
    local = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    x2 = jnp.where(local == a1[:, None], NEG_INF, x)
    c2 = jnp.max(x2, axis=-1)
    v1_old, v2_old, i1_old = v1_sc[:], v2_sc[:], i1_sc[:]
    v1_new = jnp.maximum(v1_old, c1)
    v2_new = jnp.maximum(jnp.minimum(v1_old, c1), jnp.maximum(v2_old, c2))
    i1_sc[:] = jnp.where(c1 > v1_old, a1.astype(jnp.int32) + vi * bv, i1_old)
    v1_sc[:] = v1_new
    v2_sc[:] = v2_new

    @pl.when(vi == nv - 1)
    def _emit():
        s = jnp.maximum(s_sc[:], 1e-30)
        lse = m_sc[:] + jnp.log(s)
        margin_ref[:] = v1_sc[:] - v2_sc[:]
        ent_ref[:] = lse - u_sc[:] / s
        mlp_ref[:] = v1_sc[:] - lse
        top1_ref[:] = i1_sc[:]


@functools.partial(jax.jit, static_argnames=("bt", "bv", "interpret"))
def margin_head(hidden: jax.Array, w_vocab: jax.Array, *,
                bt: int = 128, bv: int = 512,
                interpret: bool = True) -> Tuple[jax.Array, ...]:
    """hidden: (T, D); w_vocab: (D, V) ->
    (margin (T,), entropy (T,), max_logprob (T,), top1 (T,) i32), fp32.

    BlockSpecs: hidden (bt, D) and weight (D, bv) tiles live in VMEM; with
    the defaults and D=8192 that is bt*D*2 + D*bv*2 ~ 10 MB < v5e VMEM.
    T/V are padded up to tile multiples; padded vocab columns are masked.
    """
    T, D = hidden.shape
    D2, V = w_vocab.shape
    assert D == D2, (hidden.shape, w_vocab.shape)
    Tp = -(-T // bt) * bt
    Vp = -(-V // bv) * bv
    if Tp != T:
        hidden = jnp.pad(hidden, ((0, Tp - T), (0, 0)))
    if Vp != V:
        w_vocab = jnp.pad(w_vocab, ((0, 0), (0, Vp - V)))
    grid = (Tp // bt, Vp // bv)

    out_shape = [
        jax.ShapeDtypeStruct((Tp,), jnp.float32),  # margin
        jax.ShapeDtypeStruct((Tp,), jnp.float32),  # entropy
        jax.ShapeDtypeStruct((Tp,), jnp.float32),  # max_logprob
        jax.ShapeDtypeStruct((Tp,), jnp.int32),    # top1
    ]
    stat_spec = pl.BlockSpec((bt,), lambda t, v: (t,))
    outs = pl.pallas_call(
        functools.partial(_kernel, V=V, bv=bv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, D), lambda t, v: (t, 0)),
            pl.BlockSpec((D, bv), lambda t, v: (0, v)),
        ],
        out_specs=[stat_spec] * 4,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((bt,), jnp.float32),  # m
            pltpu.VMEM((bt,), jnp.float32),  # s
            pltpu.VMEM((bt,), jnp.float32),  # u
            pltpu.VMEM((bt,), jnp.float32),  # v1
            pltpu.VMEM((bt,), jnp.float32),  # v2
            pltpu.VMEM((bt,), jnp.int32),    # i1
        ],
        interpret=interpret,
    )(hidden, w_vocab)
    return tuple(o[:T] for o in outs)
