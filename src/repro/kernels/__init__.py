"""Pallas TPU kernels for the compute hot-spots MCAL exercises at scale:

* ``margin_head``     — fused vocab projection + online top-2/entropy/lse
                        (pool scoring over 100k-262k vocabularies);
* ``flash_attention`` — blockwise attention, causal/sliding-window, GQA via
                        BlockSpec index mapping (prefill hot-spot);
* ``ssd_scan``        — Mamba2 SSD chunked scan, state carried in VMEM.

``ops`` holds the jit'd wrappers (kernel or jnp-ref, backend-gated);
``ref`` the pure-jnp oracles used by the allclose test sweeps.
"""
from repro.kernels import ops, ref  # noqa: F401
from repro.kernels.flash_attention import flash_attention  # noqa: F401
from repro.kernels.margin_head import margin_head  # noqa: F401
from repro.kernels.ssd_scan import ssd_scan  # noqa: F401
