"""Tiled pairwise squared-euclidean-distance Pallas kernel.

The k-center M(.) engine (``repro.core.selection_device``) needs blocks of
the (N, M) squared-distance matrix between pool features and center/anchor
features: the full matrix never has to exist at once — greedy farthest-point
only consumes a running column-min.  This kernel produces one (bn, bm) tile
per grid step from a (bn, D) row tile and a (bm, D) center tile, both VMEM
resident, via the expansion

    ||x - c||^2 = ||x||^2 - 2 x.c + ||c||^2

so the inner product rides the MXU ((bn, D) x (D, bm) per step) and HBM
traffic stays O(N*D + M*D + N*M) instead of the O(N*M*D) a materialized
difference tensor would cost.

Padded center columns are masked to ``BIG`` (not 0).  Today the wrapper
trims to the true (N, M) before returning, so no caller observes them —
the mask exists for the planned in-kernel column-min epilogue (ROADMAP:
fold the anchor min into the kernel), where a phantom zero distance in a
padded column would corrupt the reduction.  Distances are clamped at 0 —
the expansion can go epsilon-negative in float for x ~ c.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 1e30


def _kernel(x_ref, c_ref, out_ref, *, M: int, bm: int):
    ci = pl.program_id(1)
    x = x_ref[:].astype(jnp.float32)                       # (bn, D)
    c = c_ref[:].astype(jnp.float32)                       # (bm, D)
    x2 = jnp.sum(x * x, axis=-1)                           # (bn,)
    c2 = jnp.sum(c * c, axis=-1)                           # (bm,)
    g = jax.lax.dot_general(
        x, c, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                # (bn, bm)
    d = jnp.maximum(x2[:, None] - 2.0 * g + c2[None, :], 0.0)
    col = ci * bm + jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    out_ref[:] = jnp.where(col < M, d, BIG)


@functools.partial(jax.jit, static_argnames=("bn", "bm", "interpret"))
def pairwise_sqdist(x: jax.Array, c: jax.Array, *, bn: int = 256,
                    bm: int = 128, interpret: bool = True) -> jax.Array:
    """x: (N, D) rows; c: (M, D) centers -> (N, M) squared distances, fp32.

    N/M are padded up to tile multiples (padded rows/cols trimmed from the
    result); D stays whole per tile like ``margin_head`` holds (bt, D).
    """
    N, D = x.shape
    M, D2 = c.shape
    assert D == D2, (x.shape, c.shape)
    Np = -(-N // bn) * bn
    Mp = -(-M // bm) * bm
    if Np != N:
        x = jnp.pad(x, ((0, Np - N), (0, 0)))
    if Mp != M:
        c = jnp.pad(c, ((0, Mp - M), (0, 0)))
    grid = (Np // bn, Mp // bm)
    out = pl.pallas_call(
        functools.partial(_kernel, M=M, bm=bm),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, D), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, D), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Np, Mp), jnp.float32),
        interpret=interpret,
    )(x, c)
    return out[:N, :M]
