"""Blockwise (flash) attention Pallas kernel with causal + sliding-window
masking and GQA via BlockSpec index mapping (no KV head expansion copy).

Grid: (B, H, Tq tiles, Tk tiles) — Tk innermost; the (o, m, l) online-
softmax carry lives in VMEM scratch and the normalized output is written at
the last Tk step.  KV blocks for query head h are fetched from kv head
h // group via the index_map, so GQA never materializes repeated K/V.

Tile defaults (bq=bk=256, hd<=256) keep q/k/v/o tiles around 0.5-1 MB —
comfortably inside v5e VMEM with double buffering.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, o_sc, m_sc, l_sc, *,
            scale: float, causal: bool, window: int, bq: int, bk: int,
            Tq: int, Tk: int):
    ti = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        o_sc[:] = jnp.zeros_like(o_sc)
        m_sc[:] = jnp.full_like(m_sc, NEG_INF)
        l_sc[:] = jnp.zeros_like(l_sc)

    q = q_ref[0, 0] * scale                      # (bq, hd)
    k = k_ref[0, 0]                              # (bk, hd)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)

    q_pos = ti * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = (q_pos < Tq) & (k_pos < Tk)
    if causal:
        ok &= q_pos >= k_pos
    if window > 0:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok, s, NEG_INF)

    m_old, l_old = m_sc[:], l_sc[:]
    m_new = jnp.maximum(m_old, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_old - m_new)
    l_sc[:] = l_old * corr + jnp.sum(p, axis=-1)
    pv = jnp.dot(p.astype(v_ref.dtype), v_ref[0, 0],
                 preferred_element_type=jnp.float32)
    o_sc[:] = o_sc[:] * corr[:, None] + pv
    m_sc[:] = m_new

    @pl.when(ki == nk - 1)
    def _emit():
        o_ref[0, 0] = (o_sc[:] / jnp.maximum(l_sc[:], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    scale: Optional[float] = None,
                    bq: int = 256, bk: int = 256,
                    interpret: bool = True) -> jax.Array:
    """q: (B, H, Tq, hd); k, v: (B, Hk, Tk, hd), H % Hk == 0 -> (B, H, Tq, hd).

    Note the head-major layout (transposed from the model's (B, T, H, hd));
    ``ops.attention`` adapts.
    """
    B, H, Tq, hd = q.shape
    _, Hk, Tk, _ = k.shape
    assert H % Hk == 0, (H, Hk)
    G = H // Hk
    scale = hd ** -0.5 if scale is None else scale
    bq = min(bq, max(Tq, 8))
    bk = min(bk, max(Tk, 8))
    Tqp = -(-Tq // bq) * bq
    Tkp = -(-Tk // bk) * bk
    if Tqp != Tq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Tqp - Tq), (0, 0)))
    if Tkp != Tk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Tkp - Tk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Tkp - Tk), (0, 0)))

    grid = (B, H, Tqp // bq, Tkp // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          bq=bq, bk=bk, Tq=Tq, Tk=Tk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, t, s: (b, h, t, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, t, s: (b, h // G, s, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, t, s: (b, h // G, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, t, s: (b, h, t, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Tqp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Tq, :]
