"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are the production fallback paths too: on hosts without Mosaic the
model layers call these, so kernel and reference stay API-identical.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import (ScoreStats, blockwise_attention,
                                 score_stats_from_logits)
from repro.models.mamba2 import ssd_chunked


def margin_head_ref(hidden: jax.Array, w_vocab: jax.Array
                    ) -> Tuple[jax.Array, ...]:
    """(T, D) x (D, V) -> (margin, entropy, max_logprob, top1)."""
    stats = score_stats_from_logits(
        jnp.einsum("td,dv->tv", hidden, w_vocab,
                   preferred_element_type=jnp.float32))
    return (stats.margin, stats.entropy, stats.max_logprob, stats.top1)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        scale: Optional[float] = None) -> jax.Array:
    """Head-major (B, H, T, hd) adapter over the blockwise jnp attention."""
    out = blockwise_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal, window=window, scale=scale,
        kv_chunk=min(1024, k.shape[2]))
    return out.transpose(0, 2, 1, 3)


def ssd_scan_ref(xh, dt, A, Bm, Cm, *, chunk: int = 128):
    return ssd_chunked(xh, dt, A, Bm, Cm, chunk)


def pairwise_sqdist_ref(x: jax.Array, c: jax.Array) -> jax.Array:
    """(N, D) x (M, D) -> (N, M) squared euclidean distances, fp32.

    Same expansion as the Pallas kernel (||x||^2 - 2 x.c + ||c||^2,
    clamped at 0) so kernel and reference round identically."""
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=-1)
    c2 = jnp.sum(c * c, axis=-1)
    g = jnp.einsum("nd,md->nm", x, c, preferred_element_type=jnp.float32)
    return jnp.maximum(x2[:, None] - 2.0 * g + c2[None, :], 0.0)
