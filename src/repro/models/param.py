"""Declarative parameter specs.

Models declare their parameters as nested dicts of :class:`ParamSpec`;
from one declaration we derive (a) real initialization, (b) abstract
ShapeDtypeStruct trees for the dry-run, and (c) the logical-axes tree the
sharding rules consume.  Keeps model code to pure functions over pytrees.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones
    scale: Optional[float] = None  # stddev; None -> 1/sqrt(fan_in)
    dtype: Any = jnp.bfloat16
    fan_in_axes: Tuple[int, ...] = ()  # axes treated as fan-in (default: all but last)

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _stddev(spec: ParamSpec) -> float:
    if spec.scale is not None:
        return spec.scale
    if spec.fan_in_axes:
        fan_in = int(np.prod([spec.shape[a] for a in spec.fan_in_axes]))
    else:
        fan_in = int(np.prod(spec.shape[:-1])) if len(spec.shape) > 1 else spec.shape[0]
    return 1.0 / np.sqrt(max(fan_in, 1))


def init_params(specs: Dict, rng: jax.Array) -> Dict:
    """Materialize a spec tree into real arrays (deterministic per path)."""
    leaves, treedef = compat.tree_flatten_with_path(specs, is_leaf=_is_spec)
    out = []
    for path, spec in leaves:
        # stable path digest: python's hash() is salted per process
        # (PYTHONHASHSEED), which made "deterministic per path" a lie
        # across runs — crc32 is reproducible everywhere
        key = jax.random.fold_in(
            rng, zlib.crc32(compat.keystr(path).encode()) % (2**31))
        if spec.init == "zeros":
            arr = jnp.zeros(spec.shape, spec.dtype)
        elif spec.init == "ones":
            arr = jnp.ones(spec.shape, spec.dtype)
        else:
            arr = (jax.random.normal(key, spec.shape, jnp.float32) * _stddev(spec)).astype(spec.dtype)
        out.append(arr)
    return compat.tree_unflatten(
        compat.tree_structure(specs, is_leaf=_is_spec), out)


def abstract_params(specs: Dict) -> Dict:
    return compat.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs, is_leaf=_is_spec
    )


def logical_axes(specs: Dict) -> Dict:
    return compat.tree_map(lambda s: s.logical, specs, is_leaf=_is_spec)


def param_count(specs: Dict) -> int:
    return sum(int(np.prod(s.shape))
               for s in compat.tree_leaves(specs, is_leaf=_is_spec))
