"""Family -> implementation registry + uniform model facade."""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax

from repro.configs.base import ModelConfig
from repro.models import param as P


def get_module(cfg: ModelConfig):
    from repro.models import encdec, hybrid, mamba2, mlp, transformer
    return {
        "dense": transformer,
        "moe": transformer,
        "vlm": transformer,
        "ssm": mamba2,
        "hybrid": hybrid,
        "audio": encdec,
        "mlp": mlp,
    }[cfg.family]


class Model:
    """Thin facade: specs/init/forward/prefill/decode with a uniform batch
    dict ({"tokens", optional "patch_embeds"/"audio_frames"})."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.mod = get_module(cfg)
        self._specs = self.mod.specs(cfg)

    # -- params ----------------------------------------------------------
    @property
    def specs(self) -> Dict:
        return self._specs

    def init(self, rng: jax.Array) -> Dict:
        return P.init_params(self._specs, rng)

    def abstract_params(self) -> Dict:
        return P.abstract_params(self._specs)

    def logical_axes(self) -> Dict:
        return P.logical_axes(self._specs)

    def param_count(self) -> int:
        return P.param_count(self._specs)

    # -- compute ----------------------------------------------------------
    def _frontend(self, batch: Dict):
        return batch.get("patch_embeds", batch.get("audio_frames"))

    def forward(self, params: Dict, batch: Dict, mesh=None) -> jax.Array:
        if self.cfg.family == "mlp":
            return self.mod.forward(self.cfg, params, batch["features"], mesh=mesh)
        fe = self._frontend(batch)
        if fe is None:
            return self.mod.forward(self.cfg, params, batch["tokens"], mesh=mesh)
        return self.mod.forward(self.cfg, params, batch["tokens"], fe, mesh=mesh)

    def prefill(self, params: Dict, batch: Dict, mesh=None):
        fe = self._frontend(batch)
        if fe is None:
            return self.mod.prefill(self.cfg, params, batch["tokens"], mesh=mesh)
        return self.mod.prefill(self.cfg, params, batch["tokens"], fe, mesh=mesh)

    def decode_step(self, params: Dict, cache: Dict, tokens: jax.Array,
                    cache_len, mesh=None):
        return self.mod.decode_step(self.cfg, params, cache, tokens, cache_len,
                                    mesh=mesh)

    def cache_specs(self, batch: int, seq_len: int):
        return self.mod.cache_specs(self.cfg, batch, seq_len)

    def init_cache(self, batch: int, seq_len: int):
        return self.mod.init_cache(self.cfg, batch, seq_len)

    def logits(self, params: Dict, hidden: jax.Array) -> jax.Array:
        from repro.models import transformer as tf
        return tf.logits_fn(self.cfg, params, hidden)


def get_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
