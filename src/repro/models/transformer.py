"""Decoder-only transformer covering the dense / moe / vlm families.

One implementation, config-driven:
  * GQA attention (optional QKV bias, RoPE, sliding window, gemma3-style
    local:global interleave via per-layer flags in the layer scan),
  * SwiGLU MLP or expert-parallel MoE (shard_map over the "model" axis with
    capacity-based dispatch and a ZeRO-3-style gather of the expert-FFN
    shard; dispatch/combine loop over k so no (n*k, D) tensor ever
    materializes),
  * optional stub patch-embedding frontend (VLM) and classification head
    (MCAL labeling tasks).

Layers are stacked and scanned (compile time O(1) in depth); remat policy is
configurable (none / per-layer / chunked).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec as P

from repro import compat
from repro.compat import shard_map as _shard_map

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.param import ParamSpec


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------


def attention_specs(cfg: ModelConfig, nl: int) -> Dict:
    """Attention params; nl == 0 -> unstacked (single shared block)."""
    hd = cfg.resolved_head_dim
    s, a = ((nl,), ("layers",)) if nl else ((), ())
    sp = {
        "norm": L.norm_specs(cfg, stacked=nl),
        "wq": ParamSpec(s + (cfg.d_model, cfg.num_heads, hd),
                        a + ("embed", "heads", None)),
        "wk": ParamSpec(s + (cfg.d_model, cfg.num_kv_heads, hd),
                        a + ("embed", "kv", None)),
        "wv": ParamSpec(s + (cfg.d_model, cfg.num_kv_heads, hd),
                        a + ("embed", "kv", None)),
        "wo": ParamSpec(s + (cfg.num_heads, hd, cfg.d_model),
                        a + ("heads", None, "embed")),
    }
    if cfg.qkv_bias:
        sp["bq"] = ParamSpec(s + (cfg.num_heads, hd), a + ("heads", None), init="zeros")
        sp["bk"] = ParamSpec(s + (cfg.num_kv_heads, hd), a + ("kv", None), init="zeros")
        sp["bv"] = ParamSpec(s + (cfg.num_kv_heads, hd), a + ("kv", None), init="zeros")
    return sp


def moe_specs(cfg: ModelConfig, nl: int) -> Dict:
    E, D, F = cfg.num_experts, cfg.d_model, cfg.d_ff
    sp = {
        "router": ParamSpec((nl, D, E), ("layers", "embed", None), dtype=jnp.float32),
        "w_gate": ParamSpec((nl, E, D, F), ("layers", "expert", "embed", "expert_mlp")),
        "w_up": ParamSpec((nl, E, D, F), ("layers", "expert", "embed", "expert_mlp")),
        "w_down": ParamSpec((nl, E, F, D), ("layers", "expert", "expert_mlp", "embed")),
    }
    if cfg.num_shared_experts:
        sp["shared"] = L.mlp_specs(cfg, stacked=nl,
                                   d_ff=cfg.num_shared_experts * cfg.d_ff)
    return sp


def specs(cfg: ModelConfig) -> Dict:
    nl = cfg.num_layers
    sp: Dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), scale=1.0),
        "blocks": {
            "attn": attention_specs(cfg, nl),
            "mlp_norm": L.norm_specs(cfg, stacked=nl),
            "mlp": moe_specs(cfg, nl) if cfg.family == "moe" else L.mlp_specs(cfg, stacked=nl),
        },
        "final_norm": L.norm_specs(cfg),
    }
    if not cfg.tie_embeddings:
        sp["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    if cfg.num_classes:
        sp["cls_head"] = ParamSpec((cfg.d_model, cfg.num_classes), ("embed", None))
    return sp


# ---------------------------------------------------------------------------
# MoE block
# ---------------------------------------------------------------------------


def _expert_ffn(cfg: ModelConfig, p: Dict, buf: jax.Array,
                axis_data: Optional[str]) -> jax.Array:
    """SwiGLU expert FFN over bucketed tokens buf (E_loc, cap, D).

    When the expert-FFN dim F is sharded over ``axis_data`` (ZeRO-3), two
    routes: "gather" re-gathers the F shards per use (optionally int8 —
    see EXPERIMENTS §Perf Cell C); "psum" computes with the local F slice
    (SwiGLU is elementwise in F) and psums the partial down-projection —
    token-bytes on the wire instead of weight-bytes.  NOTE: "psum" is only
    valid when every ``axis_data`` rank holds the SAME tokens (replicated);
    with data-sharded tokens (the a2a route) it would sum unrelated
    tokens' outputs — use "gather" there.
    """
    w_gate, w_up, w_down = p["w_gate"], p["w_up"], p["w_down"]
    if axis_data is not None and cfg.moe_ffn_mode == "gather":
        def gather(w, ax):
            if cfg.moe_gather_dtype == "int8":
                # Forward: quantize the local shard against a per-expert
                # global scale (one tiny pmax) and gather int8 — the wire
                # halves vs bf16.  Backward: the exact transpose of a tiled
                # all-gather (psum_scatter), unquantized.
                @jax.custom_vjp
                def q_gather(x):
                    smax = jax.lax.pmax(
                        jnp.max(jnp.abs(x.astype(jnp.float32)),
                                axis=(1, 2), keepdims=True), axis_data)
                    scale = smax / 127.0 + 1e-12
                    q8 = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                                  -127, 127).astype(jnp.int8)
                    qg = jax.lax.all_gather(q8, axis_data, axis=ax,
                                            tiled=True)
                    return (qg.astype(jnp.float32) * scale).astype(x.dtype)

                dtype = w.dtype  # static via closure (not a JAX residual)

                def _fwd(x):
                    return q_gather(x), ()

                def _bwd(_, g):
                    return (jax.lax.psum_scatter(
                        g, axis_data, scatter_dimension=ax,
                        tiled=True).astype(dtype),)

                q_gather.defvjp(_fwd, _bwd)
                return q_gather(w)
            return jax.lax.all_gather(w, axis_data, axis=ax, tiled=True)

        w_gate = gather(w_gate, 2)
        w_up = gather(w_up, 2)
        w_down = gather(w_down, 1)

    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(buf.dtype) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, w_down)
    if axis_data is not None and cfg.moe_ffn_mode == "psum":
        out_buf = jax.lax.psum(out_buf.astype(jnp.float32),
                               axis_data).astype(buf.dtype)
    return out_buf


def _bucket_by(ids: jax.Array, n_buckets: int, cap: int):
    """Scatter positions for copies with bucket `ids` (invalid == n_buckets).
    Returns (bucket, slot, keep): slot < cap kept; rest dropped."""
    onehot = jax.nn.one_hot(ids, n_buckets + 1, dtype=jnp.int32)
    pos = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot, axis=-1) - 1
    keep = (ids < n_buckets) & (pos < cap)
    return jnp.where(keep, ids, 0), jnp.where(keep, pos, cap), keep


def _moe_local(cfg: ModelConfig, p: Dict, x: jax.Array, e0,
               n_local_experts: int, axis_data: Optional[str]) -> jax.Array:
    """Per-device MoE over x (n, D); local experts [e0, e0 + E_loc)."""
    n, D = x.shape
    E = cfg.num_experts
    k = min(cfg.experts_per_token, E)
    router_logits = jnp.einsum("nd,de->ne", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                      # (n, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # buffer slot assignment: global cumsum over all n*k assignments
    flat_e = top_e.reshape(-1) - e0                              # (n*k,)
    mine = (flat_e >= 0) & (flat_e < n_local_experts)
    flat_e = jnp.where(mine, flat_e, n_local_experts)            # trash bucket
    cap = int(np.ceil(n * k / E * cfg.moe_capacity_factor))
    cap = max(min(cap, n * k), min(n * k, 16))
    dest_e, dest_c, keep = _bucket_by(flat_e, n_local_experts, cap)
    dest_e = dest_e.reshape(n, k)
    dest_c = dest_c.reshape(n, k)                                # cap == trash
    keep = keep.reshape(n, k)

    # dispatch: loop over k so only (n, D)-sized scatters materialize
    buf = jnp.zeros((n_local_experts, cap + 1, D), x.dtype)
    for j in range(k):
        vals = jnp.where(keep[:, j][:, None], x, 0)
        buf = buf.at[dest_e[:, j], dest_c[:, j]].add(vals)
    buf = buf[:, :cap]

    out_buf = _expert_ffn(cfg, p, buf, axis_data)                # (E_loc, cap, D)

    out = jnp.zeros((n, D), jnp.float32)
    for j in range(k):
        rows = out_buf[dest_e[:, j], jnp.minimum(dest_c[:, j], cap - 1)]
        w = jnp.where(keep[:, j], top_p[:, j], 0.0).astype(jnp.float32)
        out = out + rows.astype(jnp.float32) * w[:, None]
    return out.astype(x.dtype)


def _moe_a2a(cfg: ModelConfig, p: Dict, x: jax.Array, tp: int,
             axis_model: str, axis_data: Optional[str]) -> jax.Array:
    """Token-routing expert parallelism (EP): tokens are all-to-all'd to
    the model-rank owning their routed expert, computed there, and
    all-to-all'd back — token-bytes move instead of expert-weight-bytes
    (EXPERIMENTS §Perf Cell C it-2).  x: (n_loc, D) UNIQUE tokens per
    device (sharded over the model axis too, unlike the replicate+psum
    route)."""
    n, D = x.shape
    E = cfg.num_experts
    k = min(cfg.experts_per_token, E)
    e_loc = E // tp

    logits = jnp.einsum("nd,de->ne", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                       # (n, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # --- dispatch: bucket copies by destination rank -----------------------
    flat_e = top_e.reshape(-1)                                   # (n*k,)
    dst = flat_e // e_loc                                        # rank id
    cap_s = int(np.ceil(n * k / tp * cfg.moe_capacity_factor))
    cap_s = max(min(cap_s, n * k), min(n * k, 16))
    dest_r, dest_c, keep = _bucket_by(dst, tp, cap_s)
    send_x = jnp.zeros((tp, cap_s + 1, D), x.dtype)
    send_le = jnp.full((tp, cap_s + 1), e_loc, jnp.int32)        # E_loc==pad
    le = jnp.where(keep, flat_e % e_loc, e_loc)
    kr = dest_r.reshape(n, k)
    kc = dest_c.reshape(n, k)
    km = keep.reshape(n, k)
    lek = le.reshape(n, k)
    for j in range(k):
        vals = jnp.where(km[:, j][:, None], x, 0)
        send_x = send_x.at[kr[:, j], kc[:, j]].add(vals)
        send_le = send_le.at[kr[:, j], kc[:, j]].min(lek[:, j])
    send_x, send_le = send_x[:, :cap_s], send_le[:, :cap_s]

    recv_x = jax.lax.all_to_all(send_x, axis_model, 0, 0, tiled=True)
    recv_le = jax.lax.all_to_all(send_le, axis_model, 0, 0, tiled=True)

    # --- local expert compute on received copies ---------------------------
    m = tp * cap_s
    rle = recv_le.reshape(m)
    cap_e = int(np.ceil(m / max(e_loc, 1) * cfg.moe_capacity_factor))
    cap_e = max(min(cap_e, m), min(m, 16))
    be, bc, bkeep = _bucket_by(rle, e_loc, cap_e)
    buf = jnp.zeros((e_loc, cap_e + 1, D), x.dtype)
    buf = buf.at[be, bc].add(
        jnp.where(bkeep[:, None], recv_x.reshape(m, D), 0))
    out_buf = _expert_ffn(cfg, p, buf[:, :cap_e], axis_data)

    # --- route results back -------------------------------------------------
    ret = out_buf[be, jnp.minimum(bc, cap_e - 1)]
    ret = jnp.where(bkeep[:, None], ret, 0).reshape(tp, cap_s, D)
    back = jax.lax.all_to_all(ret, axis_model, 0, 0, tiled=True)

    out = jnp.zeros((n, D), jnp.float32)
    for j in range(k):
        rows = back[kr[:, j], jnp.minimum(kc[:, j], cap_s - 1)]
        w = jnp.where(km[:, j], top_p[:, j], 0.0).astype(jnp.float32)
        out = out + rows.astype(jnp.float32) * w[:, None]
    return out.astype(x.dtype)


def moe_block(cfg: ModelConfig, p: Dict, x: jax.Array, mesh=None) -> jax.Array:
    """x: (B, S, D) -> (B, S, D).  Experts sharded over "model"."""
    B, S, D = x.shape
    xf = x.reshape(B * S, D)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh is not None else {}
    if int(np.prod(list(sizes.values()) or [1])) == 1:
        out = _moe_local(cfg, p, xf, 0, cfg.num_experts, None)
    else:
        tp = sizes.get("model", 1)
        assert cfg.num_experts % tp == 0, (cfg.num_experts, tp)
        e_loc = cfg.num_experts // tp
        axis_data = "data" if sizes.get("data", 1) > 1 else None
        batch_axes = tuple(a for a in ("pod", "data") if sizes.get(a, 1) > 1)
        wspec_ff = P("model", None, axis_data)
        wspec_down = P("model", axis_data, None)
        n_rows = xf.shape[0]
        use_a2a = (cfg.moe_route == "a2a" and tp > 1 and
                   n_rows % (tp * max(np.prod([sizes[a] for a in batch_axes],
                                              dtype=int), 1)) == 0)

        if use_a2a:
            # token-routing EP: tokens sharded over "model" too; each copy
            # travels to its expert's owner and back (§Perf Cell C it-2)
            tok_axes = batch_axes + ("model",)

            def body(xl, router, wg, wu, wd):
                pl = {"router": router, "w_gate": wg, "w_up": wu,
                      "w_down": wd}
                return _moe_a2a(cfg, pl, xl, tp, "model", axis_data)

            out = _shard_map(
                body,
                mesh=mesh,
                in_specs=(P(tok_axes, None), P(None, None),
                          wspec_ff, wspec_ff, wspec_down),
                out_specs=P(tok_axes, None),
            )(xf, p["router"], p["w_gate"], p["w_up"], p["w_down"])
        else:
            def body(xl, router, wg, wu, wd):
                e0 = jax.lax.axis_index("model") * e_loc if tp > 1 else 0
                pl = {"router": router, "w_gate": wg, "w_up": wu,
                      "w_down": wd}
                out = _moe_local(cfg, pl, xl, e0, e_loc, axis_data)
                if tp > 1:
                    out = jax.lax.psum(out, "model")
                return out

            out = _shard_map(
                body,
                mesh=mesh,
                in_specs=(P(batch_axes or None, None), P(None, None),
                          wspec_ff, wspec_ff, wspec_down),
                out_specs=P(batch_axes or None, None),
            )(xf, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    out = out.reshape(B, S, D)
    if cfg.num_shared_experts:
        out = out + L.apply_mlp(cfg, p["shared"], x)
    return out


# ---------------------------------------------------------------------------
# transformer blocks (full-sequence: train / prefill)
# ---------------------------------------------------------------------------


def _qkv(cfg: ModelConfig, p: Dict, x: jax.Array, positions: jax.Array,
         mesh=None):
    from repro.distributed.sharding import constrain
    xn = L.apply_norm(cfg, p["norm"], x)
    q = jnp.einsum("btd,dnh->btnh", xn, p["wq"])
    kk = jnp.einsum("btd,dnh->btnh", xn, p["wk"])
    vv = jnp.einsum("btd,dnh->btnh", xn, p["wv"])
    if cfg.qkv_bias:
        q, kk, vv = q + p["bq"], kk + p["bk"], vv + p["bv"]
    if cfg.pos_embed == "rope":
        q = L.apply_rope(q, positions, cfg.rope_theta)
        kk = L.apply_rope(kk, positions, cfg.rope_theta)
    # pin batch/head shardings so SPMD propagation never un-shards the
    # batch inside the scanned + rematted block (see DESIGN.md)
    q = constrain(q, mesh, cfg.sharding, "batch", "seq", "heads", None)
    kk = constrain(kk, mesh, cfg.sharding, "batch", "seq", "kv", None)
    vv = constrain(vv, mesh, cfg.sharding, "batch", "seq", "kv", None)
    return q, kk, vv


def _block(cfg: ModelConfig, p: Dict, x: jax.Array, *, positions: jax.Array,
           is_global: jax.Array, mesh=None, kv_chunk: int = 1024,
           with_cache: bool = False):
    from repro.distributed.sharding import constrain, mesh_axis_sizes
    x = constrain(x, mesh, cfg.sharding, "batch", "seq", "act_embed")
    q, kk, vv = _qkv(cfg, p["attn"], x, positions, mesh=mesh)
    T = x.shape[1]
    ck = min(kv_chunk, T,
             L.pick_kv_chunk(x.shape[0], T, cfg.num_heads))
    # seq_serve + sliding window: exchange a window-sized halo instead of
    # gathering the whole sequence-sharded K/V (EXPERIMENTS §Perf Cell B)
    use_halo = False
    if mesh is not None and cfg.sharding == "seq_serve" and \
            cfg.sliding_window > 0:
        tp = mesh_axis_sizes(mesh).get("model", 1)
        use_halo = tp > 1 and T % tp == 0 and cfg.sliding_window <= T // tp

    def local_attn(a):
        if use_halo:
            from repro.serving.halo_attention import halo_window_attention
            return halo_window_attention(
                *a, window=cfg.sliding_window, mesh=mesh, axis="model",
                batch_axes=("pod", "data"))
        return L.blockwise_attention(*a, causal=True,
                                     window=cfg.sliding_window, kv_chunk=ck)

    if cfg.local_global_ratio and cfg.sliding_window:
        attn_out = jax.lax.cond(
            is_global,
            lambda a: L.blockwise_attention(*a, causal=True, window=0, kv_chunk=ck),
            local_attn,
            (q, kk, vv),
        )
    else:
        attn_out = local_attn((q, kk, vv)) if cfg.sliding_window > 0 else \
            L.blockwise_attention(q, kk, vv, causal=True, kv_chunk=ck)
    x = x + jnp.einsum("btnh,nhd->btd", attn_out, p["attn"]["wo"])
    x = constrain(x, mesh, cfg.sharding, "batch", "seq", "act_embed")
    xn = L.apply_norm(cfg, p["mlp_norm"], x)
    if cfg.family == "moe":
        x = x + moe_block(cfg, p["mlp"], xn, mesh=mesh)
    else:
        x = x + L.apply_mlp(cfg, p["mlp"], xn)
    x = constrain(x, mesh, cfg.sharding, "batch", "seq", "act_embed")
    cache = {"k": kk.astype(cfg.jnp_dtype), "v": vv.astype(cfg.jnp_dtype)} if with_cache else None
    return x, cache


def _layer_flags(cfg: ModelConfig) -> jax.Array:
    """is_global flag per layer (gemma3 5:1 pattern; all-global otherwise)."""
    if cfg.local_global_ratio:
        r = cfg.local_global_ratio + 1
        return jnp.array([(i % r) == (r - 1) for i in range(cfg.num_layers)])
    return jnp.ones((cfg.num_layers,), bool)


def _scan_blocks(cfg: ModelConfig, params: Dict, x: jax.Array,
                 positions: jax.Array, mesh=None, with_cache: bool = False):
    flags = _layer_flags(cfg)
    blocks = params["blocks"]

    def body(h, layer):
        p, flag = layer
        out, cache = _block(cfg, p, h, positions=positions, is_global=flag,
                            mesh=mesh, with_cache=with_cache)
        return out, cache

    if cfg.remat == "chunk" and cfg.remat_chunk > 1 and cfg.scan_layers:
        k = cfg.remat_chunk
        nl = cfg.num_layers
        assert nl % k == 0, (nl, k)

        def chunk_body(h, chunk):
            h, caches = jax.lax.scan(body, h, chunk)
            return h, caches

        chunk_body = jax.checkpoint(chunk_body,
                                    policy=jax.checkpoint_policies.nothing_saveable)
        reshaped = compat.tree_map(lambda a: a.reshape((nl // k, k) + a.shape[1:]), blocks)
        rflags = flags.reshape(nl // k, k)
        x, caches = jax.lax.scan(chunk_body, x, (reshaped, rflags))
        if with_cache:
            caches = compat.tree_map(
                lambda a: a.reshape((nl,) + a.shape[2:]), caches)
    elif cfg.scan_layers:
        if cfg.remat == "layer":
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        x, caches = jax.lax.scan(body, x, (blocks, flags))
    else:
        caches_list = []
        fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) \
            if cfg.remat == "layer" else body
        for i in range(cfg.num_layers):
            p_i = compat.tree_map(lambda a: a[i], blocks)
            x, c = fn(x, (p_i, flags[i]))
            caches_list.append(c)
        caches = compat.tree_map(lambda *cs: jnp.stack(cs), *caches_list) if with_cache else None
    return x, (caches if with_cache else None)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def embed_tokens(cfg: ModelConfig, params: Dict, tokens: jax.Array,
                 patch_embeds: Optional[jax.Array] = None) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.tie_embeddings:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    if patch_embeds is not None:  # VLM stub frontend: prepend patch tokens
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
    return x


def forward(cfg: ModelConfig, params: Dict, tokens: jax.Array,
            patch_embeds: Optional[jax.Array] = None, mesh=None) -> jax.Array:
    """Full-sequence forward -> final hidden states (B, T, D)."""
    x = embed_tokens(cfg, params, tokens, patch_embeds)
    positions = jnp.arange(x.shape[1])
    x, _ = _scan_blocks(cfg, params, x, positions, mesh=mesh)
    return L.apply_norm(cfg, params["final_norm"], x)


def prefill(cfg: ModelConfig, params: Dict, tokens: jax.Array,
            patch_embeds: Optional[jax.Array] = None, mesh=None):
    """Full-sequence forward that also emits the stacked KV cache
    (L, B, T, Hk, hd) — the inference-prefill step."""
    x = embed_tokens(cfg, params, tokens, patch_embeds)
    positions = jnp.arange(x.shape[1])
    x, caches = _scan_blocks(cfg, params, x, positions, mesh=mesh, with_cache=True)
    hidden = L.apply_norm(cfg, params["final_norm"], x)
    return hidden, caches


def lm_head_weight(cfg: ModelConfig, params: Dict) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def logits_fn(cfg: ModelConfig, params: Dict, hidden: jax.Array) -> jax.Array:
    return jnp.einsum("...d,dv->...v", hidden, lm_head_weight(cfg, params))


# ---------------------------------------------------------------------------
# decode path
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int):
    """Abstract KV cache + logical axes (for dry-run + serving init)."""
    hd = cfg.resolved_head_dim
    shape = (cfg.num_layers, batch, seq_len, cfg.num_kv_heads, hd)
    logical = ("layers", "cache_batch", "cache_seq", "kv", None)
    struct = jax.ShapeDtypeStruct(shape, cfg.jnp_dtype)
    return ({"k": struct, "v": struct}, {"k": logical, "v": logical})


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    ab, _ = cache_specs(cfg, batch, seq_len)
    return compat.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), ab)


def decode_step(cfg: ModelConfig, params: Dict, cache: Dict,
                tokens: jax.Array, cache_len: jax.Array, mesh=None
                ) -> Tuple[jax.Array, Dict]:
    """One decode step.  tokens: (B, 1); cache k/v: (L, B, S, Hk, hd)."""
    x = embed_tokens(cfg, params, tokens)
    positions = cache_len + jnp.arange(x.shape[1])
    flags = _layer_flags(cfg)

    def body(h, layer):
        p, flag, c = layer
        q, kk, vv = _qkv(cfg, p["attn"], h, positions)
        k_cache = jax.lax.dynamic_update_slice(
            c["k"], kk.astype(c["k"].dtype), (0, cache_len, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            c["v"], vv.astype(c["v"].dtype), (0, cache_len, 0, 0))
        if cfg.local_global_ratio and cfg.sliding_window:
            out = jax.lax.cond(
                flag,
                lambda: L.decode_attention(q, k_cache, v_cache, kv_len=cache_len + 1),
                lambda: L.decode_attention(q, k_cache, v_cache, kv_len=cache_len + 1,
                                           window=cfg.sliding_window),
            )
        else:
            out = L.decode_attention(q, k_cache, v_cache, kv_len=cache_len + 1,
                                     window=cfg.sliding_window)
        h = h + jnp.einsum("btnh,nhd->btd", out, p["attn"]["wo"])
        xn2 = L.apply_norm(cfg, p["mlp_norm"], h)
        if cfg.family == "moe":
            h = h + moe_block(cfg, p["mlp"], xn2, mesh=mesh)
        else:
            h = h + L.apply_mlp(cfg, p["mlp"], xn2)
        return h, {"k": k_cache, "v": v_cache}

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], flags, cache))
    hidden = L.apply_norm(cfg, params["final_norm"], x)
    logits = logits_fn(cfg, params, hidden[:, -1:, :])
    return logits, new_cache
