"""Whisper-style encoder-decoder (audio backbone; conv frontend is a STUB —
``input_specs`` supplies precomputed frame embeddings (B, encoder_tokens, D)).

LayerNorm + GELU + learned positions, per the Whisper architecture.  Decoder
layers: causal self-attention, cross-attention to the encoder output, MLP.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro import compat

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.param import ParamSpec
from repro.models import transformer as tf


def _xattn_specs(cfg: ModelConfig, nl: int) -> Dict:
    hd = cfg.resolved_head_dim
    return {
        "norm": L.norm_specs(cfg, stacked=nl),
        "wq": ParamSpec((nl, cfg.d_model, cfg.num_heads, hd),
                        ("layers", "embed", "heads", None)),
        "wk": ParamSpec((nl, cfg.d_model, cfg.num_kv_heads, hd),
                        ("layers", "embed", "kv", None)),
        "wv": ParamSpec((nl, cfg.d_model, cfg.num_kv_heads, hd),
                        ("layers", "embed", "kv", None)),
        "wo": ParamSpec((nl, cfg.num_heads, hd, cfg.d_model),
                        ("layers", "heads", None, "embed")),
    }


def specs(cfg: ModelConfig) -> Dict:
    ne, nd = cfg.encoder_layers, cfg.num_layers
    sp = {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), scale=1.0),
        "enc_pos": ParamSpec((cfg.encoder_tokens, cfg.d_model), ("seq", "embed"), scale=0.02),
        "dec_pos": ParamSpec((cfg.max_seq_len, cfg.d_model), ("seq", "embed"), scale=0.02),
        "encoder": {
            "attn": tf.attention_specs(cfg, ne),
            "mlp_norm": L.norm_specs(cfg, stacked=ne),
            "mlp": L.mlp_specs(cfg, stacked=ne),
        },
        "decoder": {
            "attn": tf.attention_specs(cfg, nd),
            "xattn": _xattn_specs(cfg, nd),
            "mlp_norm": L.norm_specs(cfg, stacked=nd),
            "mlp": L.mlp_specs(cfg, stacked=nd),
        },
        "enc_final_norm": L.norm_specs(cfg),
        "final_norm": L.norm_specs(cfg),
    }
    if not cfg.tie_embeddings:
        sp["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    if cfg.num_classes:
        sp["cls_head"] = ParamSpec((cfg.d_model, cfg.num_classes), ("embed", None))
    return sp


def _cross_attn(cfg: ModelConfig, p: Dict, x: jax.Array, enc_k: jax.Array,
                enc_v: jax.Array) -> jax.Array:
    xn = L.apply_norm(cfg, p["norm"], x)
    q = jnp.einsum("btd,dnh->btnh", xn, p["wq"])
    out = L.blockwise_attention(q, enc_k, enc_v, causal=False,
                                kv_chunk=min(512, enc_k.shape[1]))
    return jnp.einsum("btnh,nhd->btd", out, p["wo"])


def _enc_kv(p: Dict, enc_out: jax.Array) -> Tuple[jax.Array, jax.Array]:
    k = jnp.einsum("btd,dnh->btnh", enc_out, p["wk"])
    v = jnp.einsum("btd,dnh->btnh", enc_out, p["wv"])
    return k, v


def encode(cfg: ModelConfig, params: Dict, audio_frames: jax.Array,
           mesh=None) -> jax.Array:
    """audio_frames: (B, encoder_tokens, D) stub frame embeddings."""
    from repro.distributed.sharding import constrain
    x = audio_frames.astype(cfg.jnp_dtype) + params["enc_pos"].astype(cfg.jnp_dtype)
    positions = jnp.arange(x.shape[1])

    def body(h, p):
        h = constrain(h, mesh, cfg.sharding, "batch", "seq", "act_embed")
        q, kk, vv = tf._qkv(cfg, p["attn"], h, positions, mesh=mesh)
        out = L.blockwise_attention(q, kk, vv, causal=False,
                                    kv_chunk=min(512, h.shape[1]))
        h = h + jnp.einsum("btnh,nhd->btd", out, p["attn"]["wo"])
        h = h + L.apply_mlp(cfg, p["mlp"], L.apply_norm(cfg, p["mlp_norm"], h))
        return h, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return L.apply_norm(cfg, params["enc_final_norm"], x)


def _decode_blocks(cfg: ModelConfig, params: Dict, x: jax.Array,
                   enc_out: jax.Array, positions: jax.Array,
                   with_cache: bool = False, mesh=None):
    from repro.distributed.sharding import constrain

    def body(h, p):
        h = constrain(h, mesh, cfg.sharding, "batch", "seq", "act_embed")
        q, kk, vv = tf._qkv(cfg, p["attn"], h, positions, mesh=mesh)
        ck = min(h.shape[1],
                 L.pick_kv_chunk(h.shape[0], h.shape[1], cfg.num_heads))
        out = L.blockwise_attention(q, kk, vv, causal=True, kv_chunk=ck)
        h = h + jnp.einsum("btnh,nhd->btd", out, p["attn"]["wo"])
        ek, ev = _enc_kv(p["xattn"], enc_out)
        h = h + _cross_attn(cfg, p["xattn"], h, ek, ev)
        h = h + L.apply_mlp(cfg, p["mlp"], L.apply_norm(cfg, p["mlp_norm"], h))
        h = constrain(h, mesh, cfg.sharding, "batch", "seq", "act_embed")
        cache = None
        if with_cache:
            cache = {"k": kk.astype(cfg.jnp_dtype), "v": vv.astype(cfg.jnp_dtype),
                     "xk": ek.astype(cfg.jnp_dtype), "xv": ev.astype(cfg.jnp_dtype)}
        return h, cache

    if cfg.remat != "none" and not with_cache:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    return jax.lax.scan(body, x, params["decoder"])


def _embed_dec(cfg: ModelConfig, params: Dict, tokens: jax.Array,
               offset) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    pos = offset + jnp.arange(tokens.shape[1])
    return x + jnp.take(params["dec_pos"], pos, axis=0).astype(x.dtype)


def forward(cfg: ModelConfig, params: Dict, tokens: jax.Array,
            audio_frames=None, mesh=None) -> jax.Array:
    enc_out = encode(cfg, params, audio_frames, mesh=mesh)
    x = _embed_dec(cfg, params, tokens, 0)
    positions = jnp.arange(x.shape[1])
    x, _ = _decode_blocks(cfg, params, x, enc_out, positions, mesh=mesh)
    return L.apply_norm(cfg, params["final_norm"], x)


def prefill(cfg: ModelConfig, params: Dict, tokens: jax.Array,
            audio_frames=None, mesh=None):
    enc_out = encode(cfg, params, audio_frames, mesh=mesh)
    x = _embed_dec(cfg, params, tokens, 0)
    positions = jnp.arange(x.shape[1])
    x, caches = _decode_blocks(cfg, params, x, enc_out, positions,
                               with_cache=True, mesh=mesh)
    return L.apply_norm(cfg, params["final_norm"], x), caches


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int):
    hd = cfg.resolved_head_dim
    nl = cfg.num_layers
    kv = jax.ShapeDtypeStruct((nl, batch, seq_len, cfg.num_kv_heads, hd), cfg.jnp_dtype)
    xkv = jax.ShapeDtypeStruct((nl, batch, cfg.encoder_tokens, cfg.num_kv_heads, hd), cfg.jnp_dtype)
    kvl = ("layers", "cache_batch", "cache_seq", "kv", None)
    xkvl = ("layers", "cache_batch", "seq", "kv", None)
    ab = {"k": kv, "v": kv, "xk": xkv, "xv": xkv}
    logical = {"k": kvl, "v": kvl, "xk": xkvl, "xv": xkvl}
    return ab, logical


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    ab, _ = cache_specs(cfg, batch, seq_len)
    return compat.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), ab)


def decode_step(cfg: ModelConfig, params: Dict, cache: Dict, tokens: jax.Array,
                cache_len, mesh=None):
    x = _embed_dec(cfg, params, tokens, cache_len)
    positions = cache_len + jnp.arange(x.shape[1])

    def body(h, layer):
        p, c = layer
        q, kk, vv = tf._qkv(cfg, p["attn"], h, positions)
        k_cache = jax.lax.dynamic_update_slice(
            c["k"], kk.astype(c["k"].dtype), (0, cache_len, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            c["v"], vv.astype(c["v"].dtype), (0, cache_len, 0, 0))
        out = L.decode_attention(q, k_cache, v_cache, kv_len=cache_len + 1)
        h = h + jnp.einsum("btnh,nhd->btd", out, p["attn"]["wo"])
        # cross-attention against the cached encoder projections
        xn = L.apply_norm(cfg, p["xattn"]["norm"], h)
        xq = jnp.einsum("btd,dnh->btnh", xn, p["xattn"]["wq"])
        xout = L.decode_attention(xq, c["xk"], c["xv"], kv_len=c["xk"].shape[1])
        h = h + jnp.einsum("btnh,nhd->btd", xout, p["xattn"]["wo"])
        h = h + L.apply_mlp(cfg, p["mlp"], L.apply_norm(cfg, p["mlp_norm"], h))
        return h, {"k": k_cache, "v": v_cache, "xk": c["xk"], "xv": c["xv"]}

    x, new_cache = jax.lax.scan(body, x, (params["decoder"], cache))
    hidden = L.apply_norm(cfg, params["final_norm"], x)
    return tf.logits_fn(cfg, params, hidden[:, -1:, :]), new_cache
