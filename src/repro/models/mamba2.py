"""Mamba2 (SSD — state-space duality) in pure JAX.

Implements the chunked SSD algorithm: intra-chunk dense matmuls (MXU
friendly) + inter-chunk state recurrence via a small scan.  This module is
the production jnp path on CPU-backed dry-runs and doubles as the oracle for
``kernels/ssd_scan``.

Simplifications vs. the reference CUDA implementation (recorded in
DESIGN.md): the short causal conv is applied to the x stream only (not B/C),
and n_groups == 1.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.param import ParamSpec


# ---------------------------------------------------------------------------
# SSD core (shared by train/prefill; ref for the Pallas kernel)
# ---------------------------------------------------------------------------


def ssd_chunked(xh: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int,
                h0: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    xh: (B, T, H, hd)   inputs per head
    dt: (B, T, H)       positive step sizes
    A:  (H,)            positive decay rates (a_t = exp(-dt * A))
    Bm: (B, T, N)       input projections (shared across heads, n_groups=1)
    Cm: (B, T, N)       output projections
    h0: (B, H, hd, N)   optional initial state
    Returns (y (B,T,H,hd), h_final (B,H,hd,N)).
    """
    Bsz, T, H, hd = xh.shape
    N = Bm.shape[-1]
    chunk = min(chunk, T)
    T0 = T
    pad = (-T) % chunk
    if pad:  # exact: dt=0 padding gives a_t=1 decay and zero state update
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        T = T + pad
    nc = T // chunk

    la = (-(dt * A)).reshape(Bsz, nc, chunk, H)            # log a_t
    cum = jnp.cumsum(la, axis=2)                           # l_t (inclusive)
    xd = (xh * dt[..., None]).reshape(Bsz, nc, chunk, H, hd)
    Bc = Bm.reshape(Bsz, nc, chunk, N)
    Cc = Cm.reshape(Bsz, nc, chunk, N)

    # intra-chunk: Y[t] = sum_{s<=t} (C_t.B_s) exp(l_t - l_s) x_s
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,t,s,H)
    mask = np.tril(np.ones((chunk, chunk), bool))
    Lmat = jnp.exp(jnp.where(mask[None, None, :, :, None], diff, -np.inf))
    scores = jnp.einsum("bctn,bcsn->bcts", Cc, Bc,
                        preferred_element_type=jnp.float32)
    W = scores[..., None] * Lmat                           # (B,nc,t,s,H)
    y_intra = jnp.einsum("bctsh,bcshd->bcthd", W.astype(xd.dtype), xd,
                         preferred_element_type=jnp.float32)

    # chunk summaries: S_c = sum_s exp(l_last - l_s) x_s (x) B_s
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)           # (B,nc,chunk,H)
    S = jnp.einsum("bcsh,bcshd,bcsn->bchdn",
                   decay_end.astype(xd.dtype), xd, Bc.astype(xd.dtype),
                   preferred_element_type=jnp.float32)
    gamma = jnp.exp(cum[:, :, -1, :])                      # (B,nc,H)

    # inter-chunk recurrence over nc chunks
    def step(h, inp):
        S_c, g_c = inp
        h_new = g_c[..., None, None] * h + S_c.astype(jnp.float32)
        return h_new, h                                     # emit H_{c-1}

    h_init = jnp.zeros((Bsz, H, hd, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    h_fin, h_prev = jax.lax.scan(
        step, h_init,
        (jnp.moveaxis(S, 1, 0), jnp.moveaxis(gamma, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                     # (B,nc,H,hd,N)

    y_inter = jnp.einsum("bctn,bchdn->bcthd", Cc.astype(jnp.float32), h_prev,
                         preferred_element_type=jnp.float32)
    y_inter = y_inter * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(Bsz, T, H, hd)
    if pad:
        y = y[:, :T0]
    return y.astype(xh.dtype), h_fin


def ssd_decode(xh: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
               Cm: jax.Array, h: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single-token SSD update.  xh: (B,H,hd); dt: (B,H); Bm/Cm: (B,N);
    h: (B,H,hd,N)."""
    a = jnp.exp(-(dt * A)).astype(jnp.float32)              # (B,H)
    upd = jnp.einsum("bhd,bn->bhdn", (xh * dt[..., None]).astype(jnp.float32),
                     Bm.astype(jnp.float32))
    h_new = a[..., None, None] * h.astype(jnp.float32) + upd
    y = jnp.einsum("bhdn,bn->bhd", h_new, Cm.astype(jnp.float32))
    return y.astype(xh.dtype), h_new


# ---------------------------------------------------------------------------
# mamba2 block
# ---------------------------------------------------------------------------


def block_specs(cfg: ModelConfig, nl: int) -> Dict:
    D, di = cfg.d_model, cfg.ssm_d_inner
    H, N, K = cfg.ssm_num_heads, cfg.ssm_state, cfg.ssm_conv_kernel
    return {
        "norm": L.norm_specs(cfg, stacked=nl),
        "w_z": ParamSpec((nl, D, di), ("layers", "embed", "mlp")),
        "w_x": ParamSpec((nl, D, di), ("layers", "embed", "mlp")),
        "w_B": ParamSpec((nl, D, N), ("layers", "embed", "state")),
        "w_C": ParamSpec((nl, D, N), ("layers", "embed", "state")),
        "w_dt": ParamSpec((nl, D, H), ("layers", "embed", "ssm_heads")),
        "conv_w": ParamSpec((nl, K, di), ("layers", "conv", "mlp"), scale=0.5),
        "A_log": ParamSpec((nl, H), ("layers", "ssm_heads"), init="zeros", dtype=jnp.float32),
        "dt_bias": ParamSpec((nl, H), ("layers", "ssm_heads"), init="zeros", dtype=jnp.float32),
        "D_skip": ParamSpec((nl, H), ("layers", "ssm_heads"), init="ones", dtype=jnp.float32),
        "gate_norm": ParamSpec((nl, di), ("layers", "mlp"), init="zeros", dtype=jnp.float32),
        "w_out": ParamSpec((nl, di, D), ("layers", "mlp", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv.  x: (B, T, C); w: (K, C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(K):
        out = out + xp[:, i:i + x.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return out.astype(x.dtype)


def _split_heads(cfg: ModelConfig, xc: jax.Array) -> jax.Array:
    B, T, di = xc.shape
    return xc.reshape(B, T, cfg.ssm_num_heads, cfg.ssm_head_dim)


def mamba_block(cfg: ModelConfig, p: Dict, x: jax.Array,
                mesh=None) -> jax.Array:
    """Full-sequence mamba2 block: x (B, T, D) -> (B, T, D)."""
    from repro.distributed.sharding import constrain
    x = constrain(x, mesh, cfg.sharding, "batch", "seq", "act_embed")
    xn = L.apply_norm(cfg, p["norm"], x)
    z = jnp.einsum("btd,de->bte", xn, p["w_z"])
    xs = jnp.einsum("btd,de->bte", xn, p["w_x"])
    Bm = jnp.einsum("btd,dn->btn", xn, p["w_B"]).astype(jnp.float32)
    Cm = jnp.einsum("btd,dn->btn", xn, p["w_C"]).astype(jnp.float32)
    dt = jax.nn.softplus(
        jnp.einsum("btd,dh->bth", xn, p["w_dt"]).astype(jnp.float32) + p["dt_bias"])
    xc = _causal_conv(xs, p["conv_w"])
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    xh = _split_heads(cfg, xc)
    A = jnp.exp(p["A_log"])
    y, _ = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + xh * p["D_skip"][None, None, :, None].astype(x.dtype)
    y = y.reshape(x.shape[0], x.shape[1], cfg.ssm_d_inner)
    y = L.rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), p["gate_norm"])
    return x + jnp.einsum("bte,ed->btd", y, p["w_out"])


def mamba_block_decode(cfg: ModelConfig, p: Dict, x: jax.Array,
                       state: Dict) -> Tuple[jax.Array, Dict]:
    """Single-token mamba2 block.  x: (B, 1, D);
    state = {"ssm": (B,H,hd,N), "conv": (B,K-1,di)}."""
    xn = L.apply_norm(cfg, p["norm"], x)[:, 0]               # (B, D)
    z = jnp.einsum("bd,de->be", xn, p["w_z"])
    xs = jnp.einsum("bd,de->be", xn, p["w_x"])
    Bm = jnp.einsum("bd,dn->bn", xn, p["w_B"]).astype(jnp.float32)
    Cm = jnp.einsum("bd,dn->bn", xn, p["w_C"]).astype(jnp.float32)
    dt = jax.nn.softplus(
        jnp.einsum("bd,dh->bh", xn, p["w_dt"]).astype(jnp.float32) + p["dt_bias"])
    # conv over the K-1 cached inputs + the new one
    K = cfg.ssm_conv_kernel
    hist = jnp.concatenate([state["conv"], xs[:, None, :]], axis=1)  # (B,K,di)
    xc = jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32),
                    p["conv_w"].astype(jnp.float32))
    xc = jax.nn.silu(xc).astype(x.dtype)
    xh = xc.reshape(-1, cfg.ssm_num_heads, cfg.ssm_head_dim)
    A = jnp.exp(p["A_log"])
    y, h_new = ssd_decode(xh, dt, A, Bm, Cm, state["ssm"])
    y = y + xh * p["D_skip"][None, :, None].astype(x.dtype)
    y = y.reshape(x.shape[0], cfg.ssm_d_inner)
    y = L.rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), p["gate_norm"])
    out = x + jnp.einsum("be,ed->bd", y, p["w_out"])[:, None, :]
    new_state = {"ssm": h_new, "conv": hist[:, 1:, :]}
    return out, new_state


# ---------------------------------------------------------------------------
# full model (pure SSM: mamba2-1.3b)
# ---------------------------------------------------------------------------


def specs(cfg: ModelConfig) -> Dict:
    sp = {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), scale=1.0),
        "blocks": block_specs(cfg, cfg.num_layers),
        "final_norm": L.norm_specs(cfg),
    }
    if not cfg.tie_embeddings:
        sp["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    if cfg.num_classes:
        sp["cls_head"] = ParamSpec((cfg.d_model, cfg.num_classes), ("embed", None))
    return sp


def forward(cfg: ModelConfig, params: Dict, tokens: jax.Array,
            patch_embeds=None, mesh=None) -> jax.Array:
    from repro.models.transformer import embed_tokens
    x = embed_tokens(cfg, params, tokens, patch_embeds)

    def body(h, p):
        return mamba_block(cfg, p, h, mesh=mesh), None

    body_fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) \
        if cfg.remat != "none" else body
    x, _ = jax.lax.scan(body_fn, x, params["blocks"])
    return L.apply_norm(cfg, params["final_norm"], x)


def prefill(cfg: ModelConfig, params: Dict, tokens: jax.Array,
            patch_embeds=None, mesh=None):
    """Prefill = full forward + final SSM/conv states per layer."""
    from repro.models.transformer import embed_tokens
    x = embed_tokens(cfg, params, tokens, patch_embeds)

    def body(h, p):
        # rerun block but emit states: duplicate minimal work via mamba_block
        # internals (kept in one place: recompute from block fn)
        from repro.distributed.sharding import constrain
        h = constrain(h, mesh, cfg.sharding, "batch", "seq", "act_embed")
        xn = L.apply_norm(cfg, p["norm"], h)
        z = jnp.einsum("btd,de->bte", xn, p["w_z"])
        xs = jnp.einsum("btd,de->bte", xn, p["w_x"])
        Bm = jnp.einsum("btd,dn->btn", xn, p["w_B"]).astype(jnp.float32)
        Cm = jnp.einsum("btd,dn->btn", xn, p["w_C"]).astype(jnp.float32)
        dt = jax.nn.softplus(
            jnp.einsum("btd,dh->bth", xn, p["w_dt"]).astype(jnp.float32) + p["dt_bias"])
        xc = jax.nn.silu(_causal_conv(xs, p["conv_w"]).astype(jnp.float32)).astype(h.dtype)
        xh = _split_heads(cfg, xc)
        A = jnp.exp(p["A_log"])
        y, h_fin = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk)
        y = y + xh * p["D_skip"][None, None, :, None].astype(h.dtype)
        y = y.reshape(h.shape[0], h.shape[1], cfg.ssm_d_inner)
        y = L.rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(h.dtype), p["gate_norm"])
        out = h + jnp.einsum("bte,ed->btd", y, p["w_out"])
        K = cfg.ssm_conv_kernel
        conv_state = xs[:, -(K - 1):, :]
        return out, {"ssm": h_fin.astype(jnp.float32), "conv": conv_state}

    x, states = jax.lax.scan(body, x, params["blocks"])
    return L.apply_norm(cfg, params["final_norm"], x), states


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int):
    H, hd, N = cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state
    K, di, nl = cfg.ssm_conv_kernel, cfg.ssm_d_inner, cfg.num_layers
    ab = {
        "ssm": jax.ShapeDtypeStruct((nl, batch, H, hd, N), jnp.float32),
        "conv": jax.ShapeDtypeStruct((nl, batch, K - 1, di), cfg.jnp_dtype),
    }
    logical = {
        "ssm": ("layers", "cache_batch", "ssm_heads", None, "state"),
        "conv": ("layers", "cache_batch", "conv", "mlp"),
    }
    return ab, logical


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    ab, _ = cache_specs(cfg, batch, seq_len)
    return compat.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), ab)


def decode_step(cfg: ModelConfig, params: Dict, cache: Dict,
                tokens: jax.Array, cache_len: jax.Array, mesh=None):
    from repro.models.transformer import embed_tokens, logits_fn
    x = embed_tokens(cfg, params, tokens)

    def body(h, layer):
        p, st = layer
        out, st_new = mamba_block_decode(cfg, p, h, st)
        return out, st_new

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    hidden = L.apply_norm(cfg, params["final_norm"], x)
    return logits_fn(cfg, params, hidden[:, -1:, :]), new_cache
