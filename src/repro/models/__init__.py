from . import registry  # noqa: F401
from .registry import get_model  # noqa: F401
