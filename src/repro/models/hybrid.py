"""Zamba2-style hybrid: Mamba2 backbone + one weight-SHARED attention+MLP
block applied every ``shared_attn_every`` layers.

Structure: ``num_layers`` mamba2 blocks grouped into
``num_layers // shared_attn_every`` super-blocks; the shared transformer
block (full attention + SwiGLU MLP, one set of weights) runs at the start of
every super-block.  Each application site keeps its own KV cache for decode
(weights shared, caches not).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro import compat

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba2
from repro.models.param import ParamSpec
from repro.models import transformer as tf


def _n_apps(cfg: ModelConfig) -> int:
    assert cfg.shared_attn_every > 0 and cfg.num_layers % cfg.shared_attn_every == 0
    return cfg.num_layers // cfg.shared_attn_every


def specs(cfg: ModelConfig) -> Dict:
    sp = {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), scale=1.0),
        "mamba_blocks": mamba2.block_specs(cfg, cfg.num_layers),
        "shared": {
            "attn": tf.attention_specs(cfg, 0),
            "mlp_norm": L.norm_specs(cfg),
            "mlp": L.mlp_specs(cfg),
        },
        "final_norm": L.norm_specs(cfg),
    }
    if not cfg.tie_embeddings:
        sp["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    if cfg.num_classes:
        sp["cls_head"] = ParamSpec((cfg.d_model, cfg.num_classes), ("embed", None))
    return sp


def _shared_block(cfg: ModelConfig, p: Dict, x: jax.Array,
                  positions: jax.Array, with_cache: bool = False, mesh=None):
    from repro.distributed.sharding import constrain
    x = constrain(x, mesh, cfg.sharding, "batch", "seq", "act_embed")
    q, kk, vv = tf._qkv(cfg, p["attn"], x, positions, mesh=mesh)
    ck = min(x.shape[1],
             L.pick_kv_chunk(x.shape[0], x.shape[1], cfg.num_heads))
    out = L.blockwise_attention(q, kk, vv, causal=True, kv_chunk=ck)
    x = x + jnp.einsum("btnh,nhd->btd", out, p["attn"]["wo"])
    x = x + L.apply_mlp(cfg, p["mlp"], L.apply_norm(cfg, p["mlp_norm"], x))
    x = constrain(x, mesh, cfg.sharding, "batch", "seq", "act_embed")
    cache = {"k": kk.astype(cfg.jnp_dtype), "v": vv.astype(cfg.jnp_dtype)} if with_cache else None
    return x, cache


def _group_params(cfg: ModelConfig, params: Dict):
    na, per = _n_apps(cfg), cfg.shared_attn_every
    return compat.tree_map(lambda a: a.reshape((na, per) + a.shape[1:]),
                        params["mamba_blocks"])


def _forward_impl(cfg: ModelConfig, params: Dict, tokens, patch_embeds,
                  with_cache: bool, mesh=None):
    x = tf.embed_tokens(cfg, params, tokens, patch_embeds)
    positions = jnp.arange(x.shape[1])
    grouped = _group_params(cfg, params)
    shared = params["shared"]

    def body(h, group):
        h, attn_cache = _shared_block(cfg, shared, h, positions, with_cache,
                                      mesh=mesh)

        def inner(h2, p):
            if with_cache:
                # rerun the mamba block while emitting final states
                out, st = _run_mamba_with_state(cfg, p, h2, mesh=mesh)
                return out, st
            return mamba2.mamba_block(cfg, p, h2, mesh=mesh), None

        h, ssm_states = jax.lax.scan(inner, h, group)
        return h, (attn_cache, ssm_states)

    if cfg.remat != "none" and not with_cache:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, caches = jax.lax.scan(body, x, grouped)
    hidden = L.apply_norm(cfg, params["final_norm"], x)
    return hidden, caches


def _run_mamba_with_state(cfg: ModelConfig, p: Dict, h: jax.Array,
                          mesh=None):
    from repro.distributed.sharding import constrain
    h = constrain(h, mesh, cfg.sharding, "batch", "seq", "act_embed")
    xn = L.apply_norm(cfg, p["norm"], h)
    z = jnp.einsum("btd,de->bte", xn, p["w_z"])
    xs = jnp.einsum("btd,de->bte", xn, p["w_x"])
    Bm = jnp.einsum("btd,dn->btn", xn, p["w_B"]).astype(jnp.float32)
    Cm = jnp.einsum("btd,dn->btn", xn, p["w_C"]).astype(jnp.float32)
    dt = jax.nn.softplus(
        jnp.einsum("btd,dh->bth", xn, p["w_dt"]).astype(jnp.float32) + p["dt_bias"])
    xc = jax.nn.silu(mamba2._causal_conv(xs, p["conv_w"]).astype(jnp.float32)).astype(h.dtype)
    xh = mamba2._split_heads(cfg, xc)
    A = jnp.exp(p["A_log"])
    y, h_fin = mamba2.ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + xh * p["D_skip"][None, None, :, None].astype(h.dtype)
    y = y.reshape(h.shape[0], h.shape[1], cfg.ssm_d_inner)
    y = L.rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(h.dtype), p["gate_norm"])
    out = h + jnp.einsum("bte,ed->btd", y, p["w_out"])
    K = cfg.ssm_conv_kernel
    return out, {"ssm": h_fin.astype(jnp.float32), "conv": xs[:, -(K - 1):, :]}


def forward(cfg: ModelConfig, params: Dict, tokens, patch_embeds=None, mesh=None):
    hidden, _ = _forward_impl(cfg, params, tokens, patch_embeds,
                              with_cache=False, mesh=mesh)
    return hidden


def prefill(cfg: ModelConfig, params: Dict, tokens, patch_embeds=None, mesh=None):
    hidden, (attn_caches, ssm_states) = _forward_impl(
        cfg, params, tokens, patch_embeds, with_cache=True, mesh=mesh)
    return hidden, {"attn": attn_caches, "ssm": ssm_states}


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int):
    na, per = _n_apps(cfg), cfg.shared_attn_every
    hd = cfg.resolved_head_dim
    H, shd, N = cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state
    K, di = cfg.ssm_conv_kernel, cfg.ssm_d_inner
    kv = jax.ShapeDtypeStruct((na, batch, seq_len, cfg.num_kv_heads, hd), cfg.jnp_dtype)
    ab = {
        "attn": {"k": kv, "v": kv},
        "ssm": {
            "ssm": jax.ShapeDtypeStruct((na, per, batch, H, shd, N), jnp.float32),
            "conv": jax.ShapeDtypeStruct((na, per, batch, K - 1, di), cfg.jnp_dtype),
        },
    }
    kvl = ("layers", "cache_batch", "cache_seq", "kv", None)
    logical = {
        "attn": {"k": kvl, "v": kvl},
        "ssm": {
            "ssm": ("layers", None, "cache_batch", "ssm_heads", None, "state"),
            "conv": ("layers", None, "cache_batch", "conv", "mlp"),
        },
    }
    return ab, logical


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    ab, _ = cache_specs(cfg, batch, seq_len)
    return compat.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), ab)


def decode_step(cfg: ModelConfig, params: Dict, cache: Dict, tokens,
                cache_len, mesh=None):
    x = tf.embed_tokens(cfg, params, tokens)
    positions = cache_len + jnp.arange(x.shape[1])
    grouped = _group_params(cfg, params)
    shared = params["shared"]

    def body(h, group):
        p_group, attn_c, ssm_c = group
        q, kk, vv = tf._qkv(cfg, shared["attn"], h, positions)
        k_cache = jax.lax.dynamic_update_slice(
            attn_c["k"], kk.astype(attn_c["k"].dtype), (0, cache_len, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            attn_c["v"], vv.astype(attn_c["v"].dtype), (0, cache_len, 0, 0))
        out = L.decode_attention(q, k_cache, v_cache, kv_len=cache_len + 1)
        h = h + jnp.einsum("btnh,nhd->btd", out, shared["attn"]["wo"])
        h = h + L.apply_mlp(cfg, shared["mlp"],
                            L.apply_norm(cfg, shared["mlp_norm"], h))

        def inner(h2, layer):
            p, st = layer
            return mamba2.mamba_block_decode(cfg, p, h2, st)

        h, ssm_new = jax.lax.scan(inner, h, (p_group, ssm_c))
        return h, ({"k": k_cache, "v": v_cache}, ssm_new)

    x, (attn_new, ssm_new) = jax.lax.scan(
        body, x, (grouped, cache["attn"], cache["ssm"]))
    hidden = L.apply_norm(cfg, params["final_norm"], x)
    return tf.logits_fn(cfg, params, hidden[:, -1:, :]), {"attn": attn_new, "ssm": ssm_new}
