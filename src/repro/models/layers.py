"""Shared neural-net layers (pure functions over param pytrees).

Everything here is written to lower cleanly under pjit on large meshes:
attention is blockwise (flash-style online softmax via lax.scan) so no
O(T^2) score tensor is ever materialized, and the final-projection scoring
path has a vocab-chunked variant mirroring the Pallas ``margin_head``
kernel.
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat

from repro.configs.base import ModelConfig
from repro.models.param import ParamSpec

# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + weight.astype(jnp.float32))
    return out.astype(dt)


def layernorm(x: jax.Array, weight: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(dt)


def apply_norm(cfg: ModelConfig, params: Dict, x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        return layernorm(x, params["scale"], params["bias"])
    return rmsnorm(x, params["scale"])


def norm_specs(cfg: ModelConfig, stacked: int = 0) -> Dict:
    lead = ((stacked,), ("layers",)) if stacked else ((), ())
    spec = {
        "scale": ParamSpec(lead[0] + (cfg.d_model,), lead[1] + ("act_embed",),
                           init="zeros" if cfg.norm == "rmsnorm" else "ones",
                           dtype=jnp.float32)
    }
    if cfg.norm == "layernorm":
        spec["bias"] = ParamSpec(lead[0] + (cfg.d_model,), lead[1] + ("act_embed",),
                                 init="zeros", dtype=jnp.float32)
    return spec


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., T, H, hd); positions: broadcastable to (..., T)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., T, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# blockwise (flash-style) attention — pure jnp, scan over kv chunks
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def pick_kv_chunk(batch: int, t_q: int, heads: int,
                  budget_bytes: float = 2e9, dp: int = 16) -> int:
    """KV-chunk length keeping the per-chunk f32 score tensor
    (B/dp, Tq, H, ckv) under ``budget_bytes`` per device (long sequences
    would otherwise materialize 10+ GB score tiles)."""
    import math
    per_col = max(batch / dp, 1) * t_q * heads * 4
    ck = budget_bytes / max(per_col, 1)
    ck = 2 ** int(max(math.log2(max(ck, 128)), 7))
    return int(min(ck, 1024, max(t_q, 128)))


def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, window: int) -> jax.Array:
    """(Tq, Tk) additive bias implementing causal (+ optional sliding window)."""
    causal = q_pos[:, None] >= k_pos[None, :]
    ok = causal
    if window > 0:
        ok = ok & (q_pos[:, None] - k_pos[None, :] < window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def blockwise_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    kv_chunk: int = 1024,
    scale: Optional[float] = None,
    kv_start=0,
) -> jax.Array:
    """Flash-style attention, O(Tq * kv_chunk) memory.

    q: (B, Tq, H, hd); k, v: (B, Tk, Hk, hd) with H % Hk == 0.
    ``q_offset`` is the absolute position of q[0] (decode: Tk - 1).
    ``kv_start`` masks keys at positions < kv_start (halo-attention's
    missing-predecessor shard).
    """
    B, Tq, H, hd = q.shape
    Tk, Hk = k.shape[1], k.shape[2]
    G = H // Hk
    scale = scale if scale is not None else hd ** -0.5

    nchunk = max(1, -(-Tk // kv_chunk))
    pad = nchunk * kv_chunk - Tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, nchunk, kv_chunk, Hk, hd)
    vc = v.reshape(B, nchunk, kv_chunk, Hk, hd)

    qg = (q * scale).reshape(B, Tq, Hk, G, hd)
    q_pos = q_offset + jnp.arange(Tq)

    def step(carry, inputs):
        o, m, l = carry  # o: (B,Tq,Hk,G,hd) f32; m,l: (B,Tq,Hk,G)
        kci, vci, base = inputs
        k_pos = base + jnp.arange(kv_chunk)
        s = jnp.einsum("btkgh,bskh->btkgs", qg, kci,
                       preferred_element_type=jnp.float32)  # (B,Tq,Hk,G,ckv)
        ok = jnp.broadcast_to((k_pos[None, :] < Tk) &
                              (k_pos[None, :] >= kv_start), (Tq, kv_chunk))
        if causal:
            ok = ok & (q_pos[:, None] >= k_pos[None, :])
            if window > 0:
                ok = ok & ((q_pos[:, None] - k_pos[None, :]) < window)
        bias = jnp.where(ok, 0.0, NEG_INF)  # (Tq, ckv)
        s = s + bias[None, :, None, None, :]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("btkgs,bskh->btkgh", p.astype(vci.dtype), vci,
                        preferred_element_type=jnp.float32)
        o_new = o * corr[..., None] + pv
        return (o_new, m_new, l_new), None

    o0 = jnp.zeros((B, Tq, Hk, G, hd), jnp.float32)
    m0 = jnp.full((B, Tq, Hk, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Tq, Hk, G), jnp.float32)
    bases = jnp.arange(nchunk) * kv_chunk
    # flash-attention backward: recompute each chunk's scores/probs in the
    # VJP instead of saving (B, Tq, H, ckv) f32 tensors per chunk
    step = jax.checkpoint(step, policy=jax.checkpoint_policies.nothing_saveable)
    (o, m, l), _ = jax.lax.scan(
        step, (o0, m0, l0), (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), bases)
    )
    out = o / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Tq, H, hd).astype(q.dtype)


def decode_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *, kv_len: jax.Array | int,
    window: int = 0, scale: Optional[float] = None,
) -> jax.Array:
    """Single-token attention over a (possibly sequence-sharded) KV cache.

    q: (B, 1, H, hd); k/v: (B, S, Hk, hd).  Written as plain einsum +
    softmax so the SPMD partitioner turns the S-sharded contraction into
    partial softmax stats + a small all-reduce (distributed flash-decode).
    """
    B, _, H, hd = q.shape
    S, Hk = k.shape[1], k.shape[2]
    G = H // Hk
    scale = scale if scale is not None else hd ** -0.5
    qg = (q * scale).reshape(B, Hk, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k, preferred_element_type=jnp.float32)
    pos = jnp.arange(S)
    kv_len = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (B,))
    valid = pos[None, :] < kv_len[:, None]
    if window > 0:
        valid = valid & (pos[None, :] >= (kv_len - window)[:, None])
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_specs(cfg: ModelConfig, stacked: int = 0, d_ff: Optional[int] = None) -> Dict:
    d_ff = d_ff or cfg.d_ff
    lead = ((stacked,), ("layers",)) if stacked else ((), ())
    if cfg.act == "swiglu":
        return {
            "w_gate": ParamSpec(lead[0] + (cfg.d_model, d_ff), lead[1] + ("embed", "mlp")),
            "w_up": ParamSpec(lead[0] + (cfg.d_model, d_ff), lead[1] + ("embed", "mlp")),
            "w_down": ParamSpec(lead[0] + (d_ff, cfg.d_model), lead[1] + ("mlp", "embed")),
        }
    return {
        "w_up": ParamSpec(lead[0] + (cfg.d_model, d_ff), lead[1] + ("embed", "mlp")),
        "b_up": ParamSpec(lead[0] + (d_ff,), lead[1] + ("mlp",), init="zeros"),
        "w_down": ParamSpec(lead[0] + (d_ff, cfg.d_model), lead[1] + ("mlp", "embed")),
        "b_down": ParamSpec(lead[0] + (cfg.d_model,), lead[1] + ("embed",), init="zeros"),
    }


def apply_mlp(cfg: ModelConfig, p: Dict, x: jax.Array) -> jax.Array:
    if cfg.act == "swiglu":
        g = jnp.einsum("...d,df->...f", x, p["w_gate"])
        u = jnp.einsum("...d,df->...f", x, p["w_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        return jnp.einsum("...f,fd->...d", h, p["w_down"])
    h = jnp.einsum("...d,df->...f", x, p["w_up"]) + p["b_up"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, p["w_down"]) + p["b_down"]


# ---------------------------------------------------------------------------
# vocab head: loss + MCAL scoring statistics
# ---------------------------------------------------------------------------


class ScoreStats(NamedTuple):
    """Per-token uncertainty statistics used by MCAL's M(.) / L(.)."""

    margin: jax.Array      # top1 - top2 logit gap
    entropy: jax.Array     # predictive entropy (nats)
    max_logprob: jax.Array # log p(top1)  (least-confidence = 1 - exp(.))
    top1: jax.Array        # argmax index


def score_stats_from_logits(logits: jax.Array) -> ScoreStats:
    """Reference implementation over materialized logits."""
    lf = logits.astype(jnp.float32)
    top2, idx = jax.lax.top_k(lf, 2)
    lse = jax.nn.logsumexp(lf, axis=-1)
    p = jnp.exp(lf - lse[..., None])
    entropy = lse - jnp.sum(p * lf, axis=-1)
    return ScoreStats(
        margin=top2[..., 0] - top2[..., 1],
        entropy=entropy,
        max_logprob=top2[..., 0] - lse,
        top1=idx[..., 0],
    )


def chunked_score_stats(hidden: jax.Array, w_vocab: jax.Array,
                        chunk: int = 8192) -> ScoreStats:
    """Online top-2/entropy/lse over vocab chunks without materializing
    (T, V) logits (jnp twin of the ``margin_head`` Pallas kernel).

    hidden: (..., D); w_vocab: (D, V).
    """
    D, V = w_vocab.shape
    nchunk = max(1, -(-V // chunk))
    pad = nchunk * chunk - V
    if pad:  # dynamic_slice clamps OOB starts -> pad so chunks never clamp
        w_vocab = jnp.pad(w_vocab, ((0, 0), (0, pad)))
    lead = hidden.shape[:-1]
    h2 = hidden.reshape(-1, D)
    T = h2.shape[0]

    def step(carry, i):
        m, s, u, v1, v2, i1 = carry
        wc = jax.lax.dynamic_slice_in_dim(w_vocab, i * chunk, chunk, axis=1)
        x = jnp.einsum("td,dv->tv", h2, wc, preferred_element_type=jnp.float32)
        col = i * chunk + jnp.arange(chunk)
        x = jnp.where(col[None, :] < V, x, NEG_INF)
        # online logsumexp + sum(x * e^x) for entropy
        cm = jnp.max(x, axis=-1)
        m_new = jnp.maximum(m, cm)
        corr = jnp.exp(m - m_new)
        e = jnp.exp(x - m_new[:, None])
        s_new = s * corr + jnp.sum(e, axis=-1)
        u_new = u * corr + jnp.sum(jnp.where(col[None, :] < V, x, 0.0) * e, axis=-1)
        # online top-2: new top2 of {v1, v2, c1, c2} given v1>=v2, c1>=c2
        c12, cidx = jax.lax.top_k(x, 2)
        c1, c2 = c12[:, 0], c12[:, 1]
        v1_new = jnp.maximum(v1, c1)
        v2_new = jnp.maximum(jnp.minimum(v1, c1), jnp.maximum(v2, c2))
        i1_new = jnp.where(c1 > v1, cidx[:, 0] + i * chunk, i1)
        return (m_new, s_new, u_new, v1_new, v2_new, i1_new), None

    init = (
        jnp.full((T,), NEG_INF, jnp.float32),
        jnp.zeros((T,), jnp.float32),
        jnp.zeros((T,), jnp.float32),
        jnp.full((T,), NEG_INF, jnp.float32),
        jnp.full((T,), NEG_INF, jnp.float32),
        jnp.zeros((T,), jnp.int32),
    )
    (m, s, u, v1, v2, i1), _ = jax.lax.scan(step, init, jnp.arange(nchunk))
    lse = m + jnp.log(jnp.maximum(s, 1e-30))
    entropy = lse - u / jnp.maximum(s, 1e-30)
    stats = ScoreStats(margin=v1 - v2, entropy=entropy, max_logprob=v1 - lse, top1=i1)
    return compat.tree_map(lambda a: a.reshape(lead), stats)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token cross-entropy, fp32."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def chunked_cross_entropy(hidden: jax.Array, w_vocab: jax.Array,
                          labels: jax.Array, chunk: int = 16384,
                          mask: Optional[jax.Array] = None) -> jax.Array:
    """CE without materializing (T, V) logits: lse accumulated per vocab
    chunk, label logit gathered on the fly.  Differentiable (scan of
    einsums)."""
    D, V = w_vocab.shape
    nchunk = max(1, -(-V // chunk))
    if nchunk * chunk != V:  # pad so dynamic_slice never clamps (see above)
        w_vocab = jnp.pad(w_vocab, ((0, 0), (0, nchunk * chunk - V)))
    lead = hidden.shape[:-1]
    h2 = hidden.reshape(-1, D)
    lab = labels.reshape(-1)
    T = h2.shape[0]

    def step(carry, i):
        m, s, ll = carry
        wc = jax.lax.dynamic_slice_in_dim(w_vocab, i * chunk, chunk, axis=1)
        x = jnp.einsum("td,dv->tv", h2, wc, preferred_element_type=jnp.float32)
        col = i * chunk + jnp.arange(chunk)
        x = jnp.where(col[None, :] < V, x, NEG_INF)
        cm = jnp.max(x, axis=-1)
        m_new = jnp.maximum(m, cm)
        s_new = s * jnp.exp(m - m_new) + jnp.sum(jnp.exp(x - m_new[:, None]), axis=-1)
        hit = (lab[:, None] == col[None, :])
        ll_new = ll + jnp.sum(jnp.where(hit, x, 0.0), axis=-1)
        return (m_new, s_new, ll_new), None

    # recompute each chunk's logits in the backward pass: without this the
    # scan saves every (T, chunk) f32 logits tile for reverse-mode
    step = jax.checkpoint(step, policy=jax.checkpoint_policies.nothing_saveable)
    init = (jnp.full((T,), NEG_INF, jnp.float32), jnp.zeros((T,), jnp.float32),
            jnp.zeros((T,), jnp.float32))
    (m, s, ll), _ = jax.lax.scan(step, init, jnp.arange(nchunk))
    nll = (m + jnp.log(jnp.maximum(s, 1e-30))) - ll
    nll = nll.reshape(lead)
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
