"""Small feature-vector classifier (MLP) — the classifier family MCAL's
*live* labeling campaigns train (the paper's CNN18/ResNet18 role at
container scale).  Conforms to the model facade: forward -> hidden
(B, 1, d_model); the classification head lives in ``cls_head`` like every
other family."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.param import ParamSpec


def specs(cfg: ModelConfig) -> Dict:
    assert cfg.input_dim > 0 and cfg.num_classes > 0
    sp: Dict = {
        "w_in": ParamSpec((cfg.input_dim, cfg.d_model), ("embed", "mlp"),
                          dtype=jnp.float32),
        "b_in": ParamSpec((cfg.d_model,), ("mlp",), init="zeros",
                          dtype=jnp.float32),
        "blocks": {
            "w": ParamSpec((cfg.num_layers, cfg.d_model, cfg.d_model),
                           ("layers", "embed", "mlp"), dtype=jnp.float32),
            "b": ParamSpec((cfg.num_layers, cfg.d_model), ("layers", "mlp"),
                           init="zeros", dtype=jnp.float32),
        },
        "final_norm": L.norm_specs(cfg),
        "cls_head": ParamSpec((cfg.d_model, cfg.num_classes),
                              ("embed", None), dtype=jnp.float32),
    }
    return sp


def forward(cfg: ModelConfig, params: Dict, features: jax.Array,
            mesh=None) -> jax.Array:
    """features: (B, input_dim) -> hidden (B, 1, d_model)."""
    x = jnp.einsum("bi,id->bd", features.astype(jnp.float32), params["w_in"])
    x = jax.nn.relu(x + params["b_in"])

    def body(h, p):
        h = jax.nn.relu(jnp.einsum("bd,de->be", h, p["w"]) + p["b"]) + h
        return h, None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    x = L.apply_norm(cfg, params["final_norm"], x[:, None, :])
    return x
