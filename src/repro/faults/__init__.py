"""Deterministic fault injection + the resilience vocabulary.

``FaultPlan`` schedules faults as pure functions of (seed, site,
invocation counter); ``FaultInjector`` is its thread-safe runtime face;
``RetryPolicy`` re-issues transient failures with seeded deterministic
jitter.  The package is a leaf: stdlib-only, imported by core/, trace/,
serving/, training/ and launch/ without cycles.

See ROADMAP "Fault injection & resilience" for the contract and the
fault-site inventory.
"""
from repro.faults.errors import (AnnotationTimeout, FaultError,
                                 InjectedKill, InjectedWorkerCrash,
                                 RetryExhausted, StragglerTimeout,
                                 TransientAnnotationError, TransientError)
from repro.faults.plan import (KINDS, Fault, FaultInjector, FaultPlan,
                               FaultRule, hash01)
from repro.faults.retry import RetryPolicy

__all__ = [
    "AnnotationTimeout", "Fault", "FaultError", "FaultInjector",
    "FaultPlan", "FaultRule", "InjectedKill", "InjectedWorkerCrash",
    "KINDS", "RetryExhausted", "RetryPolicy", "StragglerTimeout",
    "TransientAnnotationError", "TransientError", "hash01",
]
