"""Exception vocabulary of the fault-injection / resilience layer.

Two families, split by how the runtime is allowed to react:

* :class:`TransientError` subclasses are RETRYABLE — a bounded-backoff
  re-issue (``faults.retry.RetryPolicy``) or a worker re-dispatch is
  expected to clear them.  They model the lossy-service failure surface:
  an annotation backend timing out, a flaky RPC, a preempted broker job.
* :class:`FaultError` subclasses are TERMINAL for the failing unit of
  work — retries were exhausted or a wall budget blew.  The fleet layer
  reacts by quarantining the tenant instead of nuking the round.

:class:`InjectedKill` deliberately derives from ``BaseException`` so the
mid-iteration kill point is NOT swallowed by ``except Exception`` paths
— it emulates a SIGKILL/preemption and must unwind all the way to the
launcher's crash-safe autosave handler.
"""
from __future__ import annotations


class TransientError(RuntimeError):
    """Base of retryable faults: a bounded re-issue should clear it."""


class TransientAnnotationError(TransientError):
    """The annotation backend dropped/garbled one request (flaky RPC)."""


class AnnotationTimeout(TransientError):
    """One annotation request exceeded its per-request deadline."""


class InjectedWorkerCrash(TransientError):
    """A :class:`~repro.core.worker.SerialWorker` job died mid-flight
    (emulated preemption) — the re-dispatch path re-runs the job."""


class FaultError(RuntimeError):
    """Base of terminal resilience failures (retries exhausted, wall
    budget blown).  The orchestrator maps these to tenant quarantine."""


class RetryExhausted(FaultError):
    """Every attempt of a :class:`~repro.faults.retry.RetryPolicy` loop
    failed; ``__cause__`` chains the last transient error."""


class StragglerTimeout(FaultError):
    """An async sweep/fit/annotation job was still running when its
    configured wall budget expired (``SweepFuture.result(timeout)``)."""


class InjectedKill(BaseException):
    """Mid-iteration kill point: emulates preemption of the whole
    process.  BaseException on purpose — only the launcher's autosave
    handler (and test harnesses) may catch it."""
