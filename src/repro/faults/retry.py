"""Bounded-backoff retry with seeded deterministic jitter.

A :class:`RetryPolicy` re-issues a unit of work after a
:class:`~repro.faults.errors.TransientError` with exponential backoff;
the jitter is NOT drawn from a global RNG but hashed from
``(policy.seed, site, invocation, attempt)`` — the same convention the
fault plan fires on — so a chaos run's retry delays (and therefore its
emitted ``retry`` events) replay bit-identically.

The policy never makes a request idempotent by itself: it is only safe
around operations that are transactional per attempt.  The annotation
service qualifies twice over — votes are counter-free hashes of
(pool seed, worker, item), so a re-issued request yields the identical
vote matrix, and the budget check precedes every charge, so a failed
attempt charges nothing (see ``AnnotationService._annotate_impl``).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, Optional, TypeVar

from repro.faults.errors import RetryExhausted, TransientError
from repro.faults.plan import hash01

T = TypeVar("T")


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff: up to ``max_attempts`` tries,
    delay ``min(base_delay * multiplier**attempt, max_delay)`` scaled by
    a deterministic jitter in ``[1 - jitter/2, 1 + jitter/2)``.

    ``timeout`` is the per-request deadline handed down to fault checks
    (an injected latency above it turns into a retryable
    ``AnnotationTimeout``).  ``sleep_scale`` scales the actual sleeps —
    0 in tests keeps the decision/emission stream while skipping the
    waiting (delays are still computed and reported deterministically).
    """

    max_attempts: int = 4
    base_delay: float = 0.05
    multiplier: float = 2.0
    max_delay: float = 2.0
    jitter: float = 0.5
    timeout: Optional[float] = None
    seed: int = 0
    sleep_scale: float = 1.0
    _calls: "itertools.count" = dataclasses.field(
        default_factory=itertools.count, repr=False, compare=False)

    def backoff(self, site: str, invocation: int, attempt: int) -> float:
        """The delay before re-attempt ``attempt + 1`` — pure in
        (seed, site, invocation, attempt)."""
        d = min(self.base_delay * self.multiplier ** attempt, self.max_delay)
        if self.jitter > 0.0:
            u = hash01(self.seed, f"retry.{site}",
                       invocation * 64 + attempt)
            d *= 1.0 + self.jitter * (u - 0.5)
        return d

    def call(self, fn: Callable[[], T], *, site: str = "request",
             notify: Optional[Callable[[int, BaseException, float],
                                       None]] = None) -> T:
        """Run ``fn`` under the policy.  Only
        :class:`~repro.faults.errors.TransientError` is retried —
        anything else (``BudgetExceeded``, programming errors, kill
        points) propagates from the first attempt untouched.  ``notify``
        observes each retry as ``(attempt, exc, delay)`` (the seam the
        service's ``retry`` trace events / ``retries_total`` counter
        hang off).  Raises :class:`RetryExhausted` chaining the last
        transient error once attempts run out.
        """
        invocation = next(self._calls)
        last: Optional[TransientError] = None
        for attempt in range(max(1, self.max_attempts)):
            try:
                return fn()
            except TransientError as e:
                last = e
                if attempt + 1 >= max(1, self.max_attempts):
                    break
                delay = self.backoff(site, invocation, attempt)
                if notify is not None:
                    notify(attempt, e, delay)
                if self.sleep_scale > 0.0:
                    time.sleep(delay * self.sleep_scale)
        raise RetryExhausted(
            f"{site}: {max(1, self.max_attempts)} attempts exhausted "
            f"(last: {type(last).__name__}: {last})") from last
