"""Seeded, counter-keyed fault schedule + the thread-safe injector.

The contract that makes chaos runs replayable: whether invocation ``c``
of site ``s`` faults is a PURE function of ``(plan.seed, s, c)`` — the
same splitmix64 mix the annotator oracle draws votes with, so a chaos
campaign re-run under the same plan fires bit-identical faults.  No
global RNG state, no wall clock: the injector only keeps per-site
invocation counters.

Site vocabulary (the fault-site inventory; see ROADMAP "Fault injection
& resilience"):

  ``annotation.request``   one human-label batch request (pre-charge)
  ``worker.<name>``        one SerialWorker job (sweep/fit/annotation
                           brokers — ``pool-sweep``, ``fit-engine``, ...)
  ``trace.flush``          one trace-store buffer flush (torn write)
  ``campaign.iteration``   one MCAL iteration entry (kill point)

Counters are process-local and NOT persisted across resume: a resumed
campaign starts every site at 0 (documented — resume-under-chaos tests
hand the resumed leg a fresh plan).
"""
from __future__ import annotations

import dataclasses
import threading
import time
import zlib
from typing import Callable, Dict, FrozenSet, Optional, Tuple

from repro.faults.errors import (AnnotationTimeout, InjectedKill,
                                 InjectedWorkerCrash,
                                 TransientAnnotationError)

_MASK = 0xFFFFFFFFFFFFFFFF

#: fault kinds -> what :meth:`FaultInjector.check` does when one fires
KINDS: FrozenSet[str] = frozenset({
    "latency",    # sleep ``duration`` (AnnotationTimeout past a deadline)
    "transient",  # raise TransientAnnotationError
    "timeout",    # raise AnnotationTimeout
    "crash",      # raise InjectedWorkerCrash
    "oserror",    # raise OSError (trace-write faults)
    "hang",       # sleep ``duration`` silently (straggler emulation)
    "kill",       # raise InjectedKill (BaseException: emulated preemption)
})


def hash01(seed: int, site: str, counter: int) -> float:
    """Uniform [0, 1) from (seed, site, counter) — splitmix64 finalizer
    over a crc32 site salt, the repo's counter-based draw convention
    (``AnnotatorPool._draws``)."""
    salt = zlib.crc32(site.encode("utf-8")) & 0xFFFFFFFF
    key = (seed * 1_000_003 + salt * 7919 + 0x51ED2701) & _MASK
    z = (key + counter * 0x9E3779B97F4A7C15) & _MASK
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK
    z ^= z >> 31
    return (z >> 11) / float(1 << 53)


@dataclasses.dataclass(frozen=True)
class FaultRule:
    """One injectable fault at one site.

    Fires when the site's invocation counter is in ``at`` (an explicit
    schedule), or — with ``at`` unset — independently per invocation
    with probability ``rate`` (counter >= ``after``).  ``duration`` is
    the emulated latency/hang in seconds, scaled by the plan's
    ``time_scale`` (0 in tests: decisions without the waiting).
    """

    site: str
    kind: str
    rate: float = 0.0
    at: Optional[Tuple[int, ...]] = None
    after: int = 0
    duration: float = 0.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(one of {sorted(KINDS)})")
        if self.at is not None:
            object.__setattr__(self, "at", tuple(int(a) for a in self.at))


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded schedule of :class:`FaultRule`\\ s, grouped by site.

    :meth:`decide` is pure: rules with an explicit ``at`` schedule win
    first (in rule order), then rate rules share ONE uniform draw per
    invocation (cumulative-rate partition), so adding a rule never
    perturbs which invocations an earlier rule fires on only reweights
    the shared draw — and two runs under the same plan fault at exactly
    the same invocations.
    """

    seed: int = 0
    rules: Tuple[FaultRule, ...] = ()
    time_scale: float = 1.0

    def __post_init__(self):
        object.__setattr__(self, "rules", tuple(self.rules))
        by_site: Dict[str, Tuple[FaultRule, ...]] = {}
        for r in self.rules:
            by_site[r.site] = by_site.get(r.site, ()) + (r,)
        object.__setattr__(self, "_by_site", by_site)

    def decide(self, site: str, counter: int) -> Optional[FaultRule]:
        """The rule firing at invocation ``counter`` of ``site`` (None =
        no fault) — pure in (seed, site, counter)."""
        rules = self._by_site.get(site)
        if not rules:
            return None
        for r in rules:
            if r.at is not None and counter in r.at:
                return r
        u, acc = None, 0.0
        for r in rules:
            if r.at is not None or counter < r.after or r.rate <= 0.0:
                continue
            if u is None:
                u = hash01(self.seed, site, counter)
            acc += r.rate
            if u < acc:
                return r
        return None

    @classmethod
    def standard_transient(cls, seed: int = 0, *,
                           time_scale: float = 0.0) -> "FaultPlan":
        """The standard chaos mix benchmarks and ``--chaos`` use: flaky
        annotation backend (transient failures + latency spikes), one
        broker-job crash per engine family, one torn trace write.  No
        kill points — a killed CLI run would re-fire the kill on resume
        (counters restart); kills are exercised by the test harness."""
        return cls(seed=seed, time_scale=time_scale, rules=(
            FaultRule("annotation.request", "transient", rate=0.15),
            FaultRule("annotation.request", "latency", rate=0.10,
                      duration=0.05),
            FaultRule("worker.pool-sweep", "crash", at=(1,)),
            FaultRule("worker.fit-engine", "crash", at=(1,)),
            FaultRule("trace.flush", "oserror", at=(0,)),
        ))


class Fault:
    """One fired fault: ``(site, counter, rule)``."""

    __slots__ = ("site", "counter", "rule")

    def __init__(self, site: str, counter: int, rule: FaultRule):
        self.site, self.counter, self.rule = site, counter, rule

    def __repr__(self):
        return (f"Fault(site={self.site!r}, counter={self.counter}, "
                f"kind={self.rule.kind!r})")


class FaultInjector:
    """Thread-safe runtime face of a :class:`FaultPlan`.

    Every resilience seam calls :meth:`check` (or the lower-level
    :meth:`tick`) once per unit of work; with no plan attached both are
    near-free no-ops, which is what the bench_faults 5%-overhead gate
    measures.  Fired faults ride the trace as ``fault_injected``
    observability events and bump the ``faults_injected_total`` counter
    when a trace/metrics surface is attached.
    """

    def __init__(self, plan: Optional[FaultPlan] = None):
        self.plan = plan if plan is not None else FaultPlan()
        self.trace = None
        self.metrics = None
        self.fired = 0
        self._counters: Dict[str, int] = {}
        self._lock = threading.Lock()
        self._sleep: Callable[[float], None] = time.sleep

    # -- wiring ---------------------------------------------------------
    def attach_trace(self, trace) -> None:
        """Emit ``fault_injected`` events into this store (observability
        kind: replay/diff ignore it, chaos runs stay diff-clean)."""
        self.trace = trace

    def attach_metrics(self, metrics) -> None:
        self.metrics = metrics

    # -- introspection --------------------------------------------------
    def counters(self) -> Dict[str, int]:
        """Per-site invocation counts seen so far (a copy)."""
        with self._lock:
            return dict(self._counters)

    # -- the injection seam ---------------------------------------------
    def tick(self, site: str, *, emit: bool = True) -> Optional[Fault]:
        """Advance ``site``'s invocation counter and return the fault
        firing at it, if any — WITHOUT acting on it.  ``emit=False``
        skips the trace event (required where the caller already holds
        the trace-store lock, e.g. inside ``TraceStore._flush_locked``)."""
        with self._lock:
            c = self._counters.get(site, 0)
            self._counters[site] = c + 1
        rule = self.plan.decide(site, c)
        if rule is None:
            return None
        self.fired += 1
        if emit and self.trace is not None:
            self.trace.emit("fault_injected", site=site, counter=int(c),
                            fault=rule.kind)
        if self.metrics is not None:
            self.metrics.inc("faults_injected_total", site=site,
                             kind=rule.kind)
        return Fault(site, c, rule)

    def check(self, site: str, *, timeout: Optional[float] = None,
              emit: bool = True) -> Optional[Fault]:
        """One unit of work at ``site``: sleep through latency/hang
        faults (scaled by the plan's ``time_scale``) and raise the
        mapped exception for failure faults.  ``timeout`` is the
        caller's per-request deadline — an injected latency above it
        becomes an :class:`AnnotationTimeout` instead of a sleep."""
        fault = self.tick(site, emit=emit)
        if fault is None:
            return None
        r, c = fault.rule, fault.counter
        where = f"{site}#{c}"
        if r.kind == "latency":
            if timeout is not None and r.duration > timeout:
                self._sleep(timeout * self.plan.time_scale)
                raise AnnotationTimeout(
                    f"injected latency {r.duration:g}s blew the "
                    f"{timeout:g}s request deadline at {where}")
            self._sleep(r.duration * self.plan.time_scale)
            return fault
        if r.kind == "hang":
            self._sleep(r.duration * self.plan.time_scale)
            return fault
        if r.kind == "transient":
            raise TransientAnnotationError(f"injected transient failure "
                                           f"at {where}")
        if r.kind == "timeout":
            raise AnnotationTimeout(f"injected request timeout at {where}")
        if r.kind == "crash":
            raise InjectedWorkerCrash(f"injected job crash at {where}")
        if r.kind == "oserror":
            raise OSError(f"injected IO fault at {where}")
        assert r.kind == "kill"
        raise InjectedKill(f"injected kill point at {where}")
