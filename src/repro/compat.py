"""Version-adaptive shims over every JAX API this repo uses that drifted
between 0.4.x and 0.5+/0.6+.

POLICY: never call a drifting JAX API directly from repo code — route it
through here.  The APIs below moved, appeared, or changed shape across the
JAX releases we support (floor: 0.4.37, see requirements.txt):

* ``jax.tree.flatten_with_path`` — only ``jax.tree_util``'s spelling exists
  on 0.4.x; the ``jax.tree`` alias landed later.
* ``jax.sharding.AxisType`` + ``Mesh(..., axis_types=...)`` — absent on
  0.4.x; newer JAX defaults them anyway, so :func:`make_mesh` accepts and
  drops the kwarg where unsupported.
* ``jax.shard_map`` — top-level export is 0.7+; before that it lives in
  ``jax.experimental.shard_map``.
* ``compiled.cost_analysis()`` — a one-element *list* of dicts on 0.4.x, a
  plain dict on newer releases; :func:`cost_analysis_dict` normalizes.

Anything stable (``jax.jit``, ``jax.numpy``, ``NamedSharding``,
``PartitionSpec``) is intentionally NOT wrapped — the shim covers drift,
not the whole API.  New code that needs one of the wrapped families must
import it from here so the next JAX bump is a one-file change.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.tree_util as jtu
from jax.sharding import Mesh, NamedSharding, PartitionSpec  # noqa: F401  (re-export)

JAX_VERSION: Tuple[int, ...] = tuple(
    int(p) for p in jax.__version__.split(".")[:3] if p.isdigit())

__all__ = [
    "JAX_VERSION",
    # pytree family
    "tree_map", "tree_leaves", "tree_flatten", "tree_unflatten",
    "tree_structure", "tree_flatten_with_path", "tree_map_with_path",
    "keystr",
    # mesh / sharding
    "Mesh", "NamedSharding", "PartitionSpec", "make_mesh", "shard_map",
    "default_axis_types",
    # compiled-artifact introspection
    "cost_analysis_dict",
]


# ---------------------------------------------------------------------------
# pytree family: jax.tree.* is the modern spelling but 0.4.x only carries
# the full set under jax.tree_util (jax.tree.flatten_with_path in
# particular is missing on 0.4.37).  jax.tree_util has every spelling on
# all supported versions, so bind the whole family there.
# ---------------------------------------------------------------------------

tree_map = jtu.tree_map
tree_leaves = jtu.tree_leaves
tree_flatten = jtu.tree_flatten
tree_unflatten = jtu.tree_unflatten
tree_structure = jtu.tree_structure
tree_flatten_with_path = jtu.tree_flatten_with_path
tree_map_with_path = jtu.tree_map_with_path
keystr = jtu.keystr


# ---------------------------------------------------------------------------
# mesh construction
# ---------------------------------------------------------------------------

_HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")


def default_axis_types(n: int):
    """``(AxisType.Auto,) * n`` where AxisType exists, else None."""
    if _HAS_AXIS_TYPES:
        return (jax.sharding.AxisType.Auto,) * n
    return None


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              axis_types: Any = None, devices=None) -> Mesh:
    """``jax.make_mesh`` that tolerates ``axis_types`` on every version.

    On 0.4.x (no ``AxisType``) the kwarg is dropped — those releases have
    no explicit-sharding mode, so Auto is the only (implicit) behaviour
    anyway.  ``axis_types=True`` asks for the version's default Auto types.
    """
    kwargs: Dict[str, Any] = {}
    if devices is not None:
        kwargs["devices"] = devices
    if axis_types is not None and _HAS_AXIS_TYPES:
        if axis_types is True:
            axis_types = default_axis_types(len(axis_shapes))
        kwargs["axis_types"] = axis_types
    if hasattr(jax, "make_mesh"):
        try:
            return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                                 **kwargs)
        except TypeError:
            # e.g. 0.4.35-0.4.38: make_mesh exists but without axis_types
            kwargs.pop("axis_types", None)
            return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                                 **kwargs)
    from jax.experimental import mesh_utils
    devs = mesh_utils.create_device_mesh(tuple(axis_shapes),
                                         devices=devices)
    return Mesh(devs, tuple(axis_names))


# ---------------------------------------------------------------------------
# shard_map entry point
# ---------------------------------------------------------------------------

try:  # jax >= 0.7 exposes shard_map at top level
    from jax import shard_map as _shard_map_impl  # type: ignore[attr-defined]
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map_impl

import inspect as _inspect

_SHARD_MAP_PARAMS = frozenset(
    _inspect.signature(_shard_map_impl).parameters)


def shard_map(f: Optional[Callable] = None, **kwargs):
    """``shard_map`` with the replication-check kwarg normalized.

    Newer JAX renamed ``check_rep`` to ``check_vma``; callers use the
    modern spelling and this translates for 0.4.x.  All other kwargs pass
    through untouched.
    """
    if "check_vma" in kwargs and "check_vma" not in _SHARD_MAP_PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    elif "check_rep" in kwargs and "check_rep" not in _SHARD_MAP_PARAMS:
        kwargs["check_vma"] = kwargs.pop("check_rep")
    if f is None:
        return lambda g: _shard_map_impl(g, **kwargs)
    return _shard_map_impl(f, **kwargs)


# ---------------------------------------------------------------------------
# compiled-artifact introspection
# ---------------------------------------------------------------------------


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """Normalize ``compiled.cost_analysis()`` to one flat dict.

    0.4.x returns ``[{...}]`` (one dict per partition — a single dict for
    the single-partition programs we lower); newer JAX returns the dict
    directly.  Missing/empty analyses normalize to ``{}``.
    """
    cost = compiled.cost_analysis()
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        if not cost:
            return {}
        cost = cost[0]
    return dict(cost)
