"""repro.obs — runtime metrics & profiling (the telemetry half of
observability; ``repro.trace`` owns the replayable decision stream) plus
the health layer that judges both (``obs.health`` / ``obs.slo``)."""
from repro.obs.export import (cache_hit_rates, prometheus_lines,
                              queue_stats, snapshot_counter, span_rollup,
                              write_prometheus)
from repro.obs.health import (ALERT_KINDS, HealthConfig, HealthEngine,
                              alert_sequence, hist_quantile)
from repro.obs.metrics import (DEFAULT_BUCKETS, MetricsRegistry, Span,
                               get_registry, log_buckets, set_registry)
from repro.obs.profiling import profile_block
from repro.obs.slo import SLO_CLAUSES, SLOSpec, evaluate_slo

__all__ = [
    "DEFAULT_BUCKETS", "MetricsRegistry", "Span", "log_buckets",
    "get_registry", "set_registry", "profile_block",
    "write_prometheus", "prometheus_lines", "span_rollup",
    "cache_hit_rates", "queue_stats", "snapshot_counter",
    "ALERT_KINDS", "HealthConfig", "HealthEngine", "alert_sequence",
    "hist_quantile", "SLO_CLAUSES", "SLOSpec", "evaluate_slo",
]
