# Streaming health engine.  jax-free: consumes the metrics registry and
# campaign/tenant state the transports already expose; emits judgment.
"""Campaign health: detectors, hysteresis-gated alerts, SLO verdicts.

Two PRs' worth of telemetry (the trace event bus, the metrics registry)
record what a campaign *did*; this module is the layer that *judges* it
while it runs.  A :class:`HealthEngine` ticks at the natural decision
boundaries — every ``MCALCampaign.iteration()`` for a solo run, every
``FleetController.rebalance()`` for a fleet — samples the campaign(s)
and the registry, runs the detector suite, and emits deduplicated,
hysteresis-gated ``alert`` / ``alert_clear`` / ``slo_breach`` events
into whatever trace it is attached to.  All three kinds are
``OBSERVABILITY_KINDS``: replay and diff ignore them, so a monitored
campaign's decision stream stays byte-identical to its monitor-off
sibling's.

Detector suite (each skipped silently when its input is not measurable):

* ``budget_burn`` — per-round burn projected against the tenant's
  allocation (fleet) or ``cfg.budget`` (solo): fires when the projected
  rounds-to-exhaustion drops inside ``burn_horizon`` (payload carries
  the ETA), escalating to ``critical`` once the next round would blow
  it.
* ``annotator_drift`` — the annotation service's running residual-error
  estimate vs the :class:`~repro.core.cost.LabelQuality` residual the
  joint search assumed: fires when reality is worse than the
  calibration by more than ``drift_tol`` (the Liao et al. concern —
  the search is optimizing against a stale quality model).
* ``fit_quality`` — power-law fit degradation: the worst log-space
  residual std among fits with enough points exceeds ``fit_resid_max``
  (the C*-vs-iteration machinery is extrapolating from a curve that no
  longer fits its own history).
* ``cache_storm`` — compile-cache miss storm: per-tick
  ``pack_cache_misses_total`` delta at least ``cache_miss_burst`` and
  outpacing hits (the shared-engine speedup is being eaten by XLA).
* ``queue_saturation`` — any broker ``queue_depth`` gauge above
  ``queue_depth_max``.
* ``fault_pressure`` — PR 9's resilience counters: any straggler
  timeout or quarantine this tick, or a retries+faults burst at least
  ``fault_burst``.

**Hysteresis + dedup.**  Each ``(tenant, detector)`` pair is a tiny
state machine: ``up_ticks`` consecutive breaching samples raise ONE
``alert``; while it is firing, further breaches emit nothing; only
``down_ticks`` consecutive healthy samples clear it (one
``alert_clear``).  A metric flapping across its threshold therefore
produces one alert and one clear, not an event per flap.

**Determinism.**  Ticks are counted, not timed.  The ledger/fit-derived
detectors and the enforceable SLO clauses are pure functions of the
decision state, so two identical runs emit byte-equal alert sequences
(:func:`alert_sequence` extracts exactly the comparable fields).  The
registry-derived detectors (cache/queue/fault) and the latency clause
read runtime telemetry and may legitimately differ across modes; they
alert but never drive enforcement.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

from repro.obs.export import queue_stats, snapshot_counter
from repro.obs.slo import SLOSpec, evaluate_slo

__all__ = ["ALERT_KINDS", "HealthConfig", "HealthEngine",
           "alert_sequence", "hist_quantile"]

# the health engine's event vocabulary — classified OBSERVABILITY_KINDS
# in repro.trace.replay, so replay/diff never see them
ALERT_KINDS = frozenset({"alert", "alert_clear", "slo_breach"})

# detector severities (budget_burn escalates to critical on imminence)
_SEVERITY = {
    "budget_burn": "warn", "annotator_drift": "warn",
    "fit_quality": "warn", "cache_storm": "warn",
    "queue_saturation": "warn", "fault_pressure": "critical",
}


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Detector thresholds + hysteresis widths.  Defaults raise fast
    (one breaching tick) and clear slow (two healthy ticks) — the
    classic anti-flap asymmetry."""

    up_ticks: int = 1           # consecutive breaches to raise
    down_ticks: int = 2         # consecutive healthy ticks to clear
    burn_horizon: float = 3.0   # alert when exhaustion is <= N rounds out
    drift_tol: float = 0.05     # residual-error drift tolerance (abs)
    fit_resid_max: float = 0.35  # log-space residual-std ceiling
    fit_min_points: int = 3     # fits with fewer points are not judged
    cache_miss_burst: float = 8.0   # per-tick compile misses = a storm
    queue_depth_max: float = 64.0
    fault_burst: float = 4.0    # per-tick faults+retries


class _AlertState:
    """One (tenant, detector) hysteresis cell."""

    __slots__ = ("firing", "breaches", "oks")

    def __init__(self):
        self.firing = False
        self.breaches = 0
        self.oks = 0


class HealthEngine:
    """The streaming judge.  Attach a trace (alert events ride it) and
    optionally a metrics registry (both an input — counters, span
    histograms — and an output: ``health_alerts_total``).  ``slo`` is an
    optional :class:`~repro.obs.slo.SLOSpec`; without one the detector
    suite still runs."""

    def __init__(self, slo: Optional[SLOSpec] = None,
                 config: Optional[HealthConfig] = None, *,
                 trace=None, metrics=None):
        self.slo = slo
        self.config = config or HealthConfig()
        self.trace = trace
        self.metrics = metrics
        self.tick = 0
        self._state: Dict[Tuple[str, str], _AlertState] = {}
        self._last_spent: Dict[str, float] = {}
        self._last_counters: Optional[Dict[str, float]] = None
        self.alerts_raised = 0
        self.alerts_cleared = 0
        self.breaches = 0

    # -- wiring -------------------------------------------------------------
    def attach_trace(self, trace) -> None:
        self.trace = trace

    def attach_metrics(self, metrics) -> None:
        self.metrics = metrics

    def _emit(self, kind: str, **payload) -> None:
        if self.trace is not None:
            self.trace.emit(kind, **payload)

    # -- sampling -----------------------------------------------------------
    def observe_campaign(self, campaign, tenant: str = "",
                         budget: Optional[float] = None) -> Dict:
        """One deterministic sample of a campaign's decision state —
        everything the ledger/fit detectors and the enforceable SLO
        clauses read.  ``budget`` overrides ``cfg.budget`` (the fleet
        passes the tenant's live allocation).  Duck-typed reads only:
        no engine, no device work, and crucially no fit is ever FORCED
        (the memoized fit cache is read as-is, the
        ``Tenant.next_spend`` rule)."""
        led = campaign.pool.ledger.snapshot()
        cfg = campaign.cfg
        quality = getattr(cfg, "label_quality", None)
        assumed = float(quality.residual_error) if quality is not None \
            else 0.0
        ann = getattr(campaign.task, "annotation", None)
        observed = None
        if ann is not None and hasattr(ann, "estimated_residual_error"):
            observed = float(ann.estimated_residual_error())
        fit_resid = None
        projected_quality = None
        cache = getattr(campaign, "_fit_models_cache", None)
        if cache is not None:
            laws = cache[1]
            resids = [law.resid_std for law in laws.values()
                      if law.n_points >= self.config.fit_min_points]
            if resids:
                fit_resid = float(max(resids))
            theta = getattr(campaign, "theta_opt", None)
            b_opt = getattr(campaign, "B_opt", None)
            if theta is not None and b_opt is not None \
                    and theta in laws:
                eps = float(laws[theta].predict(int(b_opt)))
                projected_quality = 1.0 - eps - assumed
        labels = int(led.get("human_labels", 0))
        spent = float(led.get("total", 0.0))
        return {
            "tenant": str(tenant),
            "spent": spent,
            "labels": labels,
            "cost_per_label": (spent / labels) if labels > 0 else None,
            "budget": (float(budget) if budget is not None
                       else getattr(cfg, "budget", None)),
            "done": bool(campaign.done),
            "assumed_residual": assumed,
            "observed_residual": observed,
            "fit_resid": fit_resid,
            "projected_quality": projected_quality,
            "iteration_p95": self._iteration_p95(tenant),
        }

    def _iteration_p95(self, tenant: str) -> Optional[float]:
        """Iteration-latency p95 from the registry's span histogram
        (None with metrics off — the latency clause simply never
        fires)."""
        if self.metrics is None:
            return None
        for h in self.metrics.snapshot().get("histograms", ()):
            if h["name"] != "span_seconds":
                continue
            labels = h.get("labels", {})
            if labels.get("name") != "iteration":
                continue
            if tenant and labels.get("tenant", "") not in ("", tenant):
                continue
            return hist_quantile(h, 0.95)
        return None

    def _registry_sample(self) -> Optional[Dict]:
        """The fleet-scope telemetry sample (tenant ``""``): registry
        counter totals + queue gauges.  None with metrics off."""
        if self.metrics is None:
            return None
        snap = self.metrics.snapshot()
        counters = {name: snapshot_counter(snap, name) for name in (
            "pack_cache_hits_total", "pack_cache_misses_total",
            "retries_total", "faults_injected_total",
            "straggler_timeouts_total", "tenants_quarantined_total")}
        return {"tenant": "", "counters": counters,
                "queues": queue_stats(snap)}

    # -- the tick boundaries ------------------------------------------------
    def tick_campaign(self, campaign, tenant: str = "") -> List[Dict]:
        """Solo boundary: one campaign, one sample, plus the registry
        scope.  Returns the current SLO breach verdicts."""
        self.tick += 1
        verdicts = self._judge(self.observe_campaign(campaign,
                                                     tenant=tenant))
        self._judge_registry()
        return verdicts

    def tick_fleet(self, tenants: Iterable, tick: Optional[int] = None
                   ) -> List[Dict]:
        """Fleet boundary (``FleetController.rebalance``): sample every
        tenant in ``tenant_id`` order (a total, config-independent
        order — the event stream must not depend on construction
        order), then the registry scope.  Returns the current breach
        verdicts for ALL tenants, the list ``--slo-enforce`` walks."""
        self.tick = self.tick + 1 if tick is None else int(tick)
        verdicts: List[Dict] = []
        for t in sorted(tenants, key=lambda t: t.tenant_id):
            verdicts.extend(self._judge(self.observe_campaign(
                t.campaign, tenant=t.tenant_id, budget=t.allocation)))
        self._judge_registry()
        return verdicts

    def tick_samples(self, samples: Iterable[Dict]) -> List[Dict]:
        """Drive the engine from pre-built samples (tests, simulation,
        offline re-judgment of a recorded run)."""
        self.tick += 1
        verdicts: List[Dict] = []
        for s in samples:
            if "counters" in s or "queues" in s:
                self._judge_telemetry(s)
            else:
                verdicts.extend(self._judge(s))
        return verdicts

    # -- detectors ----------------------------------------------------------
    def _judge(self, s: Dict) -> List[Dict]:
        """Run the per-tenant detectors + SLO clauses over one sample,
        advancing the hysteresis cells.  Detector order is fixed."""
        tenant = s["tenant"]
        self._detect_burn(s)
        self._detect_drift(s)
        self._detect_fit(s)
        verdicts = evaluate_slo(self.slo, s)
        breached = {v["slo"]: v for v in verdicts}
        for clause in (self.slo.clauses() if self.slo is not None else ()):
            v = breached.get(clause)
            self._step(tenant, f"slo:{clause}", v is not None,
                       kind="slo_breach",
                       payload=(dict(v) if v is not None else None))
        return verdicts

    def _detect_burn(self, s: Dict) -> None:
        budget, spent = s.get("budget"), s["spent"]
        if budget is None or s["done"]:
            # no allocation to burn against (or nothing left to spend):
            # leave the cell untouched rather than count a healthy tick
            return
        tenant = s["tenant"]
        burn = spent - self._last_spent.get(tenant, 0.0)
        self._last_spent[tenant] = spent
        remaining = budget - spent
        if remaining <= 0.0:
            eta = 0.0
        elif burn > 0.0:
            eta = remaining / burn
        else:
            eta = float("inf")
        firing = eta <= self.config.burn_horizon
        self._step(tenant, "budget_burn", firing, payload={
            "spent": spent, "budget": float(budget),
            "burn_per_round": burn,
            "eta_rounds": (None if eta == float("inf") else eta),
            "severity": ("critical" if eta <= 1.0 else "warn")})

    def _detect_drift(self, s: Dict) -> None:
        observed = s.get("observed_residual")
        if observed is None:
            return
        drift = observed - s["assumed_residual"]
        self._step(s["tenant"], "annotator_drift",
                   drift > self.config.drift_tol, payload={
                       "assumed": s["assumed_residual"],
                       "observed": observed, "drift": drift})

    def _detect_fit(self, s: Dict) -> None:
        resid = s.get("fit_resid")
        if resid is None:
            return
        self._step(s["tenant"], "fit_quality",
                   resid > self.config.fit_resid_max, payload={
                       "resid_std": resid,
                       "ceiling": self.config.fit_resid_max})

    def _judge_registry(self) -> None:
        s = self._registry_sample()
        if s is not None:
            self._judge_telemetry(s)

    def _judge_telemetry(self, s: Dict) -> None:
        """The registry-scope detectors: counter deltas + queue gauges.
        The first sample only establishes the delta baseline — startup
        compiles are expected, not a storm."""
        counters = s.get("counters", {})
        queues = s.get("queues", {})
        last, self._last_counters = self._last_counters, dict(counters)
        if last is not None:
            d = {k: counters.get(k, 0.0) - last.get(k, 0.0)
                 for k in counters}
            misses = d.get("pack_cache_misses_total", 0.0)
            hits = d.get("pack_cache_hits_total", 0.0)
            self._step("", "cache_storm",
                       misses >= self.config.cache_miss_burst
                       and misses > hits,
                       payload={"misses": misses, "hits": hits})
            hard = (d.get("straggler_timeouts_total", 0.0)
                    + d.get("tenants_quarantined_total", 0.0))
            soft = (d.get("retries_total", 0.0)
                    + d.get("faults_injected_total", 0.0))
            self._step("", "fault_pressure",
                       hard >= 1.0 or soft >= self.config.fault_burst,
                       payload={"stragglers_or_quarantines": hard,
                                "faults_and_retries": soft})
        depth = max((st.get("depth", 0.0) for st in queues.values()),
                    default=0.0)
        self._step("", "queue_saturation",
                   depth > self.config.queue_depth_max,
                   payload={"depth": depth, "queues": sorted(queues)})

    # -- the hysteresis/dedup cell ------------------------------------------
    def _step(self, tenant: str, detector: str, firing: bool, *,
              payload: Optional[Dict] = None, kind: str = "alert") -> None:
        cell = self._state.setdefault((tenant, detector), _AlertState())
        if firing:
            cell.breaches += 1
            cell.oks = 0
            if not cell.firing and cell.breaches >= self.config.up_ticks:
                cell.firing = True
                self.alerts_raised += 1
                if kind == "slo_breach":
                    self.breaches += 1
                body = dict(payload or {})
                for k in ("tick", "tenant", "detector"):
                    body.pop(k, None)   # envelope fields win
                body.setdefault("severity",
                                _SEVERITY.get(detector, "warn"))
                self._emit(kind, tick=self.tick, tenant=tenant,
                           detector=detector, **body)
                if self.metrics is not None:
                    self.metrics.inc("health_alerts_total",
                                     detector=detector)
        else:
            cell.oks += 1
            cell.breaches = 0
            if cell.firing and cell.oks >= self.config.down_ticks:
                cell.firing = False
                self.alerts_cleared += 1
                self._emit("alert_clear", tick=self.tick, tenant=tenant,
                           detector=detector)

    # -- introspection ------------------------------------------------------
    def active(self) -> List[Tuple[str, str]]:
        """Currently firing (tenant, detector) pairs, sorted."""
        return sorted(k for k, c in self._state.items() if c.firing)

    def counts(self) -> Dict:
        return {"ticks": int(self.tick),
                "alerts_raised": int(self.alerts_raised),
                "alerts_cleared": int(self.alerts_cleared),
                "slo_breaches": int(self.breaches),
                "active": ["/".join(filter(None, k)) or k[1]
                           for k in self.active()]}


def hist_quantile(h: Dict, q: float) -> Optional[float]:
    """Approximate quantile from a snapshot histogram dict (upper bucket
    bound at the first cumulative count crossing ``q``; the recorded max
    for the overflow bucket).  None for an empty histogram."""
    total = int(h.get("count", 0))
    if total <= 0:
        return None
    target = q * total
    cum = 0
    for bound, count in zip(h["buckets"], h["counts"]):
        cum += int(count)
        if cum >= target:
            return float(bound)
    return float(h["max"]) if h.get("max") is not None else None


def alert_sequence(trace_path: str) -> List[Dict]:
    """The judgment stream as executed, read back from a trace: ordered
    ``{tick, tenant, detector, state}`` records (``state`` is ``raise``,
    ``clear``, or ``breach``).  The determinism assertion — same run,
    same judgments — compares exactly this across sibling runs (wall
    timestamps and payload telemetry excluded by construction)."""
    from repro.trace.store import read_trace
    state = {"alert": "raise", "alert_clear": "clear",
             "slo_breach": "breach"}
    return [{"tick": int(e.payload.get("tick", -1)),
             "tenant": str(e.payload.get("tenant", "")),
             "detector": str(e.payload.get("detector", "")),
             "state": state[e.kind]}
            for e in read_trace(trace_path) if e.kind in ALERT_KINDS]
