# Declarative SLO specs + breach evaluation.  jax-free: the health
# engine and report tooling import this without touching the engines.
"""Service-level objectives for a labeling campaign.

An SLO spec is a small declarative JSON document::

    {"cost_per_label_max": 0.15,
     "iteration_p95_max": 30.0,
     "projected_quality_min": 0.80}

Three clauses, all optional (``null``/absent = not contracted):

* ``cost_per_label_max`` — ceiling on committed campaign spend per
  committed human label (``ledger.total / ledger.human_labels``), the
  paper's own objective read as a running invariant: MCAL exists to keep
  this number below the human-only baseline.
* ``iteration_p95_max`` — ceiling on the iteration-latency p95 in
  seconds, read from the metrics registry's ``span_seconds{name=
  "iteration"}`` histogram (PR 8).  Wall-clock, hence **advisory**: it
  alerts but is never enforced (see below).
* ``projected_quality_min`` — floor on the projected achievable quality
  ``1 - (predicted machine-label error at the planned operating point)
  - (assumed annotator residual)``, read from the campaign's memoized
  power-law fits — the search's own forecast, judged continuously.

**Determinism contract.**  Breach verdicts for the cost and quality
clauses are pure functions of the campaign ledger and the measurement
history, so two identical runs produce identical verdict sequences at
every :meth:`~repro.core.tenant.FleetController.rebalance` boundary —
which is why ``--slo-enforce`` may drive the downgrade cascade off
them.  The latency clause reads wall-clock histograms; its verdicts
carry ``enforceable: False`` and the controller never acts on them.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional

__all__ = ["SLOSpec", "evaluate_slo", "SLO_CLAUSES"]

# evaluation (and therefore event-stream) order is fixed: verdict
# sequences must not depend on dict iteration order
SLO_CLAUSES = ("cost_per_label", "iteration_p95", "projected_quality")


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """One campaign's (or fleet's) service-level contract.  ``None``
    clauses are simply not evaluated."""

    cost_per_label_max: Optional[float] = None
    iteration_p95_max: Optional[float] = None
    projected_quality_min: Optional[float] = None

    @classmethod
    def from_dict(cls, d: Dict) -> "SLOSpec":
        """Strict load: unknown keys are rejected, not silently dropped
        (a typoed clause name must not read as 'no contract')."""
        known = {f.name for f in dataclasses.fields(cls)}
        extra = sorted(set(d) - known)
        if extra:
            raise ValueError(
                f"unknown SLO clause(s) {extra}; known: {sorted(known)}")
        kw = {k: (None if v is None else float(v)) for k, v in d.items()}
        for k, v in kw.items():
            if v is not None and v <= 0.0:
                raise ValueError(f"SLO clause {k} must be positive "
                                 f"(got {v!r})")
        return cls(**kw)

    @classmethod
    def load(cls, path: str) -> "SLOSpec":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    def clauses(self) -> List[str]:
        """The contracted clause names, in evaluation order."""
        out = []
        if self.cost_per_label_max is not None:
            out.append("cost_per_label")
        if self.iteration_p95_max is not None:
            out.append("iteration_p95")
        if self.projected_quality_min is not None:
            out.append("projected_quality")
        return out


def evaluate_slo(spec: Optional[SLOSpec], obs: Dict) -> List[Dict]:
    """Judge one observation against the spec.

    ``obs`` is a plain dict (assembled by the health engine) with keys
    ``tenant`` plus the measured clause inputs ``cost_per_label``,
    ``iteration_p95``, ``projected_quality`` — any of them ``None``
    when not yet measurable (no labels committed, no fits, metrics
    off), in which case that clause is skipped rather than breached.

    Returns breach verdicts in fixed clause order::

        {"tenant", "slo", "value", "limit", "enforceable"}
    """
    if spec is None:
        return []
    tenant = str(obs.get("tenant", ""))
    out: List[Dict] = []

    def breach(name: str, value, limit, *, enforceable: bool) -> None:
        out.append({"tenant": tenant, "slo": name, "value": float(value),
                    "limit": float(limit), "enforceable": bool(enforceable)})

    v = obs.get("cost_per_label")
    if spec.cost_per_label_max is not None and v is not None \
            and v > spec.cost_per_label_max:
        breach("cost_per_label", v, spec.cost_per_label_max,
               enforceable=True)
    v = obs.get("iteration_p95")
    if spec.iteration_p95_max is not None and v is not None \
            and v > spec.iteration_p95_max:
        breach("iteration_p95", v, spec.iteration_p95_max,
               enforceable=False)      # wall-clock: advisory only
    v = obs.get("projected_quality")
    if spec.projected_quality_min is not None and v is not None \
            and v < spec.projected_quality_min:
        breach("projected_quality", v, spec.projected_quality_min,
               enforceable=True)
    return out
