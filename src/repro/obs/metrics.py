# Low-overhead runtime metrics: counters, gauges, log-bucket histograms,
# nested spans.  jax-free by construction (the optional device fence
# imports jax lazily) so report tooling can import it anywhere.
"""Runtime metrics & profiling registry (the obs/ half of observability).

Division of labor with ``repro.trace``: the trace store records the
campaign's *decision* stream — what was bought, measured, and chosen —
and must replay bit-identically.  This module records where the
*runtime* went: wall-clock per engine hot path, compile-cache hits vs
misses, queue depths, per-tenant attribution.  Metric events ride the
same JSONL transport as the trace (kinds ``metric_span`` /
``metric_snapshot``) but are classified ``OBSERVABILITY_KINDS``, so
``replay.diff()`` between an instrumented and an uninstrumented campaign
stays clean.

Design constraints:

* **Bounded memory.**  Histograms keep fixed log-spaced bucket counts
  plus sum/count/min/max — never raw samples.  A week-long campaign
  holds the same few KB per metric as a smoke test.
* **One lock.**  All mutation goes through a single registry lock;
  critical sections are a dict lookup + float add, so contention from
  concurrent tenant rounds stays negligible (bench_obs gates the whole
  instrumented campaign at <= 3% overhead).
* **Disabled mode is free.**  Every instrumented call site guards on
  ``metrics is None`` (mirroring the ``trace is None`` convention), so
  an un-instrumented run executes byte-identical code.

Spans nest per thread::

    with registry.span("iteration"):
        with registry.span("sweep", sink="stats") as sp:
            out = adapter.score(params, page)
            sp.fence(out)        # block_until_ready at span exit

and a :class:`Span` doubles as a decorator.  ``registry.bind(tenant=t)``
pushes thread-local labels onto everything recorded by that thread —
the orchestrator wraps each tenant round in a bind so shared-engine
spans attribute per tenant without threading ids through every call.
"""
from __future__ import annotations

import bisect
import functools
import math
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "DEFAULT_BUCKETS", "MetricsRegistry", "Span", "log_buckets",
    "get_registry", "set_registry",
]


def log_buckets(lo: float = 1e-6, hi: float = 100.0,
                per_decade: int = 4) -> Tuple[float, ...]:
    """Fixed log-spaced histogram bucket upper bounds covering [lo, hi].

    ``per_decade`` bounds per factor of 10; the implicit +Inf overflow
    bucket is always present, so the bucket count is ``len(bounds)+1``
    regardless of what gets observed."""
    if not (lo > 0.0 and hi > lo):
        raise ValueError(f"need 0 < lo < hi, got lo={lo} hi={hi}")
    n = int(math.ceil(math.log10(hi / lo) * per_decade))
    return tuple(lo * 10.0 ** (k / per_decade) for k in range(n + 1))


# seconds-scale default: 1us .. 100s at 4 buckets/decade (33 bounds)
DEFAULT_BUCKETS = log_buckets(1e-6, 100.0, per_decade=4)

_LabelKey = Tuple[Tuple[str, str], ...]
_Key = Tuple[str, _LabelKey]


class _Hist:
    """Streaming histogram: per-bucket counts + sum/count/min/max.

    Bounds are upper edges (``value <= bounds[i]`` lands in bucket i);
    values above the last bound land in the overflow slot.  No samples
    are retained."""

    __slots__ = ("bounds", "counts", "sum", "count", "min", "max")

    def __init__(self, bounds: Sequence[float]):
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = [0] * (len(self.bounds) + 1)
        self.sum = 0.0
        self.count = 0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value: float) -> None:
        v = float(value)
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.sum += v
        self.count += 1
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def to_dict(self) -> Dict:
        return {
            "buckets": list(self.bounds), "counts": list(self.counts),
            "sum": self.sum, "count": self.count,
            "min": None if self.count == 0 else self.min,
            "max": None if self.count == 0 else self.max,
        }


def _label_key(labels: Dict[str, object]) -> _LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Span:
    """One timed region: context manager AND decorator.

    Entering pushes onto the thread's span stack (giving a nested
    ``path`` like ``round/iteration/sweep``), exiting records the
    wall-clock into the ``span_seconds`` histogram and — when the
    registry has a trace attached — emits a ``metric_span`` event.
    ``fence(x)`` registers device values to ``jax.block_until_ready``
    at exit, so the recorded time covers the device work the span
    dispatched, not just the host-side submit.  An exception unwinds
    the stack normally and stamps the span ``status="error"`` (and is
    re-raised — spans never swallow)."""

    __slots__ = ("registry", "name", "labels", "path", "_t0", "_fences")

    def __init__(self, registry: "MetricsRegistry", name: str,
                 labels: Dict[str, object]):
        self.registry = registry
        self.name = name
        self.labels = {str(k): str(v) for k, v in labels.items()}
        self.path = name
        self._t0 = 0.0
        self._fences: List[object] = []

    def fence(self, value: object) -> None:
        """Queue a device value for block_until_ready at span exit."""
        if value is not None:
            self._fences.append(value)

    def __enter__(self) -> "Span":
        stack = self.registry._span_stack()
        if stack:
            self.path = stack[-1].path + "/" + self.name
        stack.append(self)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, etype, evalue, tb) -> bool:
        fenced = False
        if self._fences and etype is None:
            import jax  # lazy: the registry itself stays jax-free

            jax.block_until_ready(self._fences)
            fenced = True
        seconds = time.perf_counter() - self._t0
        stack = self.registry._span_stack()
        if stack and stack[-1] is self:
            stack.pop()
        status = "ok" if etype is None else "error"
        self.registry._record_span(self, seconds, status, fenced)
        return False  # never swallow

    def __call__(self, fn):
        """Decorator form: each call runs inside a fresh span."""
        registry, name, labels = self.registry, self.name, self.labels

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            with registry.span(name, **labels):
                return fn(*args, **kwargs)

        return wrapper


class MetricsRegistry:
    """Thread-safe process metrics: counters, gauges, histograms, spans.

    Keys are ``(name, sorted-label-items)``; thread-locally *bound*
    labels (see :meth:`bind`) merge under every metric the thread
    records, losing to explicit labels on collision.  ``attach_trace``
    tees span events into a :class:`repro.trace.TraceStore` so the
    metrics stream interleaves with (or sits beside) the campaign
    trace; ``snapshot()`` returns a JSON-ready structure and
    ``write_prometheus`` renders the textfile exposition format."""

    def __init__(self, *, buckets: Sequence[float] = DEFAULT_BUCKETS,
                 trace: Optional[object] = None):
        self._lock = threading.Lock()
        self._counters: Dict[_Key, float] = {}
        self._gauges: Dict[_Key, float] = {}
        self._hists: Dict[_Key, _Hist] = {}
        self._buckets = tuple(float(b) for b in buckets)
        self._local = threading.local()
        self.trace = trace

    # -- thread-local state ------------------------------------------------
    def _span_stack(self) -> List[Span]:
        try:
            return self._local.spans
        except AttributeError:
            self._local.spans = []
            return self._local.spans

    def _bound(self) -> Dict[str, str]:
        try:
            return self._local.bound
        except AttributeError:
            self._local.bound = {}
            return self._local.bound

    def bind(self, **labels):
        """Context manager: merge ``labels`` under every metric this
        thread records while inside (explicit labels win)."""
        return _Bind(self, {str(k): str(v) for k, v in labels.items()})

    def _key(self, name: str, labels: Dict[str, object]) -> _Key:
        bound = self._bound()
        if bound:
            merged = dict(bound)
            merged.update(labels)
            labels = merged
        return (name, _label_key(labels))

    # -- counters / gauges / histograms ------------------------------------
    def inc(self, name: str, value: float = 1.0, /, **labels) -> None:
        key = self._key(name, labels)
        with self._lock:
            self._counters[key] = self._counters.get(key, 0.0) + float(value)

    def set_gauge(self, name: str, value: float, /, **labels) -> None:
        key = self._key(name, labels)
        with self._lock:
            self._gauges[key] = float(value)

    def add_gauge(self, name: str, delta: float, /, **labels) -> float:
        """Relative gauge move (queue depths); returns the new value."""
        key = self._key(name, labels)
        with self._lock:
            v = self._gauges.get(key, 0.0) + float(delta)
            self._gauges[key] = v
            return v

    def observe(self, name: str, value: float, /, **labels) -> None:
        key = self._key(name, labels)
        with self._lock:
            h = self._hists.get(key)
            if h is None:
                h = self._hists[key] = _Hist(self._buckets)
            h.observe(value)

    # -- spans -------------------------------------------------------------
    def span(self, name: str, /, **labels) -> Span:
        return Span(self, name, labels)

    def _record_span(self, sp: Span, seconds: float, status: str,
                     fenced: bool) -> None:
        labels = dict(sp.labels)
        labels["name"] = sp.name
        self.observe("span_seconds", seconds, **labels)
        if status != "ok":
            self.inc("span_errors_total", name=sp.name)
        trace = self.trace
        if trace is not None:
            bound = self._bound()
            out = dict(bound, **sp.labels) if bound else sp.labels
            trace.emit("metric_span", name=sp.name, path=sp.path,
                       seconds=float(seconds), status=status,
                       fenced=fenced, labels=out)

    # -- export ------------------------------------------------------------
    def attach_trace(self, trace: object) -> None:
        """Tee metric events into a TraceStore (same file as the
        campaign trace, or a standalone metrics.jsonl — both replay-
        clean, the kinds are observability-only)."""
        self.trace = trace

    def snapshot(self) -> Dict:
        """Point-in-time JSON-ready dump of every metric."""
        with self._lock:
            counters = [{"name": n, "labels": dict(lk), "value": v}
                        for (n, lk), v in sorted(self._counters.items())]
            gauges = [{"name": n, "labels": dict(lk), "value": v}
                      for (n, lk), v in sorted(self._gauges.items())]
            hists = [dict({"name": n, "labels": dict(lk)}, **h.to_dict())
                     for (n, lk), h in sorted(self._hists.items())]
        return {"counters": counters, "gauges": gauges,
                "histograms": hists}

    def emit_snapshot(self, **extra) -> None:
        """Emit the full registry state as one ``metric_snapshot``
        event (observability kind — replay/diff ignore it)."""
        if self.trace is not None:
            self.trace.emit("metric_snapshot", snapshot=self.snapshot(),
                            **extra)

    def write_prometheus(self, path: str) -> None:
        from repro.obs.export import write_prometheus

        write_prometheus(self.snapshot(), path)

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()


class _Bind:
    __slots__ = ("registry", "labels", "_saved")

    def __init__(self, registry: MetricsRegistry, labels: Dict[str, str]):
        self.registry = registry
        self.labels = labels
        self._saved: Dict[str, str] = {}

    def __enter__(self):
        bound = self.registry._bound()
        self._saved = dict(bound)
        bound.update(self.labels)
        return self

    def __exit__(self, *exc):
        self.registry._local.bound = self._saved
        return False


# -- process-wide default registry ----------------------------------------
_default_lock = threading.Lock()
_default: Optional[MetricsRegistry] = None


def get_registry() -> MetricsRegistry:
    """The process-wide registry (created on first use).  Launchers and
    benchmarks share it so one snapshot covers the whole run; tests
    build private registries instead."""
    global _default
    with _default_lock:
        if _default is None:
            _default = MetricsRegistry()
        return _default


def set_registry(registry: Optional[MetricsRegistry]) -> None:
    global _default
    with _default_lock:
        _default = registry
