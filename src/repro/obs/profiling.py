# Opt-in deep-dive profiling: bracket one chosen campaign iteration
# with jax.profiler.trace.  Everything here degrades to a no-op when
# the profiler is unavailable — an opt-in dump must never kill a
# campaign that already spent real annotation budget.
"""``--profile DIR`` support: one iteration under ``jax.profiler``.

The metrics registry answers "where did the time go" at span
granularity; this answers "why" at op granularity, for exactly one
iteration (profiles are huge — bracketing the whole campaign would
drown the trace viewer and the disk).  Usage::

    with profile_block("prof_dir", enabled=(it == args.profile_iter)):
        camp.iteration()

View with ``tensorboard --logdir prof_dir`` or perfetto.
"""
from __future__ import annotations

import sys
from contextlib import contextmanager

__all__ = ["profile_block"]


@contextmanager
def profile_block(outdir: str, enabled: bool = True):
    if not enabled or not outdir:
        yield False
        return
    try:
        import jax

        ctx = jax.profiler.trace(outdir)
        ctx.__enter__()
    except Exception as e:  # profiler backend missing / refused to start
        print(f"# profile: jax.profiler unavailable ({type(e).__name__}: "
              f"{e}) — continuing without", file=sys.stderr)
        yield False
        return
    try:
        yield True
    finally:
        # a broken profiler teardown must not lose the iteration's work
        # (and must never mask an exception from the profiled body)
        try:
            ctx.__exit__(None, None, None)
        except Exception as e:
            print(f"# profile: trace teardown failed "
                  f"({type(e).__name__}: {e})", file=sys.stderr)
