# Metrics exporters + report-side rollups.  jax-free: report tooling
# imports this without touching the engine stack.
"""Export surfaces for :class:`repro.obs.MetricsRegistry` snapshots.

Three consumers share this module:

* ``write_prometheus`` renders a snapshot in the Prometheus textfile
  exposition format (node_exporter textfile-collector style) so a
  long-running campaign can be scraped by pointing the collector at
  the file the launcher rewrites each iteration.
* ``span_rollup`` / ``cache_hit_rates`` / ``queue_stats`` fold the raw
  snapshot (or a stream of ``metric_span`` events) into the per-engine
  breakdowns that ``launch/report.py --metrics`` renders.
* the benchmark harness embeds raw snapshots into ``BENCH_*.json``.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = [
    "write_prometheus", "prometheus_lines", "span_rollup",
    "cache_hit_rates", "queue_stats", "snapshot_counter",
]


def _fmt(v: float) -> str:
    if v != v:
        return "NaN"
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    return repr(float(v))


def _labels(labels: Dict[str, str], extra: Tuple[Tuple[str, str], ...] = ()
            ) -> str:
    items = sorted(labels.items()) + list(extra)
    if not items:
        return ""
    body = ",".join(
        '%s="%s"' % (k, str(v).replace("\\", r"\\").replace('"', r"\"")
                     .replace("\n", r"\n"))
        for k, v in items)
    return "{" + body + "}"


def _sanitize_name(name: str) -> str:
    return "".join(c if (c.isalnum() or c in "_:") else "_" for c in name)


def prometheus_lines(snapshot: Dict, prefix: str = "repro_") -> List[str]:
    """Render a registry snapshot as Prometheus exposition-format lines.

    Counters keep their ``_total`` suffix convention from the call
    sites; histograms expand to cumulative ``_bucket{le=...}`` series
    plus ``_sum`` / ``_count``."""
    out: List[str] = []
    seen_type: set = set()

    def head(name: str, kind: str):
        if name not in seen_type:
            seen_type.add(name)
            out.append(f"# TYPE {name} {kind}")

    for c in snapshot.get("counters", ()):
        name = _sanitize_name(prefix + c["name"])
        head(name, "counter")
        out.append(f"{name}{_labels(c['labels'])} {_fmt(c['value'])}")
    for g in snapshot.get("gauges", ()):
        name = _sanitize_name(prefix + g["name"])
        head(name, "gauge")
        out.append(f"{name}{_labels(g['labels'])} {_fmt(g['value'])}")
    for h in snapshot.get("histograms", ()):
        name = _sanitize_name(prefix + h["name"])
        head(name, "histogram")
        cum = 0
        for bound, count in zip(list(h["buckets"]) + [math.inf],
                                h["counts"]):
            cum += int(count)
            le = "+Inf" if bound == math.inf else _fmt(bound)
            out.append(f"{name}_bucket"
                       f"{_labels(h['labels'], (('le', le),))} {cum}")
        out.append(f"{name}_sum{_labels(h['labels'])} {_fmt(h['sum'])}")
        out.append(f"{name}_count{_labels(h['labels'])} {int(h['count'])}")
    return out


def write_prometheus(snapshot: Dict, path: str,
                     prefix: str = "repro_") -> None:
    """Atomic-enough textfile write: the collector convention tolerates
    torn reads poorly, so write to a sidecar and rename."""
    import os

    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write("\n".join(prometheus_lines(snapshot, prefix)) + "\n")
    os.replace(tmp, path)


# -- report-side rollups ----------------------------------------------------

def span_rollup(events: Iterable) -> Dict[Tuple[str, str], Dict]:
    """Fold ``metric_span`` trace events into per-(span name, tenant)
    totals: ``{(name, tenant): {count, seconds, max, errors}}``.
    ``events`` yields anything with ``.kind`` / ``.payload`` (TraceEvent)
    or plain dicts."""
    out: Dict[Tuple[str, str], Dict] = {}
    for e in events:
        kind = getattr(e, "kind", None) or e.get("kind")
        if kind != "metric_span":
            continue
        p = getattr(e, "payload", None)
        if p is None:
            p = e.get("payload", e)
        labels = p.get("labels") or {}
        key = (str(p.get("name", "?")), str(labels.get("tenant", "")))
        s = out.setdefault(key, {"count": 0, "seconds": 0.0, "max": 0.0,
                                 "errors": 0})
        sec = float(p.get("seconds", 0.0))
        s["count"] += 1
        s["seconds"] += sec
        if sec > s["max"]:
            s["max"] = sec
        if p.get("status") != "ok":
            s["errors"] += 1
    return out


def snapshot_counter(snapshot: Optional[Dict], name: str,
                     **labels) -> float:
    """Sum every counter series matching ``name`` whose labels include
    the given key/values (extra labels on the series are fine)."""
    if not snapshot:
        return 0.0
    want = {str(k): str(v) for k, v in labels.items()}
    total = 0.0
    for c in snapshot.get("counters", ()):
        if c["name"] != name:
            continue
        have = c.get("labels", {})
        if all(have.get(k) == v for k, v in want.items()):
            total += float(c["value"])
    return total


def cache_hit_rates(snapshot: Optional[Dict]) -> Dict[str, Dict]:
    """Per-engine pack-shape compile-cache hit rates from the
    ``pack_cache_{hits,misses}_total{engine=...}`` counters."""
    out: Dict[str, Dict] = {}
    if not snapshot:
        return out
    engines: set = set()
    for c in snapshot.get("counters", ()):
        if c["name"] in ("pack_cache_hits_total",
                         "pack_cache_misses_total"):
            engines.add(c.get("labels", {}).get("engine", "?"))
    for eng in sorted(engines):
        hits = snapshot_counter(snapshot, "pack_cache_hits_total",
                                engine=eng)
        misses = snapshot_counter(snapshot, "pack_cache_misses_total",
                                  engine=eng)
        total = hits + misses
        out[eng] = {"hits": int(hits), "misses": int(misses),
                    "rate": (hits / total) if total else None}
    return out


def queue_stats(snapshot: Optional[Dict]) -> Dict[str, Dict]:
    """Broker queue depth gauges + wait histograms, keyed by queue."""
    out: Dict[str, Dict] = {}
    if not snapshot:
        return out
    for g in snapshot.get("gauges", ()):
        if g["name"] == "queue_depth":
            q = g.get("labels", {}).get("queue", "?")
            out.setdefault(q, {})["depth"] = float(g["value"])
    for h in snapshot.get("histograms", ()):
        if h["name"] == "queue_wait_seconds":
            q = h.get("labels", {}).get("queue", "?")
            s = out.setdefault(q, {})
            s["waits"] = int(h["count"])
            s["wait_mean"] = (h["sum"] / h["count"]) if h["count"] else 0.0
            s["wait_max"] = h["max"] if h["max"] is not None else 0.0
    return out
