"""The engine broker thread: one daemonized serial worker per runtime.

``PoolSweepRunner.submit``, ``FitEngine.submit_fit``/``submit_call`` and
``AnnotationService.submit`` all broker jobs onto a single worker thread
and hand back a :class:`~repro.serving.sweep.SweepFuture`.  The seed
implementation grew one lazy ``ThreadPoolExecutor`` per engine, whose
worker threads are neither daemonized nor ever joined — an abandoned
future kept the interpreter alive at exit (concurrent.futures joins its
workers atexit), and a fleet of campaigns leaked one thread per engine.

:class:`SerialWorker` is the shared replacement:

* the worker thread is a **daemon** — an abandoned in-flight job can
  never hang interpreter shutdown;
* ``submit`` preserves the executor surface the engines already use
  (it returns a ``concurrent.futures.Future``, so ``SweepFuture``'s
  done/cancel/result semantics are unchanged — cancelling a queued job
  still works through ``Future.set_running_or_notify_cancel``);
* ``close()`` is the missing join: idempotent, drains the queue sentinel
  and joins the thread, after which ``submit`` raises.  Every engine
  exposes it (plus the context-manager sugar), and campaign teardown
  calls it — the shutdown regression tests in
  ``tests/test_shutdown.py`` pin both properties.
"""
from __future__ import annotations

import queue
import threading
from concurrent.futures import Future
from typing import Optional


class WorkerClosed(RuntimeError):
    """``submit`` after ``close()`` — the broker thread is gone."""


class SerialWorker:
    """One daemon thread draining a FIFO job queue into Futures.

    The thread is started lazily on the first ``submit`` (engines that
    never go async never pay for a thread) and named so thread dumps
    attribute stuck jobs to their engine.
    """

    def __init__(self, name: str = "serial-worker"):
        self._name = name
        self._q: "queue.SimpleQueue" = queue.SimpleQueue()
        self._thread: Optional[threading.Thread] = None
        self._closed = False
        self._lock = threading.Lock()

    # -- the executor surface ----------------------------------------------
    def submit(self, fn, *args, **kw) -> Future:
        fut: Future = Future()
        with self._lock:
            if self._closed:
                raise WorkerClosed(
                    f"{self._name}: submit after close() — the broker "
                    f"thread has been joined")
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._loop, name=self._name, daemon=True)
                self._thread.start()
            self._q.put((fut, fn, args, kw))
        return fut

    def _loop(self):
        while True:
            item = self._q.get()
            if item is None:          # the close() sentinel
                return
            fut, fn, args, kw = item
            if not fut.set_running_or_notify_cancel():
                continue              # cancelled while queued
            try:
                fut.set_result(fn(*args, **kw))
            except BaseException as e:   # delivered at result()
                fut.set_exception(e)

    # -- lifecycle -----------------------------------------------------------
    @property
    def alive(self) -> bool:
        """True while the worker thread exists and has not been joined."""
        return self._thread is not None and self._thread.is_alive()

    def close(self, timeout: Optional[float] = None) -> None:
        """Idempotent shutdown: finish queued jobs, join the thread.
        Safe to call on a worker that never started (no thread, no-op
        beyond flipping the closed flag)."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            thread = self._thread
            if thread is not None:
                self._q.put(None)
        if thread is not None:
            thread.join(timeout)

    def __enter__(self) -> "SerialWorker":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
