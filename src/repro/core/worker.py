"""The engine broker thread: one daemonized serial worker per runtime.

``PoolSweepRunner.submit``, ``FitEngine.submit_fit``/``submit_call`` and
``AnnotationService.submit`` all broker jobs onto a single worker thread
and hand back a :class:`~repro.serving.sweep.SweepFuture`.  The seed
implementation grew one lazy ``ThreadPoolExecutor`` per engine, whose
worker threads are neither daemonized nor ever joined — an abandoned
future kept the interpreter alive at exit (concurrent.futures joins its
workers atexit), and a fleet of campaigns leaked one thread per engine.

:class:`SerialWorker` is the shared replacement:

* the worker thread is a **daemon** — an abandoned in-flight job can
  never hang interpreter shutdown;
* ``submit`` preserves the executor surface the engines already use
  (it returns a ``concurrent.futures.Future``, so ``SweepFuture``'s
  done/cancel/result semantics are unchanged — cancelling a queued job
  still works through ``Future.set_running_or_notify_cancel``);
* ``close()`` is the missing join: idempotent, drains the queue sentinel
  and joins the thread, after which ``submit`` raises.  It returns
  whether the thread actually joined within ``timeout`` and warns on a
  leaked (still-running) thread.  Every engine exposes it (plus the
  context-manager sugar), and campaign teardown calls it — the shutdown
  regression tests in ``tests/test_shutdown.py`` pin both properties;
* a crashed job never poisons the queue: the loop delivers the
  exception at ``result()`` and keeps draining, and with a
  ``RetryPolicy``/``FaultInjector`` attached (``attach_faults``) a
  transiently-crashed job is RE-DISPATCHED in place — the resilience
  seam ``repro.faults`` exercises with injected
  :class:`~repro.faults.errors.InjectedWorkerCrash` faults.
"""
from __future__ import annotations

import queue
import threading
import warnings
from concurrent.futures import Future
from typing import Optional


class WorkerClosed(RuntimeError):
    """``submit`` after ``close()`` — the broker thread is gone."""


class SerialWorker:
    """One daemon thread draining a FIFO job queue into Futures.

    The thread is started lazily on the first ``submit`` (engines that
    never go async never pay for a thread) and named so thread dumps
    attribute stuck jobs to their engine.
    """

    def __init__(self, name: str = "serial-worker", *,
                 retry=None, faults=None):
        self._name = name
        self._q: "queue.SimpleQueue" = queue.SimpleQueue()
        self._thread: Optional[threading.Thread] = None
        self._closed = False
        self._lock = threading.Lock()
        self.retry = retry            # faults.RetryPolicy: re-dispatch
        self.faults = faults          # faults.FaultInjector: chaos seam
        self.metrics = None           # obs registry for retries_total
        self.redispatches = 0         # transient job crashes survived

    # -- resilience wiring ---------------------------------------------------
    @property
    def fault_site(self) -> str:
        """This worker's fault-plan site key (``worker.<name>``)."""
        return f"worker.{self._name}"

    def attach_faults(self, faults, retry=None) -> None:
        """Wire the chaos seam: every job ticks ``worker.<name>`` before
        running (an injected crash raises into the job), and with a
        retry policy transiently-crashed jobs are re-dispatched."""
        self.faults = faults
        if retry is not None:
            self.retry = retry

    # -- the executor surface ----------------------------------------------
    def submit(self, fn, *args, **kw) -> Future:
        fut: Future = Future()
        with self._lock:
            if self._closed:
                raise WorkerClosed(
                    f"{self._name}: submit after close() — the broker "
                    f"thread has been joined")
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._loop, name=self._name, daemon=True)
                self._thread.start()
            self._q.put((fut, fn, args, kw))
        return fut

    def _run_job(self, fn, args, kw):
        """One dispatch of a job through the fault seam; re-dispatched
        as a whole by the retry policy on a transient crash."""
        if self.faults is not None:
            self.faults.check(self.fault_site)
        return fn(*args, **kw)

    def _notify_retry(self, attempt: int, exc: BaseException,
                      delay: float) -> None:
        self.redispatches += 1
        if self.metrics is not None:
            self.metrics.inc("retries_total", site=self.fault_site)

    def _loop(self):
        while True:
            item = self._q.get()
            if item is None:          # the close() sentinel
                return
            fut, fn, args, kw = item
            if not fut.set_running_or_notify_cancel():
                continue              # cancelled while queued
            try:
                if self.retry is not None:
                    result = self.retry.call(
                        lambda: self._run_job(fn, args, kw),
                        site=self.fault_site, notify=self._notify_retry)
                else:
                    result = self._run_job(fn, args, kw)
                fut.set_result(result)
            except BaseException as e:   # delivered at result()
                fut.set_exception(e)

    # -- lifecycle -----------------------------------------------------------
    @property
    def alive(self) -> bool:
        """True while the worker thread exists and has not been joined."""
        return self._thread is not None and self._thread.is_alive()

    def close(self, timeout: Optional[float] = None) -> bool:
        """Idempotent shutdown: finish queued jobs, join the thread.
        Safe to call on a worker that never started (no thread, no-op
        beyond flipping the closed flag).

        Returns True when the broker thread is gone (joined, never
        started, or already closed with its thread finished); False —
        with a warning — when it failed to join within ``timeout`` and
        leaked (a stuck job; the daemon flag keeps it from hanging
        interpreter exit)."""
        with self._lock:
            already = self._closed
            self._closed = True
            thread = self._thread
            if thread is not None and not already:
                self._q.put(None)
        if thread is None:
            return True
        if not already:
            thread.join(timeout)
        if thread.is_alive():
            warnings.warn(
                f"{self._name}: broker thread failed to join within "
                f"{timeout!r}s and leaked (stuck job?)",
                RuntimeWarning, stacklevel=2)
            return False
        return True

    def __enter__(self) -> "SerialWorker":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
