"""Joint (|B|, theta) minimum-cost search (paper §3, Alg. 1 line 18) and the
delta-adaptation rule (line 20).

Given per-theta truncated power laws, the fitted training cost model, and the
sunk cost so far, the search scans a vectorized grid of candidate training
sizes (multiples of delta above the current |B|) x the theta grid and returns
the feasible minimizer of

    C(B, theta) = (|X| - |S|) * C_h + C_spent + C_grow(|B_i| -> B; delta)

subject to  (|S| / |X|) * eps_theta(B) <= eps_target,  |S| = theta * (|X| - |T| - B).

theta = 0 (human-label everything) is always feasible and acts as the
fallback arm.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.cost import LabelingService, TrainCostModel
from repro.core.powerlaw import PowerLaw

MAX_GRID = 4096


@dataclasses.dataclass(frozen=True)
class SearchResult:
    cost: float                 # predicted total C*
    B_opt: int                  # optimal training-set size
    theta_opt: float            # optimal machine-label fraction
    machine_labeled: int        # |S*| at the optimum
    feasible: bool              # False -> only the human-all arm exists
    human_all_cost: float       # cost of the theta=0 fallback
    # full surface for diagnostics/benchmarks: cost[b_idx, theta_idx]
    grid_B: Optional[np.ndarray] = None
    grid_theta: Optional[np.ndarray] = None
    grid_cost: Optional[np.ndarray] = None
    grid_feasible: Optional[np.ndarray] = None


def _grow_cost_vec(cost_model: TrainCostModel, current_B: int,
                   grid_B: np.ndarray, delta: int) -> np.ndarray:
    """Vectorized cost_to_grow for grid points current_B + j*delta."""
    j = np.round((grid_B - current_B) / max(delta, 1)).astype(np.int64)
    if cost_model.exponent == 1:
        # sum_{i=1..j} (current_B + i*delta)
        return cost_model.c_u * (j * current_B + delta * j * (j + 1) / 2.0)
    out = np.zeros(len(grid_B), np.float64)
    for i, b in enumerate(grid_B):
        out[i] = cost_model.cost_to_grow(current_B, int(b), delta)
    return out


def joint_search(
    *,
    pool_size: int,
    test_size: int,
    current_B: int,
    spent: float,
    laws: Dict[float, PowerLaw],
    cost_model: TrainCostModel,
    delta: int,
    service: LabelingService,
    eps_target: float,
    keep_surface: bool = False,
) -> SearchResult:
    X = pool_size
    C_h = service.price_per_label
    human_all = X * C_h + spent

    B_max = X - test_size
    delta = max(int(delta), 1)
    n_steps = max(int((B_max - current_B) // delta), 0)
    stride = max(n_steps // MAX_GRID, 1) * delta if n_steps > MAX_GRID else delta
    grid_B = np.arange(current_B, B_max + 1, stride, dtype=np.int64)
    if len(grid_B) == 0:
        grid_B = np.asarray([current_B], np.int64)

    thetas = np.asarray(sorted(laws.keys()), np.float64)
    grow = _grow_cost_vec(cost_model, current_B, grid_B, delta)

    eps = np.stack([laws[t].predict(grid_B) for t in thetas], axis=1)  # (Nb, Nt)
    remaining = np.maximum(X - test_size - grid_B, 0)[:, None]         # (Nb, 1)
    S = thetas[None, :] * remaining                                    # (Nb, Nt)
    feasible = (S / X) * eps <= eps_target
    cost = (X - S) * C_h + spent + grow[:, None]

    masked = np.where(feasible, cost, np.inf)
    best_flat = int(np.argmin(masked))
    bi, ti = np.unravel_index(best_flat, masked.shape)
    best_cost = float(masked[bi, ti])

    if not np.isfinite(best_cost) or best_cost >= human_all:
        return SearchResult(
            cost=human_all, B_opt=current_B, theta_opt=0.0, machine_labeled=0,
            feasible=bool(np.isfinite(best_cost)), human_all_cost=human_all,
            grid_B=grid_B if keep_surface else None,
            grid_theta=thetas if keep_surface else None,
            grid_cost=cost if keep_surface else None,
            grid_feasible=feasible if keep_surface else None)
    return SearchResult(
        cost=best_cost, B_opt=int(grid_B[bi]), theta_opt=float(thetas[ti]),
        machine_labeled=int(round(S[bi, ti])), feasible=True,
        human_all_cost=human_all,
        grid_B=grid_B if keep_surface else None,
        grid_theta=thetas if keep_surface else None,
        grid_cost=cost if keep_surface else None,
        grid_feasible=feasible if keep_surface else None)


def budget_search(
    *,
    pool_size: int,
    test_size: int,
    current_B: int,
    spent: float,
    laws: Dict[float, PowerLaw],
    cost_model: TrainCostModel,
    delta: int,
    service: LabelingService,
    budget: float,
) -> SearchResult:
    """Budget-constrained variant (§4): minimize predicted overall error
    subject to total cost <= budget."""
    X = pool_size
    C_h = service.price_per_label
    human_all = X * C_h + spent

    B_max = X - test_size
    delta = max(int(delta), 1)
    grid_B = np.arange(current_B, B_max + 1, delta, dtype=np.int64)
    if len(grid_B) == 0:
        grid_B = np.asarray([current_B], np.int64)
    if len(grid_B) > MAX_GRID:
        grid_B = grid_B[:: len(grid_B) // MAX_GRID + 1]
    thetas = np.asarray(sorted(laws.keys()), np.float64)
    grow = _grow_cost_vec(cost_model, current_B, grid_B, delta)
    eps = np.stack([laws[t].predict(grid_B) for t in thetas], axis=1)
    remaining = np.maximum(X - test_size - grid_B, 0)[:, None]
    S = thetas[None, :] * remaining
    cost = (X - S) * C_h + spent + grow[:, None]
    overall_err = (S / X) * eps
    within = cost <= budget

    if human_all <= budget:  # human-all is error-free and affordable
        return SearchResult(cost=human_all, B_opt=current_B, theta_opt=0.0,
                            machine_labeled=0, feasible=True,
                            human_all_cost=human_all)
    masked = np.where(within, overall_err, np.inf)
    best_flat = int(np.argmin(masked))
    bi, ti = np.unravel_index(best_flat, masked.shape)
    if not np.isfinite(masked[bi, ti]):
        # nothing fits the budget: stop training now, machine-label all
        return SearchResult(cost=float(cost[0, -1]), B_opt=current_B,
                            theta_opt=1.0,
                            machine_labeled=int(remaining[0, 0]),
                            feasible=False, human_all_cost=human_all)
    return SearchResult(cost=float(cost[bi, ti]), B_opt=int(grid_B[bi]),
                        theta_opt=float(thetas[ti]),
                        machine_labeled=int(round(S[bi, ti])), feasible=True,
                        human_all_cost=human_all)


def adapt_delta(
    *,
    current_B: int,
    B_opt: int,
    cstar: float,
    spent: float,
    pool_size: int,
    test_size: int,
    machine_labeled: int,
    cost_model: TrainCostModel,
    service: LabelingService,
    beta: float = 0.05,
    max_N: int = 64,
) -> int:
    """Alg. 1 line 20: delta_opt = (B_opt - B_i)/N with the fewest retrains
    whose predicted total cost stays within (1 + beta) * C* — "proceeding
    faster to B_opt to reduce training cost" (§4).  Growing in one jump is
    cheapest but each intermediate retrain refines the estimates, so the
    beta slack lets the schedule keep at least the affordable granularity.
    If even the single cheapest jump violates the bound (stale C*), still
    jump — it is the cheapest path to B_opt."""
    gap = B_opt - current_B
    if gap <= 0:
        return 0
    fixed_human = (pool_size - machine_labeled) * service.price_per_label
    for N in range(1, max_N + 1):
        delta = int(np.ceil(gap / N))
        c = fixed_human + spent + cost_model.cost_to_grow(current_B, B_opt, delta)
        if c <= cstar * (1.0 + beta):
            return delta
    return gap  # N = 1: cheapest possible path to B_opt
