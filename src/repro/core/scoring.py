"""Device-resident pool-scoring engine — MCAL's per-iteration hot path.

Every MCAL iteration scores the entire unlabeled pool twice (Alg. 1):
M(.) ranks candidates for the next delta human labels, L(.) ranks the
remainder for the machine-label prefix.  The seed implementation ran this
as a host-side python loop — chunked forward, transfer logits to host,
numpy statistics per chunk — which serializes device work against host
round-trips and re-materializes (chunk, V) logits in host memory.

This engine runs the whole pool as ONE jit-compiled program:

* the pool is padded into ``(n_microbatches, microbatch, ...)`` and swept
  with ``lax.map`` — device-resident end to end, no host sync until the
  packed statistics are fetched;
* per microbatch: model forward + the vocab head fused into
  :class:`ScoreStats` (margin / entropy / max-logprob / top1) via the
  dense reference, the vocab-chunked online-softmax path, or the Pallas
  ``margin_head`` kernel (``head_mode``), so (T, V) logits never hit HBM
  for large vocabularies;
* microbatch counts are bucketed to powers of two so a shrinking
  candidate set re-uses O(log N) compiled programs instead of recompiling
  every MCAL iteration;
* the padded pool buffer is donated to the computation (where the backend
  supports donation) and top-k candidate selection happens on device
  (``lax.top_k`` over the packed scores, padding masked to -inf);
* the same sweep optionally emits pooled last-hidden-state features
  (``ScoringConfig.with_features`` / :meth:`PoolScoringEngine.pool_features`)
  which stay device-resident — the k-center selection engine
  (``core.selection_device``) consumes them for M(.) without a host
  round-trip.

The seed's host loop is preserved as :func:`score_pool_reference` — the
oracle the engine is validated against (tests/test_scoring.py) and the
baseline ``benchmarks/bench_selection.py`` measures speedup over.

With a mesh, the microbatch dimension is sharded over the ``data`` axis
(params replicated) and the same program scales across the pool's devices.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.core.selection import UNCERTAINTY_METRICS  # noqa: F401 (re-export)
from repro.models import layers as L
from repro.models.layers import ScoreStats


def next_pow2(n: int) -> int:
    """The pow2 bucketing primitive shared by every device engine that
    pads pools for compile-cache reuse (:meth:`PoolScoringEngine._pack`,
    ``selection_device.k_center_greedy_device``)."""
    return 1 << max(n - 1, 0).bit_length()


def pack_shape(n: int, microbatch: int) -> Tuple[int, int]:
    """The engine's pow2 microbatch bucketing for an ``n``-row pool:
    ``(n_mb, mb)`` with ``n_mb * mb >= n``.  Shared with the streaming
    sweep runtime (``serving.sweep``) so pages pack identically to an
    unpaged engine sweep and hit the same compile cache."""
    if n >= microbatch:
        mb = microbatch
        n_mb = next_pow2(math.ceil(n / mb))
    else:
        mb = max(next_pow2(n), 8)
        n_mb = 1
    return n_mb, mb


def resolve_head_weight(cfg, params) -> jax.Array:
    """The (D, V) scoring-head matrix for any model family: the explicit
    classifier head when present, otherwise the (possibly tied) LM head."""
    if "cls_head" in params:
        return params["cls_head"]
    from repro.models.transformer import lm_head_weight
    return lm_head_weight(cfg, params)


# ---------------------------------------------------------------------------
# score packing (shared by the engine, the emulator, and serving)
# ---------------------------------------------------------------------------


def uncertainty_from_stats(stats: ScoreStats, metric: str) -> jax.Array:
    """Higher = more uncertain, device-side (jnp twin of
    ``selection.uncertainty_scores``)."""
    if metric == "margin":
        return -stats.margin
    if metric == "entropy":
        return stats.entropy
    if metric == "least_confidence":
        return 1.0 - jnp.exp(stats.max_logprob)
    raise ValueError(f"unknown uncertainty metric {metric!r}")


def stats_from_confidence(conf: np.ndarray, num_classes: int,
                          top1: np.ndarray) -> ScoreStats:
    """Pack a scalar confidence in [~0, 1] into a consistent ScoreStats
    (the emulator's scoring path; margin == confidence by convention)."""
    conf = np.asarray(conf, np.float64)
    return ScoreStats(
        margin=conf,
        entropy=np.maximum(1.0 - conf, 0.0) * np.log(num_classes),
        max_logprob=np.minimum(conf - 1.0, -1e-9),
        top1=np.asarray(top1))


def head_stats(hidden: jax.Array, w_head: jax.Array, *, mode: str = "auto",
               vocab_chunk: int = 8192, pallas_interpret: bool = True,
               pallas_bt: int = 128, pallas_bv: int = 512) -> ScoreStats:
    """Fused vocab projection + ScoreStats for last-token hidden states.

    ``hidden``: (T, D); ``w_head``: (D, V).  ``mode``:
      dense    materialize (T, V) logits (exact reference; small V),
      chunked  online top-2/logsumexp over vocab chunks (jnp),
      pallas   the ``margin_head`` TPU kernel,
      auto     dense when V fits comfortably, else chunked.
    """
    V = w_head.shape[-1]
    if mode == "auto":
        mode = "dense" if V <= 4096 else "chunked"
    if mode == "dense":
        logits = jnp.einsum("td,dv->tv", hidden, w_head,
                            preferred_element_type=jnp.float32)
        return L.score_stats_from_logits(logits)
    if mode == "chunked":
        return L.chunked_score_stats(hidden, w_head, chunk=vocab_chunk)
    if mode == "pallas":
        from repro.kernels.margin_head import margin_head
        margin, entropy, max_logprob, top1 = margin_head(
            hidden, w_head, bt=pallas_bt, bv=pallas_bv,
            interpret=pallas_interpret)
        return ScoreStats(margin=margin, entropy=entropy,
                          max_logprob=max_logprob, top1=top1)
    raise ValueError(f"unknown head mode {mode!r}")


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScoringConfig:
    microbatch: int = 1024
    head_mode: str = "auto"        # auto | dense | chunked | pallas
    vocab_chunk: int = 8192
    pallas_interpret: bool = True  # interpret kernels off-TPU
    pallas_bt: int = 128
    pallas_bv: int = 512
    donate_pool: bool = True       # donate the padded pool buffer
    with_features: bool = True     # also return last-hidden features


class PoolScoringEngine:
    """jit-compiled microbatched pool scorer for one model.

    ``model`` is the registry facade; feature-classifier families consume
    ``(N, input_dim)`` float pools, token families ``(N, T)`` int pools
    (last-position statistics — the serving/labeling convention).
    """

    def __init__(self, model, cfg: ScoringConfig = ScoringConfig(),
                 mesh=None):
        self.model = model
        self.cfg = cfg
        self.mesh = mesh
        self._batch_key = ("features" if model.cfg.family == "mlp"
                           else "tokens")
        donate = cfg.donate_pool and jax.default_backend() != "cpu"
        self._donate = donate
        kwargs = {"donate_argnums": (1,) if donate else ()}
        if mesh is not None:
            xs_spec = NamedSharding(mesh, P(None, "data"))
            p_spec = NamedSharding(mesh, P())
            kwargs["in_shardings"] = (p_spec, xs_spec)
        self._score_all = jax.jit(self._score_padded, **kwargs)
        # (n_mb, mb) pack buckets swept so far — the compile-cache key set,
        # persisted in campaign checkpoints (cache_keys / warm) — and the
        # warmed AOT executables dispatched in place of the jit wrapper
        # (lower().compile() does not populate jit's dispatch cache)
        self.pack_keys: set = set()
        self._compiled: dict = {}
        # runtime metrics (repro.obs.MetricsRegistry); None = free no-op
        self.metrics = None

    def _note_pack(self, key: Tuple[int, int]) -> None:
        """Record a pack-bucket touch: compile-cache hit when the bucket
        was already swept, miss when this is its first (compiling) use."""
        if self.metrics is not None:
            if key in self.pack_keys:
                self.metrics.inc("pack_cache_hits_total", engine="scoring")
            else:
                self.metrics.inc("pack_cache_misses_total",
                                 engine="scoring")
        self.pack_keys.add(key)

    # -- model plumbing ----------------------------------------------------

    def _microbatch_stats(self, params, x) -> Tuple[ScoreStats, jax.Array]:
        hidden = self.model.forward(params, {self._batch_key: x})
        h = hidden[:, -1, :].astype(jnp.float32)
        c = self.cfg
        w = resolve_head_weight(self.model.cfg, params)
        stats = head_stats(h, w.astype(jnp.float32),
                           mode=c.head_mode, vocab_chunk=c.vocab_chunk,
                           pallas_interpret=c.pallas_interpret,
                           pallas_bt=c.pallas_bt, pallas_bv=c.pallas_bv)
        return stats, h

    def _score_padded(self, params, xs):
        """xs: (n_mb, mb, ...) -> packed ScoreStats (n_mb * mb,), features."""

        def body(x):
            stats, h = self._microbatch_stats(params, x)
            if not self.cfg.with_features:
                h = jnp.zeros((x.shape[0], 0), jnp.float32)
            return stats, h

        stats, feats = jax.lax.map(body, xs)
        stats = compat.tree_map(lambda a: a.reshape(-1), stats)
        # explicit shape: reshape(-1, D) divides by D, which is 0 when
        # feature emission is disabled
        return stats, feats.reshape(
            (feats.shape[0] * feats.shape[1], feats.shape[2]))

    # -- pool plumbing -----------------------------------------------------

    def _pack(self, pool_x) -> Tuple[jax.Array, int]:
        """Pad the pool to a power-of-two microbatch count and fold it into
        (n_mb, mb, ...).  Bucketing (pow2 microbatch count, pow2 small-pool
        width) bounds the number of compiled programs at O(log N) as MCAL's
        candidate set shrinks across iterations."""
        x = jnp.asarray(pool_x)
        n = x.shape[0]
        n_mb, mb = pack_shape(n, self.cfg.microbatch)
        pad = n_mb * mb - n
        if pad:
            x = jnp.concatenate(
                [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
        elif self._donate and isinstance(pool_x, jax.Array):
            # donation would otherwise invalidate the caller's own buffer
            # (asarray/reshape alias device arrays when no padding copies)
            x = jnp.copy(x)
        self._note_pack((n_mb, mb))
        return x.reshape((n_mb, mb) + x.shape[1:]), n

    # -- public API --------------------------------------------------------

    def score_pages(self, params, xs) -> Tuple[ScoreStats, jax.Array]:
        """The jit-compiled packed scoring step over a pre-packed
        ``(n_mb, mb, ...)`` page (see :func:`pack_shape`) — the sweep
        runtime's page kernel (``serving.sweep.EngineSweepAdapter``).
        Returns PACKED statistics/features (padding rows included; the
        caller masks by its own valid count).  Shares the compile cache
        with :meth:`score`, and donates the page buffer where the backend
        supports donation."""
        self._note_pack((int(xs.shape[0]), int(xs.shape[1])))
        return self._run_packed(params, xs)

    def cache_keys(self):
        """Sorted (n_mb, mb) pack buckets this engine has compiled."""
        return sorted(self.pack_keys)

    def _run_packed(self, params, xs):
        """Dispatch one packed page: the warmed AOT executable when the
        bucket was prewarmed, the jit wrapper otherwise."""
        exe = self._compiled.get((int(xs.shape[0]), int(xs.shape[1])))
        return (exe or self._score_all)(params, xs)

    def warm(self, params, keys) -> int:
        """AOT-compile the packed scoring step for the given (n_mb, mb)
        pack buckets (e.g. restored from a campaign checkpoint) without
        scoring a row; the executables are kept and dispatched directly.
        Feature classifiers only — token pools carry a sequence dim the
        pack key does not determine."""
        if self._batch_key != "features":
            raise NotImplementedError(
                "warm() supports feature-classifier engines")
        count = 0
        for n_mb, mb in keys:
            key = (int(n_mb), int(mb))
            if key in self._compiled:
                continue
            xs = jax.ShapeDtypeStruct(
                key + (self.model.cfg.input_dim,), jnp.float32)
            self._compiled[key] = self._score_all.lower(params, xs).compile()
            self.pack_keys.add(key)
            count += 1
        if count and self.metrics is not None:
            self.metrics.inc("warm_compiles_total", count, engine="scoring")
        return count

    def score(self, params, pool_x) -> Tuple[ScoreStats, jax.Array]:
        """Score the whole pool.  Returns device-resident ScoreStats and
        (N, D) last-hidden features, trimmed to the true pool size."""
        xs, n = self._pack(pool_x)
        stats, feats = self._run_packed(params, xs)
        return (compat.tree_map(lambda a: a[:n], stats), feats[:n])

    def pool_features(self, params, pool_x) -> jax.Array:
        """Device-resident (N, D) pooled last-hidden features from the same
        jit-compiled sweep (identical microbatching / compile cache / mesh
        sharding as :meth:`score`).  The k-center selection engine
        (``core.selection_device``) consumes these directly — features
        never round-trip through the host."""
        if not self.cfg.with_features:
            raise ValueError(
                "engine built with with_features=False emits no features; "
                "construct it with ScoringConfig(with_features=True)")
        return self.score(params, pool_x)[1]

    def score_host(self, params, pool_x) -> Tuple[ScoreStats, np.ndarray]:
        """:meth:`score` fetched to host numpy (the task-facade boundary)."""
        stats, feats = self.score(params, pool_x)
        return (compat.tree_map(np.asarray, stats), np.asarray(feats))

    def top_k(self, params, pool_x, k: int,
              metric: str = "margin") -> np.ndarray:
        """Indices (into ``pool_x`` rows) of the k most uncertain samples,
        selected on device; sorted most-uncertain-first."""
        xs, n = self._pack(pool_x)
        k = min(k, n)
        if k <= 0:
            return np.zeros((0,), np.int64)
        stats, _ = self._run_packed(params, xs)
        scores = uncertainty_from_stats(stats, metric)
        valid = jnp.arange(scores.shape[0]) < n
        _, idx = jax.lax.top_k(jnp.where(valid, scores, -jnp.inf), k)
        return np.asarray(idx, np.int64)

    def rank_confident(self, params, pool_x,
                       metric: str = "margin") -> np.ndarray:
        """Full pool ordering most-confident-first (L(.)); scores come from
        the device sweep, the stable argsort stays on host."""
        stats, _ = self.score(params, pool_x)
        scores = np.asarray(uncertainty_from_stats(stats, metric))
        return np.argsort(scores, kind="stable")


# ---------------------------------------------------------------------------
# the seed host path, kept as the reference oracle
# ---------------------------------------------------------------------------


def score_pool_reference(model, params, pool_x, chunk: int = 2048,
                         batch_key: Optional[str] = None
                         ) -> Tuple[ScoreStats, np.ndarray]:
    """The seed implementation: chunked forward with a host round-trip per
    chunk, numpy statistics at the end.  Exact; used to validate the engine
    and as the benchmark baseline."""
    batch_key = batch_key or ("features" if model.cfg.family == "mlp"
                              else "tokens")
    w = resolve_head_weight(model.cfg, params)
    outs, feats = [], []
    n = np.asarray(pool_x).shape[0]
    for lo in range(0, n, chunk):
        x = jnp.asarray(np.asarray(pool_x)[lo:lo + chunk])
        hidden = model.forward(params, {batch_key: x})
        logits = jnp.einsum("btd,dv->btv", hidden.astype(jnp.float32),
                            w.astype(jnp.float32))[:, -1]
        outs.append(np.asarray(logits, np.float32))
        feats.append(np.asarray(hidden[:, -1], np.float32))
    logits = np.concatenate(outs)
    stats = L.score_stats_from_logits(jnp.asarray(logits))
    return compat.tree_map(np.asarray, stats), np.concatenate(feats)
