"""Labeling-task abstraction consumed by the MCAL driver.

A task owns a pool of ``pool_size`` unlabeled items and exposes:

* ``human_label(idx)``   -> labels (the simulated annotation service);
* ``train(idx, labels)`` -> $ training cost (re-trains the classifier on the
  human-labeled set, fixed epochs per the paper);
* ``score(idx)``         -> (ScoreStats, features) from the current model;
* ``predict(idx)``       -> argmax machine labels;
* ``eval_correct(idx, labels)`` -> bool array (prediction == label).

:class:`LiveTask` is the real path: a JAX classifier trained with the
framework's own train loop, training cost profiled from the measured
step time x the instance price (the paper's c_u profiling), scoring via the
margin-head path.  The paper-scale replays in benchmarks use
:class:`repro.core.emulator.EmulatedTask` instead — same interface, same
driver.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Protocol, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.selection import UNCERTAINTY_METRICS


class LabelingTask(Protocol):
    pool_size: int
    num_classes: int
    arch_name: str

    def human_label(self, idx: np.ndarray) -> np.ndarray: ...
    def train(self, idx: np.ndarray, labels: np.ndarray) -> float: ...
    def score(self, idx: np.ndarray): ...
    def predict(self, idx: np.ndarray) -> np.ndarray: ...
    def eval_correct(self, idx: np.ndarray, labels: np.ndarray) -> np.ndarray: ...


@dataclasses.dataclass
class LiveTask:
    """MCAL over a real JAX classifier + feature dataset.

    ``features``: (N, d) float array; ``groundtruth``: (N,) int labels —
    human labels are simulated as groundtruth (the paper's assumption:
    human labels are perfect).
    """

    features: np.ndarray
    groundtruth: np.ndarray
    num_classes: int
    arch_name: str = "mlp"
    hidden: int = 64
    depth: int = 2
    epochs: int = 40
    batch_size: int = 256
    learning_rate: float = 1e-2
    price_per_hour: float = 3.6      # the paper's 4xK80 VM price
    seed: int = 0
    measured_cost: bool = False      # False -> cost = c_u_nominal * |B| (deterministic)
    c_u_nominal: float = 1e-4        # $/sample-iteration when not measuring
    score_microbatch: int = 2048     # pool-scoring engine microbatch
    sweep_page: int = 8192           # pool-sweep runtime page rows
    fit_fused: bool = True           # fused-scan retrain engine (False ->
                                     # the per-step host-loop oracle)
    fit_resident: bool = False       # keep the labeled set device-resident,
                                     # scatter in only newly bought labels
    mesh: Optional[object] = None    # host/device mesh: microbatch dim of
                                     # the scoring sweep + the fused-fit
                                     # program shard over its "data" axis
    annotation: Optional[object] = None  # AnnotationService (or a shared
                                     # service's AnnotationSession): route
                                     # human_label through a noisy multi-
                                     # annotator oracle (None = the
                                     # paper's perfect-label assumption)
    engines: Optional[object] = None  # launch.orchestrator.SharedEngines:
                                     # reuse a fleet's scoring/sweep/fit
                                     # engine families (and their pow2
                                     # compile caches) instead of building
                                     # per-task ones.  Requires matching
                                     # model/data shapes; the fleet owns
                                     # the engine lifecycle.

    def __post_init__(self):
        self.pool_size = len(self.features)
        if self.engines is not None:
            # shared-engine fleet mode: adopt the bundle's model + train
            # config so this task's params are exactly what the bundle's
            # compiled programs were built for.  Engines are stateless
            # per call given params (the fused fit derives its state from
            # the rng each call), so per-tenant results are bit-identical
            # to owning private engines — EXCEPT the fit engine's
            # resident pool, which is per-engine state and must stay off.
            b = self.engines
            assert not self.fit_resident, \
                "fit_resident keeps per-engine state; unsupported with " \
                "shared engines"
            assert b.input_dim == self.features.shape[1] and \
                b.num_classes == self.num_classes, \
                "shared engines were built for a different data shape"
            self.cfg = b.cfg
            self.model = b.model
            self.tc = b.tc
            self._engine = b.scoring
            self._sweep = b.sweep
            self._fit = b.fit
            self._params = None
            self._res_idx = np.zeros((0,), np.int64)
            self.metrics = None
            return
        from repro.configs.base import ModelConfig, TrainConfig
        from repro.models.registry import get_model
        cfg = ModelConfig(
            name=f"{self.arch_name}-live", family="mlp",
            num_layers=self.depth, d_model=self.hidden,
            num_classes=self.num_classes, input_dim=self.features.shape[1],
            dtype="float32", remat="none")
        self.cfg = cfg
        self.model = get_model(cfg)
        # constant LR so one compiled step serves every |B| (no re-jit per
        # MCAL iteration); the paper's step schedule is exercised by the
        # LM-arch training path.
        self.tc = TrainConfig(learning_rate=self.learning_rate,
                              schedule="constant",
                              weight_decay=1e-4, grad_clip=1.0)
        self._params = None
        from repro.core.scoring import PoolScoringEngine, ScoringConfig
        from repro.serving.sweep import (EngineSweepAdapter, PoolSweepRunner,
                                         SweepConfig)
        from repro.training.fit_device import FitConfig, FitEngine
        self._engine = PoolScoringEngine(
            self.model, ScoringConfig(microbatch=self.score_microbatch),
            mesh=self.mesh)
        self._sweep = PoolSweepRunner(
            EngineSweepAdapter(self._engine),
            SweepConfig(page_rows=self.sweep_page))
        self._fit = FitEngine(self.model, self.tc,
                              FitConfig(epochs=self.epochs,
                                        batch_size=self.batch_size),
                              mesh=self.mesh)
        self._res_idx = np.zeros((0,), np.int64)  # resident-pool row ledger
        self.metrics = None  # runtime metrics registry (attach_metrics)

    def attach_trace(self, trace) -> None:
        """Wire the campaign event bus into this task's runtimes: the
        paged sweep runner (page cursors, sink finalizations) and the fit
        engine (submit/fold timestamps for async retrains).  SHARED
        engines are left unwired — their telemetry interleaves every
        tenant's jobs and belongs to the fleet's observability, not to
        one tenant's trace (all of it is OBSERVABILITY_KINDS, so tenant
        decision streams stay complete without it)."""
        if self.engines is not None:
            return
        self._sweep.trace = trace
        self._fit.trace = trace

    def attach_metrics(self, metrics) -> None:
        """Wire the runtime metrics registry (repro.obs) through this
        task's engine stack: sweep page/fold timings, fit spans +
        compile-cache hit/miss counters, and the k-center span.  Unlike
        :meth:`attach_trace`, SHARED engines are wired too — the fleet
        hands every tenant the same registry and attributes per-tenant
        time via the orchestrator's bound ``tenant`` label, so there is
        one metrics surface per process, not one per tenant."""
        self.metrics = metrics
        self._sweep.metrics = metrics
        self._fit.metrics = metrics
        self._engine.metrics = metrics

    def attach_faults(self, faults, retry=None) -> None:
        """Wire the chaos injector (and optional re-dispatch retry
        policy) into the OWNED engines' broker workers (fault sites
        ``worker.pool-sweep``/``worker.fit-engine``).  Shared engines
        are left unwired, same reasoning as :meth:`attach_trace`: their
        jobs interleave every tenant's work, so injecting there would
        chaos the whole fleet, not this tenant."""
        if self.engines is not None:
            return
        self._sweep.attach_faults(faults, retry)
        self._fit.attach_faults(faults, retry)

    def close(self) -> None:
        """Idempotent task teardown: join the OWNED engines' broker
        threads (shared engines belong to the fleet; the annotation
        service/session closes itself — a session's close is a no-op,
        a privately attached service's joins its broker)."""
        if self.engines is None:
            self._sweep.close()
            self._fit.close()
        ann = self.annotation
        if ann is not None and hasattr(ann, "close"):
            ann.close()

    # -- annotation service ------------------------------------------------
    def human_label(self, idx: np.ndarray) -> np.ndarray:
        """Purchased human labels.  With an :attr:`annotation` service
        attached these are AGGREGATED noisy-annotator votes (charged per
        request by the buyer — see ``SharedPool.buy_labels``); without
        one, the paper's perfect-label assumption."""
        idx = np.asarray(idx, np.int64)
        gt = self.groundtruth[idx]
        if self.annotation is not None:
            return self.annotation.annotate(idx, gt)
        return gt

    def oracle_labels(self, idx: np.ndarray) -> np.ndarray:
        """TRUE labels for evaluation only — never charged, never noisy
        (the simulation oracle measured_error is computed against)."""
        return self.groundtruth[np.asarray(idx, np.int64)]

    # -- training ------------------------------------------------------------
    def train(self, idx: np.ndarray, labels: np.ndarray) -> float:
        """Re-train from scratch on (idx, labels) for ``epochs`` epochs
        (fixed epochs => per-iteration cost proportional to |B|, Eqn. 4).

        Runs as ONE fused device program (``training.fit_device.FitEngine``:
        epochs x steps in a single ``lax.scan``, shuffles from
        ``jax.random.permutation`` on device, (n, batch) pow2-bucketed so
        growing |B| reuses the compile cache).  ``fit_fused=False`` keeps
        the per-step host loop — the exact-agreement oracle (identical
        permutation sequence -> bit-identical params on a CPU host).  With
        ``fit_resident`` the labeled set stays device-resident across MCAL
        iterations and only newly bought labels are scattered in."""
        idx = np.asarray(idx, np.int64)
        n = len(idx)
        rng = jax.random.key(self.seed)
        t0 = time.perf_counter()
        if not self.fit_fused:
            params, losses = self._fit.fit_reference(
                rng, self.features[idx].astype(np.float32),
                np.asarray(labels, np.int32))
        elif self.fit_resident:
            prev = len(self._res_idx)
            if n < prev or not np.array_equal(idx[:prev], self._res_idx):
                # not an append-only extension of the resident set: rebuild
                self._fit.reset_resident()
                prev = 0
            if n > prev:
                fresh = idx[prev:]
                self._fit.extend_resident(
                    self.features[fresh].astype(np.float32),
                    np.asarray(labels, np.int32)[prev:])
            self._res_idx = idx.copy()
            params, losses = self._fit.fit_resident(rng)
        else:
            params, losses = self._fit.fit(
                rng, self.features[idx].astype(np.float32),
                np.asarray(labels, np.int32))
        jax.block_until_ready(losses)
        wall = time.perf_counter() - t0
        self._params = params
        if self.measured_cost:
            return wall / 3600.0 * self.price_per_hour
        return self.c_u_nominal * n

    def train_cost(self, n: int) -> Optional[float]:
        """The $ cost :meth:`train` will charge for an ``n``-row retrain
        when it is known WITHOUT training (the deterministic nominal
        c_u * |B| model) — None under ``measured_cost`` (wall-clock
        pricing).  The campaign's async-fit path pays this at submit
        time so the shared ledger is never stale while a retrain is in
        flight."""
        return None if self.measured_cost else self.c_u_nominal * n

    def submit_train(self, idx: np.ndarray, labels: np.ndarray,
                     then: Optional[callable] = None):
        """Async retrain (``FitEngine.submit_fit`` worker): runs
        :meth:`train` off-thread and returns a ``FitFuture`` of its $ cost
        — or of ``(cost, then())`` when a ``then`` continuation is given
        (the campaign chains its measurement sweep there, so it reads the
        freshly trained params on the same worker and the retrain dispatch
        overlaps the measurement's host-side paging)."""
        idx = np.asarray(idx, np.int64).copy()
        labels = np.asarray(labels).copy()

        def job():
            c = self.train(idx, labels)
            return (c, then()) if then is not None else c

        return self._fit.submit_call(job)

    # -- scoring ----------------------------------------------------------
    # Pool-scale passes (top-k M(.), k-center features, the L(.)/commit
    # rank) stream through the paged pool-sweep runtime
    # (``serving.sweep.PoolSweepRunner`` over the device engine), so the
    # pool never materializes on the device and only each sink's fold
    # returns to the host.  Small measurement scoring (the test set) stays
    # on the direct engine path; the seed host loop survives as
    # ``repro.core.scoring.score_pool_reference`` (the oracle the engine
    # is validated against and benchmarked over).

    def _pool(self, idx: np.ndarray) -> np.ndarray:
        assert self._params is not None, "train() before score()"
        return self.features[np.asarray(idx, np.int64)].astype(np.float32)

    def score(self, idx: np.ndarray):
        stats, feats = self._engine.score_host(self._params, self._pool(idx))
        return stats, feats

    def topk_candidates(self, metric: str, k: int,
                        candidates: np.ndarray) -> np.ndarray:
        """M(.) fast path: paged sweep folding a device top-k reservoir —
        only the k chosen rows ever reach the host."""
        from repro.serving.sweep import TopKSink
        rows = self._sweep.run(self._params, self._pool(candidates),
                               TopKSink(k, metric))
        return np.asarray(candidates, np.int64)[rows]

    def kcenter_candidates(self, k: int, candidates: np.ndarray,
                           anchors: Optional[np.ndarray] = None):
        """M(.) k-center fast path: the paged sweep emits device-resident
        features and the greedy farthest-point loop runs on device too —
        the only host transfers are the k chosen rows and their features.
        The host oracle ``selection.k_center_greedy`` remains the
        reference path."""
        from repro.core.selection_device import k_center_greedy_device
        from repro.serving.sweep import FeatureSink
        feats = self._sweep.run(self._params, self._pool(candidates),
                                FeatureSink())
        rows = k_center_greedy_device(feats, k, anchors=anchors,
                                      metrics=self.metrics)
        picked = np.asarray(candidates, np.int64)[rows]
        return picked, np.asarray(feats[jnp.asarray(rows)], np.float32)

    def anchor_features(self, idx: np.ndarray) -> np.ndarray:
        """(len(idx), D) pooled features of ``idx`` under the CURRENT
        classifier (one paged feature sweep) — the campaign's k-center
        anchor set, rebuildable from ``B_idx`` alone on resume."""
        from repro.serving.sweep import FeatureSink
        return np.asarray(
            self._sweep.run(self._params, self._pool(idx), FeatureSink()),
            np.float32)

    def machine_label_sweep(self, idx: np.ndarray, metric: str = "margin",
                            *, checkpoint=None, checkpoint_every: int = 0,
                            on_checkpoint=None):
        """L(.)/commit fast path: one paged sweep over ``idx`` ->
        (rows most-confident-first, machine labels row-aligned with
        ``idx``).  Only the rank field + top1 per row return to host.

        ``checkpoint`` resumes a previously cut ``SweepCheckpoint``
        mid-pool (bit-identical to an uninterrupted sweep);
        ``checkpoint_every``/``on_checkpoint`` cut a cursor every N pages
        and hand it to the callback — the launcher persists it in its
        ``--state`` file so a preempted commit sweep restarts mid-pool."""
        from repro.serving.sweep import RankTop1Sink
        order, top1 = self._sweep.run(self._params, self._pool(idx),
                                      RankTop1Sink(metric),
                                      checkpoint=checkpoint,
                                      checkpoint_every=checkpoint_every,
                                      on_checkpoint=on_checkpoint)
        return order, top1

    def submit_candidates(self, metric: str, k: int, candidates: np.ndarray,
                          anchors: Optional[np.ndarray] = None):
        """Async M(.): launch the ranking sweep on the runner's worker
        thread and return a ``SweepFuture`` — the campaign overlaps its
        host-side fits/search and synchronizes at ``result()``.
        Uncertainty metrics resolve to the picked pool indices; k-center
        to the same ``(picked, features)`` pair as
        :meth:`kcenter_candidates`."""
        from repro.serving.sweep import TopKSink
        cand = np.asarray(candidates, np.int64)
        if metric in UNCERTAINTY_METRICS:
            return self._sweep.submit(
                self._params, self._pool(cand), TopKSink(k, metric),
                map_result=lambda rows: cand[rows])
        if metric == "kcenter":
            return self._sweep.submit_call(self.kcenter_candidates, k, cand,
                                           anchors)
        raise ValueError(f"no async sweep for metric {metric!r}")

    def predict(self, idx: np.ndarray) -> np.ndarray:
        stats, _ = self._engine.score_host(self._params, self._pool(idx))
        return np.asarray(stats.top1, np.int64)

    # -- compile-cache persistence ----------------------------------------
    def pack_cache_keys(self) -> Dict:
        """The pow2 pack-shape buckets both device engines have compiled
        (scoring sweep pages + fused-fit programs) — JSON-embeddable in
        campaign checkpoints so a resumed replay prewarms them instead of
        recompiling mid-loop."""
        return {"scoring": [list(k) for k in self._engine.cache_keys()],
                "fit": [list(k) for k in self._fit.cache_keys()]}

    def prewarm_caches(self, keys: Optional[Dict]):
        """Rebuild both engines' compile caches from persisted pack keys
        (requires a trained model for the scoring side)."""
        if not keys:
            return
        self._fit.warm(keys.get("fit", ()))
        if self._params is not None:
            self._engine.warm(self._params, keys.get("scoring", ()))

    def eval_correct(self, idx: np.ndarray, labels: np.ndarray) -> np.ndarray:
        return self.predict(idx) == np.asarray(labels)
