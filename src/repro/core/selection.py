"""Sample-selection functions M(.) (train-set acquisition) and L(.)
(machine-labeling confidence ranking).

All uncertainty metrics consume :class:`repro.models.layers.ScoreStats`
(computed pool-wide by the distributed scoring step / Pallas margin_head
kernel); k-center consumes last-hidden-state features.  Ranking/argpartition
happen on host over numpy arrays — the expensive part (model inference over
the pool) is the distributed job, not this.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

UNCERTAINTY_METRICS = ("margin", "entropy", "least_confidence")
METRICS = UNCERTAINTY_METRICS + ("kcenter",)


def uncertainty_scores(metric: str, stats) -> np.ndarray:
    """Higher score = more uncertain (better M(.) candidate)."""
    if metric == "margin":
        return -np.asarray(stats.margin, np.float64)
    if metric == "entropy":
        return np.asarray(stats.entropy, np.float64)
    if metric == "least_confidence":
        return 1.0 - np.exp(np.asarray(stats.max_logprob, np.float64))
    raise ValueError(f"unknown uncertainty metric {metric!r}")


def select_for_training(
    metric: str,
    k: int,
    stats=None,
    features: Optional[np.ndarray] = None,
    candidates: Optional[np.ndarray] = None,
    anchors: Optional[np.ndarray] = None,
    rng: Optional[np.random.Generator] = None,
) -> np.ndarray:
    """M(.): pick ``k`` pool indices to human-label next.

    ``candidates`` are pool indices still unlabeled; uncertainty metrics rank
    by ``stats`` rows aligned with ``candidates``; ``kcenter`` runs greedy
    farthest-point on ``features`` rows (aligned the same way) against
    ``anchors`` (features of already-labeled samples).
    """
    assert candidates is not None
    k = min(k, len(candidates))
    if k <= 0:
        return np.zeros((0,), np.int64)
    if metric == "random":
        rng = rng or np.random.default_rng(0)
        return rng.choice(candidates, size=k, replace=False)
    if metric == "kcenter":
        assert features is not None
        sel = k_center_greedy(features, k, anchors=anchors)
        return np.asarray(candidates)[sel]
    scores = uncertainty_scores(metric, stats)
    assert len(scores) == len(candidates)
    top = np.argpartition(-scores, k - 1)[:k]
    return np.asarray(candidates)[top]


def rank_for_machine_labeling(stats, metric: str = "margin") -> np.ndarray:
    """L(.): order rows most-confident-first."""
    scores = uncertainty_scores(metric, stats)  # high = uncertain
    return np.argsort(scores, kind="stable")     # ascending = confident first


def k_center_greedy(features: np.ndarray, k: int,
                    anchors: Optional[np.ndarray] = None,
                    chunk: int = 4096) -> np.ndarray:
    """Greedy k-center (farthest-point) selection.  O(k * N * d) chunked.

    Returns row indices into ``features``.
    """
    X = np.asarray(features, np.float32)
    N = X.shape[0]
    k = min(k, N)
    if k <= 0:  # same contract as the device twin: nothing selected
        return np.zeros((0,), np.int64)
    min_d = np.full((N,), np.inf, np.float32)

    def update(center_vec):
        for lo in range(0, N, chunk):
            hi = min(lo + chunk, N)
            d = np.sum((X[lo:hi] - center_vec[None, :]) ** 2, axis=1)
            np.minimum(min_d[lo:hi], d, out=min_d[lo:hi])

    if anchors is not None and len(anchors):
        for a in np.asarray(anchors, np.float32):
            update(a)
        first = int(np.argmax(min_d))
    else:
        first = 0
    chosen = [first]
    update(X[first])
    for _ in range(1, k):
        nxt = int(np.argmax(min_d))
        chosen.append(nxt)
        update(X[nxt])
    return np.asarray(chosen, np.int64)


def machine_label_error_curve(stats, correct: np.ndarray,
                              thetas: Sequence[float],
                              metric: str = "margin") -> np.ndarray:
    """eps_T(S^theta): error of the top-theta confidence fraction (Fig. 5).

    ``correct`` is a bool array (classifier prediction == human label),
    row-aligned with ``stats``.  Returns the error rate over the
    most-confident ``theta`` fraction for each theta.
    """
    order = rank_for_machine_labeling(stats, metric)
    wrong = (~np.asarray(correct, bool))[order]
    n = len(wrong)
    cum_wrong = np.cumsum(wrong)
    out = []
    for th in thetas:
        m = max(int(round(th * n)), 1)
        m = min(m, n)
        out.append(cum_wrong[m - 1] / m)
    return np.asarray(out, np.float64)
