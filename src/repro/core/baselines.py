"""Baseline labeling strategies MCAL is compared against (paper §5).

* ``human_all_cost``  — label everything with the service.
* ``run_naive_al``    — classic active learning with fixed batch size
  delta: acquire delta samples by M(.), retrain, and stop as soon as
  machine-labeling ALL remaining samples meets the overall error bound
  ((|S|/|X|) * eps_T <= eps, theta = 1); then machine-label the rest.
  Sweeping delta and taking the best gives the paper's "oracle assisted
  AL" (Tbl. 2) — the oracle picks delta in hindsight.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core import mcal
from repro.core import selection as sel
from repro.core.cost import CostLedger, LabelingService


def _buy(task, ledger: CostLedger, service: LabelingService,
         idx: np.ndarray) -> np.ndarray:
    """Purchase labels for ``idx`` with the same repeats-inclusive
    charging convention as ``SharedPool.buy_labels`` — baselines must
    price noisy-annotation votes like the campaigns they are compared
    against, or the savings comparison is skewed in their favor."""
    ann = getattr(task, "annotation", None)
    v0 = ann.votes_bought if ann is not None else 0
    labels = task.human_label(idx)
    votes = (ann.votes_bought - v0) if ann is not None else len(idx)
    ledger.pay_human(len(idx), service, votes=votes)
    return labels


@dataclasses.dataclass
class ALResult:
    cost: float
    ledger: Dict
    B_size: int
    S_size: int
    measured_error: float
    iterations: int
    machine_fraction: float
    met_constraint: bool


def run_naive_al(task, service: LabelingService, delta_frac: float,
                 eps_target: float = 0.05, metric: str = "margin",
                 test_frac: float = 0.05, max_iters: int = 120,
                 seed: int = 0) -> ALResult:
    X = task.pool_size
    rng = np.random.default_rng(seed)
    ledger = CostLedger()

    T_size = max(int(round(test_frac * X)), 16)
    T_idx = rng.choice(X, T_size, replace=False)
    T_labels = _buy(task, ledger, service, T_idx)

    in_T = np.zeros(X, bool)
    in_T[T_idx] = True
    in_B = np.zeros(X, bool)
    delta = max(int(round(delta_frac * X)), 8)

    labels = np.full(X, -1, np.int64)
    labels[T_idx] = T_labels

    b0 = rng.choice(np.nonzero(~in_T)[0], delta, replace=False)
    in_B[b0] = True
    labels[b0] = _buy(task, ledger, service, b0)

    it = 0
    met = False
    while it < max_iters:
        B_idx = np.nonzero(in_B)[0]
        ledger.pay_training(task.train(B_idx, labels[B_idx]))
        correct = task.eval_correct(T_idx, labels[T_idx])
        eps_T = float(np.mean(~correct))
        remaining = np.nonzero(~in_T & ~in_B)[0]
        overall = eps_T * len(remaining) / X
        it += 1
        if overall <= eps_target:
            met = True
            break
        if len(remaining) <= delta:
            break
        stats, feats = task.score(remaining)
        pick = sel.select_for_training(metric, delta, stats=stats,
                                       features=feats, candidates=remaining,
                                       rng=rng)
        labels[pick] = _buy(task, ledger, service, pick)
        in_B[pick] = True

    remaining = np.nonzero(~in_T & ~in_B)[0]
    if met and len(remaining):
        labels[remaining] = task.predict(remaining)
        S = len(remaining)
    else:  # constraint never met: humans finish the job
        if len(remaining):
            labels[remaining] = _buy(task, ledger, service, remaining)
        S = 0
    gt = mcal.oracle_labels(task, np.arange(X))  # evaluation only
    return ALResult(
        cost=ledger.total, ledger=ledger.snapshot(),
        B_size=int(np.sum(in_B)), S_size=S,
        measured_error=float(np.mean(labels != gt)), iterations=it,
        machine_fraction=S / X, met_constraint=met)


def oracle_al(task_factory, service: LabelingService,
              deltas=(0.01, 0.017, 0.033, 0.067, 0.10, 0.133, 0.167, 0.20),
              eps_target: float = 0.05, seed: int = 0):
    """Sweep delta; return (best_delta, best result, all results)."""
    results = {}
    for d in deltas:
        results[d] = run_naive_al(task_factory(), service, d,
                                  eps_target=eps_target, seed=seed)
    best = min(results, key=lambda d: results[d].cost)
    return best, results[best], results
