"""Cost models: human labeling services + iterative training cost (Eqn. 4).

Training cost: with per-iteration cost proportional to the current training
set size (fixed epochs) and acquisitions of ``delta`` per iteration, total
cost from scratch to ``B`` is the paper's Eqn. 4::

    C_t(B, delta) = 1/2 * c_u * B * (B/delta + 1)

``c_u`` ($ per sample-iteration) is profiled on real hardware by timing the
jitted train step (see :mod:`repro.core.task`).  The cubic variant (epochs
proportional to size -> per-iteration cost ~ size^2) is exposed through
``exponent=2``; any exponent falls back to an explicit schedule sum.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class LabelingService:
    name: str
    price_per_label: float  # $

    def cost(self, n: int) -> float:
        return float(n) * self.price_per_label


AMAZON = LabelingService("amazon", 0.04)
SATYAM = LabelingService("satyam", 0.003)
SERVICES = {s.name: s for s in (AMAZON, SATYAM)}


def schedule_sizes(start: int, end: int, delta: int) -> np.ndarray:
    """Training-set sizes at each retrain when growing start -> end by delta."""
    if end <= start:
        return np.zeros((0,), np.int64)
    delta = max(int(delta), 1)
    return np.arange(start + delta, end + 1, delta, dtype=np.int64)


@dataclasses.dataclass
class TrainCostModel:
    """Per-iteration training cost = c_u * size^exponent."""

    c_u: float = 0.0
    exponent: int = 1

    def iteration_cost(self, size) -> np.ndarray:
        return self.c_u * np.asarray(size, np.float64) ** self.exponent

    def cost_from_scratch(self, B: float, delta: float) -> float:
        """Eqn. 4 closed form (exponent 1); schedule sum otherwise."""
        B = float(B)
        delta = max(float(delta), 1.0)
        if self.exponent == 1:
            return 0.5 * self.c_u * B * (B / delta + 1.0)
        sizes = schedule_sizes(0, int(round(B)), int(round(delta)))
        return float(np.sum(self.iteration_cost(sizes)))

    def cost_to_grow(self, start: float, end: float, delta: float) -> float:
        """Future training cost to grow an existing set start -> end."""
        if end <= start:
            return 0.0
        if self.exponent == 1:
            # sum over sizes start+delta, start+2delta, ..., end
            delta = max(float(delta), 1.0)
            m = int(np.ceil((end - start) / delta))
            sizes = np.minimum(start + delta * np.arange(1, m + 1), end)
            return float(self.c_u * np.sum(sizes))
        sizes = schedule_sizes(int(round(start)), int(round(end)),
                               int(round(delta)))
        return float(np.sum(self.iteration_cost(sizes)))

    def fit(self, sizes: Sequence[float], costs: Sequence[float]) -> "TrainCostModel":
        """Least-squares through the origin of cost vs size^exponent."""
        s = np.asarray(sizes, np.float64) ** self.exponent
        c = np.asarray(costs, np.float64)
        denom = float(np.dot(s, s))
        self.c_u = float(np.dot(s, c) / denom) if denom > 0 else 0.0
        return self


@dataclasses.dataclass
class CostLedger:
    """Running account of a labeling campaign."""

    human: float = 0.0
    training: float = 0.0
    human_labels: int = 0

    def pay_human(self, n: int, service: LabelingService) -> float:
        c = service.cost(n)
        self.human += c
        self.human_labels += n
        return c

    def pay_training(self, c: float) -> float:
        self.training += c
        return c

    @property
    def total(self) -> float:
        return self.human + self.training

    def snapshot(self) -> dict:
        return {"human": self.human, "training": self.training,
                "total": self.total, "human_labels": self.human_labels}
