"""Cost models: human labeling services + iterative training cost (Eqn. 4).

Training cost: with per-iteration cost proportional to the current training
set size (fixed epochs) and acquisitions of ``delta`` per iteration, total
cost from scratch to ``B`` is the paper's Eqn. 4::

    C_t(B, delta) = 1/2 * c_u * B * (B/delta + 1)

``c_u`` ($ per sample-iteration) is profiled on real hardware by timing the
jitted train step (see :mod:`repro.core.task`).  The cubic variant (epochs
proportional to size -> per-iteration cost ~ size^2) is exposed through
``exponent=2``; any exponent falls back to an explicit schedule sum.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class LabelingService:
    """Per-request pricing of one annotation service.

    ``tiers`` is an optional marginal volume-discount schedule: sorted
    ``(min_requests, price)`` breakpoints — requests past ``min_requests``
    (cumulative, across the whole campaign) are priced at that tier's
    rate, like cloud-annotation volume pricing sheets.  ``cost(n, start)``
    integrates the schedule over the request interval
    ``[start, start + n)``, so tier boundaries are honored mid-batch.
    With repeated labeling every VOTE is one priced request —
    :meth:`CostLedger.pay_human` threads its cumulative request counter
    through ``start``.
    """

    name: str
    price_per_label: float  # $ per request at the base tier
    tiers: Optional[Tuple[Tuple[int, float], ...]] = None

    def __post_init__(self):
        if self.tiers:
            bounds = [int(b) for b, _ in self.tiers]
            assert bounds == sorted(bounds) and bounds[0] >= 0, \
                "tiers must be sorted (min_requests, price) breakpoints"

    def price_at(self, count: int) -> float:
        """Marginal $ price of request number ``count`` (0-based)."""
        price = self.price_per_label
        for bound, p in self.tiers or ():
            if count >= bound:
                price = p
            else:
                break
        return price

    def cost(self, n: int, start: int = 0) -> float:
        """$ for requests ``start .. start + n - 1`` (piecewise over the
        tier schedule; flat ``n * price_per_label`` without tiers)."""
        n = int(n)
        if n <= 0:
            return 0.0
        if not self.tiers:
            return float(n) * self.price_per_label
        start = int(start)
        end = start + n
        edges = [b for b, _ in self.tiers if start < b < end]
        total, lo = 0.0, start
        for edge in edges + [end]:
            total += (edge - lo) * self.price_at(lo)
            lo = edge
        return total

    def scaled(self, repeats: float) -> "LabelingService":
        """The effective per-LABEL service under an expected ``repeats``
        votes per label — what cost predictions (Eqn. 4's joint search)
        should price future human labels at.  Tier boundaries are kept in
        label units (flattened to the base rate: predictions stay simple
        and slightly conservative under volume discounts)."""
        if repeats == 1.0:
            return self
        return LabelingService(self.name,
                               self.price_per_label * float(repeats))


AMAZON = LabelingService("amazon", 0.04)
SATYAM = LabelingService("satyam", 0.003)
SERVICES = {s.name: s for s in (AMAZON, SATYAM)}


@dataclasses.dataclass(frozen=True)
class LabelQuality:
    """The economics of imperfect human labels (noisy annotation service).

    ``residual_error`` is the expected error rate of the AGGREGATED
    labels the service returns (majority / Dawid-Skene over ``repeats``
    noisy votes) — it eats into the campaign's accuracy target, since
    even a perfect classifier trained and measured on such labels cannot
    beat it.  ``avg_repeats`` is the expected priced votes per purchased
    label — future human labels in Eqn. 4's joint search must be priced
    repeats-inclusive or the (|B|, theta) optimum is computed against a
    fictional cheaper service.  ``AnnotationService.expected_quality()``
    derives both from the annotator pool's confusion matrices.
    """

    residual_error: float = 0.0
    avg_repeats: float = 1.0

    def effective_target(self, eps_target: float) -> float:
        """The machine-labeling error budget left after the aggregated
        human labels spend their share (conservative: the residual is
        charged on the whole pool)."""
        return max(eps_target - self.residual_error, 0.0)

    def effective_service(self, service: LabelingService) -> LabelingService:
        return service.scaled(self.avg_repeats)


def schedule_sizes(start: int, end: int, delta: int) -> np.ndarray:
    """Training-set sizes at each retrain when growing start -> end by delta."""
    if end <= start:
        return np.zeros((0,), np.int64)
    delta = max(int(delta), 1)
    return np.arange(start + delta, end + 1, delta, dtype=np.int64)


@dataclasses.dataclass
class TrainCostModel:
    """Per-iteration training cost = c_u * size^exponent."""

    c_u: float = 0.0
    exponent: int = 1

    def iteration_cost(self, size) -> np.ndarray:
        return self.c_u * np.asarray(size, np.float64) ** self.exponent

    def cost_from_scratch(self, B: float, delta: float) -> float:
        """Eqn. 4 closed form (exponent 1); schedule sum otherwise."""
        B = float(B)
        delta = max(float(delta), 1.0)
        if self.exponent == 1:
            return 0.5 * self.c_u * B * (B / delta + 1.0)
        sizes = schedule_sizes(0, int(round(B)), int(round(delta)))
        return float(np.sum(self.iteration_cost(sizes)))

    def cost_to_grow(self, start: float, end: float, delta: float) -> float:
        """Future training cost to grow an existing set start -> end."""
        if end <= start:
            return 0.0
        if self.exponent == 1:
            # sum over sizes start+delta, start+2delta, ..., end
            delta = max(float(delta), 1.0)
            m = int(np.ceil((end - start) / delta))
            sizes = np.minimum(start + delta * np.arange(1, m + 1), end)
            return float(self.c_u * np.sum(sizes))
        sizes = schedule_sizes(int(round(start)), int(round(end)),
                               int(round(delta)))
        return float(np.sum(self.iteration_cost(sizes)))

    def fit(self, sizes: Sequence[float], costs: Sequence[float]) -> "TrainCostModel":
        """Least-squares through the origin of cost vs size^exponent."""
        s = np.asarray(sizes, np.float64) ** self.exponent
        c = np.asarray(costs, np.float64)
        denom = float(np.dot(s, s))
        self.c_u = float(np.dot(s, c) / denom) if denom > 0 else 0.0
        return self


@dataclasses.dataclass
class CostLedger:
    """Running account of a labeling campaign.

    ``human_labels`` counts distinct items human-labeled;
    ``human_votes`` counts priced annotation REQUESTS — with repeated
    labeling (noisy multi-annotator oracles) one label costs several
    votes, and tier pricing is applied against the cumulative request
    count, so the ledger threads it through every charge.

    When a campaign trace is attached (``trace``/``trace_name``), every
    charge emits a ``charge`` event carrying the running balance — the
    ledger itself is the charging site, so nothing can spend without
    leaving an audit line.  The trace attachment is runtime wiring, not
    account state: ``as_dict``/``from_dict`` ignore it and a restored
    ledger must be re-attached by its owner.
    """

    human: float = 0.0
    training: float = 0.0
    human_labels: int = 0
    human_votes: int = 0
    trace: Optional[object] = dataclasses.field(
        default=None, repr=False, compare=False)
    trace_name: str = dataclasses.field(
        default="campaign", repr=False, compare=False)

    def _emit_charge(self, what: str, **extra) -> None:
        if self.trace is not None:
            self.trace.emit("charge", ledger=self.trace_name, what=what,
                            human=self.human, training=self.training,
                            human_labels=self.human_labels,
                            human_votes=self.human_votes,
                            total=self.total, **extra)

    def pay_human(self, n: int, service: LabelingService, *,
                  repeats: int = 1, votes: Optional[int] = None) -> float:
        """Charge ``n`` freshly labeled items.  ``repeats`` (uniform) or
        ``votes`` (exact, e.g. under an adaptive-repeats policy) sets how
        many priced requests they took; ``n = 0`` charges nothing."""
        n = int(n)
        v = int(votes) if votes is not None else n * max(int(repeats), 1)
        if n <= 0 and v <= 0:
            return 0.0
        c = service.cost(v, start=self.human_votes)
        self.human += c
        self.human_labels += max(n, 0)
        self.human_votes += v
        self._emit_charge("human", n=max(n, 0), votes=v, cost=c)
        return c

    def pay_votes(self, v: int, service: LabelingService) -> float:
        """Charge ``v`` top-up annotation requests that buy no NEW labels
        (adaptive-repeats rounds re-asking about already-counted items)."""
        return self.pay_human(0, service, votes=v)

    def pay_training(self, c: float) -> float:
        self.training += c
        self._emit_charge("training", cost=float(c))
        return c

    @property
    def total(self) -> float:
        return self.human + self.training

    def as_dict(self) -> dict:
        """The persistable fields, round-trippable via :meth:`from_dict`
        (campaign ``state_dict`` embeds exactly this)."""
        return {"human": self.human, "training": self.training,
                "human_labels": self.human_labels,
                "human_votes": self.human_votes}

    @classmethod
    def from_dict(cls, d: dict) -> "CostLedger":
        return cls(human=float(d["human"]), training=float(d["training"]),
                   human_labels=int(d["human_labels"]),
                   # pre-annotation checkpoints priced one vote per label
                   human_votes=int(d.get("human_votes",
                                         d["human_labels"])))

    def snapshot(self) -> dict:
        return dict(self.as_dict(), total=self.total)
