"""The MCAL driver (paper Alg. 1) + architecture selection + budget variant.

One campaign = one (task, labeling service, MCALConfig).  The loop:

  bootstrap:  human-label a test set T (test_frac) and a random seed set B0
              (delta0_frac); train; measure eps_T(S^theta) over the theta grid.
  iterate:    fit the per-theta truncated power laws and the training-cost
              model from the measurement history; joint-search (|B|, theta)
              for the predicted minimum cost C*; once C* stabilizes
              (|dC*| <= stability_tol) adapt delta (Alg. 1 line 20) and stop
              when |B| has reached B_opt; otherwise acquire delta more
              samples ranked by M(.), human-label, retrain, re-measure.
  bail-out:   if training spend exceeds bailout_frac of the full human-
              labeling cost while no feasible machine labeling exists, label
              everything with humans (the paper's ImageNet behaviour).
  commit:     rank the remaining pool by L(.), machine-label the largest
              prefix the *measured* test-set error curve admits within
              eps_target, human-label the residual.

Cost-accounting convention (Eqn. 1): predicted C = (|X| - |S|) * C_h +
training spend so far + future training cost — human labels for T, B and the
residual are all inside (|X| - |S|).

``select_architecture`` runs several campaigns over a shared pool/ledger
(labels bought once, every candidate trains) until all their C* estimates
stabilize, then continues only the argmin-C* campaign — the paper's
CNN18/Res18/Res50 selection.  ``budget`` in MCALConfig switches the search
to the budget-constrained variant (min error s.t. cost <= budget).
"""
from __future__ import annotations

import contextlib
import dataclasses
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import selection as sel
from repro.core.cost import (CostLedger, LabelQuality, LabelingService,
                             TrainCostModel)
from repro.core.powerlaw import PowerLaw, fit_power_law
from repro.core.search import SearchResult, adapt_delta, budget_search, joint_search
from repro.faults.errors import StragglerTimeout
from repro.trace.store import sanitize as _trace_sanitize

DEFAULT_THETAS = tuple(round(0.05 * i, 2) for i in range(1, 21))

# campaign state_dict schema version.  v1: pre-trace checkpoints (no
# version field); v2: adds "version" + the "trace" append cursor.
STATE_VERSION = 2


@dataclasses.dataclass(frozen=True)
class MCALConfig:
    eps_target: float = 0.05
    thetas: Tuple[float, ...] = DEFAULT_THETAS
    delta0_frac: float = 0.01
    test_frac: float = 0.05
    metric: str = "margin"          # M(.)
    l_metric: str = "margin"        # L(.)
    stability_tol: float = 0.05     # Delta (Alg. 1 line 19)
    beta: float = 0.05              # delta-adaptation slack (line 20)
    bailout_frac: float = 0.10      # exploration tax x%
    bailout_min_s: float = 0.25     # "cannot machine-label any": |S*|/|X| floor
    cost_exponent: int = 1          # per-iteration cost ~ |B|^exponent
    max_iters: int = 200
    min_fit_points: int = 3
    seed: int = 0
    keep_surface: bool = False
    budget: Optional[float] = None  # set -> budget-constrained variant
    sweep_async: bool = False       # overlap the M(.) sweep with the
                                    # host-side fits + joint search
    fit_async: bool = False         # defer each retrain + its measurement
                                    # sweep onto the fit-engine worker,
                                    # synchronizing at the next consumer
    label_quality: Optional[LabelQuality] = None
                                    # noisy annotation-service economics:
                                    # residual aggregated-label error is
                                    # folded into the accuracy target and
                                    # future human labels are priced
                                    # repeats-inclusive in the joint
                                    # search (None = perfect labels)


@dataclasses.dataclass
class IterationRecord:
    i: int
    B_size: int
    delta: int
    eps_theta: Dict[float, float]
    cstar: float
    B_opt: int
    theta_opt: float
    feasible: bool
    stable: bool
    human_spent: float
    training_spent: float
    search: Optional[SearchResult] = None

    def to_dict(self) -> Dict:
        """JSON form — the ``iteration`` trace-event payload and the
        ``state_dict`` history entry.  ``search`` surfaces (the optional
        keep_surface grids) are in-memory only and never serialized."""
        return {
            "i": int(self.i), "B_size": int(self.B_size),
            "delta": int(self.delta),
            "eps_theta": {str(t): float(e)
                          for t, e in self.eps_theta.items()},
            "cstar": float(self.cstar), "B_opt": int(self.B_opt),
            "theta_opt": float(self.theta_opt),
            "feasible": bool(self.feasible), "stable": bool(self.stable),
            "human_spent": float(self.human_spent),
            "training_spent": float(self.training_spent)}

    @classmethod
    def from_dict(cls, d: Dict) -> "IterationRecord":
        return cls(
            i=int(d["i"]), B_size=int(d["B_size"]), delta=int(d["delta"]),
            eps_theta={float(t): float(e)
                       for t, e in d["eps_theta"].items()},
            cstar=float(d["cstar"]), B_opt=int(d["B_opt"]),
            theta_opt=float(d["theta_opt"]), feasible=bool(d["feasible"]),
            stable=bool(d["stable"]),
            human_spent=float(d["human_spent"]),
            training_spent=float(d["training_spent"]))


@dataclasses.dataclass
class MCALResult:
    labels: np.ndarray
    machine_mask: np.ndarray
    ledger: Dict
    history: List[IterationRecord]
    decision: str                  # hybrid | human_all
    B_size: int
    S_size: int
    theta_final: float
    measured_error: float          # vs groundtruth (simulation oracle)
    arch_name: str = ""

    @property
    def total_cost(self) -> float:
        return self.ledger["total"]

    def to_dict(self, with_history: bool = True) -> Dict:
        """JSON form — the ``commit`` trace-event payload.  The label
        arrays stay out (they are the campaign's product, not its
        decision record); ``pool_size`` preserves their shape so
        :meth:`from_dict` round-trips."""
        d = {
            "decision": str(self.decision), "B_size": int(self.B_size),
            "S_size": int(self.S_size),
            "theta_final": float(self.theta_final),
            "measured_error": float(self.measured_error),
            "arch_name": str(self.arch_name),
            "pool_size": int(len(self.labels)),
            "ledger": {k: (int(v) if isinstance(v, (int, np.integer))
                           else float(v))
                       for k, v in self.ledger.items()},
        }
        if with_history:
            d["history"] = [r.to_dict() for r in self.history]
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "MCALResult":
        n = int(d.get("pool_size", 0))
        return cls(
            labels=np.full(n, -1, np.int64),
            machine_mask=np.zeros(n, bool), ledger=dict(d["ledger"]),
            history=[IterationRecord.from_dict(r)
                     for r in d.get("history", [])],
            decision=str(d["decision"]), B_size=int(d["B_size"]),
            S_size=int(d["S_size"]),
            theta_final=float(d["theta_final"]),
            measured_error=float(d["measured_error"]),
            arch_name=str(d.get("arch_name", "")))


def _fitted_payload(laws: Dict[float, PowerLaw],
                    cm: TrainCostModel) -> Dict:
    """The persistable form of one round of power-law/cost fits — shared
    by ``state_dict`` and the ``powerlaw_fit`` trace event so a replayed
    fit is byte-identical to a checkpointed one."""
    return {
        # np.inf (plain power law) is not strict JSON -> None
        "laws": {str(t): {
            "alpha": law.alpha, "gamma": law.gamma,
            "k": None if not np.isfinite(law.k) else law.k,
            "resid_std": law.resid_std, "n_points": law.n_points}
            for t, law in laws.items()},
        "cost_model": {"c_u": cm.c_u, "exponent": cm.exponent},
    }


def oracle_labels(task, idx: np.ndarray) -> np.ndarray:
    """TRUE labels for evaluation only.  Tasks expose ``oracle_labels``
    precisely so measurement never routes through ``human_label`` — with
    a noisy annotation service attached, that path returns aggregated
    noisy votes AND consumes priced annotation requests, so using it as
    the free evaluation oracle both corrupted ``measured_error`` and
    bypassed ``CostLedger.pay_human`` for the requests it burned."""
    fn = getattr(task, "oracle_labels", None)
    return fn(idx) if fn is not None else task.human_label(idx)


class SharedPool:
    """Label store shared across campaigns (arch selection buys labels once)."""

    def __init__(self, pool_size: int, ledger: Optional[CostLedger] = None):
        self.pool_size = pool_size
        self.labels = np.full(pool_size, -1, np.int64)
        self.is_test = np.zeros(pool_size, bool)
        self.in_B = np.zeros(pool_size, bool)
        self.T_idx: Optional[np.ndarray] = None
        self.B_idx: np.ndarray = np.zeros((0,), np.int64)
        self.ledger = ledger or CostLedger()

    def buy_labels(self, task, idx: np.ndarray, service: LabelingService):
        """THE charging site: every purchased label pays through
        ``CostLedger.pay_human`` at the service's tier rates — with an
        annotation service on the task, repeats-inclusive (the per-call
        vote count the service reports, so adaptive-repeats batches are
        charged exactly what they consumed)."""
        idx = np.asarray(idx, np.int64)
        fresh = idx[self.labels[idx] < 0]
        if len(fresh):
            ann = getattr(task, "annotation", None)
            v0 = ann.votes_bought if ann is not None else 0
            self.labels[fresh] = task.human_label(fresh)
            votes = (ann.votes_bought - v0) if ann is not None \
                else len(fresh)
            self.ledger.pay_human(len(fresh), service, votes=votes)

    def unlabeled_candidates(self) -> np.ndarray:
        mask = (~self.is_test) & (~self.in_B)
        return np.nonzero(mask)[0]


class MCALCampaign:
    def __init__(self, task, service: LabelingService, cfg: MCALConfig,
                 shared: Optional[SharedPool] = None):
        self.task = task
        self.service = service
        self.cfg = cfg
        self.pool = shared or SharedPool(task.pool_size)
        self.rng = np.random.default_rng(cfg.seed)
        self.history: List[IterationRecord] = []
        # per-theta (B, eps) measurement history
        self.eps_hist: Dict[float, List[Tuple[int, float]]] = {
            t: [] for t in cfg.thetas}
        self.train_sizes: List[int] = []
        self.train_costs: List[float] = []
        self.delta = 0
        self.cstar_old: Optional[float] = None
        self.stable = False
        self.done = False
        # this campaign's own training spend: C* predictions compare
        # architectures as if each were running alone (the shared ledger
        # still collects every candidate's spend as the exploration tax)
        self.own_training = 0.0
        self.freeze_delta = False   # exploration keeps delta at delta0
        self.decision = "hybrid"
        self.B_opt = 0
        self.theta_opt = 0.0
        # k-center anchor cache: features of B under the CURRENT classifier
        # (invalidated every retrain, rebuilt from B_idx on demand/resume)
        self._anchor_feats: Optional[np.ndarray] = None
        # in-flight async M(.) sweep: (submitted_k, SweepFuture)
        self._pending: Optional[Tuple[int, object]] = None
        # in-flight async retrain + measurement: (|B| at submit, FitFuture)
        self._fit_pending: Optional[Tuple[int, object]] = None
        # memoized power-law/cost fits: (history key, laws, cost model)
        self._fit_models_cache: Optional[Tuple] = None
        # commit-sweep cursor wiring (set by the launcher, not MCALConfig:
        # these are process-local restart plumbing, not campaign policy)
        self.sweep_checkpoint_every = 0          # pages between cursor cuts
        self.on_sweep_checkpoint = None          # callback(SweepCheckpoint)
        self.resume_sweep_checkpoint = None      # cursor to resume from
        # straggler wall budgets for the async folds (seconds; None =
        # wait forever, the pre-resilience behavior).  Launcher-set
        # plumbing like the cursors above (--sweep-timeout/--fit-timeout)
        self.sweep_timeout = None
        self.fit_timeout = None
        self._iter = 0
        # campaign event bus (attach_trace): None = tracing off
        self.trace = None
        # runtime metrics registry (attach_metrics): None = metrics off
        self.metrics = None
        # chaos injector (attach_faults): None = injection off
        self.faults = None
        # streaming health engine (attach_health): None = monitoring off
        self.health = None

    def attach_trace(self, trace) -> None:
        """Wire the campaign event bus through every engine family: this
        driver's decision sites, the shared ledger's charging sites, the
        annotation broker (vote rounds, top-ups, quality snapshots), and
        the task's sweep/fit runtimes (cursor cuts, submit/fold
        timestamps).  Call before ``bootstrap``/``load_state_dict`` so
        the trace opens with the campaign's first event."""
        self.trace = trace
        self.pool.ledger.trace = trace
        self.pool.ledger.trace_name = "campaign"
        ann = getattr(self.task, "annotation", None)
        if ann is not None and hasattr(ann, "attach_trace"):
            ann.attach_trace(trace)
        if hasattr(self.task, "attach_trace"):
            self.task.attach_trace(trace)

    def attach_metrics(self, metrics) -> None:
        """Wire a runtime metrics registry (``repro.obs``) through the
        campaign: loop-phase spans (bootstrap/iteration/commit) here,
        engine hot-path telemetry via the task's ``attach_metrics``, and
        the annotation broker's queue/EM counters.  Orthogonal to
        :meth:`attach_trace` — metric events are OBSERVABILITY_KINDS, so
        an instrumented campaign's decision stream diffs clean against
        an uninstrumented sibling's."""
        self.metrics = metrics
        ann = getattr(self.task, "annotation", None)
        if ann is not None and hasattr(ann, "attach_metrics"):
            ann.attach_metrics(metrics)
        if hasattr(self.task, "attach_metrics"):
            self.task.attach_metrics(metrics)

    def attach_faults(self, faults, retry=None) -> None:
        """Wire a :class:`repro.faults.FaultInjector` (and optional
        :class:`~repro.faults.RetryPolicy`) through every fault site this
        campaign owns: the annotation request path (per-service or
        per-session), the task's sweep/fit broker workers, the trace
        store's flush path, and this driver's own mid-iteration kill
        point.  Call AFTER ``attach_trace``/``attach_metrics`` so fault/
        retry events ride the same surfaces.  All injected telemetry is
        OBSERVABILITY_KINDS — a chaos run whose retries succeed stays
        diff-clean against its fault-free sibling."""
        self.faults = faults
        if self.trace is not None:
            faults.attach_trace(self.trace)
            if hasattr(self.trace, "attach_faults"):
                self.trace.attach_faults(faults)
        if self.metrics is not None:
            faults.attach_metrics(self.metrics)
        ann = getattr(self.task, "annotation", None)
        if ann is not None and hasattr(ann, "attach_faults"):
            ann.attach_faults(faults, retry)
        if hasattr(self.task, "attach_faults"):
            self.task.attach_faults(faults, retry)

    def attach_health(self, health) -> None:
        """Wire a :class:`repro.obs.health.HealthEngine` to this
        campaign's iteration boundary: after every iteration the engine
        samples the ledger/fit state and emits its hysteresis-gated
        ``alert`` events.  Call AFTER ``attach_trace``/``attach_metrics``
        — the engine inherits this campaign's trace and registry unless
        it already has its own.  Alert kinds are OBSERVABILITY_KINDS, so
        a monitored campaign's decision stream diffs clean against a
        monitor-off sibling's."""
        self.health = health
        if health.trace is None and self.trace is not None:
            health.attach_trace(self.trace)
        if health.metrics is None and self.metrics is not None:
            health.attach_metrics(self.metrics)

    def _mspan(self, name: str):
        """A named campaign-phase span, or a no-op context when metrics
        are off (the ``trace is None`` convention, span-shaped)."""
        if self.metrics is None:
            return contextlib.nullcontext()
        return self.metrics.span(name)

    def _emit(self, kind: str, **payload) -> None:
        if self.trace is not None:
            self.trace.emit(kind, **_trace_sanitize(payload))

    # -- bootstrap ----------------------------------------------------------
    def bootstrap(self, *, adopt: bool = False):
        with self._mspan("bootstrap"):
            return self._bootstrap_impl(adopt=adopt)

    def _bootstrap_impl(self, *, adopt: bool = False):
        X = self.task.pool_size
        p = self.pool
        if self.trace is not None:
            # config = campaign policy (decisions must match across
            # sibling runs); runtime = execution mode (scheduling only,
            # normalized out by trace diff)
            cfgd = dataclasses.asdict(self.cfg)
            runtime = {"sweep_async": cfgd.pop("sweep_async"),
                       "fit_async": cfgd.pop("fit_async")}
            self._emit("campaign_begin", config=cfgd, runtime=runtime,
                       pool_size=int(X),
                       arch=getattr(self.task, "arch_name", ""))
        if not adopt:
            T_size = max(int(round(self.cfg.test_frac * X)), 16)
            p.T_idx = self.rng.choice(X, T_size, replace=False)
            p.is_test[p.T_idx] = True
            p.buy_labels(self.task, p.T_idx, self.service)
            delta0 = max(int(round(self.cfg.delta0_frac * X)), 8)
            b0 = self.rng.choice(p.unlabeled_candidates(), delta0,
                                 replace=False)
            p.in_B[b0] = True
            p.B_idx = b0
            p.buy_labels(self.task, b0, self.service)
        self.delta = len(p.B_idx)
        self._emit("bootstrap", T_size=int(len(p.T_idx)),
                   B_size=int(len(p.B_idx)), adopt=bool(adopt))
        self._train_and_measure()

    # -- internals ----------------------------------------------------------
    def _train_and_measure(self):
        p = self.pool
        self._anchor_feats = None   # the representation moves every retrain
        nB = len(p.B_idx)
        if self.cfg.fit_async and hasattr(self.task, "submit_train"):
            # Defer the retrain + its L(.) measurement sweep onto the fit
            # engine's worker thread: the retrain dispatch overlaps the
            # measurement's host-side paging, and in architecture
            # selection every candidate's retrain runs concurrently.
            # The training cost is paid UP FRONT (it must be known
            # without training — deterministic c_u * |B| pricing; a
            # measured-cost task falls through to the synchronous path),
            # so the shared ledger every sibling campaign's records and
            # bailout/budget checks read is never stale while the fit is
            # in flight.  _sync_fit() folds the measurement at the next
            # consumer (the top of iteration()/search()/commit()), so
            # iteration records are identical to the synchronous
            # campaign's.
            c = (self.task.train_cost(nB)
                 if hasattr(self.task, "train_cost") else None)
            if c is not None:
                self._pay_training(nB, c)
                T_idx, labels_T = p.T_idx, p.labels[p.T_idx]

                def measure():
                    stats_T, _ = self.task.score(T_idx)
                    return stats_T, self.task.eval_correct(T_idx, labels_T)

                self._fit_pending = (nB, self.task.submit_train(
                    p.B_idx, p.labels[p.B_idx], then=measure))
                return
        c = self.task.train(p.B_idx, p.labels[p.B_idx])
        self._pay_training(nB, c)
        stats_T, _ = self.task.score(p.T_idx)
        correct = self.task.eval_correct(p.T_idx, p.labels[p.T_idx])
        self._record_measurement(nB, stats_T, correct)

    def _pay_training(self, nB: int, c: float):
        p = self.pool
        p.ledger.pay_training(c)
        self.own_training += c
        self.train_sizes.append(nB)
        self.train_costs.append(c)

    def _record_measurement(self, nB: int, stats_T, correct):
        curve = sel.machine_label_error_curve(
            stats_T, correct, self.cfg.thetas, self.cfg.l_metric)
        for t, e in zip(self.cfg.thetas, curve):
            self.eps_hist[t].append((nB, float(e)))
        # emitted at fold time on the MAIN thread (under fit_async the
        # fold happens at the next consumer), so the decision stream is
        # position-identical to the synchronous campaign's
        self._emit("measure", B=int(nB),
                   eps={str(t): float(e)
                        for t, e in zip(self.cfg.thetas, curve)})

    def _sync_fit(self):
        """Fold an in-flight async retrain (``fit_async``): collect its
        measurement sweep from the worker and record it exactly as the
        synchronous path would have (the training cost was already paid
        at submit time)."""
        if self._fit_pending is None:
            return
        nB, fut = self._fit_pending
        self._fit_pending = None
        try:
            _c, (stats_T, correct) = fut.result(self.fit_timeout)
        except StragglerTimeout:
            if self.metrics is not None:
                self.metrics.inc("straggler_timeouts_total", engine="fit")
            raise
        self._record_measurement(nB, stats_T, correct)

    def _fit_models(self) -> Tuple[Dict[float, PowerLaw], TrainCostModel]:
        """Fit the per-theta truncated power laws + the training-cost
        model, memoized on the measurement-history key (iteration() reads
        the fits several times per loop, and a resumed campaign restores
        the persisted fits into this cache so it starts without refits)."""
        key = (len(self.train_sizes),
               sum(len(v) for v in self.eps_hist.values()))
        if self._fit_models_cache is not None \
                and self._fit_models_cache[0] == key:
            return self._fit_models_cache[1], self._fit_models_cache[2]
        laws = {}
        for t, pts in self.eps_hist.items():
            sizes = [s for s, _ in pts]
            errs = [e for _, e in pts]
            laws[t] = fit_power_law(sizes, errs,
                                    truncated=len(pts) >= self.cfg.min_fit_points)
        cm = TrainCostModel(exponent=self.cfg.cost_exponent).fit(
            self.train_sizes, self.train_costs)
        self._fit_models_cache = (key, laws, cm)
        # once per fresh measurement-history key (the memo guarantees
        # it), so state-saving and non-saving runs emit identically
        self._emit("powerlaw_fit", train_points=int(key[0]),
                   **_fitted_payload(laws, cm))
        return laws, cm

    # -- noisy-annotation economics ---------------------------------------
    def _quality(self) -> LabelQuality:
        return self.cfg.label_quality or LabelQuality()

    def _effective_service(self) -> LabelingService:
        """Future human labels priced repeats-inclusive: what every
        prediction (joint search, delta adaptation, bailout/budget
        thresholds) must use, or machine labeling looks worse than it is
        relative to a fictional one-vote-per-label service."""
        return self._quality().effective_service(self.service)

    def search(self, keep_surface: Optional[bool] = None) -> SearchResult:
        self._sync_fit()
        laws, cm = self._fit_models()
        p = self.pool
        kw = dict(pool_size=self.task.pool_size, test_size=len(p.T_idx),
                  current_B=len(p.B_idx), spent=self.own_training,
                  laws=laws, cost_model=cm, delta=self.delta,
                  service=self._effective_service())
        if self.cfg.budget is not None:
            res = budget_search(budget=self.cfg.budget, **kw)
        else:
            # residual aggregated-label error eats into the target: even
            # a perfect classifier measured against service labels cannot
            # beat the annotators, so the machine-label slice must clear
            # the rest
            res = joint_search(
                eps_target=self._quality().effective_target(
                    self.cfg.eps_target),
                keep_surface=self.cfg.keep_surface
                if keep_surface is None else keep_surface, **kw)
        self._emit("search", cost=res.cost, B_opt=int(res.B_opt),
                   theta_opt=float(res.theta_opt),
                   machine_labeled=int(res.machine_labeled),
                   feasible=bool(res.feasible),
                   human_all_cost=res.human_all_cost)
        return res

    # -- one loop body --------------------------------------------------------
    def iteration(self, *, acquire: bool = True,
                  forced_acquisition: Optional[np.ndarray] = None):
        with self._mspan("iteration"):
            rec = self._iteration_impl(acquire=acquire,
                                       forced_acquisition=forced_acquisition)
        if self.metrics is not None:
            self.metrics.inc("campaign_iterations_total")
            self.metrics.set_gauge("campaign_spent_total",
                                   float(self.pool.ledger.total))
        if self.health is not None:
            self.health.tick_campaign(self)
        return rec

    def _iteration_impl(self, *, acquire: bool = True,
                        forced_acquisition: Optional[np.ndarray] = None):
        assert not self.done
        if self.faults is not None:
            # the kill point sits BEFORE any mutation of this iteration
            # (and before the async-fit fold), so an InjectedKill here
            # leaves the campaign exactly at the previous iteration's
            # committed state — what the autosave sidecar persists
            self.faults.check("campaign.iteration")
        self._sync_fit()   # fold last iteration's async retrain first:
        p = self.pool      # everything below reads its params/measurement
        X = self.task.pool_size
        # async overlap: launch this iteration's M(.) sweep (device) before
        # the host-side power-law fits + joint search below; acquire()
        # synchronizes at the fold.  The sweep is submitted at the current
        # delta — prefix-stable rankings (top-k, greedy k-center) let
        # acquire() trim to any smaller final take; a larger adapted delta
        # falls back to a synchronous re-rank.
        self._pending = None
        if (acquire and forced_acquisition is None and self.cfg.sweep_async
                and self.cfg.metric != "random"
                and hasattr(self.task, "submit_candidates")):
            cand = p.unlabeled_candidates()
            k = min(self.delta, len(cand))
            if k > 0:
                anchors = (self._anchor_features()
                           if self.cfg.metric == "kcenter" else None)
                self._pending = (k, self.task.submit_candidates(
                    self.cfg.metric, k, cand, anchors=anchors))
        res = self.search()
        self.B_opt, self.theta_opt = res.B_opt, res.theta_opt

        # stability (line 19) + delta adaptation (line 20)
        stable_now = (self.cstar_old is not None and res.cost > 0 and
                      abs(res.cost - self.cstar_old) / res.cost
                      <= self.cfg.stability_tol)
        if stable_now:
            self.stable = True
        self.cstar_old = res.cost

        rec = IterationRecord(
            i=self._iter, B_size=len(p.B_idx), delta=self.delta,
            eps_theta={t: self.eps_hist[t][-1][1] for t in self.cfg.thetas},
            cstar=res.cost, B_opt=res.B_opt, theta_opt=res.theta_opt,
            feasible=res.feasible, stable=self.stable,
            human_spent=p.ledger.human, training_spent=p.ledger.training,
            search=res if self.cfg.keep_surface else None)
        self.history.append(rec)
        self._emit("iteration", **rec.to_dict())
        self._iter += 1

        if self.cfg.budget is not None:
            # budget variant: stop training when the next acquisition would
            # break the budget (reserve the residual human labels' worth).
            # Acquisition labels are priced repeats-inclusive.
            next_spend = (self.delta *
                          self._effective_service().price_per_label +
                          self._fit_models()[1].iteration_cost(
                              len(p.B_idx) + self.delta))
            if p.ledger.total + float(next_spend) > self.cfg.budget:
                self._finish("budget")
                self._drop_pending()
                return rec
        else:
            # bail-out (paper §5.1 footnote): exploration tax exceeded while
            # the classifier still cannot machine-label any meaningful
            # fraction (ImageNet behaviour) -> human-label everything.
            human_all = X * self._effective_service().price_per_label
            no_meaningful_S = (not res.feasible or res.theta_opt == 0.0 or
                               res.machine_labeled < self.cfg.bailout_min_s * X)
            if no_meaningful_S and \
                    p.ledger.training > self.cfg.bailout_frac * human_all:
                self.decision = "human_all"
                self._finish("bailout")
                self._drop_pending()
                return rec

        if self.stable and not self.freeze_delta:
            nd = adapt_delta(
                current_B=len(p.B_idx), B_opt=res.B_opt, cstar=res.cost,
                spent=self.own_training, pool_size=X, test_size=len(p.T_idx),
                machine_labeled=res.machine_labeled,
                cost_model=self._fit_models()[1],
                service=self._effective_service(), beta=self.cfg.beta)
            if nd > 0:
                self.delta = nd

        # Alg. 1 line 9: continue only while growing B is predicted to
        # reduce cost (C* < C(B_opt + delta) <=> B_opt > |B|).  Gated on the
        # fit having min_fit_points and a stable C* so one noisy early fit
        # cannot end the campaign at a bad |B|.  Exploration-frozen
        # campaigns (arch selection) never self-terminate.
        enough = len(self.train_sizes) >= self.cfg.min_fit_points
        if enough and self.stable and res.feasible and \
                res.B_opt <= len(p.B_idx) and not self.freeze_delta:
            self._finish("converged")
            self._drop_pending()
            return rec

        if self._iter >= self.cfg.max_iters:
            self._finish("max_iters")
            self._drop_pending()
            return rec

        if acquire:
            self.acquire(forced_acquisition)
        return rec

    def acquire(self, forced: Optional[np.ndarray] = None):
        """Buy delta labels ranked by M(.), retrain, re-measure.  If
        ``iteration`` launched an async ranking sweep, synchronize here
        (the fold) and trim its prefix-stable ranking to the final take."""
        p = self.pool
        cand = p.unlabeled_candidates()
        pending, self._pending = self._pending, None
        if len(cand) == 0:
            if pending is not None:
                pending[1].cancel()
            self._finish("pool_exhausted")
            return
        if forced is not None:
            if pending is not None:
                pending[1].cancel()
            pick = np.asarray(forced, np.int64)
        else:
            take = min(self.delta, len(cand))
            if self.stable and self.B_opt > len(p.B_idx):
                take = min(take, self.B_opt - len(p.B_idx))
            pick = None
            if pending is not None:
                if take <= pending[0]:
                    try:
                        out = pending[1].result(self.sweep_timeout)
                    except StragglerTimeout:
                        if self.metrics is not None:
                            self.metrics.inc("straggler_timeouts_total",
                                             engine="sweep")
                        raise
                    full = out[0] if isinstance(out, tuple) else out
                    pick = np.asarray(full[:take], np.int64)
                else:   # adapted delta outgrew the submitted sweep
                    pending[1].cancel()
            if pick is None:   # no sweep in flight, or delta grew past it
                pick = self._rank_candidates(take, cand)
        if self.trace is not None:
            # the full index set would dominate the trace; a CRC over the
            # ordered picks still pins the acquisition bit-exactly across
            # sibling runs (sync vs async must select identically)
            pick_arr = np.ascontiguousarray(np.asarray(pick, np.int64))
            self._emit("acquisition", n=int(len(pick_arr)),
                       digest=int(zlib.crc32(pick_arr.tobytes())),
                       forced=bool(forced is not None))
        p.buy_labels(self.task, pick, self.service)
        p.in_B[pick] = True
        p.B_idx = np.concatenate([p.B_idx, pick])
        self._train_and_measure()

    def _finish(self, reason: str):
        """End the loop; the ``done`` event records WHY (budget | bailout
        | converged | max_iters | pool_exhausted | fleet_ceiling |
        quarantined)."""
        self.done = True
        self._emit("done", reason=reason)

    def _drop_pending(self):
        """Cancel (best-effort) and forget an in-flight async M(.) sweep —
        early loop exits must not leave a pool sweep burning the device."""
        if self._pending is not None:
            self._pending[1].cancel()
            self._pending = None

    def _anchor_features(self) -> Optional[np.ndarray]:
        """k-center anchor set: features of the human-labeled set B under
        the CURRENT classifier (the covered set in the live representation
        space).  Cached per training round — the representation moves
        every retrain — and rebuilt from ``B_idx`` alone, so resumed
        campaigns recover it with one feature sweep."""
        p = self.pool
        if len(p.B_idx) == 0:
            return None
        if self._anchor_feats is None:
            if hasattr(self.task, "anchor_features"):
                self._anchor_feats = self.task.anchor_features(p.B_idx)
            else:
                self._anchor_feats = np.asarray(
                    self.task.score(p.B_idx)[1], np.float32)
        return self._anchor_feats

    def _rank_candidates(self, k: int, cand: np.ndarray) -> np.ndarray:
        """M(.): pick ``k`` of ``cand``.  Engine-backed tasks take sweep
        fast paths — uncertainty metrics via the paged device top-k sink
        (no pool-wide stats transfer), k-center via the device greedy
        farthest-point engine over sweep-emitted device features
        (``core.selection_device``); random and tasks without an engine
        fall back to the host reference path."""
        if k <= 0:
            return np.zeros((0,), np.int64)
        if self.cfg.metric in sel.UNCERTAINTY_METRICS and \
                hasattr(self.task, "topk_candidates"):
            return self.task.topk_candidates(self.cfg.metric, k, cand)
        if self.cfg.metric == "kcenter" and \
                hasattr(self.task, "kcenter_candidates"):
            pick, _ = self.task.kcenter_candidates(
                k, cand, anchors=self._anchor_features())
            return pick
        stats = feats = None
        if self.cfg.metric in sel.UNCERTAINTY_METRICS or \
                self.cfg.metric == "kcenter":
            stats, feats = self.task.score(cand)
        anchors = (self._anchor_features() if self.cfg.metric == "kcenter"
                   else None)
        return sel.select_for_training(
            self.cfg.metric, k, stats=stats, features=feats,
            candidates=cand, anchors=anchors, rng=self.rng)

    def propose_acquisition(self, k: int) -> np.ndarray:
        """Rank candidates by this campaign's M(.) without committing."""
        self._sync_fit()
        cand = self.pool.unlabeled_candidates()
        return self._rank_candidates(min(k, len(cand)), cand)

    def _machine_label(self, idx: np.ndarray):
        """L(.): one scoring sweep over ``idx`` -> (rows most-confident-
        first, machine labels row-aligned with ``idx``).  Sweep-capable
        tasks stream ``idx`` through the paged pool-sweep runtime (only
        the rank field + top1 per row reach the host); the predicted
        labels come from the same sweep's top1, so committing a campaign
        costs a single pool pass.  Cursor-capable tasks additionally cut a
        resumable ``SweepCheckpoint`` every ``sweep_checkpoint_every``
        pages (and resume one), so a preempted commit sweep restarts
        mid-pool from the launcher's ``--state`` file."""
        if hasattr(self.task, "machine_label_sweep"):
            kw = {}
            if self.sweep_checkpoint_every or \
                    self.resume_sweep_checkpoint is not None:
                kw = dict(checkpoint=self.resume_sweep_checkpoint,
                          checkpoint_every=self.sweep_checkpoint_every,
                          on_checkpoint=self.on_sweep_checkpoint)
                self.resume_sweep_checkpoint = None   # consumed
            order, pred = self.task.machine_label_sweep(
                idx, self.cfg.l_metric, **kw)
            return np.asarray(order, np.int64), np.asarray(pred, np.int64)
        stats, _ = self.task.score(idx)
        order = sel.rank_for_machine_labeling(stats, self.cfg.l_metric)
        return order, np.asarray(stats.top1, np.int64)

    # -- commit ----------------------------------------------------------------
    def commit(self) -> MCALResult:
        with self._mspan("commit"):
            return self._commit_impl()

    def _commit_impl(self) -> MCALResult:
        self._sync_fit()
        p = self.pool
        X = self.task.pool_size
        remaining = p.unlabeled_candidates()
        machine_mask = np.zeros(X, bool)

        if self.cfg.budget is not None and len(remaining):
            # afford as many residual human labels as the budget allows;
            # machine-label the most confident rest (accuracy is what gives)
            afford = max(self.cfg.budget - p.ledger.total, 0.0)
            n_human = min(
                int(afford / self._effective_service().price_per_label),
                len(remaining))
            m = len(remaining) - n_human
            order, pred = self._machine_label(remaining)
            S_idx = remaining[order[:m]]
            residual = remaining[order[m:]]
            if m:
                p.labels[S_idx] = pred[order[:m]]
                machine_mask[S_idx] = True
            p.buy_labels(self.task, residual, self.service)
            gt = oracle_labels(self.task, np.arange(X))
            return self._emit_commit(MCALResult(
                labels=p.labels.copy(), machine_mask=machine_mask,
                ledger=p.ledger.snapshot(), history=self.history,
                decision="budget", B_size=len(p.B_idx), S_size=int(m),
                theta_final=m / max(len(remaining), 1),
                measured_error=float(np.mean(p.labels != gt)),
                arch_name=getattr(self.task, "arch_name", "")))

        if self.decision == "human_all" or self.theta_opt <= 0.0 \
                or len(remaining) == 0:
            p.buy_labels(self.task, remaining, self.service)
            self.decision = "human_all"
            theta_final, S_size = 0.0, 0
        else:
            # measured (not predicted) feasibility at the final model
            stats_T, _ = self.task.score(p.T_idx)
            correct = self.task.eval_correct(p.T_idx, p.labels[p.T_idx])
            fine = np.linspace(0.01, 1.0, 100)
            curve = sel.machine_label_error_curve(
                stats_T, correct, fine, self.cfg.l_metric)
            S_frac = fine * len(remaining) / X
            # the human-labeled (1 - S/X) share carries the annotation
            # service's residual aggregated-label error; the machine slice
            # must fit in what is left of the target
            overall = S_frac * curve + \
                (1.0 - S_frac) * self._quality().residual_error
            ok = np.nonzero(overall <= self.cfg.eps_target)[0]
            theta_final = float(fine[ok[-1]]) if len(ok) else 0.0
            m = int(round(theta_final * len(remaining)))
            if m <= 0:
                p.buy_labels(self.task, remaining, self.service)
                self.decision = "human_all"
                theta_final, S_size = 0.0, 0
            else:
                order, pred = self._machine_label(remaining)
                S_idx = remaining[order[:m]]
                residual = remaining[order[m:]]
                p.labels[S_idx] = pred[order[:m]]
                machine_mask[S_idx] = True
                p.buy_labels(self.task, residual, self.service)
                S_size = m

        # evaluation oracle — NEVER human_label: with an annotation
        # service that would burn (uncharged) requests and compare against
        # noisy votes (see oracle_labels)
        gt = oracle_labels(self.task, np.arange(X))
        measured_error = float(np.mean(p.labels != gt))
        return self._emit_commit(MCALResult(
            labels=p.labels.copy(), machine_mask=machine_mask,
            ledger=p.ledger.snapshot(), history=self.history,
            decision=self.decision, B_size=len(p.B_idx), S_size=S_size,
            theta_final=theta_final, measured_error=measured_error,
            arch_name=getattr(self.task, "arch_name", "")))

    def _emit_commit(self, res: MCALResult) -> MCALResult:
        """The terminal decision event; flushed immediately — a campaign
        that committed must never lose its commit to the write buffer."""
        if self.trace is not None:
            self._emit("commit", **res.to_dict(with_history=False))
            self.trace.flush()
        return res

    def run(self) -> MCALResult:
        self.bootstrap()
        while not self.done:
            self.iteration()
        return self.commit()

    def close(self) -> None:
        """Idempotent campaign teardown: cancel any in-flight async sweep
        or retrain, then join the task's owned broker threads (shared
        fleet engines stay up — the fleet owns them).  A closed campaign
        keeps its results; only its async machinery is gone."""
        self._drop_pending()
        if self._fit_pending is not None:
            self._fit_pending[1].cancel()
            self._fit_pending = None
        if hasattr(self.task, "close"):
            self.task.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- campaign fault tolerance ------------------------------------------
    def state_dict(self) -> Dict:
        """JSON-serializable loop state: a preempted labeling campaign
        resumes mid-loop from this (the classifier itself is retrained from
        the persisted label set — labels are the expensive thing)."""
        self._sync_fit()
        p = self.pool
        fitted = None
        if self.train_sizes:
            laws, cm = self._fit_models()
            fitted = _fitted_payload(laws, cm)
        state = {
            # schema version: loaders reject anything newer than they
            # understand instead of failing on a missing/renamed key
            "version": STATE_VERSION,
            # fitted power-law/cost state + the engines' pack-shape compile
            # cache keys: a resumed paper-scale replay starts without
            # refits and prewarms its compiled programs upfront.
            "fitted": fitted,
            "pack_keys": (self.task.pack_cache_keys()
                          if hasattr(self.task, "pack_cache_keys")
                          else None),
            # the full iteration trace (minus any keep_surface search
            # payloads) + the acquisition RNG stream: a resumed campaign
            # reports the whole trajectory and --metric random draws
            # continue where the preempted stream stopped.
            "history": [r.to_dict() for r in self.history],
            "rng": self.rng.bit_generator.state,
            # annotation-service runtime state (None without a noisy
            # oracle): per-worker confusion estimates, the pending-request
            # cursor, and the repeats ledger — with the persisted label
            # store this is exactly what makes a preempted noisy-oracle
            # campaign replay future requests bit-identically.
            "annotation": (self.task.annotation.state_dict()
                           if getattr(self.task, "annotation", None)
                           is not None else None),
            "labels": p.labels.tolist(),
            "is_test": np.nonzero(p.is_test)[0].tolist(),
            "B_idx": p.B_idx.tolist(),
            "ledger": p.ledger.as_dict(),
            "eps_hist": {str(t): v for t, v in self.eps_hist.items()},
            "train_sizes": self.train_sizes,
            "train_costs": self.train_costs,
            "delta": self.delta,
            "cstar_old": self.cstar_old,
            "stable": self.stable,
            "own_training": self.own_training,
            "iter": self._iter,
            # decision state: a campaign resumed after bail-out must still
            # know it chose human_all (and an exploration-frozen campaign
            # that it is frozen) — these were silently dropped before.
            "done": bool(self.done),
            "decision": self.decision,
            "B_opt": int(self.B_opt),
            "theta_opt": float(self.theta_opt),
            "freeze_delta": bool(self.freeze_delta),
        }
        # the trace append cursor: flush FIRST so the persisted cursor
        # always points inside the file, then record where appends resume
        # (TraceStore.resume truncates anything the checkpoint never saw)
        if self.trace is not None:
            self._emit("state_save", iter=self._iter,
                       B_size=int(len(p.B_idx)))
            self.trace.flush()
            state["trace"] = {"next_seq": int(self.trace.next_seq)}
        else:
            state["trace"] = None
        return state

    def load_state_dict(self, s: Dict):
        v = int(s.get("version", 1))
        if v > STATE_VERSION:
            raise ValueError(
                f"campaign state has schema version {v}, but this build "
                f"understands at most version {STATE_VERSION} — it was "
                f"written by a newer repro package; resume with that "
                f"version (or re-run the campaign) instead")
        # fold any in-flight async retrain first: discarding its future
        # while the worker still trains would race the resume retrain
        # below on the same task/engine buffers
        self._sync_fit()
        p = self.pool
        p.labels = np.asarray(s["labels"], np.int64)
        p.is_test[:] = False
        p.is_test[np.asarray(s["is_test"], np.int64)] = True
        p.T_idx = np.asarray(s["is_test"], np.int64)
        p.B_idx = np.asarray(s["B_idx"], np.int64)
        p.in_B[:] = False
        p.in_B[p.B_idx] = True
        p.ledger = CostLedger.from_dict(s["ledger"])
        if self.trace is not None:
            # from_dict built a fresh ledger object: re-wire the bus so
            # post-resume charges keep emitting
            p.ledger.trace = self.trace
            p.ledger.trace_name = "campaign"
        ann = getattr(self.task, "annotation", None)
        if ann is not None and s.get("annotation") is not None:
            ann.load_state_dict(s["annotation"])
            if self.trace is not None and hasattr(ann, "attach_trace"):
                ann.attach_trace(self.trace)
        self.eps_hist = {float(t): [tuple(x) for x in v]
                         for t, v in s["eps_hist"].items()}
        self.train_sizes = list(s["train_sizes"])
        self.train_costs = list(s["train_costs"])
        self.delta = int(s["delta"])
        self.cstar_old = s["cstar_old"]
        self.stable = bool(s["stable"])
        self.own_training = float(s["own_training"])
        self._iter = int(s["iter"])
        # decision state (absent in pre-sweep checkpoints -> fresh defaults)
        self.done = bool(s.get("done", False))
        self.decision = str(s.get("decision", "hybrid"))
        self.B_opt = int(s.get("B_opt", 0))
        self.theta_opt = float(s.get("theta_opt", 0.0))
        self.freeze_delta = bool(s.get("freeze_delta", False))
        # iteration trace + acquisition RNG stream (absent in pre-PR4
        # checkpoints -> empty history / reseeded stream, as before)
        self.history = [IterationRecord.from_dict(r)
                        for r in s.get("history", [])]
        if "rng" in s:
            self.rng = np.random.default_rng()
            self.rng.bit_generator.state = s["rng"]
        self._pending = None
        self._fit_pending = None
        # restore the fitted power-law/cost state into the memo cache so
        # the first search() after resume runs without a single refit
        self._fit_models_cache = None
        fitted = s.get("fitted")
        if fitted:
            laws = {float(t): PowerLaw(
                alpha=f["alpha"], gamma=f["gamma"],
                k=np.inf if f["k"] is None else f["k"],
                resid_std=f["resid_std"], n_points=int(f["n_points"]))
                for t, f in fitted["laws"].items()}
            cm = TrainCostModel(c_u=fitted["cost_model"]["c_u"],
                                exponent=int(fitted["cost_model"]["exponent"]))
            key = (len(self.train_sizes),
                   sum(len(v) for v in self.eps_hist.values()))
            self._fit_models_cache = (key, laws, cm)
        # retrain the classifier on the persisted label set
        self._anchor_feats = None
        self.task.train(p.B_idx, p.labels[p.B_idx])
        # prewarm the engines' pack-shape compile caches (best understood
        # as paying the resumed campaign's compiles upfront)
        if hasattr(self.task, "prewarm_caches"):
            self.task.prewarm_caches(s.get("pack_keys"))
        if self.cfg.metric == "kcenter":
            # one feature sweep over B_idx rebuilds the k-center anchor
            # state under the freshly retrained classifier
            self._anchor_features()
        # observability only: replay filters this out, so a preempted-
        # and-resumed campaign's decision stream equals the continuous
        # run's (the resume retrain above charges nothing — its cost was
        # paid before the checkpoint)
        self._emit("resume", iter=self._iter, B_size=int(len(p.B_idx)))


def run_mcal(task, service: LabelingService,
             cfg: MCALConfig = MCALConfig(),
             trace: Optional[object] = None,
             metrics: Optional[object] = None,
             faults: Optional[object] = None,
             retry: Optional[object] = None,
             health: Optional[object] = None) -> MCALResult:
    camp = MCALCampaign(task, service, cfg)
    if trace is not None:
        camp.attach_trace(trace)
    if metrics is not None:
        camp.attach_metrics(metrics)
    if faults is not None:
        camp.attach_faults(faults, retry)
    if health is not None:
        # last: the engine inherits whatever trace/metrics are attached
        camp.attach_health(health)
    return camp.run()


def select_architecture(
    tasks: Dict[str, object], service: LabelingService,
    cfg: MCALConfig = MCALConfig(), max_explore_iters: int = 24,
) -> Tuple[str, MCALResult, Dict[str, List[IterationRecord]]]:
    """Paper §4 extension: explore all candidate classifiers over a shared
    pool until every campaign's C* stabilizes, then continue the argmin-C*
    campaign alone.  Labels are bought once; every candidate pays its own
    training cost into the shared ledger (the exploration tax)."""
    names = list(tasks)
    pool = SharedPool(tasks[names[0]].pool_size)
    camps = {n: MCALCampaign(tasks[n], service, cfg, shared=pool)
             for n in names}
    for c in camps.values():
        c.freeze_delta = True       # exploration stays at delta0
    camps[names[0]].bootstrap()
    for n in names[1:]:
        camps[n].bootstrap(adopt=True)

    def argmin_cstar():
        cs = {n: camps[n].cstar_old if camps[n].cstar_old is not None
              else np.inf for n in names}
        return min(cs, key=cs.get)

    rounds, leader_votes, last_leader = 0, 0, None
    while rounds < max_explore_iters:
        # leader rotates: its M(.) picks the next acquisition for everyone
        leader = camps[names[rounds % len(names)]]
        # elect early once the C* ranking is confidently settled: every
        # campaign has a fit and the argmin is unchanged 3 rounds running
        # ("trains each classifier up to the point where it is able to
        # confidently predict which architecture achieves the lowest cost")
        if all(c.stable for c in camps.values()) or leader_votes >= 3:
            break
        pick = leader.propose_acquisition(leader.delta)
        for i, n in enumerate(names):
            # every campaign adopts the same acquisition; only one mutates B
            camps[n].iteration(acquire=(i == 0), forced_acquisition=pick)
            if i == 0:
                continue
            camps[n]._train_and_measure()
        for c in camps.values():
            # fold async retrains before the election reads the histories
            # (with fit_async every candidate's retrain ran concurrently)
            c._sync_fit()
        cur = argmin_cstar()
        enough = all(len(c.train_sizes) >= cfg.min_fit_points
                     for c in camps.values())
        leader_votes = leader_votes + 1 if (enough and cur == last_leader) else 0
        last_leader = cur
        if any(c.done for c in camps.values()):
            break
        rounds += 1

    for c in camps.values():
        c._sync_fit()
    cstars = {n: camps[n].cstar_old if camps[n].cstar_old is not None
              else np.inf for n in names}
    winner = min(cstars, key=cstars.get)
    wc = camps[winner]
    wc.freeze_delta = False
    wc.stable = False   # re-establish C* stability in the continuation
    while not wc.done:
        wc.iteration()
    result = wc.commit()
    histories = {n: camps[n].history for n in names}
    return winner, result, histories
