"""Multi-tenant fleet accounting: many campaigns, one budget envelope.

One :class:`Tenant` is one :class:`~repro.core.mcal.MCALCampaign` plus
the fleet-facing state the orchestrator schedules it by: a priority, a
per-tenant budget allocation, its own trace, and the downgrade knobs the
:class:`FleetController` can turn when the FLEET (not the tenant)
overspends.

The controller rolls every tenant's campaign ledger into a fleet view
and enforces an optional hard global ceiling between scheduling rounds.
Over-ceiling relief is a criticality-ordered downgrade cascade — always
the same three passes, always walking tenants in ``(priority asc,
tenant_id asc)`` order, always stopping at the first state that fits
under the ceiling, so the same priority config produces the same
downgrade sequence every run (and the fleet trace replays it):

1. **pause** — the lowest-priority running tenants sit out the next
   scheduling round (acquisitions cost nothing while paused; pauses
   lift automatically at the next rebalance);
2. **shrink_votes** — tenants on a repeated-labeling policy get a
   halved-repeats, no-top-up session policy (future labels cost fewer
   priced votes; applied at most once per tenant);
3. **force_commit** — tenants are ended early (``done`` reason
   ``fleet_ceiling``), Pyrrhus-style: they commit with what they have.

Under-spenders subsidize over-askers first: surplus against per-tenant
allocations is pooled and granted in ``(priority desc, tenant_id asc)``
order before any downgrade runs, so a fleet that fits in aggregate
never downgrades anyone.

Everything the controller does is emitted into a FLEET trace (kinds
:data:`FLEET_KINDS` — a separate file from any tenant's decision
stream, which stays diffable against its solo-run sibling).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core.mcal import MCALCampaign, MCALConfig

# the fleet controller's own event vocabulary (fleet trace, not any
# tenant's): pass to trace.replay.diff(kinds=FLEET_KINDS) to assert two
# fleet runs made identical budget decisions
FLEET_KINDS = frozenset({
    "fleet_begin", "fleet_round", "redistribute", "downgrade",
    "quarantine", "fleet_done",
})

# the cascade, in relief order (least to most destructive)
DOWNGRADE_ACTIONS = ("pause", "shrink_votes", "force_commit")


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's fleet-facing contract.  ``priority`` orders the
    downgrade cascade (HIGHER survives longer); ``budget`` is this
    tenant's allocation inside the fleet envelope (None = uncapped, and
    the tenant neither contributes surplus nor receives grants)."""

    tenant_id: str
    priority: int = 0
    budget: Optional[float] = None
    seed: int = 0
    cfg: MCALConfig = MCALConfig()

    @classmethod
    def from_dict(cls, d: Dict) -> "TenantSpec":
        """The ``--tenants`` config-file form: MCALConfig fields ride in
        a nested ``cfg`` dict (unknown keys are rejected by the
        dataclass constructor, not silently dropped)."""
        cfg = MCALConfig(**d.get("cfg", {}))
        return cls(tenant_id=str(d["tenant_id"]),
                   priority=int(d.get("priority", 0)),
                   budget=(None if d.get("budget") is None
                           else float(d["budget"])),
                   seed=int(d.get("seed", 0)), cfg=cfg)


class Tenant:
    """One campaign inside a fleet: the campaign itself plus the
    scheduling/downgrade state the controller owns."""

    def __init__(self, spec: TenantSpec, campaign: MCALCampaign,
                 trace=None):
        self.spec = spec
        self.campaign = campaign
        self.trace = trace
        self.allocation = spec.budget       # moves under redistribution
        self.paused = False                 # one-round acquisition pause
        self.votes_shrunk = False           # shrink_votes applied
        self.forced = False                 # force_commit applied
        self.quarantined = False            # isolated after a fault
        self.quarantine_error = ""          # what ended it (for reports)
        self._shrink_ratio = 1.0            # projected label-price scale

    # -- identity ----------------------------------------------------------
    @property
    def tenant_id(self) -> str:
        return self.spec.tenant_id

    @property
    def priority(self) -> int:
        return self.spec.priority

    # -- fleet accounting --------------------------------------------------
    @property
    def spent(self) -> float:
        """This tenant's campaign-ledger total (the fleet roll-up sums
        exactly these — the service-side ledger is the same requests
        seen from the annotation endpoint, not extra money)."""
        return self.campaign.pool.ledger.total

    @property
    def done(self) -> bool:
        return self.campaign.done

    @property
    def running(self) -> bool:
        return not self.campaign.done

    def next_spend(self) -> float:
        """Projected cost of this tenant's NEXT scheduling round: delta
        labels at the effective (repeats-inclusive) price plus one
        retrain at the fitted per-iteration cost — the same projection
        the budget variant's stop rule uses, read from the memoized fit
        cache so projecting never emits a ``powerlaw_fit`` the solo run
        would not have."""
        c = self.campaign
        if c.done or self.paused:
            return 0.0
        delta = max(int(c.delta), 1)
        price = c._effective_service().price_per_label
        if self._shrink_ratio < 1.0:
            price *= self._shrink_ratio
        spend = delta * price
        cache = c._fit_models_cache
        if cache is not None:
            spend += float(cache[2].iteration_cost(
                len(c.pool.B_idx) + delta))
        return float(spend)

    # -- downgrade knobs (FleetController only) ----------------------------
    def apply_downgrade(self, action: str) -> bool:
        """Apply one cascade action; True iff it changed anything (the
        controller only emits — and only counts relief for — actions
        that actually landed)."""
        if not self.running:
            return False
        if action == "pause":
            if self.paused:
                return False
            self.paused = True
            return True
        if action == "shrink_votes":
            return self._shrink_votes()
        if action == "force_commit":
            if self.forced:
                return False
            self.forced = True
            self.campaign._drop_pending()
            self.campaign._finish("fleet_ceiling")
            return True
        raise ValueError(f"unknown downgrade action {action!r}")

    def _shrink_votes(self) -> bool:
        """Halve the tenant's repeated-labeling spend: swap the session
        policy for a ``max(1, repeats // 2)``-vote, no-top-up one.  Only
        meaningful for tenants on an :class:`AnnotationSession` with a
        multi-vote policy; applied at most once."""
        from repro.annotation.service import RepeatPolicy
        if self.votes_shrunk:
            return False
        ann = getattr(self.campaign.task, "annotation", None)
        if ann is None or not hasattr(ann, "set_policy"):
            return False
        pol = ann.policy
        if pol.cap <= 1:
            return False
        shrunk = max(1, pol.repeats // 2)
        ann.set_policy(RepeatPolicy(repeats=shrunk,
                                    aggregator=pol.aggregator))
        self.votes_shrunk = True
        self._shrink_ratio = shrunk / float(pol.cap)
        return True

    def close(self) -> None:
        self.campaign.close()


class FleetController:
    """The between-rounds budget authority over a tenant fleet.

    ``rebalance`` is called at every scheduling-round boundary (by the
    orchestrator, in serial and concurrent modes alike — at the same
    points, so its decisions are mode-independent): lift last round's
    pauses, redistribute surplus, then — if the projected fleet spend
    still breaches the global ceiling — run the downgrade cascade.
    Pure function of the tenants' ledgers and the priority config; every
    decision emits into the fleet trace."""

    def __init__(self, tenants: List[Tenant],
                 global_budget: Optional[float] = None, trace=None, *,
                 health=None, slo_enforce: bool = False):
        ids = [t.tenant_id for t in tenants]
        assert len(set(ids)) == len(ids), f"duplicate tenant ids: {ids}"
        self.tenants = list(tenants)
        self.global_budget = global_budget
        self.trace = trace
        self.round = 0
        # streaming health engine (repro.obs.health): ticked at every
        # rebalance boundary; with slo_enforce its ENFORCEABLE breach
        # verdicts drive the downgrade cascade (same walk order)
        self.health = health
        self.slo_enforce = bool(slo_enforce)
        self._slo_strikes: Dict[str, int] = {}
        if trace is not None:
            trace.emit("fleet_begin", ceiling=global_budget, tenants=[
                {"tenant_id": t.tenant_id, "priority": t.priority,
                 "budget": t.allocation} for t in self.tenants])

    def _emit(self, kind: str, **payload) -> None:
        if self.trace is not None:
            self.trace.emit(kind, **payload)

    # -- the fleet ledger roll-up ------------------------------------------
    def spent(self) -> float:
        return sum(t.spent for t in self.tenants)

    def projected(self) -> float:
        return sum(t.spent + t.next_spend() for t in self.tenants)

    def ledger_snapshot(self) -> Dict:
        """Fleet roll-up + per-tenant balances (the ``--report`` fleet
        view and the ``fleet_done`` payload)."""
        per = {t.tenant_id: dict(t.campaign.pool.ledger.snapshot(),
                                 allocation=t.allocation,
                                 priority=t.priority, paused=t.paused,
                                 votes_shrunk=t.votes_shrunk,
                                 forced=t.forced, done=t.done)
               for t in self.tenants}
        return {"ceiling": self.global_budget, "total": self.spent(),
                "projected": self.projected(), "tenants": per}

    # -- cascade order ------------------------------------------------------
    def _cascade_order(self) -> List[Tenant]:
        """Least critical first: (priority asc, tenant_id asc) — ties
        break on the id, so the order is total and config-deterministic."""
        return sorted((t for t in self.tenants if t.running),
                      key=lambda t: (t.priority, t.tenant_id))

    # -- the round boundary -------------------------------------------------
    def rebalance(self) -> Dict:
        """One round boundary: lift pauses, redistribute, downgrade if
        the ceiling is still breached.  Returns the round summary (also
        emitted as ``fleet_round``)."""
        for t in self.tenants:
            t.paused = False            # pauses last exactly one round
        self._redistribute()
        downgrades = []
        if self.global_budget is not None:
            downgrades = self._cascade()
        if self.health is not None:
            verdicts = self.health.tick_fleet(self.tenants,
                                              tick=self.round)
            if self.slo_enforce and verdicts:
                downgrades = downgrades + self._enforce_slo(verdicts)
        summary = {"round": int(self.round), "spent": float(self.spent()),
                   "projected": float(self.projected()),
                   "ceiling": self.global_budget,
                   "downgrades": downgrades}
        self._emit("fleet_round", **summary)
        self.round += 1
        return summary

    def _redistribute(self) -> None:
        """Under-spenders' surplus flows to over-askers before anyone is
        downgraded.  Surplus/need are measured against the per-tenant
        allocations (uncapped tenants sit out both sides); grants land
        in (priority desc, tenant_id asc) order — the most critical
        over-asker is topped up first."""
        capped = [t for t in self.tenants if t.allocation is not None]
        surplus = 0.0
        for t in sorted(capped, key=lambda t: (t.priority, t.tenant_id)):
            # a finished tenant's leftover allocation is the canonical
            # surplus (its next_spend is 0, so the same formula covers it)
            free = t.allocation - (t.spent + t.next_spend())
            if free > 0.0:
                surplus += free
                t.allocation -= free
        if surplus <= 0.0:
            return
        takers = sorted((t for t in capped if t.running),
                        key=lambda t: (-t.priority, t.tenant_id))
        for t in takers:
            need = (t.spent + t.next_spend()) - t.allocation
            if need <= 0.0:
                continue
            grant = min(need, surplus)
            if grant <= 0.0:
                break
            t.allocation += grant
            surplus -= grant
            self._emit("redistribute", round=int(self.round),
                       tenant=t.tenant_id, amount=float(grant),
                       remaining_pool=float(surplus))

    def _cascade(self) -> List[Dict]:
        """The criticality-ordered downgrade cascade: three passes,
        least-destructive first, each walking tenants least-critical
        first and stopping the moment the projection fits under the
        ceiling.  Deterministic by construction — the walk order is a
        pure function of the priority config, and each step's projection
        depends only on the tenants' ledgers."""
        applied: List[Dict] = []
        for action in DOWNGRADE_ACTIONS:
            if self.projected() <= self.global_budget:
                break
            for t in self._cascade_order():
                if self.projected() <= self.global_budget:
                    break
                if t.apply_downgrade(action):
                    ev = {"round": int(self.round),
                          "tenant": t.tenant_id, "action": action,
                          "projected": float(self.projected()),
                          "ceiling": float(self.global_budget)}
                    applied.append(ev)
                    self._emit("downgrade", **ev)
        return applied

    def _enforce_slo(self, verdicts: List[Dict]) -> List[Dict]:
        """``--slo-enforce``: breach verdicts drive the downgrade
        cascade.  Only ENFORCEABLE clauses count (the deterministic
        ledger/fit-derived ones — wall-clock latency breaches alert but
        never downgrade).  Breaching tenants are walked in the same
        ``(priority asc, tenant_id asc)`` order as the budget cascade;
        each consecutive breached rebalance escalates one cascade step —
        pause first, then shrink_votes, then force_commit — so a breach
        that a round of sitting out (or cheaper votes) cures never costs
        the tenant its campaign.  Verdicts are pure functions of the
        tenants' ledgers and fits, so the walk (hence the ``downgrade``
        event stream) is deterministic."""
        breached: Dict[str, str] = {}
        for v in verdicts:
            if v.get("enforceable"):
                breached.setdefault(v["tenant"], v["slo"])
        applied: List[Dict] = []
        for t in self._cascade_order():
            if t.tenant_id not in breached:
                continue
            strike = self._slo_strikes.get(t.tenant_id, 0)
            self._slo_strikes[t.tenant_id] = strike + 1
            for action in DOWNGRADE_ACTIONS[min(strike,
                                                len(DOWNGRADE_ACTIONS)
                                                - 1):]:
                if t.apply_downgrade(action):
                    ev = {"round": int(self.round),
                          "tenant": t.tenant_id, "action": action,
                          "slo": breached[t.tenant_id],
                          "projected": float(self.projected()),
                          "ceiling": (float(self.global_budget)
                                      if self.global_budget is not None
                                      else None)}
                    applied.append(ev)
                    self._emit("downgrade", **ev)
                    break
        return applied

    def resolve_stall(self) -> None:
        """Every running tenant is paused and the ceiling still binds:
        waiting cannot help (nothing gets cheaper while paused), so the
        orchestrator ends the stall by forcing the remaining tenants to
        commit, least-critical first — the cascade's terminal action,
        applied fleet-wide, still fully deterministic and traced."""
        for t in self._cascade_order():
            if t.apply_downgrade("force_commit"):
                self._emit("downgrade", round=int(self.round),
                           tenant=t.tenant_id, action="force_commit",
                           projected=float(self.projected()),
                           ceiling=(float(self.global_budget)
                                    if self.global_budget is not None
                                    else None))

    def quarantine(self, tenant: Tenant, error: BaseException,
                   phase: str = "iteration") -> bool:
        """Isolate a tenant whose round died on a TERMINAL resilience
        fault (retries exhausted, straggler wall budget blown) instead
        of nuking the fleet: its campaign ends with ``done`` reason
        ``quarantined`` (pending async work dropped), its remaining
        allocation flows into the next ``rebalance``'s surplus walk
        (a done tenant projects ``next_spend() == 0``, so the existing
        redistribution picks the headroom up unchanged), and the
        decision is emitted as a fleet-trace ``quarantine`` event.
        Sibling tenants' decision streams stay diffable against their
        solo runs — quarantine only ever REMOVES a spender.  Returns
        True iff this call performed the isolation (idempotent)."""
        if tenant.quarantined:
            return False
        tenant.quarantined = True
        tenant.quarantine_error = f"{type(error).__name__}: {error}"
        c = tenant.campaign
        c._drop_pending()
        if getattr(c, "_fit_pending", None) is not None:
            c._fit_pending[1].cancel()
            c._fit_pending = None
        if not c.done:
            c._finish("quarantined")
        self._emit("quarantine", round=int(self.round),
                   tenant=tenant.tenant_id, phase=phase,
                   error=tenant.quarantine_error)
        return True

    def finish(self) -> Dict:
        """Terminal fleet event: the final roll-up, flushed."""
        snap = self.ledger_snapshot()
        self._emit("fleet_done", **snap)
        if self.trace is not None:
            self.trace.flush()
        return snap


def downgrade_sequence(trace_path: str) -> List[Dict]:
    """The cascade as executed, read back from a fleet trace: ordered
    ``{round, tenant, action}`` records — the determinism assertion
    ("same priority config => same downgrade order") compares exactly
    this across runs."""
    from repro.trace.store import read_trace
    return [{"round": int(e.payload["round"]),
             "tenant": str(e.payload["tenant"]),
             "action": str(e.payload["action"])}
            for e in read_trace(trace_path) if e.kind == "downgrade"]
