"""Truncated power-law error model (paper Eqn. 3).

    eps(n) = alpha * n^(-gamma) * exp(-n / k)

The family is log-linear — ``log eps = c0 - c1*log n - c2*n`` with
``alpha = e^c0, gamma = c1, 1/k = c2`` — so the fit is a tiny (weighted)
linear least-squares with the sign constraints ``gamma >= 0, 1/k >= 0``
enforced by active-set clamping.  Cheap enough to refit every MCAL
iteration for every theta.  A plain power law (``k = inf``) is the Fig. 2
baseline.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

EPS_FLOOR = 1e-6


@dataclasses.dataclass(frozen=True)
class PowerLaw:
    alpha: float
    gamma: float
    k: float = np.inf          # truncation scale; inf -> plain power law
    resid_std: float = 0.0     # residual std in log space (fit quality)
    n_points: int = 0

    def predict(self, n) -> np.ndarray:
        n = np.maximum(np.asarray(n, np.float64), 1.0)
        out = self.alpha * n ** (-self.gamma)
        if np.isfinite(self.k):
            out = out * np.exp(-n / self.k)
        return out

    def __call__(self, n):
        return self.predict(n)


def _solve(X: np.ndarray, y: np.ndarray, w: np.ndarray) -> np.ndarray:
    sw = np.sqrt(w)
    coef, *_ = np.linalg.lstsq(X * sw[:, None], y * sw, rcond=None)
    return coef


def fit_power_law(
    sizes: Sequence[float],
    errors: Sequence[float],
    *,
    truncated: bool = True,
    weights: Optional[Sequence[float]] = None,
) -> PowerLaw:
    """Fit eps(n); clamps eps to a floor so perfect iterations stay finite.

    With fewer than 3 (truncated) / 2 (plain) points the fit degrades
    gracefully (constant, then pinned-slope).
    """
    n = np.asarray(sizes, np.float64)
    e = np.maximum(np.asarray(errors, np.float64), EPS_FLOOR)
    assert n.shape == e.shape and n.ndim == 1
    w = np.ones_like(n) if weights is None else np.asarray(weights, np.float64)
    y = np.log(e)
    ln = np.log(n)

    if len(n) == 1:
        return PowerLaw(alpha=float(e[0]), gamma=0.0, n_points=1)
    if len(n) == 2 or not truncated:
        X = np.stack([np.ones_like(ln), -ln], axis=1)
        c = _solve(X, y, w)
        gamma = max(c[1], 0.0)
        if gamma != c[1]:  # re-fit intercept only
            c0 = np.average(y, weights=w)
            c = np.array([c0, 0.0])
        resid = y - X @ np.array([c[0], gamma])
        return PowerLaw(alpha=float(np.exp(c[0])), gamma=float(gamma),
                        resid_std=float(np.std(resid)), n_points=len(n))

    # full 3-parameter truncated fit
    X = np.stack([np.ones_like(ln), -ln, -n], axis=1)
    c = _solve(X, y, w)
    gamma, inv_k = c[1], c[2]
    if gamma < 0 and inv_k < 0:
        c0 = np.average(y, weights=w)
        gamma, inv_k, c = 0.0, 0.0, np.array([c0, 0.0, 0.0])
    elif gamma < 0:      # drop the power term, keep exponential falloff
        X2 = np.stack([np.ones_like(ln), -n], axis=1)
        c2 = _solve(X2, y, w)
        gamma, inv_k = 0.0, max(c2[1], 0.0)
        c = np.array([c2[0], 0.0, inv_k])
    elif inv_k < 0:      # plain power law
        X2 = np.stack([np.ones_like(ln), -ln], axis=1)
        c2 = _solve(X2, y, w)
        gamma, inv_k = max(c2[1], 0.0), 0.0
        c = np.array([c2[0], gamma, 0.0])
    resid = y - (c[0] - gamma * ln - inv_k * n)
    k = 1.0 / inv_k if inv_k > 0 else np.inf
    return PowerLaw(alpha=float(np.exp(c[0])), gamma=float(gamma), k=float(k),
                    resid_std=float(np.std(resid)), n_points=len(n))


def required_size(law: PowerLaw, target_eps: float,
                  n_max: float = 1e9) -> float:
    """Smallest n with law(n) <= target_eps (inf if unreachable by n_max).

    Monotone-decreasing family -> bisection.
    """
    if law.predict(1.0) <= target_eps:
        return 1.0
    if law.predict(n_max) > target_eps:
        return np.inf
    lo, hi = 1.0, float(n_max)
    for _ in range(64):
        mid = 0.5 * (lo + hi)
        if law.predict(mid) <= target_eps:
            hi = mid
        else:
            lo = mid
    return hi
