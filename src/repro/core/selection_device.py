"""Device-resident greedy k-center (farthest-point) M(.) engine.

The host oracle ``selection.k_center_greedy`` walks the pool with a python
loop — one numpy sweep over all N rows per selected center, O(k * N * d)
with a host round-trip per center.  At paper pool sizes (ImageNet: 1.3M
rows) that loop is the last per-iteration MCAL hot path off-device.  This
module runs the same greedy recursion as ONE jit-compiled program:

* the pool is padded into ``(n_blocks, block, d)`` with the same
  power-of-two bucketing as the scoring engine's ``_pack``, so a shrinking
  candidate set re-uses O(log N) compiled programs across MCAL iterations
  (k is bucketed to the next power of two as well — greedy selection is
  prefix-stable, so computing a few extra centers and trimming to k
  changes nothing);
* a ``lax.fori_loop`` carries ``(min_d, chosen)``: per step one argmax
  over the running min-distances picks the farthest point, then the
  min-distances are updated from tiled distance blocks — the expansion
  ``||x - c||^2 = ||x||^2 - 2 x.c + ||c||^2``, so no (N, d) difference
  tensor is ever materialized and the inner product rides the MXU.  A
  pool that fits one row tile (``KCenterConfig.block``) sweeps as a
  single fused matvec; larger pools go tile-by-tile via ``lax.map`` so
  peak temporaries stay O(block) at ImageNet pool sizes;
* anchor initialization (features of already-labeled samples) is a real
  (N, M) tiled distance-matrix workload and routes through the
  ``kernels.ops.pairwise_sqdist`` gate — the Pallas ``pairwise_dist``
  kernel when the backend probe enables kernels, interpret mode on
  non-TPU hosts, the repo-wide convention.  The per-center in-loop
  update is a matvec — XLA already saturates it, so it stays on the jnp
  expansion.

Oracle-test contract (tests/test_selection_device.py)
-----------------------------------------------------

The engine must return the EXACT chosen-index sequence of the host oracle
— not approximately, not as a set-overlap score — across seeded grids of
(N, d, k, anchors, duplicate rows).  Two details make that a sound,
testable contract rather than a float-rounding lottery:

* tie-breaking is pinned: both engines take the FIRST index attaining the
  max min-distance (``argmax`` first-occurrence, numpy and XLA agree), so
  duplicate rows / equidistant points resolve identically;
* the test grids use integer-valued float32 features small enough that
  every squared distance is exactly representable, so the host's direct
  ``sum((x - c)^2)`` and the device's MXU expansion produce bit-equal
  distances and the argmax walks are identical.  On arbitrary real-valued
  features the two paths can round differently near exact ties; MCAL's
  acquisition is indifferent to which of two equidistant points it buys,
  but the *test* harness pins the stronger exact contract.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.scoring import next_pow2 as _next_pow2
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class KCenterConfig:
    block: int = 65536             # row tile for min-distance updates
    use_kernel: Optional[bool] = None   # None -> backend probe (ops.use_pallas)


def _make_dist_sweep(X: jax.Array, block: int):
    """Build the per-center distance sweep ``dist(c) -> (Np,)`` over the
    padded pool, with row sqnorms hoisted out of the greedy loop.

    A pool that fits one row tile runs as a single fused matvec (the fast
    path — sequential ``lax.map`` tiles and the reshape round-trip both
    measurably slow a CPU host); larger pools sweep tile-by-tile so peak
    temporaries stay O(block) regardless of N (the ImageNet-scale
    regime)."""
    Np, d = X.shape
    if Np <= block:
        x2 = jnp.sum(X * X, axis=-1)

        def dist(c):
            return jnp.maximum(x2 - 2.0 * (X @ c) + jnp.dot(c, c), 0.0)

        return dist

    Xb = X.reshape(Np // block, block, d)
    x2b = jnp.sum(Xb * Xb, axis=-1)

    def dist(c):
        c2 = jnp.dot(c, c)

        def blk(args):
            xb, x2 = args
            return jnp.maximum(x2 - 2.0 * (xb @ c) + c2, 0.0)

        return jax.lax.map(blk, (Xb, x2b)).reshape(-1)

    return dist


@functools.partial(
    jax.jit, static_argnames=("k", "block", "has_anchors"))
def _kcenter_padded(X, n, mind0, *, k: int, block: int, has_anchors: bool):
    """X: (Np, d) padded pool; n: true row count; mind0: (Np,) initial
    min-distances (+inf rows, or min-over-anchors when ``has_anchors``).
    Returns the (k,) chosen row indices, host-oracle-identical."""
    Np, d = X.shape
    dist = _make_dist_sweep(X, block)
    valid = jnp.arange(Np) < n
    min_d = jnp.where(valid, mind0, -jnp.inf)

    first = jnp.argmax(min_d) if has_anchors else jnp.int32(0)
    chosen = jnp.zeros((k,), jnp.int32).at[0].set(first)
    min_d = jnp.minimum(
        min_d, jnp.where(valid, dist(X[first]), -jnp.inf))

    def body(i, carry):
        min_d, chosen = carry
        j = jnp.argmax(min_d)
        chosen = chosen.at[i].set(j)
        return (jnp.minimum(min_d, jnp.where(valid, dist(X[j]), -jnp.inf)),
                chosen)

    min_d, chosen = jax.lax.fori_loop(1, k, body, (min_d, chosen))
    return chosen


@functools.partial(jax.jit, static_argnames=("block", "use_kernel"))
def _anchor_min_dist(X, A, m, *, block: int, use_kernel: bool):
    """(Np,) min squared distance to the first ``m`` rows of the padded
    anchor matrix ``A`` — the tiled (N, M) distance-matrix leg.

    The column-min folds per row tile, so peak distance temporaries are
    O(block * Ma) however large the pool.  Both branches go through the
    ``ops.pairwise_sqdist`` gate (Pallas kernel — interpret mode off-TPU
    — or the jnp reference) so the distance expansion exists in exactly
    one place per path and cannot drift from the oracle contract."""
    Ma = A.shape[0]
    amask = jnp.arange(Ma) < m

    def blk(xb):
        d = ops.pairwise_sqdist(xb, A, force_pallas=use_kernel)
        return jnp.min(jnp.where(amask[None, :], d, jnp.inf), axis=1)

    Xb = X.reshape(-1, block, X.shape[1])
    if Xb.shape[0] == 1:
        return blk(Xb[0])
    return jax.lax.map(blk, Xb).reshape(-1)


def k_center_greedy_device(features, k: int, anchors=None,
                           cfg: KCenterConfig = KCenterConfig(),
                           metrics=None) -> np.ndarray:
    """Drop-in device twin of ``selection.k_center_greedy``.

    ``features``: (N, d) array (host numpy or device-resident — e.g. the
    scoring engine's feature emission, which never leaves the device);
    ``anchors``: (M, d) features of already-selected/labeled samples.
    Returns (k,) row indices into ``features`` as host int64.
    ``metrics`` (a ``repro.obs.MetricsRegistry``) wraps the greedy loop
    in a ``kcenter`` span; None keeps the call un-instrumented.
    """
    if metrics is not None:
        # the asarray fetch at the end already syncs, so the span covers
        # the device loop's real time, not just dispatch
        with metrics.span("kcenter"):
            return _kcenter_host(features, k, anchors, cfg)
    return _kcenter_host(features, k, anchors, cfg)


def _kcenter_host(features, k: int, anchors,
                  cfg: KCenterConfig) -> np.ndarray:
    X = jnp.asarray(features, jnp.float32)
    N, d = X.shape
    k = int(min(k, N))
    if k <= 0:
        return np.zeros((0,), np.int64)

    use_kernel = (ops.use_pallas() if cfg.use_kernel is None
                  else cfg.use_kernel)

    # pow2-bucketed padding, mirroring PoolScoringEngine._pack
    if N >= cfg.block:
        block = cfg.block
        nb = _next_pow2(math.ceil(N / block))
    else:
        block = max(_next_pow2(N), 8)
        nb = 1
    Np = nb * block
    if Np != N:
        X = jnp.pad(X, ((0, Np - N), (0, 0)))

    has_anchors = anchors is not None and len(anchors) > 0
    if has_anchors:
        A = jnp.asarray(anchors, jnp.float32)
        m = A.shape[0]
        Ma = max(_next_pow2(m), 8)
        if Ma != m:
            A = jnp.pad(A, ((0, Ma - m), (0, 0)))
        mind0 = _anchor_min_dist(X, A, m, block=block,
                                 use_kernel=use_kernel)
    else:
        mind0 = jnp.full((Np,), jnp.inf, jnp.float32)

    chosen = _kcenter_padded(
        X, N, mind0, k=min(_next_pow2(k), Np), block=block,
        has_anchors=has_anchors)
    return np.asarray(chosen[:k], np.int64)
