"""MCAL — Minimum Cost Human-Machine Active Labeling (the paper's core).

Public API:
    run_mcal(task, service, cfg)      one campaign -> MCALResult
    select_architecture(tasks, ...)   multi-classifier variant
    MCALConfig / MCALCampaign         driver
    fit_power_law / PowerLaw          Eqn. 3 error model
    TrainCostModel / LabelingService  Eqn. 4 + $ models
    joint_search / budget_search      (|B|, theta) optimization
    PoolScoringEngine                 device-resident pool-scoring sweep
    k_center_greedy_device            device-resident k-center M(.) engine
    TenantSpec / Tenant / FleetController   multi-tenant fleet accounting
"""
from repro.core.cost import (AMAZON, SATYAM, SERVICES, CostLedger,
                             LabelQuality, LabelingService, TrainCostModel)
from repro.core.emulator import EmulatedTask, make_emulated_task
from repro.core.mcal import (MCALCampaign, MCALConfig, MCALResult,
                             SharedPool, run_mcal, select_architecture)
from repro.core.powerlaw import PowerLaw, fit_power_law, required_size
from repro.core.search import (SearchResult, adapt_delta, budget_search,
                               joint_search)
from repro.core.scoring import (PoolScoringEngine, ScoringConfig,
                                score_pool_reference)
from repro.core.selection_device import (KCenterConfig,
                                         k_center_greedy_device)
from repro.core.task import LiveTask
from repro.core.tenant import (FLEET_KINDS, FleetController, Tenant,
                               TenantSpec, downgrade_sequence)
from repro.core.worker import SerialWorker, WorkerClosed
from repro.core import selection  # noqa: F401
