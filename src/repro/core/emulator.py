"""Learning-curve emulator: paper-scale MCAL replay without GPUs/datasets.

The container cannot train ResNet18 on CIFAR for real, so the §5 benchmark
replays drive the *identical* MCAL driver against an emulated task whose
ground truth follows the paper's own modeling assumption — a truncated
power law (Eqn. 3) per machine-label fraction:

    per-sample error prob   p(u; B) = (q+1) * u^q * eps_full(B)
    =>  eps_theta(B) = eps_full(B) * theta^q        (error of top-theta slice)

where ``u`` in [0, 1] is the sample's latent confidence quantile (hardness),
``eps_full`` is the model's full-pool generalization-error power law, and
``q`` concentrates errors in the low-confidence tail (Fig. 5's behaviour:
margin-ranked confident samples are near-perfect).  The classifier's margin
is emulated as ``1 - u`` plus ranking noise, so MCAL's entire measurement
machinery (rank test set by margin, measure error of top-theta slice, fit
truncated power laws) runs unchanged.

Correctness draws are deterministic per (seed, sample, training size) so
repeated scoring of the same model is consistent.

Calibrations at the bottom map the paper's (dataset x architecture) grid to
(alpha, gamma, k, q, c_u) tuples chosen to match the paper's reported error
levels and training-cost magnitudes (Tbl. 1-2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.powerlaw import PowerLaw
from repro.core.scoring import stats_from_confidence
from repro.models.layers import ScoreStats


@dataclasses.dataclass
class EmulatedTask:
    pool_size: int
    num_classes: int
    law: PowerLaw                 # eps_full(B): full-pool generalization error
    q: float = 2.0                # confidence concentration (eps_theta ~ theta^q)
    c_u: float = 0.004            # $ per sample-iteration (fixed-epoch retrain)
    rank_noise: float = 0.02      # emulated margin-ranking imperfection
    arch_name: str = "emulated"
    seed: int = 0
    min_train: int = 8
    sweep_page: int = 65536       # pool-sweep page rows (L(.)/commit pass)
    annotation: Optional[object] = None  # AnnotationService: route
                                  # human_label through a noisy multi-
                                  # annotator oracle (None = perfect)

    def __post_init__(self):
        root = np.random.default_rng(self.seed)
        # latent per-sample confidence quantile (hardness)
        self.u = root.permutation(self.pool_size) / max(self.pool_size - 1, 1)
        self.labels_gt = root.integers(0, self.num_classes, self.pool_size)
        self._B = 0
        self.trace = None   # campaign event bus (attach_trace)

    def attach_trace(self, trace) -> None:
        """Forward the campaign event bus to the per-call sweep runners
        (this task builds one per ``machine_label_sweep``)."""
        self.trace = trace

    # -- annotation service ------------------------------------------------
    def human_label(self, idx: np.ndarray) -> np.ndarray:
        """Purchased human labels — aggregated noisy-annotator votes when
        an :attr:`annotation` service is attached (the buyer charges per
        vote through ``CostLedger.pay_human``), perfect ground truth
        otherwise (the paper's assumption)."""
        idx = np.asarray(idx, np.int64)
        gt = self.labels_gt[idx]
        if self.annotation is not None:
            return self.annotation.annotate(idx, gt)
        return gt

    def oracle_labels(self, idx: np.ndarray) -> np.ndarray:
        """TRUE labels for evaluation only (never charged, never noisy)."""
        return self.labels_gt[np.asarray(idx, np.int64)]

    # -- training -----------------------------------------------------------
    def train(self, idx: np.ndarray, labels: np.ndarray) -> float:
        n = len(idx)
        self._B = n
        return self.c_u * n

    # -- the emulated classifier -------------------------------------------
    def _err_prob(self, u: np.ndarray) -> np.ndarray:
        B = max(self._B, self.min_train)
        eps = float(self.law.predict(B))
        return np.minimum((self.q + 1.0) * u ** self.q * eps, 1.0)

    def _wrong(self, idx: np.ndarray) -> np.ndarray:
        """Deterministic per (seed, sample, B) misclassification draw."""
        idx = np.asarray(idx, np.int64)
        rng = np.random.Generator(np.random.Philox(key=self.seed + 7919 * self._B))
        r = rng.random(self.pool_size)[idx]
        return r < self._err_prob(self.u[idx])

    def score(self, idx: np.ndarray) -> Tuple[ScoreStats, np.ndarray]:
        idx = np.asarray(idx, np.int64)
        rng = np.random.Generator(
            np.random.Philox(key=self.seed + 104729 + 7919 * self._B))
        noise = rng.normal(0.0, self.rank_noise, self.pool_size)[idx]
        conf = 1.0 - self.u[idx] + noise
        stats = stats_from_confidence(conf, self.num_classes,
                                      self.predict(idx))
        feats = np.stack([conf, self.u[idx]], axis=1)
        return stats, feats

    def machine_label_sweep(self, idx: np.ndarray, metric: str = "margin",
                            *, checkpoint=None, checkpoint_every: int = 0,
                            on_checkpoint=None):
        """L(.)/commit pass through the same paged sweep runtime the live
        path uses (host adapter, ``sweep_page`` rows per page), so paper-
        scale replays exercise the cursor/sink machinery without a device
        in the loop.  Per-sample draws are deterministic per global index,
        so the paged fold is exactly the full-pool ranking.  Cursor
        kwargs mirror ``LiveTask.machine_label_sweep`` (replay campaigns
        driven through the launcher's ``--state`` file resume a preempted
        commit sweep mid-pool)."""
        from repro.serving.sweep import (HostTaskAdapter, PoolSweepRunner,
                                         RankTop1Sink, SweepConfig)
        runner = PoolSweepRunner(HostTaskAdapter(self.score),
                                 SweepConfig(page_rows=self.sweep_page))
        runner.trace = self.trace
        return runner.run(None, np.asarray(idx, np.int64),
                          RankTop1Sink(metric), checkpoint=checkpoint,
                          checkpoint_every=checkpoint_every,
                          on_checkpoint=on_checkpoint)

    def kcenter_candidates(self, k: int, candidates: np.ndarray,
                           anchors: Optional[np.ndarray] = None):
        """Device k-center M(.) over the emulated feature space — same
        fast path the engine-backed LiveTask takes, so paper-scale replay
        campaigns exercise ``core.selection_device`` at pool size."""
        from repro.core.selection_device import k_center_greedy_device
        _, feats = self.score(candidates)
        rows = k_center_greedy_device(feats, k, anchors=anchors)
        picked = np.asarray(candidates, np.int64)[rows]
        return picked, np.asarray(feats, np.float32)[rows]

    def predict(self, idx: np.ndarray) -> np.ndarray:
        idx = np.asarray(idx, np.int64)
        wrong = self._wrong(idx)
        pred = self.labels_gt[idx].copy()
        pred[wrong] = (pred[wrong] + 1) % self.num_classes
        return pred

    def eval_correct(self, idx: np.ndarray, labels: np.ndarray) -> np.ndarray:
        return self.predict(idx) == np.asarray(labels)


# ---------------------------------------------------------------------------
# paper calibrations (dataset x architecture)
# ---------------------------------------------------------------------------
# eps_full laws calibrated to published learning-curve levels:
#   Fashion-MNIST/Res18:  ~8% err @ 4k,  ~5% @ 60k
#   CIFAR-10/Res18:       ~22% @ 4k, ~9% @ 20k, ~6% @ 50k
#   CIFAR-100/Res18:      ~60% @ 4k, ~30% @ 20k, ~22% @ 50k
# c_u from the paper's economics: Res18 CIFAR training spend ~\$90 at
# |B|=11k, delta=3.3k (see DESIGN.md) -> c_u ~ 0.004 $/sample-iteration.
# CNN18 trains ~3x cheaper but generalizes worse; Res50 ~3x costlier,
# slightly better.  EfficientNet-B0/ImageNet: 60-200x Res18's cost.

# ``pool`` is the train split MCAL labels; ``full`` (train + canonical test
# split) is what the paper's "Human Cost" rows price (70k x $0.04 = $2800
# for Fashion, 60k x $0.04 = $2400 for CIFAR), so savings are computed
# against ``full`` x price.
DATASETS: Dict[str, Dict] = {
    "fashion": {"pool": 60_000, "full": 70_000, "classes": 10},
    "cifar10": {"pool": 50_000, "full": 60_000, "classes": 10},
    "cifar100": {"pool": 50_000, "full": 60_000, "classes": 100},
    "imagenet": {"pool": 1_200_000, "full": 1_331_167, "classes": 1000},
}

# (alpha, gamma, k, q, c_u) — chosen so the analytic optimum of the
# emulated objective lands on the paper's Table 1/2 operating points
# (see EXPERIMENTS.md §Paper-claims for the calibration check):
#   cifar10/res18  -> B~22%, S~64%, cost ~$810 (paper: 22.2%, 65%, $792)
#   fashion/res18  -> B~4%,  S~84%, cost ~$404 (paper: 6.1%, 85%, $400)
#   cifar100/res18 -> cost ~$1729           (paper: $1698)
# cnn18 = cheaper-but-weaker, res50 = stronger-but-3x-costlier (Fig. 8-10).
CALIBRATIONS: Dict[Tuple[str, str], Tuple[float, float, float, float, float]] = {
    ("fashion", "cnn18"):    (3.30, 0.28, 4e5, 4.8, 0.0013),
    ("fashion", "resnet18"): (1.50, 0.35, 4e5, 6.0, 0.0040),
    ("fashion", "resnet50"): (1.40, 0.355, 4e5, 6.0, 0.0120),
    ("cifar10", "cnn18"):    (35.0, 0.44, 2e5, 1.0, 0.0013),
    ("cifar10", "resnet18"): (16.0, 0.55, 2e5, 1.2, 0.0040),
    ("cifar10", "resnet50"): (14.5, 0.56, 2e5, 1.2, 0.0120),
    ("cifar100", "cnn18"):   (198., 0.32, 2e5, 1.0, 0.0013),
    ("cifar100", "resnet18"): (90.0, 0.40, 2e5, 1.2, 0.0040),
    ("cifar100", "resnet50"): (82.0, 0.405, 2e5, 1.2, 0.0120),
    # ImageNet/EffNet-B0: 1000-class confidences are poorly concentrated
    # (q ~ 0.2) and training is ~20-200x Res18's cost, so machine labeling
    # never pays; MCAL must bail out to human-all after the exploration tax
    # (paper §5.1 — their run explored up to 454K images first).
    ("imagenet", "efficientnet-b0"): (5.2, 0.25, 1e7, 0.2, 0.08),
}


def make_emulated_task(dataset: str, arch: str, *, seed: int = 0,
                       pool_size: Optional[int] = None,
                       rank_noise: float = 0.02,
                       sweep_page: int = 65536) -> EmulatedTask:
    d = DATASETS[dataset]
    alpha, gamma, k, q, c_u = CALIBRATIONS[(dataset, arch)]
    return EmulatedTask(
        pool_size=pool_size or d["pool"], num_classes=d["classes"],
        law=PowerLaw(alpha=alpha, gamma=gamma, k=k), q=q, c_u=c_u,
        rank_noise=rank_noise, arch_name=arch, seed=seed,
        sweep_page=sweep_page)
