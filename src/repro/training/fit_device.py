"""Device-resident retrain engine — MCAL's per-iteration training hot path.

Every MCAL iteration retrains the classifier from scratch on the labeled
set for a fixed number of epochs (per-iteration cost proportional to |B|,
Eqn. 4).  The seed implementation (``LiveTask.train``) ran this as a
per-step Python host loop: a host permutation per epoch, a numpy batch
gather + one host-to-device upload + one jitted-step dispatch per batch,
blocking at every step.  This engine runs the ENTIRE fixed-epoch retrain
as ONE jit-compiled device program:

* the labeled set ``(x, y)`` is padded once with the engine's pow2
  bucketing and uploaded once (or kept **campaign-resident** across MCAL
  iterations with only the newly bought labels scattered in —
  :meth:`FitEngine.extend_resident` / :meth:`FitEngine.fit_resident`);
* epoch shuffles come from ``jax.random.permutation`` inside the program
  (:func:`epoch_orders`): a permutation of the PADDED row range is cut per
  epoch and its valid (< n) entries are stably partitioned to the front,
  so the first-n prefix is a uniform permutation of the true rows while
  every shape stays static;
* ``epochs x steps`` are fused into a single ``lax.scan`` over the train
  step; the ragged tail of each epoch wraps into the front of the SAME
  epoch's permutation (``(s*bs + arange(bs)) % n``) exactly like the host
  loop's wrap, so padding rows are never trained on and no masked loss is
  needed;
* the train state is donated into the program (where the backend supports
  donation) and threaded through the scan carry;
* ``(n, batch)`` is bucketed through the same :func:`scoring.pack_shape`
  convention as every other device engine (``(steps_per_epoch, bs) =
  pack_shape(n, batch_size)``, padded pool = ``steps_per_epoch * bs``
  rows), so successive MCAL iterations with growing |B| reuse O(log N)
  compiled programs instead of recompiling every retrain.

The per-step host loop survives as :meth:`FitEngine.fit_reference` — the
exact-agreement oracle (same permutation sequence -> bit-identical params
and per-step losses on a CPU host; tests/test_fit_device.py) and the
baseline ``benchmarks/bench_fit.py`` enforces the >= 2x gate over.

:meth:`FitEngine.submit_fit` mirrors ``PoolSweepRunner.submit``: the fit
runs on the engine's worker thread and the caller synchronizes at
``result()``, so ``MCALCampaign._train_and_measure`` overlaps the retrain
dispatch with the L(.) measurement sweep (and, in architecture selection,
every candidate's retrain runs concurrently).

With a mesh, the program is jit-compiled with the same state shardings
``make_sharded_train_step`` derives (``state_pspecs`` over the logical-axis
trees) and the mesh-aware raw step, so the fused retrain data-parallelizes
without changing the scan.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.worker import SerialWorker

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs.base import TrainConfig
from repro.core.scoring import pack_shape
from repro.distributed import sharding as shd
# the sweep runtime's future wrapper, shared rather than mirrored so
# worker-handle hardening lands in one place
from repro.serving.sweep import SweepFuture as FitFuture
from repro.training.train_loop import (init_train_state, make_train_step,
                                       state_pspecs)


def fit_plan(n: int, batch_size: int) -> Tuple[int, int, int]:
    """The engine's schedule for an ``n``-row labeled set:
    ``(steps_per_epoch, bs, n_pad)`` with ``n_pad = steps_per_epoch * bs``
    — the :func:`scoring.pack_shape` pow2 bucketing, so the compile-cache
    key set stays O(log N) as |B| grows across MCAL iterations.  One epoch
    sweeps the padded row count (every sample is visited at least once per
    epoch; the ragged tail wraps into the front of the epoch's
    permutation)."""
    spe, bs = pack_shape(n, batch_size)
    return spe, bs, spe * bs


def epoch_orders(key_data: jax.Array, epochs: int, n_pad: int,
                 n: jax.Array) -> jax.Array:
    """(epochs, n_pad) int32 row orders: per epoch, a
    ``jax.random.permutation`` of the padded row range with its valid
    (< n) entries stably partitioned to the front — the first-n prefix is
    a uniform random permutation of the true rows, computed entirely with
    static shapes (``n`` stays a traced scalar).  Shared verbatim by the
    fused scan and the reference host loop, so both consume the identical
    permutation sequence."""
    key = jax.random.wrap_key_data(key_data)

    def one(e):
        perm = jax.random.permutation(jax.random.fold_in(key, e), n_pad)
        return perm[jnp.argsort(perm >= n, stable=True)]

    return jax.vmap(one)(jnp.arange(epochs))


# one shared jitted wrapper (static epochs/n_pad) so the reference loop's
# permutation program caches across retrains like the fused path's does
_epoch_orders_jit = jax.jit(epoch_orders, static_argnums=(1, 2))




@dataclasses.dataclass(frozen=True)
class FitConfig:
    epochs: int = 40
    batch_size: int = 256
    donate_state: bool = True   # donate the init state into the program


class FitEngine:
    """jit-compiled fused multi-epoch trainer for one (model, TrainConfig).

    ``fit(rng, x, y) -> (params, losses)`` retrains from scratch on the
    full labeled set as one device program; ``fit_resident`` does the same
    over the campaign-resident device pool (only newly bought labels are
    scattered in per iteration, :meth:`extend_resident`).  ``losses`` is
    the per-step training loss, ``(epochs * steps_per_epoch,)``.
    """

    def __init__(self, model, tc: TrainConfig, cfg: FitConfig = FitConfig(),
                 mesh=None, policy: str = "tp"):
        self.model = model
        self.tc = tc
        self.cfg = cfg
        self.mesh = mesh
        self.policy = policy
        self._batch_key = ("features" if model.cfg.family == "mlp"
                           else "tokens")
        self._step = make_train_step(model, tc, mesh=mesh, jit=False)
        self._programs: Dict[Tuple[int, int, int], Any] = {}
        # AOT-compiled executables from warm(): jit's dispatch cache is
        # NOT populated by lower().compile(), so these are dispatched
        # directly — a warmed bucket never traces or compiles again
        self._compiled: Dict[Tuple[int, int, int], Any] = {}
        self._ref_step = None
        self._exec: Optional[SerialWorker] = None
        # campaign-resident labeled pool: device buffers + valid row count
        self._res_x: Optional[jax.Array] = None
        self._res_y: Optional[jax.Array] = None
        self._res_n = 0
        # campaign event bus (observability only: submit/fold timestamps
        # for async retrains; the fold emit runs on the worker thread)
        self.trace = None
        self._submit_seq = 0
        # runtime metrics (repro.obs.MetricsRegistry); None = free no-op
        self.metrics = None
        # resilience seam: chaos injector + broker re-dispatch policy,
        # handed to the lazy SerialWorker (site ``worker.fit-engine``)
        self.faults = None
        self.retry = None

    def attach_faults(self, faults, retry=None) -> None:
        """Wire the fault injector (and optional re-dispatch policy)
        into the fit broker: every submitted job ticks the
        ``worker.fit-engine`` site, and transient crashes re-dispatch."""
        self.faults = faults
        if retry is not None:
            self.retry = retry
        if self._exec is not None:
            self._exec.attach_faults(faults, retry)

    # -- program construction ------------------------------------------------

    def _donate(self) -> bool:
        return self.cfg.donate_state and jax.default_backend() != "cpu"

    def _program(self, n: int):
        """The fused program for the ``fit_plan`` bucket of ``n`` (compile
        cache keyed on the bucket, not the raw size)."""
        spe, bs, n_pad = fit_plan(n, self.cfg.batch_size)
        key = (spe, bs, n_pad)
        prog = self._programs.get(key)
        if self.metrics is not None:
            self.metrics.inc("pack_cache_hits_total" if prog is not None
                             else "pack_cache_misses_total", engine="fit")
        if prog is not None:
            return prog, key
        epochs, step, batch_key = self.cfg.epochs, self._step, self._batch_key

        def program(state, xp, yp, nn, key_data):
            orders = epoch_orders(key_data, epochs, n_pad, nn)

            def body(state, t):
                e, s = t // spe, t % spe
                pos = (s * bs + jnp.arange(bs)) % nn
                rows = orders[e][pos]
                batch = {batch_key: xp[rows], "labels": yp[rows]}
                state, metrics = step(state, batch)
                return state, metrics["loss"]

            state, losses = jax.lax.scan(
                body, state, jnp.arange(epochs * spe, dtype=jnp.int32))
            return state, losses

        kwargs: Dict[str, Any] = {
            "donate_argnums": (0,) if self._donate() else ()}
        if self.mesh is not None:
            _, pspecs = state_pspecs(self.model, self.tc, self.mesh,
                                     self.policy)
            rep = NamedSharding(self.mesh, P())
            kwargs["in_shardings"] = (shd.tree_named(self.mesh, pspecs),
                                      rep, rep, rep, rep)
        prog = jax.jit(program, **kwargs)
        self._programs[key] = prog
        return prog, key

    # -- packing -------------------------------------------------------------

    def _pack_host(self, x, y, n: int) -> Tuple[np.ndarray, np.ndarray]:
        """Pad (x, y) to the fit_plan bucket on host (one h2d upload)."""
        _, _, n_pad = fit_plan(n, self.cfg.batch_size)
        x = np.asarray(x)
        xp = np.zeros((n_pad,) + x.shape[1:], x.dtype)
        xp[:n] = x
        yp = np.zeros((n_pad,), np.int32)
        yp[:n] = np.asarray(y, np.int32)
        return xp, yp

    @staticmethod
    def _keys(rng: jax.Array) -> Tuple[jax.Array, jax.Array]:
        init_key, shuffle_key = jax.random.split(rng)
        return init_key, shuffle_key

    def init_state(self, rng: jax.Array) -> Dict:
        return init_train_state(self.model, self.tc, rng)

    # -- the fused path ------------------------------------------------------

    def fit(self, rng: jax.Array, x, y) -> Tuple[Dict, jax.Array]:
        """One fused retrain-from-scratch over the full labeled set:
        ``(params, per-step losses)``, device-resident (dispatch is async —
        callers that time the retrain must block on ``losses``)."""
        n = int(np.asarray(x).shape[0])
        xp, yp = self._pack_host(x, y, n)
        return self._run(rng, jnp.asarray(xp), jnp.asarray(yp), n)

    def _run(self, rng, xd, yd, n: int) -> Tuple[Dict, jax.Array]:
        if self.metrics is not None:
            # fence on losses: the span covers the device retrain, not
            # just the async dispatch (runs on the fit worker for
            # submit_fit, so campaign-side overlap is unaffected).
            # labeled by the fit_plan bucket, not raw n — O(log N) series
            n_pad = fit_plan(n, self.cfg.batch_size)[2]
            with self.metrics.span("fit", n_pad=n_pad) as sp:
                params, losses = self._run_impl(rng, xd, yd, n)
                sp.fence(losses)
            return params, losses
        return self._run_impl(rng, xd, yd, n)

    def _run_impl(self, rng, xd, yd, n: int) -> Tuple[Dict, jax.Array]:
        prog, key = self._program(n)
        prog = self._compiled.get(key, prog)   # warmed AOT executable
        init_key, shuffle_key = self._keys(rng)
        state = self.init_state(init_key)
        key_data = jax.random.key_data(
            jax.random.fold_in(shuffle_key, n))
        state, losses = prog(state, xd, yd, jnp.int32(n), key_data)
        return state["params"], losses

    # -- campaign-resident pool ---------------------------------------------

    @property
    def resident_size(self) -> int:
        return self._res_n

    def reset_resident(self):
        self._res_x = self._res_y = None
        self._res_n = 0

    def extend_resident(self, new_x, new_y) -> int:
        """Scatter newly bought labels into the device-resident pool
        (growing the buffers to the next ``fit_plan`` bucket when needed);
        returns the new valid row count.  Successive MCAL iterations pay
        h2d only for the delta rows."""
        new_x = np.asarray(new_x)
        new_y = np.asarray(new_y, np.int32)
        d = int(new_x.shape[0])
        if d == 0:
            return self._res_n
        n = self._res_n + d
        _, _, n_pad = fit_plan(n, self.cfg.batch_size)
        if self._res_x is None:
            self._res_x = jnp.zeros((n_pad,) + new_x.shape[1:], new_x.dtype)
            self._res_y = jnp.zeros((n_pad,), jnp.int32)
        elif n_pad > self._res_x.shape[0]:
            grow = n_pad - self._res_x.shape[0]
            self._res_x = jnp.concatenate(
                [self._res_x,
                 jnp.zeros((grow,) + self._res_x.shape[1:],
                           self._res_x.dtype)])
            self._res_y = jnp.concatenate(
                [self._res_y, jnp.zeros((grow,), jnp.int32)])
        self._res_x = jax.lax.dynamic_update_slice(
            self._res_x, jnp.asarray(new_x),
            (self._res_n,) + (0,) * (new_x.ndim - 1))
        self._res_y = jax.lax.dynamic_update_slice(
            self._res_y, jnp.asarray(new_y), (self._res_n,))
        self._res_n = n
        return n

    def fit_resident(self, rng: jax.Array) -> Tuple[Dict, jax.Array]:
        """:meth:`fit` over the resident pool — no pool upload at all (the
        compiled program is shared with :meth:`fit`: same bucket, same
        cache key)."""
        if self._res_n == 0:
            raise ValueError("resident pool is empty; extend_resident first")
        return self._run(rng, self._res_x, self._res_y, self._res_n)

    # -- async handle --------------------------------------------------------

    def _executor(self) -> SerialWorker:
        if self._exec is None:
            self._exec = SerialWorker("fit-engine", retry=self.retry,
                                      faults=self.faults)
            self._exec.metrics = self.metrics
        return self._exec

    def close(self) -> None:
        """Idempotent engine shutdown: join the fit worker thread (no-op
        if nothing was ever submitted).  ``submit_fit``/``submit_call``
        afterwards raise — synchronous ``fit`` calls remain valid."""
        if self._exec is not None:
            self._exec.close()

    def __enter__(self) -> "FitEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _traced(self, fn: Callable, label: str) -> Callable:
        """Bracket a worker-thread job with fit_submit/fit_done events —
        the submit/fold timestamps the live report's overlap view reads.
        The pairing key is a per-engine job counter (events from the
        worker interleave arbitrarily with the main thread's)."""
        if self.trace is None:
            return fn
        job, self._submit_seq = self._submit_seq, self._submit_seq + 1
        self.trace.emit("fit_submit", job=int(job), what=label)
        trace = self.trace

        def wrapped(*args, **kw):
            out = fn(*args, **kw)
            trace.emit("fit_done", job=int(job), what=label)
            return out
        return wrapped

    def submit_fit(self, rng: jax.Array, x, y) -> FitFuture:
        """Launch :meth:`fit` on the engine's worker thread (mirrors
        ``PoolSweepRunner.submit``); the caller overlaps its own work and
        synchronizes at ``result()``."""
        return FitFuture(self._executor().submit(
            self._traced(self.fit, "fit"), rng, x, y), label="fit")

    def submit_call(self, fn: Callable, *args, **kw) -> FitFuture:
        """Run an arbitrary callable on the fit worker (composite jobs
        like retrain + measurement sweep that start with a fit)."""
        return FitFuture(self._executor().submit(
            self._traced(fn, "call"), *args, **kw), label="fit[call]")

    # -- compile-cache bookkeeping ------------------------------------------

    def cache_keys(self) -> List[Tuple[int, int, int]]:
        """The (steps_per_epoch, bs, n_pad) buckets compiled so far —
        persisted in campaign checkpoints so a resumed paper-scale replay
        can prewarm them (:meth:`warm`) instead of paying compiles
        mid-campaign."""
        return sorted(self._programs)

    def warm(self, keys) -> int:
        """AOT-compile the programs for ``keys`` (cache-key tuples or raw
        pool sizes) without running a single train step — a resumed
        campaign pays its compiles upfront instead of mid-loop.  The
        compiled executables are kept and dispatched directly by
        :meth:`fit` (``lower().compile()`` does not populate jit's own
        dispatch cache); returns how many programs were compiled."""
        if self.metrics is None:
            return self._warm_impl(keys)
        with self.metrics.span("warm", engine="fit"):
            count = self._warm_impl(keys)
        if count:
            self.metrics.inc("warm_compiles_total", count, engine="fit")
        return count

    def _warm_impl(self, keys) -> int:
        from repro.training.train_loop import abstract_train_state
        if self._batch_key != "features":
            raise NotImplementedError(
                "warm() supports feature-classifier models")
        ab_state, _ = abstract_train_state(self.model, self.tc)
        kd = jax.random.key_data(jax.random.key(0))
        count = 0
        for k in keys:
            n_pad = int(k[2]) if isinstance(k, (tuple, list)) else \
                fit_plan(int(k), self.cfg.batch_size)[2]
            prog, key = self._program(n_pad)
            if key in self._compiled:
                continue
            xs = jax.ShapeDtypeStruct((n_pad, self.model.cfg.input_dim),
                                      jnp.float32)
            ys = jax.ShapeDtypeStruct((n_pad,), jnp.int32)
            nn = jax.ShapeDtypeStruct((), jnp.int32)
            self._compiled[key] = prog.lower(ab_state, xs, ys, nn,
                                             kd).compile()
            count += 1
        return count

    # -- the per-step host loop, kept as the reference oracle ---------------

    def fit_reference(self, rng: jax.Array, x, y) -> Tuple[Dict, jax.Array]:
        """The seed ``LiveTask.train`` shape: one numpy batch gather + one
        h2d upload + one jitted-step dispatch per batch, blocking on every
        step — over the SAME permutation sequence (:func:`epoch_orders`)
        and schedule (:func:`fit_plan`) as the fused scan.  Bit-identical
        params and losses on a CPU host; the benchmark baseline."""
        n = int(np.asarray(x).shape[0])
        spe, bs, n_pad = fit_plan(n, self.cfg.batch_size)
        xp, yp = self._pack_host(x, y, n)
        if self._ref_step is None:
            self._ref_step = make_train_step(self.model, self.tc,
                                             mesh=self.mesh, jit=True)
        init_key, shuffle_key = self._keys(rng)
        key_data = jax.random.key_data(jax.random.fold_in(shuffle_key, n))
        orders = np.asarray(_epoch_orders_jit(key_data, self.cfg.epochs,
                                              n_pad, jnp.int32(n)))
        state = self.init_state(init_key)
        losses = []
        arange = np.arange(bs)
        for e in range(self.cfg.epochs):
            order = orders[e]
            for s in range(spe):
                sel = order[(s * bs + arange) % n]
                batch = {self._batch_key: jnp.asarray(xp[sel]),
                         "labels": jnp.asarray(yp[sel])}
                state, metrics = self._ref_step(state, batch)
                losses.append(metrics["loss"])
        return state["params"], jnp.stack(losses)
