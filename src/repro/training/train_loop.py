"""Train-step factory + train state.

``make_train_step`` builds a jitted ``(state, batch) -> (state, metrics)``
closure for any model in the zoo (LM loss over tokens/labels, or a
classification head when ``cfg.num_classes`` is set — the path MCAL's live
labeling campaigns use).  ``make_sharded_train_step`` is the pjit variant the
launcher and the multi-pod dry-run consume: state/batch shardings are derived
from the logical-axis trees, optimizer slots inherit their parameter's axes
(ZeRO), and the same closure lowers unchanged on 1 CPU device or 512 chips.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat

from repro.configs.base import ModelConfig, TrainConfig
from repro.distributed import sharding as shd
from repro.models import layers as L
from repro.models import transformer as tf
from repro.models.param import ParamSpec, _is_spec
from repro.training import optimizer as opt
from repro.training.schedules import make_schedule


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def loss_fn(model, params: Dict, batch: Dict, mesh=None) -> jax.Array:
    cfg = model.cfg
    hidden = model.forward(params, batch, mesh=mesh)
    if cfg.num_classes:
        pooled = jnp.mean(hidden.astype(jnp.float32), axis=1)
        logits = jnp.einsum("bd,dc->bc", pooled.astype(hidden.dtype),
                            params["cls_head"])
        return L.cross_entropy(logits, batch["labels"])
    w = tf.lm_head_weight(cfg, params)
    labels = batch["labels"]
    if cfg.family == "vlm" and cfg.frontend_tokens:
        hidden = hidden[:, cfg.frontend_tokens:, :]  # loss on text positions
    hidden = shd.constrain(hidden, mesh, cfg.sharding,
                           "batch", "seq", "act_embed")
    # When the mesh shards the vocab ("model" axis divides V), materialized
    # vocab-sharded logits + psum'd softmax stats is the cheap TP path:
    # per-device logits are (B_loc, T, V/tp) and the chunked scan's
    # dynamic-slice (which would all-gather the sharded head) is avoided.
    tp = 1
    if mesh is not None:
        tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    if cfg.logits_chunk and (tp <= 1 or cfg.vocab_size % tp != 0):
        return L.chunked_cross_entropy(hidden, w, labels, chunk=cfg.logits_chunk)
    logits = jnp.einsum("btd,dv->btv", hidden, w,
                        preferred_element_type=jnp.float32)
    logits = shd.constrain(logits, mesh, cfg.sharding,
                           "batch", "seq", "vocab")
    return L.cross_entropy(logits, labels)


# ---------------------------------------------------------------------------
# state
# ---------------------------------------------------------------------------


def init_train_state(model, tc: TrainConfig, rng: jax.Array) -> Dict:
    params = model.init(rng)
    slots = opt.init_slots(compat.tree_leaves(params), tc)
    return {"params": params, "opt": slots, "step": jnp.zeros((), jnp.int32)}


def _leaf_specs(model) -> list:
    """[(shape, logical)] per param leaf, leaf-aligned with tree.leaves."""
    spec_leaves = compat.tree_leaves(model.specs, is_leaf=_is_spec)
    return [(s.shape, s.logical) for s in spec_leaves]


def abstract_train_state(model, tc: TrainConfig) -> Tuple[Dict, Dict]:
    """(abstract state, logical-axes state) without allocating anything."""
    ab_params = model.abstract_params()
    lg_params = model.logical_axes()
    ab_slots, lg_slots = opt.abstract_slots(_leaf_specs(model), tc)
    ab = {"params": ab_params, "opt": ab_slots,
          "step": jax.ShapeDtypeStruct((), jnp.int32)}
    lg = {"params": lg_params, "opt": lg_slots, "step": ()}
    return ab, lg


def state_pspecs(model, tc: TrainConfig, mesh, policy: str):
    ab, lg = abstract_train_state(model, tc)
    return ab, shd.tree_pspecs(ab, lg, mesh, policy)


# ---------------------------------------------------------------------------
# step factories
# ---------------------------------------------------------------------------


def make_train_step(model, tc: TrainConfig, mesh=None, jit: bool = True):
    """When ``tc.grad_accum > 1`` every batch leaf must arrive pre-split as
    (grad_accum, micro_batch, ...) — the loader adds the leading microbatch
    dim on the host so the sharded batch axis is never reshaped inside the
    step (reshaping a sharded axis would insert collectives)."""
    sched = make_schedule(tc)

    def grads_of(params, batch):
        return jax.value_and_grad(functools.partial(loss_fn, model))(
            params, batch, mesh=mesh)

    def step(state, batch):
        params = state["params"]
        if tc.grad_accum > 1:
            micro = batch  # leading dim == grad_accum (pre-split)
            acc_dt = jnp.bfloat16 if tc.accum_dtype == "bfloat16" \
                else jnp.float32

            def acc(carry, mb):
                tot_loss, tot_g = carry
                l, g = grads_of(params, mb)
                return (tot_loss + l,
                        compat.tree_map(lambda a, b: a + b.astype(acc_dt),
                                     tot_g, g)), None

            zeros = compat.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            (loss, grads), _ = jax.lax.scan(acc, (jnp.float32(0.0), zeros), micro)
            loss = loss / tc.grad_accum
            grads = compat.tree_map(lambda g: g / tc.grad_accum, grads)
        else:
            loss, grads = grads_of(params, batch)
        grads, gnorm = opt.clip_by_global_norm(grads, tc.grad_clip)
        lr = sched(state["step"])
        new_params, new_slots = opt.adamw_update(
            params, grads, state["opt"], state["step"], lr, tc)
        new_state = {"params": new_params, "opt": new_slots,
                     "step": state["step"] + 1}
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm,
                   "lr": lr}
        return new_state, metrics

    return jax.jit(step, donate_argnums=0) if jit else step


def make_sharded_train_step(model, tc: TrainConfig, mesh, policy: str,
                            batch_pspecs: Dict):
    """pjit train step with explicit in/out shardings (launcher + dry-run).

    Returns (step_fn, abstract_state, state_shardings).
    """
    ab_state, pspecs = state_pspecs(model, tc, mesh, policy)
    state_sh = shd.tree_named(mesh, pspecs)
    batch_sh = {k: shd.named(mesh, v) for k, v in batch_pspecs.items()}
    raw = make_train_step(model, tc, mesh=mesh, jit=False)
    metrics_sh = shd.named(mesh, jax.sharding.PartitionSpec())
    step = jax.jit(
        raw,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, {"loss": metrics_sh, "grad_norm": metrics_sh,
                                  "lr": metrics_sh}),
        donate_argnums=0,
    )
    return step, ab_state, state_sh
