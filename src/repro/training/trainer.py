"""Trainer: the fault-tolerant composition of loader + sharded step +
checkpoint + straggler monitor.

Responsibilities:
  * build the (optionally pjit-sharded) train step for the mesh;
  * resume from the latest published checkpoint if one exists
    (checkpoint/restart fault tolerance; re-mesh handled by restore());
  * checkpoint every ``ckpt_every`` steps, atomically;
  * time each step through the StragglerMonitor.

The same class drives the reduced-config smoke train runs and the
production launcher (launch/train.py).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable, Dict, Iterable, Optional

import jax
import numpy as np

from repro.configs.base import TrainConfig
from repro.data.loader import ShardedLoader
from repro.distributed import checkpoint as ckpt
from repro.distributed.straggler import StragglerMonitor
from repro.training.train_loop import (init_train_state, make_sharded_train_step,
                                       make_train_step)


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str = ""
    ckpt_every: int = 100
    log_every: int = 10
    max_steps: int = 1000


class Trainer:
    def __init__(self, model, tc: TrainConfig, tcfg: TrainerConfig,
                 mesh=None, policy: str = "fsdp_tp",
                 batch_pspecs: Optional[Dict] = None, seed: int = 0,
                 log_fn: Callable[[str], None] = print):
        self.model = model
        self.tc = tc
        self.tcfg = tcfg
        self.mesh = mesh
        self.log = log_fn
        self.monitor = StragglerMonitor()
        if mesh is not None and batch_pspecs is not None:
            self.step_fn, _, self.state_sh = make_sharded_train_step(
                model, tc, mesh, policy, batch_pspecs)
        else:
            self.step_fn, self.state_sh = make_train_step(model, tc), None
        self.state = self._init_or_resume(seed)

    def _init_or_resume(self, seed: int):
        state = init_train_state(self.model, self.tc, jax.random.key(seed))
        if self.tcfg.ckpt_dir:
            last = ckpt.latest_step(self.tcfg.ckpt_dir)
            if last is not None:
                state, manifest = ckpt.restore(
                    self.tcfg.ckpt_dir, last, state, shardings=self.state_sh)
                self.log(f"[trainer] resumed from step {last}")
        return state

    @property
    def step(self) -> int:
        return int(jax.device_get(self.state["step"]))

    def fit(self, batches: Iterable[Dict]) -> Dict[str, Any]:
        last_metrics: Dict[str, Any] = {}
        for batch in batches:
            if self.step >= self.tcfg.max_steps:
                break
            self.monitor.start()
            self.state, metrics = self.step_fn(self.state, batch)
            jax.block_until_ready(metrics["loss"])
            event = self.monitor.stop()
            if event is not None:
                self.log(f"[trainer] straggler at step {event.step}: "
                         f"{event.duration * 1e3:.0f}ms vs median "
                         f"{event.median * 1e3:.0f}ms")
            s = self.step
            if self.tcfg.log_every and s % self.tcfg.log_every == 0:
                self.log(f"[trainer] step {s} loss "
                         f"{float(jax.device_get(metrics['loss'])):.4f}")
            if self.tcfg.ckpt_dir and self.tcfg.ckpt_every and \
                    s % self.tcfg.ckpt_every == 0:
                ckpt.save(self.tcfg.ckpt_dir, s, self.state)
            last_metrics = metrics
        if self.tcfg.ckpt_dir:
            ckpt.save(self.tcfg.ckpt_dir, self.step, self.state)
        return {k: float(jax.device_get(v)) for k, v in last_metrics.items()}
