"""AdamW in pure JAX with giant-model memory levers.

Per-leaf optimizer slots (a list aligned with ``compat.tree_leaves(params)``):

* first moment ``m`` stored in ``moment_dtype`` — float32 / bfloat16 / int8
  (int8 uses symmetric per-tensor scaling, requantized each step);
* second moment either full ``v`` or Adafactor-style factored ``(vr, vc)``
  over the last two axes for >=2-D leaves (leading stack axes stay batched);
* 1-D leaves (norm scales, biases) are never weight-decayed or factored.

The slot layout is declared once (:func:`slot_spec`) so the dry-run can build
abstract state + logical shardings without allocating anything: slots inherit
their parameter's logical axes, which under ``fsdp_tp`` shards optimizer
state like the weights (ZeRO-style).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import compat
import numpy as np

from repro.configs.base import TrainConfig

# Minimum size of each of the last two dims for factoring to pay off.
_FACTOR_MIN = 8


def _factorable(shape: Tuple[int, ...]) -> bool:
    return len(shape) >= 2 and shape[-1] >= _FACTOR_MIN and shape[-2] >= _FACTOR_MIN


def _decayed(shape: Tuple[int, ...]) -> bool:
    return len(shape) >= 2


# ---------------------------------------------------------------------------
# int8 moment quantization (symmetric, per-tensor scale)
# ---------------------------------------------------------------------------


def quantize_int8(x: jax.Array) -> Dict[str, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def dequantize_int8(slot: Dict[str, jax.Array]) -> jax.Array:
    return slot["q"].astype(jnp.float32) * slot["scale"]


# ---------------------------------------------------------------------------
# slot construction
# ---------------------------------------------------------------------------


def slot_spec(shape: Tuple[int, ...], logical: Tuple, tc: TrainConfig):
    """Describe the slot arrays for one parameter leaf.

    Returns {name: (shape, dtype, logical)}.
    """
    out: Dict[str, Tuple[Tuple[int, ...], Any, Tuple]] = {}
    if tc.moment_dtype == "int8":
        out["m_q"] = (shape, jnp.int8, logical)
        out["m_scale"] = ((), jnp.float32, ())
    else:
        mdt = jnp.float32 if tc.moment_dtype == "float32" else jnp.bfloat16
        out["m"] = (shape, mdt, logical)
    if tc.factored_second_moment and _factorable(shape):
        out["vr"] = (shape[:-1], jnp.float32, logical[:-1])
        out["vc"] = (shape[:-2] + shape[-1:], jnp.float32, logical[:-2] + logical[-1:])
    else:
        out["v"] = (shape, jnp.float32, logical)
    return out


def init_slots(params_leaves: Sequence[jax.Array], tc: TrainConfig) -> List[Dict]:
    slots = []
    for p in params_leaves:
        spec = slot_spec(p.shape, (None,) * p.ndim, tc)
        slots.append({k: jnp.zeros(sh, dt) for k, (sh, dt, _) in spec.items()})
    return slots


def abstract_slots(param_specs: Sequence[Tuple[Tuple[int, ...], Tuple]],
                   tc: TrainConfig):
    """(shape, logical) per leaf -> (abstract slots, logical slots)."""
    ab, lg = [], []
    for shape, logical in param_specs:
        spec = slot_spec(shape, logical, tc)
        ab.append({k: jax.ShapeDtypeStruct(sh, dt) for k, (sh, dt, _) in spec.items()})
        lg.append({k: axes for k, (_, _, axes) in spec.items()})
    return ab, lg


# ---------------------------------------------------------------------------
# update
# ---------------------------------------------------------------------------


def _get_m(slot: Dict) -> jax.Array:
    if "m_q" in slot:
        return dequantize_int8({"q": slot["m_q"], "scale": slot["m_scale"]})
    return slot["m"].astype(jnp.float32)


def _put_m(slot: Dict, m: jax.Array, tc: TrainConfig) -> None:
    if tc.moment_dtype == "int8":
        q = quantize_int8(m)
        slot["m_q"], slot["m_scale"] = q["q"], q["scale"]
    elif tc.moment_dtype == "bfloat16":
        slot["m"] = m.astype(jnp.bfloat16)
    else:
        slot["m"] = m


def _second_moment(slot: Dict, g2: jax.Array, b2: jax.Array) -> jax.Array:
    """Update second-moment slot in place; return the dense estimate."""
    if "v" in slot:
        v = b2 * slot["v"] + (1.0 - b2) * g2
        slot["v"] = v
        return v
    # Adafactor-style factored estimate over the last two axes
    vr = b2 * slot["vr"] + (1.0 - b2) * jnp.mean(g2, axis=-1)
    vc = b2 * slot["vc"] + (1.0 - b2) * jnp.mean(g2, axis=-2)
    slot["vr"], slot["vc"] = vr, vc
    denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
    return vr[..., None] * vc[..., None, :] / denom[..., None]


def adamw_update(params, grads, slots: List[Dict], step: jax.Array,
                 lr: jax.Array, tc: TrainConfig):
    """One AdamW step.  ``slots`` is leaf-aligned with ``params``."""
    p_leaves, treedef = compat.tree_flatten(params)
    g_leaves = compat.tree_leaves(grads)
    assert len(p_leaves) == len(g_leaves) == len(slots)
    b1, b2 = jnp.float32(tc.beta1), jnp.float32(tc.beta2)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    new_p, new_slots = [], []
    for p, g, slot in zip(p_leaves, g_leaves, slots):
        slot = dict(slot)
        gf = g.astype(jnp.float32)
        m = b1 * _get_m(slot) + (1.0 - b1) * gf
        _put_m(slot, m, tc)
        v = _second_moment(slot, gf * gf, b2)
        update = (m / bc1) / (jnp.sqrt(v / bc2) + tc.eps)
        if tc.weight_decay and _decayed(p.shape):
            update = update + tc.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * update).astype(p.dtype))
        new_slots.append(slot)
    return compat.tree_unflatten(treedef, new_p), new_slots


def clip_by_global_norm(grads, max_norm: float):
    leaves = compat.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))
    if max_norm <= 0:
        return grads, gnorm
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return compat.tree_map(lambda l: (l.astype(jnp.float32) * scale).astype(l.dtype),
                        grads), gnorm


def slot_bytes(slots: List[Dict]) -> int:
    return sum(int(np.prod(a.shape)) * a.dtype.itemsize
               for s in slots for a in s.values())
