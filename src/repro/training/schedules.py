"""Learning-rate schedules.

``paper_steps`` reproduces the paper's recipe (§5): 200 epochs with 10x LR
reductions at epochs 80/120/160/180 — expressed as fractions of
``total_steps`` (0.4 / 0.6 / 0.8 / 0.9) so it applies at any step budget.
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

from repro.configs.base import TrainConfig

PAPER_BOUNDARIES = (0.4, 0.6, 0.8, 0.9)  # epochs 80/120/160/180 of 200
PAPER_DECAY = 0.1


def make_schedule(tc: TrainConfig) -> Callable:
    """step (int array) -> lr (f32 array)."""
    base = tc.learning_rate
    total = max(tc.total_steps, 1)

    def warmup_scale(step):
        if tc.warmup_steps <= 0:
            return 1.0
        return jnp.minimum((step + 1) / tc.warmup_steps, 1.0)

    if tc.schedule == "constant":
        def fn(step):
            return jnp.asarray(base, jnp.float32) * warmup_scale(step)
    elif tc.schedule == "cosine":
        def fn(step):
            frac = jnp.clip(step / total, 0.0, 1.0)
            cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
            lr = base * (0.1 + 0.9 * cos)  # decay to 10% of peak
            return jnp.asarray(lr, jnp.float32) * warmup_scale(step)
    elif tc.schedule == "paper_steps":
        bounds = jnp.asarray([b * total for b in PAPER_BOUNDARIES])

        def fn(step):
            k = jnp.sum(step >= bounds)
            return jnp.asarray(base * PAPER_DECAY ** k, jnp.float32) * warmup_scale(step)
    else:
        raise ValueError(f"unknown schedule {tc.schedule!r}")
    return fn
