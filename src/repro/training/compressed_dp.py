"""Compressed data-parallel train step: int8 error-feedback gradient
all-reduce (the slow-axis trick for the pod interconnect).

``make_compressed_dp_train_step`` builds a shard_map-based DP step:
params/optimizer replicated, batch sharded over the DP axes, per-shard
gradients reduced with :func:`repro.distributed.compression.compressed_psum`
over ``compress_axis`` (int8 payload + one f32 scale on the wire — 4x less
than f32, 2x less than bf16) and plain psum over the remaining DP axes.
The quantization residual (error-feedback state, one f32 tree per shard)
rides in the train state, keeping the scheme unbiased over steps.

This is the pure-DP replicated-parameter regime (small/medium models, e.g.
the `fsdp`-policy winners of EXPERIMENTS §Perf with replication instead of
ZeRO); for sharded-parameter regimes the compression applies to the
reduce-scatter in the same way.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro import compat
from repro.compat import shard_map as _shard_map

from repro.configs.base import TrainConfig
from repro.training import optimizer as opt
from repro.training.schedules import make_schedule
from repro.training.train_loop import loss_fn


def init_ef_state(params) -> Dict:
    """Per-shard f32 residual tree (replicated layout, per-device values)."""
    return compat.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def make_compressed_dp_train_step(model, tc: TrainConfig, mesh,
                                  compress_axis: str = "data",
                                  plain_axes: Tuple[str, ...] = ()):
    """-> step((state, ef), batch) -> ((state, ef), metrics).

    ``state`` is the usual {params, opt, step} (replicated); ``ef`` the
    error-feedback tree.  Batch leaves are sharded over
    (compress_axis, *plain_axes) on dim 0.
    """
    from repro.distributed.compression import tree_compressed_psum
    sched = make_schedule(tc)
    dp_axes = (compress_axis,) + tuple(plain_axes)

    def body(params, slots, stepc, ef, batch):
        loss, grads = jax.value_and_grad(
            functools.partial(loss_fn, model))(params, batch)
        # int8 + EF over the slow axis; exact psum over the rest
        grads, new_ef = tree_compressed_psum(grads, ef, compress_axis)
        for ax in plain_axes:
            grads = compat.tree_map(lambda g: jax.lax.pmean(g, ax), grads)
        loss = jax.lax.pmean(loss, dp_axes)
        grads, gnorm = opt.clip_by_global_norm(grads, tc.grad_clip)
        lr = sched(stepc)
        new_params, new_slots = opt.adamw_update(
            params, grads, slots, stepc, lr, tc)
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm,
                   "lr": lr}
        return new_params, new_slots, stepc + 1, new_ef, metrics

    batch_spec = P(dp_axes if len(dp_axes) > 1 else dp_axes[0])
    mapped = _shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(), P(), P(), batch_spec),
        out_specs=(P(), P(), P(), P(), P()),
        check_vma=False,  # outputs are provably replicated via the psum
    )

    @jax.jit
    def step(carry, batch):
        state, ef = carry
        new_p, new_s, new_step, new_ef, metrics = mapped(
            state["params"], state["opt"], state["step"], ef, batch)
        return ({"params": new_p, "opt": new_s, "step": new_step},
                new_ef), metrics

    return step
