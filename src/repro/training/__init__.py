from repro.training import optimizer, schedules, train_loop  # noqa: F401
from repro.training.fit_device import (FitConfig, FitEngine,  # noqa: F401
                                       FitFuture)
