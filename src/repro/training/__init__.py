from repro.training import optimizer, schedules, train_loop  # noqa: F401
