"""Serving launcher: batched generation with the ServeEngine.

Smoke-scale:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config, get_smoke
    from repro.models.registry import get_model
    from repro.serving.engine import ServeEngine

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = get_model(cfg)
    params = model.init(jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)

    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.family == "audio":
        batch["audio_frames"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.encoder_tokens, cfg.d_model)),
            jnp.float32)
    if cfg.family == "vlm" and cfg.frontend_tokens:
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.frontend_tokens, cfg.d_model)),
            jnp.float32)

    engine = ServeEngine(model, params,
                         max_seq=args.prompt_len + args.gen + 8,
                         batch_size=args.batch)
    t0 = time.perf_counter()
    out = engine.generate(batch, args.gen)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    print(f"[serve] generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(np.asarray(out)[:2])


if __name__ == "__main__":
    main()
