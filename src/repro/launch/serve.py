"""Serving launcher: batched generation with the ServeEngine.

Smoke-scale:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --batch 4 --prompt-len 32 --gen 16

Pool-sweep mode (MCAL machine-labeling pass through the serving runtime):
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --score-pool 256 --sweep-page 8 --sweep-async
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--score-pool", type=int, default=0,
                    help="score a random N-row token pool through the "
                         "paged sweep runtime instead of generating")
    ap.add_argument("--sweep-page", type=int, default=0,
                    help="sweep page rows (default: --batch)")
    ap.add_argument("--sweep-async", action="store_true",
                    help="run the pool sweep through the async handle "
                         "(SweepFuture) instead of blocking")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config, get_smoke
    from repro.models.registry import get_model
    from repro.serving.engine import ServeEngine

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = get_model(cfg)
    params = model.init(jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)

    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.family == "audio":
        batch["audio_frames"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.encoder_tokens, cfg.d_model)),
            jnp.float32)
    if cfg.family == "vlm" and cfg.frontend_tokens:
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.frontend_tokens, cfg.d_model)),
            jnp.float32)

    engine = ServeEngine(model, params,
                         max_seq=args.prompt_len + args.gen + 8,
                         batch_size=args.batch)

    if args.score_pool:
        # MCAL machine-labeling pass: stream an N-row prompt pool through
        # the jit'd scoring step as paged, double-buffered sweep work
        pool = {"tokens": rng.integers(
            0, cfg.vocab_size,
            (args.score_pool, args.prompt_len)).astype(np.int32)}
        if cfg.family == "audio":
            pool["audio_frames"] = rng.normal(size=(
                args.score_pool, cfg.encoder_tokens,
                cfg.d_model)).astype(np.float32)
        if cfg.family == "vlm" and cfg.frontend_tokens:
            pool["patch_embeds"] = rng.normal(size=(
                args.score_pool, cfg.frontend_tokens,
                cfg.d_model)).astype(np.float32)
        page = args.sweep_page or args.batch
        warm = {k: v[:page] for k, v in pool.items()}
        engine.score_pool(warm, page_rows=page)  # warm the page program
        t0 = time.perf_counter()
        if args.sweep_async:
            stats = engine.score_pool_async(pool, page_rows=page).result()
        else:
            stats = engine.score_pool(pool, page_rows=page)
        jax.block_until_ready(stats.margin)
        dt = time.perf_counter() - t0
        mode = "async" if args.sweep_async else "sync"
        print(f"[serve] pool sweep ({mode}) scored {args.score_pool} rows "
              f"in {dt:.2f}s ({args.score_pool / dt:.1f} rows/s, "
              f"page={page})")
        print("mean margin:", float(np.mean(np.asarray(stats.margin))))
        return

    t0 = time.perf_counter()
    out = engine.generate(batch, args.gen)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    print(f"[serve] generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(np.asarray(out)[:2])


if __name__ == "__main__":
    main()
