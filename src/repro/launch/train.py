"""Training launcher.

Smoke-scale on this host:
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
        --smoke --steps 30 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On a real cluster the same entry point runs with --no-smoke: full config,
production mesh, sharded loader (each host feeds its addressable shard) —
the Trainer handles resume/checkpoint/straggler monitoring either way.
"""
from __future__ import annotations

import argparse

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on host devices")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    from repro.configs import get_config, get_smoke
    from repro.configs.base import TrainConfig
    from repro.data.loader import ShardedLoader
    from repro.data.synth import make_lm_tokens
    from repro.launch.mesh import make_host_mesh, make_production_mesh
    from repro.models.registry import get_model
    from repro.training.trainer import Trainer, TrainerConfig

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = get_model(cfg)
    print(f"[train] arch={args.arch} params={model.param_count():,}")
    tc = TrainConfig(learning_rate=args.lr, schedule="paper_steps",
                     total_steps=args.steps)
    tcfg = TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                         max_steps=args.steps, log_every=5)
    mesh = make_host_mesh() if args.smoke else make_production_mesh()
    trainer = Trainer(model, tc, tcfg, mesh=None if args.smoke else mesh,
                      seed=args.seed)

    toks = make_lm_tokens(args.batch * 64, args.seq + 1, cfg.vocab_size,
                          seed=args.seed)
    data = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    loader = ShardedLoader(data, args.batch, mesh=None, seed=args.seed)

    def batches():
        while True:
            yield from loader.epoch()

    metrics = trainer.fit(batches())
    print(f"[train] done at step {trainer.step}: {metrics}")


if __name__ == "__main__":
    main()
