"""Live campaign observability from the trace alone.

Renders a running (or finished) campaign's cost-vs-iteration curve,
ledger burn rate, and annotator quality drift straight from its trace
file — including one that is still being written (``read_trace``
tolerates the mid-write truncated final line), so an operator can watch
a campaign without touching the process driving it:

    PYTHONPATH=src python -m repro.launch.report TRACE.jsonl
    PYTHONPATH=src python -m repro.launch.report TRACE.jsonl --watch 5
    PYTHONPATH=src python -m repro.launch.report TRACE.jsonl --json

Everything here reads events only — no jax, no engines, no recompute
(:func:`summarize` imports nothing heavier than the trace store).
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional

from repro.trace.store import read_trace


def summarize(path: str) -> Dict:
    """One pass over the trace -> the observability summary the text and
    JSON views render.  Safe on a trace mid-write."""
    events = read_trace(path)
    out: Dict = {
        "trace": path, "campaign": events[0].campaign if events else "",
        "events": len(events), "status": "empty" if not events else
        "running", "config": {}, "runtime": {}, "pool_size": 0,
        "iterations": [], "ledger": None, "service_ledger": None,
        "burn": None, "annotator": [], "sweeps": {"cuts": 0, "done": 0},
        "fits": {"submitted": 0, "folded": 0},
        "saves": 0, "resumes": 0, "done_reason": None, "commit": None,
    }
    if not events:
        return out

    charges: List = []          # campaign-ledger charge events
    for e in events:
        p = e.payload
        if e.kind == "campaign_begin":
            out["config"] = dict(p.get("config", {}))
            out["runtime"] = dict(p.get("runtime", {}))
            out["pool_size"] = int(p.get("pool_size", 0))
        elif e.kind == "charge":
            if p.get("ledger") == "campaign":
                charges.append(e)
                out["ledger"] = {k: p[k] for k in (
                    "human", "training", "human_labels", "human_votes",
                    "total")}
            else:
                out["service_ledger"] = {k: p[k] for k in (
                    "human", "human_votes", "total")}
        elif e.kind == "iteration":
            out["iterations"].append({
                "i": p["i"], "B_size": p["B_size"], "delta": p["delta"],
                "cstar": p["cstar"], "B_opt": p["B_opt"],
                "theta_opt": p["theta_opt"], "stable": p["stable"],
                "human_spent": p["human_spent"],
                "training_spent": p["training_spent"]})
        elif e.kind == "annotator_snapshot":
            acc = p.get("worker_accuracy") or []
            out["annotator"].append({
                "ts": e.ts, "residual_error": p.get("residual_error"),
                "avg_repeats": p.get("avg_repeats"),
                "min_worker_accuracy": min(acc) if acc else None,
                "mean_worker_accuracy": (sum(acc) / len(acc)
                                         if acc else None)})
        elif e.kind == "sweep_cut":
            out["sweeps"]["cuts"] += 1
        elif e.kind == "sweep_done":
            out["sweeps"]["done"] += 1
        elif e.kind == "fit_submit":
            out["fits"]["submitted"] += 1
        elif e.kind == "fit_done":
            out["fits"]["folded"] += 1
        elif e.kind == "state_save":
            out["saves"] += 1
        elif e.kind == "resume":
            out["resumes"] += 1
        elif e.kind == "done":
            out["done_reason"] = p.get("reason")
            out["status"] = f"done:{p.get('reason')}"
        elif e.kind == "commit":
            out["commit"] = {k: p.get(k) for k in (
                "decision", "B_size", "S_size", "theta_final",
                "measured_error")}
            out["commit"]["total_cost"] = p.get("ledger", {}).get("total")
            out["status"] = "committed"

    # ledger burn rate: $ per wall-clock second over the charge stream,
    # plus a recent window (the live number an operator actually watches)
    if len(charges) >= 2:
        span = charges[-1].ts - charges[0].ts
        spent = (charges[-1].payload["total"] - charges[0].payload["total"])
        recent = charges[-min(len(charges), 8):]
        rspan = recent[-1].ts - recent[0].ts
        rspent = (recent[-1].payload["total"] - recent[0].payload["total"])
        out["burn"] = {
            "per_second": spent / span if span > 0 else None,
            "recent_per_second": rspent / rspan if rspan > 0 else None,
            "window_seconds": span}
    return out


def render(s: Dict) -> str:
    """The terminal view of one :func:`summarize` pass."""
    lines = [f"campaign {s['campaign']}  [{s['status']}]  "
             f"{s['events']} events  pool={s['pool_size']}"]
    rt = s["runtime"]
    if rt:
        lines.append("runtime: " + ", ".join(f"{k}={v}"
                                             for k, v in rt.items()))
    if s["iterations"]:
        lines.append("")
        lines.append(f"{'it':>4} {'|B|':>7} {'delta':>6} {'C*':>10} "
                     f"{'B_opt':>7} {'theta':>6} {'human$':>9} "
                     f"{'train$':>9} {'stable':>6}")
        for r in s["iterations"]:
            lines.append(
                f"{r['i']:>4} {r['B_size']:>7} {r['delta']:>6} "
                f"{r['cstar']:>10.2f} {r['B_opt']:>7} "
                f"{r['theta_opt']:>6.2f} {r['human_spent']:>9.2f} "
                f"{r['training_spent']:>9.2f} "
                f"{'yes' if r['stable'] else '':>6}")
    if s["ledger"]:
        led = s["ledger"]
        lines.append("")
        lines.append(
            f"ledger: total ${led['total']:.2f}  (human ${led['human']:.2f}"
            f" / training ${led['training']:.2f}  "
            f"{led['human_labels']} labels, {led['human_votes']} votes)")
    if s["burn"]:
        b = s["burn"]
        rate = b["recent_per_second"] or b["per_second"]
        if rate is not None:
            lines.append(f"burn rate: ${rate:.3f}/s (recent)  "
                         f"${b['per_second']:.3f}/s overall over "
                         f"{b['window_seconds']:.1f}s")
    if s["annotator"]:
        first, last = s["annotator"][0], s["annotator"][-1]
        lines.append(
            f"annotators: residual error {first['residual_error']:.3f} -> "
            f"{last['residual_error']:.3f}, avg repeats "
            f"{last['avg_repeats']:.2f}, worker accuracy "
            f"min {last['min_worker_accuracy']:.2f} / "
            f"mean {last['mean_worker_accuracy']:.2f} "
            f"({len(s['annotator'])} snapshots)")
    ov = s["fits"]
    if ov["submitted"] or s["sweeps"]["cuts"] or s["sweeps"]["done"]:
        lines.append(
            f"runtimes: {ov['folded']}/{ov['submitted']} async fits "
            f"folded, {s['sweeps']['done']} sweeps "
            f"({s['sweeps']['cuts']} cursor cuts)")
    if s["saves"] or s["resumes"]:
        lines.append(f"fault tolerance: {s['saves']} state saves, "
                     f"{s['resumes']} resumes")
    if s["commit"]:
        c = s["commit"]
        lines.append(
            f"COMMITTED: {c['decision']}  |B|={c['B_size']} "
            f"S={c['S_size']} theta={c['theta_final']:.2f}  "
            f"measured_error={c['measured_error']:.4f}  "
            f"total ${c['total_cost']:.2f}")
    elif s["done_reason"]:
        lines.append(f"loop done ({s['done_reason']}), not yet committed")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None):
    ap = argparse.ArgumentParser(
        description="live view of an MCAL campaign trace")
    ap.add_argument("trace", help="trace JSONL path (may be mid-write)")
    ap.add_argument("--json", action="store_true",
                    help="print the summary as JSON instead of text")
    ap.add_argument("--watch", type=float, default=0.0, metavar="SECONDS",
                    help="re-render every N seconds until the campaign "
                         "commits (0 = render once)")
    args = ap.parse_args(argv)
    while True:
        s = summarize(args.trace)
        if args.json:
            print(json.dumps(s, indent=2))
        else:
            print(render(s))
        if not args.watch or s["commit"] is not None:
            return
        time.sleep(args.watch)
        print()


if __name__ == "__main__":
    main()
