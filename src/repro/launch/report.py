"""Live campaign observability from the trace alone.

Renders a running (or finished) campaign's cost-vs-iteration curve,
ledger burn rate, and annotator quality drift straight from its trace
file — including one that is still being written (``read_trace``
tolerates the mid-write truncated final line), so an operator can watch
a campaign without touching the process driving it:

    PYTHONPATH=src python -m repro.launch.report TRACE.jsonl
    PYTHONPATH=src python -m repro.launch.report TRACE.jsonl --watch 5
    PYTHONPATH=src python -m repro.launch.report TRACE.jsonl --json

The positional path may also be a fleet TRACE DIR (the orchestrator's
``--trace-dir``): every tenant trace renders, plus the fleet's
``metrics.jsonl`` when present.  ``--metrics`` adds the runtime panel
(per-engine time breakdown, compile-cache hit rates, queue depths,
burn rate vs throughput) from the ``metric_span``/``metric_snapshot``
events — recorded telemetry only, nothing is recomputed.  ``--health``
adds the judgment panel (active alerts, SLO breaches, recent
``alert``/``alert_clear``/``slo_breach`` events; in a fleet dir the
stream rides ``fleet.jsonl``) — combined with ``--watch`` it is a live
alert panel.

Everything here reads events only — no jax, no engines, no recompute
(:func:`summarize` imports nothing heavier than the trace store and
the jax-free ``repro.obs`` rollups).
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Optional, Tuple

from repro.trace.store import TraceError, read_trace

# rate denominators below this span (seconds) are noise, not signal: a
# single-burst charge stream or a freshly-resumed trace re-emits its
# events microseconds apart, and dividing by that would report an
# absurd (or inf/NaN) burn rate instead of "no rate yet"
MIN_RATE_SPAN = 1e-3


def summarize(path: str) -> Dict:
    """One pass over the trace -> the observability summary the text and
    JSON views render.  Safe on a trace mid-write."""
    events = read_trace(path)
    out: Dict = {
        "trace": path, "campaign": events[0].campaign if events else "",
        "events": len(events), "status": "empty" if not events else
        "running", "config": {}, "runtime": {}, "pool_size": 0,
        "iterations": [], "ledger": None, "service_ledger": None,
        "burn": None, "annotator": [], "sweeps": {"cuts": 0, "done": 0},
        "fits": {"submitted": 0, "folded": 0},
        "saves": 0, "resumes": 0, "done_reason": None, "commit": None,
        "faults": {"injected": 0, "retries": 0, "autosaves": 0,
                   "by_site": {}},
    }
    if not events:
        return out

    charges: List = []          # campaign-ledger charge events
    for e in events:
        p = e.payload
        if e.kind == "campaign_begin":
            out["config"] = dict(p.get("config", {}))
            out["runtime"] = dict(p.get("runtime", {}))
            out["pool_size"] = int(p.get("pool_size", 0))
        elif e.kind == "charge":
            if p.get("ledger") == "campaign":
                charges.append(e)
                out["ledger"] = {k: p[k] for k in (
                    "human", "training", "human_labels", "human_votes",
                    "total")}
            else:
                out["service_ledger"] = {k: p[k] for k in (
                    "human", "human_votes", "total")}
        elif e.kind == "iteration":
            out["iterations"].append({
                "i": p["i"], "B_size": p["B_size"], "delta": p["delta"],
                "cstar": p["cstar"], "B_opt": p["B_opt"],
                "theta_opt": p["theta_opt"], "stable": p["stable"],
                "human_spent": p["human_spent"],
                "training_spent": p["training_spent"]})
        elif e.kind == "annotator_snapshot":
            acc = p.get("worker_accuracy") or []
            out["annotator"].append({
                "ts": e.ts, "residual_error": p.get("residual_error"),
                "avg_repeats": p.get("avg_repeats"),
                "min_worker_accuracy": min(acc) if acc else None,
                "mean_worker_accuracy": (sum(acc) / len(acc)
                                         if acc else None)})
        elif e.kind == "sweep_cut":
            out["sweeps"]["cuts"] += 1
        elif e.kind == "sweep_done":
            out["sweeps"]["done"] += 1
        elif e.kind == "fit_submit":
            out["fits"]["submitted"] += 1
        elif e.kind == "fit_done":
            out["fits"]["folded"] += 1
        elif e.kind == "state_save":
            out["saves"] += 1
        elif e.kind == "resume":
            out["resumes"] += 1
        elif e.kind == "fault_injected":
            out["faults"]["injected"] += 1
            site = p.get("site", "?")
            out["faults"]["by_site"][site] = (
                out["faults"]["by_site"].get(site, 0) + 1)
        elif e.kind == "retry":
            out["faults"]["retries"] += 1
        elif e.kind == "autosave":
            out["faults"]["autosaves"] += 1
        elif e.kind == "done":
            out["done_reason"] = p.get("reason")
            out["status"] = f"done:{p.get('reason')}"
        elif e.kind == "commit":
            out["commit"] = {k: p.get(k) for k in (
                "decision", "B_size", "S_size", "theta_final",
                "measured_error")}
            out["commit"]["total_cost"] = p.get("ledger", {}).get("total")
            out["status"] = "committed"

    # ledger burn rate: $ per wall-clock second over the charge stream,
    # plus a recent window (the live number an operator actually watches)
    if len(charges) >= 2:
        span = charges[-1].ts - charges[0].ts
        spent = (charges[-1].payload["total"] - charges[0].payload["total"])
        recent = charges[-min(len(charges), 8):]
        rspan = recent[-1].ts - recent[0].ts
        rspent = (recent[-1].payload["total"] - recent[0].payload["total"])
        out["burn"] = {
            "per_second": spent / span if span > MIN_RATE_SPAN else None,
            "recent_per_second": (rspent / rspan
                                  if rspan > MIN_RATE_SPAN else None),
            "window_seconds": span}
    return out


def render(s: Dict) -> str:
    """The terminal view of one :func:`summarize` pass."""
    lines = [f"campaign {s['campaign']}  [{s['status']}]  "
             f"{s['events']} events  pool={s['pool_size']}"]
    rt = s["runtime"]
    if rt:
        lines.append("runtime: " + ", ".join(f"{k}={v}"
                                             for k, v in rt.items()))
    if s["iterations"]:
        lines.append("")
        lines.append(f"{'it':>4} {'|B|':>7} {'delta':>6} {'C*':>10} "
                     f"{'B_opt':>7} {'theta':>6} {'human$':>9} "
                     f"{'train$':>9} {'stable':>6}")
        for r in s["iterations"]:
            lines.append(
                f"{r['i']:>4} {r['B_size']:>7} {r['delta']:>6} "
                f"{r['cstar']:>10.2f} {r['B_opt']:>7} "
                f"{r['theta_opt']:>6.2f} {r['human_spent']:>9.2f} "
                f"{r['training_spent']:>9.2f} "
                f"{'yes' if r['stable'] else '':>6}")
    if s["ledger"]:
        led = s["ledger"]
        lines.append("")
        lines.append(
            f"ledger: total ${led['total']:.2f}  (human ${led['human']:.2f}"
            f" / training ${led['training']:.2f}  "
            f"{led['human_labels']} labels, {led['human_votes']} votes)")
    if s["burn"] and s["burn"]["per_second"] is not None:
        b = s["burn"]
        rate = b["recent_per_second"]
        rate = b["per_second"] if rate is None else rate
        lines.append(f"burn rate: ${rate:.3f}/s (recent)  "
                     f"${b['per_second']:.3f}/s overall over "
                     f"{b['window_seconds']:.1f}s")
    if s["annotator"]:
        first, last = s["annotator"][0], s["annotator"][-1]
        lines.append(
            f"annotators: residual error {first['residual_error']:.3f} -> "
            f"{last['residual_error']:.3f}, avg repeats "
            f"{last['avg_repeats']:.2f}, worker accuracy "
            f"min {last['min_worker_accuracy']:.2f} / "
            f"mean {last['mean_worker_accuracy']:.2f} "
            f"({len(s['annotator'])} snapshots)")
    ov = s["fits"]
    if ov["submitted"] or s["sweeps"]["cuts"] or s["sweeps"]["done"]:
        lines.append(
            f"runtimes: {ov['folded']}/{ov['submitted']} async fits "
            f"folded, {s['sweeps']['done']} sweeps "
            f"({s['sweeps']['cuts']} cursor cuts)")
    if s["saves"] or s["resumes"]:
        lines.append(f"fault tolerance: {s['saves']} state saves, "
                     f"{s['resumes']} resumes")
    f = s.get("faults") or {}
    if f.get("injected") or f.get("retries") or f.get("autosaves"):
        sites = ", ".join(f"{k}×{v}" for k, v in
                          sorted(f.get("by_site", {}).items()))
        lines.append(
            f"fault pressure: {f.get('injected', 0)} injected"
            + (f" ({sites})" if sites else "")
            + f", {f.get('retries', 0)} retries, "
              f"{f.get('autosaves', 0)} autosaves")
    if s["commit"]:
        c = s["commit"]
        lines.append(
            f"COMMITTED: {c['decision']}  |B|={c['B_size']} "
            f"S={c['S_size']} theta={c['theta_final']:.2f}  "
            f"measured_error={c['measured_error']:.4f}  "
            f"total ${c['total_cost']:.2f}")
    elif s["done_reason"]:
        lines.append(f"loop done ({s['done_reason']}), not yet committed")
    return "\n".join(lines)


def summarize_metrics(paths: List[str]) -> Dict:
    """Fold the ``metric_span`` stream and the last ``metric_snapshot``
    from one or more trace files into the ``--metrics`` panel's data.

    Recorded telemetry only: span rows come from ``repro.obs.export``'s
    jax-free rollups; the registry snapshot (counters/gauges/histograms)
    is whatever the campaign last emitted — nothing is recomputed."""
    from repro.obs.export import (cache_hit_rates, queue_stats,
                                  snapshot_counter, span_rollup)
    events = []
    for p in paths:
        events.extend(read_trace(p))
    spans = span_rollup(events)
    snapshot = None
    for e in events:
        if e.kind == "metric_snapshot":
            snapshot = e.payload.get("snapshot")
    rows = [{"name": name, "tenant": tenant, **stats}
            for (name, tenant), stats in sorted(
                spans.items(),
                key=lambda kv: -kv[1]["seconds"])]
    return {
        "spans": rows,
        "snapshot": snapshot,
        "cache": cache_hit_rates(snapshot) if snapshot else {},
        "queues": queue_stats(snapshot) if snapshot else {},
        "rows_swept": (snapshot_counter(snapshot, "sweep_rows_total")
                       if snapshot else 0.0),
        "votes": (snapshot_counter(snapshot, "annotation_votes_total")
                  if snapshot else 0.0),
    }


def render_metrics(ms: Dict, burn: Optional[Dict] = None) -> str:
    """The terminal view of one :func:`summarize_metrics` pass."""
    lines = ["", "== metrics =="]
    rows = ms["spans"]
    if rows:
        total = sum(r["seconds"] for r in rows) or 1.0
        tenants = any(r["tenant"] for r in rows)
        head = f"{'span':<12}"
        if tenants:
            head += f" {'tenant':<10}"
        head += (f" {'count':>6} {'total_s':>9} {'mean_ms':>9} "
                 f"{'max_ms':>9} {'share':>6} {'err':>4}")
        lines.append(head)
        for r in rows:
            line = f"{r['name']:<12}"
            if tenants:
                line += f" {r['tenant'] or '-':<10}"
            mean = r["seconds"] / r["count"] if r["count"] else 0.0
            line += (f" {r['count']:>6} {r['seconds']:>9.3f} "
                     f"{mean * 1e3:>9.2f} {r['max'] * 1e3:>9.2f} "
                     f"{100.0 * r['seconds'] / total:>5.1f}% "
                     f"{r['errors']:>4}")
            lines.append(line)
    else:
        lines.append("(no metric_span events)")
    if ms["cache"]:
        parts = []
        for eng, c in sorted(ms["cache"].items()):
            parts.append(f"{eng} {int(c['hits'])}/"
                         f"{int(c['hits'] + c['misses'])} hits "
                         f"({100.0 * c['rate']:.1f}%)")
        lines.append("compile cache: " + "  ".join(parts))
    if ms["queues"]:
        parts = []
        for q, st in sorted(ms["queues"].items()):
            part = f"{q} depth={int(st.get('depth', 0))}"
            if st.get("waits"):
                part += (f" waits={int(st['waits'])}"
                         f" mean={st['wait_mean'] * 1e3:.1f}ms"
                         f" max={st['wait_max'] * 1e3:.1f}ms")
            parts.append(part)
        lines.append("queues: " + "  ".join(parts))
    # burn rate vs throughput: $/s from the campaign ledger stream next
    # to the device-side row/vote counters the registry accumulated
    sweep_s = sum(r["seconds"] for r in rows if r["name"] == "sweep")
    if ms["rows_swept"]:
        thr = (f"{ms['rows_swept']:.0f} rows swept"
               + (f" ({ms['rows_swept'] / sweep_s:,.0f} rows/s in sweeps)"
                  if sweep_s > MIN_RATE_SPAN else ""))
        if ms["votes"]:
            thr += f", {ms['votes']:.0f} votes"
        rate = None
        if burn:
            rate = burn.get("recent_per_second") or burn.get("per_second")
        if rate is not None:
            thr += f"  @ ${rate:.3f}/s burn"
        lines.append("throughput: " + thr)
    return "\n".join(lines)


def summarize_health(paths: List[str]) -> Dict:
    """Fold the health engine's judgment stream (``alert`` /
    ``alert_clear`` / ``slo_breach`` events) from one or more traces
    into the ``--health`` panel's data.

    Replaying the hysteresis output is trivial because the engine
    already deduplicated it: an ``alert``/``slo_breach`` event opens a
    ``(tenant, detector)`` incident, the matching ``alert_clear``
    closes it — whatever is still open at end-of-trace is the live
    alert set."""
    from repro.obs.health import ALERT_KINDS
    events = []
    for p in paths:
        events.extend(e for e in read_trace(p) if e.kind in ALERT_KINDS)
    events.sort(key=lambda e: e.ts)
    active: Dict[Tuple[str, str], Dict] = {}
    log: List[Dict] = []
    raised = cleared = breaches = 0
    for e in events:
        p = e.payload
        key = (str(p.get("tenant", "")), str(p.get("detector", "")))
        row = {"ts": e.ts, "tick": p.get("tick"), "tenant": key[0],
               "detector": key[1], "kind": e.kind,
               "severity": p.get("severity", "warn")}
        log.append(row)
        if e.kind == "alert_clear":
            cleared += 1
            active.pop(key, None)
        else:
            raised += 1
            if e.kind == "slo_breach":
                breaches += 1
            active[key] = row
    return {
        "alerts_raised": raised, "alerts_cleared": cleared,
        "slo_breaches": breaches, "events": log,
        "active": [active[k] for k in sorted(active)],
    }


def render_health(hs: Dict, tail: int = 8) -> str:
    """The terminal view of one :func:`summarize_health` pass — the
    live alert panel ``--watch --health`` re-renders."""
    lines = ["", "== health =="]
    if not hs["events"]:
        lines.append("(no health events — engine not attached, "
                     "or nothing to report)")
        return "\n".join(lines)
    lines.append(
        f"{hs['alerts_raised']} raised / {hs['alerts_cleared']} cleared "
        f"({hs['slo_breaches']} SLO breaches), "
        f"{len(hs['active'])} active")
    for a in hs["active"]:
        who = a["tenant"] or "fleet"
        lines.append(f"  ACTIVE [{a['severity']}] {who}: {a['detector']}"
                     f"  (since tick {a['tick']})")
    recent = hs["events"][-tail:]
    lines.append(f"last {len(recent)} events:")
    mark = {"alert": "!", "slo_breach": "x", "alert_clear": "-"}
    for r in recent:
        who = r["tenant"] or "fleet"
        lines.append(f"  {mark.get(r['kind'], '?')} tick {r['tick']:>3}  "
                     f"{who:<10} {r['detector']:<22} {r['kind']}")
    return "\n".join(lines)


def _trace_paths(path: str) -> Tuple[List[str], List[str]]:
    """(campaign traces, metric-event sources) for a file or fleet dir.

    A file is both its own campaign trace and its own metrics stream
    (solo campaigns interleave metric events into the one trace).  A
    fleet dir contributes every tenant trace plus the orchestrator's
    standalone ``metrics.jsonl``; ``fleet.jsonl`` is control-plane only
    and renders through neither view."""
    if os.path.isdir(path):
        names = sorted(os.listdir(path))
        camps = [os.path.join(path, n) for n in names
                 if n.endswith(".jsonl")
                 and n not in ("fleet.jsonl", "metrics.jsonl")]
        if not camps:
            raise FileNotFoundError(
                f"no campaign traces in {path!r} yet")
        metrics = [os.path.join(path, n) for n in names
                   if n == "metrics.jsonl"]
        return camps, metrics + camps
    return [path], [path]


def _health_paths(path: str) -> List[str]:
    """Where ``--health`` reads alert events: a solo trace carries its
    own judgment stream; in a fleet dir the health engine rides the
    orchestrator's ``fleet.jsonl`` (tenant traces are still scanned —
    a tenant may have attached its own engine solo-style)."""
    if os.path.isdir(path):
        names = sorted(os.listdir(path))
        return [os.path.join(path, n) for n in names
                if n.endswith(".jsonl") and n != "metrics.jsonl"]
    return [path]


def main(argv: Optional[List[str]] = None):
    ap = argparse.ArgumentParser(
        description="live view of an MCAL campaign trace")
    ap.add_argument("trace", help="trace JSONL path (may be mid-write) "
                                  "or a fleet trace dir")
    ap.add_argument("--json", action="store_true",
                    help="print the summary as JSON instead of text")
    ap.add_argument("--watch", type=float, default=0.0, metavar="SECONDS",
                    help="re-render every N seconds until the campaign "
                         "commits (0 = render once)")
    ap.add_argument("--metrics", action="store_true",
                    help="append the runtime metrics panel (per-engine "
                         "time breakdown, cache hit rates, queue depths, "
                         "burn vs throughput)")
    ap.add_argument("--metrics-file", default=None, metavar="PATH",
                    help="read metric events from PATH instead of the "
                         "trace itself")
    ap.add_argument("--health", action="store_true",
                    help="append the health panel (active alerts, SLO "
                         "breaches, recent judgment events) — with "
                         "--watch this is a live alert panel")
    args = ap.parse_args(argv)
    while True:
        try:
            camps, msources = _trace_paths(args.trace)
            if args.metrics_file:
                msources = [args.metrics_file]
            summaries = [summarize(p) for p in camps]
            ms = summarize_metrics(msources) if args.metrics else None
            hs = (summarize_health(_health_paths(args.trace))
                  if args.health else None)
        except (TraceError, OSError) as exc:
            # a watched trace can vanish mid-poll (rotation, the writer
            # re-creating its dir, a tenant not started yet) — in watch
            # mode that is a transient, not an error: re-wait
            if not args.watch:
                raise
            print(f"# waiting for {args.trace}: {exc}", flush=True)
            time.sleep(args.watch)
            continue
        if args.json:
            blob: Dict = (summaries[0] if len(summaries) == 1
                          else {"tenants": summaries})
            if ms is not None:
                blob = dict(blob)
                blob["metrics"] = {k: v for k, v in ms.items()
                                   if k != "snapshot"}
                blob["metrics"]["snapshot"] = ms["snapshot"]
            if hs is not None:
                blob = dict(blob)
                blob["health"] = hs
            print(json.dumps(blob, indent=2))
        else:
            for i, s in enumerate(summaries):
                if i:
                    print()
                print(render(s))
            if ms is not None:
                burn = (summaries[0]["burn"]
                        if len(summaries) == 1 else None)
                print(render_metrics(ms, burn))
            if hs is not None:
                print(render_health(hs))
        done = all(s["commit"] is not None for s in summaries)
        if not args.watch or done:
            return
        time.sleep(args.watch)
        print()


if __name__ == "__main__":
    main()
