"""Analytic per-device memory residents per cell (the fits-proof).

The dry-run's CPU-backend ``temp_bytes`` over-counts hoisted f32 converts
of bf16 weights/caches (EXPERIMENTS §Dry-run); this script computes the
TPU-side residents analytically so the fits claim is reproducible:

  params shard + optimizer slots + grad/accum carry (train)
  + residual-stream scan carries + KV/SSM cache shard (serve)

Usage:  PYTHONPATH=src python -m repro.launch.fitsproof [--mesh single]
"""
from __future__ import annotations

import argparse
import math

from repro.configs import ARCH_IDS, cells, get_config
from repro.launch.roofline import mesh_sizes, param_counts, _cache_bytes

HBM_PER_CHIP = 16e9


def residents(cfg, shape, mesh_kind: str, grad_accum: int = 1):
    sizes = mesh_sizes(mesh_kind)
    n_dev = math.prod(sizes.values())
    dp = sizes.get("pod", 1) * sizes.get("data", 1)
    tp = sizes.get("model", 1)
    pc = param_counts(cfg)
    wd = dp * tp if cfg.sharding in ("fsdp_tp", "fsdp") else tp
    params = pc.total * 2 / wd
    out = {"params": params}
    if shape.kind == "train":
        big = pc.total >= 100e9
        m_bytes = 1 if big else 4            # int8 moments for giants
        v_bytes = 0.1 if big or pc.total >= 10e9 else 4  # factored v
        out["opt"] = pc.total * (m_bytes + v_bytes) / wd
        grad_b = 2 if big else 4
        out["grads"] = pc.total * grad_b / wd
        b_local = max(shape.global_batch // dp, 1)
        layers = cfg.num_layers + cfg.encoder_layers
        out["carries"] = (b_local * shape.seq_len * cfg.d_model * 2 *
                          layers / max(grad_accum, 1))
    else:
        cache_ways = n_dev  # cache_batch x cache_seq shard over the mesh
        out["cache"] = _cache_bytes(cfg, shape.global_batch,
                                    shape.seq_len) / cache_ways
        out["act"] = (shape.global_batch / dp) * \
            min(shape.seq_len, 4096) * cfg.d_model * 4 * 4
    out["total"] = sum(out.values())
    out["fits"] = out["total"] <= HBM_PER_CHIP * 0.9
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=("single", "multi"))
    args = ap.parse_args()
    import json
    accums = {}
    try:
        with open("results/dryrun.jsonl") as f:
            for line in f:
                r = json.loads(line)
                if r.get("grad_accum") and r["mesh"] == args.mesh:
                    accums[(r["arch"], r["shape"])] = r["grad_accum"]
    except FileNotFoundError:
        pass
    print(f"{'arch':22s} {'shape':12s} {'params':>8s} {'opt':>7s} "
          f"{'grads':>7s} {'carry':>7s} {'cache':>7s} {'total':>8s} fits")
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in cells(arch):
            ga = accums.get((arch, shape.name), 1)
            r = residents(cfg, shape, args.mesh, ga)
            gb = lambda k: f"{r.get(k, 0) / 1e9:7.2f}"
            print(f"{arch:22s} {shape.name:12s} {gb('params')} {gb('opt')} "
                  f"{gb('grads')} {gb('carries')} {gb('cache')} "
                  f"{r['total'] / 1e9:8.2f} {'Y' if r['fits'] else 'NO'}")


if __name__ == "__main__":
    main()
