import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production meshes and extract the roofline inputs.

The two lines above MUST run before any other import (jax locks the device
count on first init), which is why this module sets XLA_FLAGS at the very
top and why nothing else in the repo sets it globally.

Per cell this emits JSON:
  flops            — compiled.cost_analysis()["flops"]
  bytes_accessed   — cost_analysis bytes (HBM traffic proxy)
  collectives      — {op: operand_bytes} parsed from the optimized HLO
  memory           — compiled.memory_analysis() per-device byte sizes
  peak_bytes       — argument+output+temp+generated (fits-check)

Usage:
  python -m repro.launch.dryrun --arch gemma3-4b --shape train_4k --mesh multi
  python -m repro.launch.dryrun --sweep          # every cell, subprocesses
"""
import argparse
import json
import math
import re
import subprocess
import sys
from typing import Dict, Optional

import numpy as np


# ---------------------------------------------------------------------------
# per-arch train config + microbatching policy
# ---------------------------------------------------------------------------


def pick_train_config(param_count: int):
    """Optimizer-memory policy by model size (ZeRO-sharded either way)."""
    from repro.configs.base import TrainConfig
    if param_count >= 100e9:
        return TrainConfig(moment_dtype="int8", factored_second_moment=True,
                           accum_dtype="bfloat16")
    if param_count >= 10e9:
        return TrainConfig(moment_dtype="bfloat16", factored_second_moment=True)
    return TrainConfig()


def pick_grad_accum(cfg, shape, mesh) -> int:
    """Smallest power-of-two microbatch count keeping the per-device
    residual-stream carries (layers x B_local x T x D x 2B, the scan
    checkpoints reverse-mode must store) under ~2 GB.  The batch-sharding
    ways come from the active policy (e.g. "fsdp" shards batch over the
    whole mesh) and each microbatch must stay divisible by them."""
    if shape.kind != "train":
        return 1
    from repro.distributed.sharding import POLICIES
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    assign = POLICIES[cfg.sharding]["batch"]
    names = (assign,) if isinstance(assign, str) else tuple(assign or ())
    ways = 1
    for n in names:
        if n in sizes and shape.global_batch % (ways * sizes[n]) == 0:
            ways *= sizes[n]
    b_local = max(shape.global_batch // ways, 1)
    layers = cfg.num_layers + cfg.encoder_layers
    seq_assign = POLICIES[cfg.sharding].get("seq")
    seq_ways = sizes.get(seq_assign, 1) if isinstance(seq_assign, str) else 1
    if shape.seq_len % max(seq_ways, 1):
        seq_ways = 1
    carry = b_local * (shape.seq_len // seq_ways) * cfg.d_model * 2 * layers
    budget = 2 * 1024 ** 3
    accum = 1
    while carry / accum > budget and accum < b_local and \
            (shape.global_batch // (accum * 2)) % ways == 0:
        accum *= 2
    return accum


# ---------------------------------------------------------------------------
# lowering one cell
# ---------------------------------------------------------------------------


def build_step(arch: str, shape_name: str, multi_pod: bool,
               policy: Optional[str] = None):
    """-> (jitted fn, example abstract args tuple, mesh, meta dict)."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, input_pspecs, input_specs
    from repro.configs.base import SHAPES_BY_NAME
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_production_mesh
    from repro.models.registry import get_model
    from repro.training.train_loop import make_sharded_train_step, state_pspecs

    cfg = get_config(arch)
    if policy:  # §Perf hillclimb: "<policy>[+int8gather][+a2a]"
        parts = policy.split("+")
        for flag in parts[1:]:
            if flag == "int8gather":
                cfg = cfg.replace(moe_gather_dtype="int8")
            elif flag == "a2a":
                cfg = cfg.replace(moe_route="a2a")
        if parts[0]:
            cfg = cfg.replace(sharding=parts[0])
    shape = SHAPES_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    policy = cfg.sharding
    model = get_model(cfg)
    meta = {"arch": arch, "shape": shape_name,
            "mesh": "multi" if multi_pod else "single",
            "policy": policy,
            "params": model.param_count()}

    if shape.kind == "train":
        import dataclasses
        accum = pick_grad_accum(cfg, shape, mesh)
        meta["grad_accum"] = accum
        tc = dataclasses.replace(pick_train_config(model.param_count()),
                                 grad_accum=accum)
        batch_ps = input_pspecs(cfg, shape, mesh, policy, accum)
        step, ab_state, _ = make_sharded_train_step(
            model, tc, mesh, policy, batch_ps)
        ab_batch = input_specs(cfg, shape, accum)
        return step, (ab_state, ab_batch), mesh, meta

    # serving path
    ab_params = model.abstract_params()
    lg_params = model.logical_axes()
    p_sh = shd.tree_named(
        mesh, shd.tree_pspecs(ab_params, lg_params, mesh, policy))
    ab_batch = input_specs(cfg, shape)
    batch_ps = input_pspecs(cfg, shape, mesh, policy)
    b_sh = {k: shd.named(mesh, v) for k, v in batch_ps.items()}

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            hidden, cache = model.prefill(params, batch, mesh=mesh)
            logits = model.logits(params, hidden[:, -1:, :])
            return logits, cache

        step = jax.jit(prefill_step, in_shardings=(p_sh, b_sh))
        return step, (ab_params, ab_batch), mesh, meta

    # decode: one new token against a seq_len cache
    ab_cache, lg_cache = model.cache_specs(shape.global_batch, shape.seq_len)
    c_sh = shd.tree_named(
        mesh, shd.tree_pspecs(ab_cache, lg_cache, mesh, policy))
    tok_sh = shd.named(mesh, batch_ps["tokens"])

    def serve_step(params, cache, tokens, cache_len):
        return model.decode_step(params, cache, tokens, cache_len, mesh=mesh)

    step = jax.jit(serve_step,
                   in_shardings=(p_sh, c_sh, tok_sh, None),
                   out_shardings=(None, c_sh),
                   donate_argnums=(1,))
    ab_tok = ab_batch["tokens"]
    ab_len = jax.ShapeDtypeStruct((), jnp.int32)
    return step, (ab_params, ab_cache, ab_tok, ab_len), mesh, meta


COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")
_SHAPE_RE = re.compile(r"([a-z]+\d*)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def parse_collectives(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective op from optimized HLO text."""
    # first pass: instruction name -> output shape bytes
    shapes: Dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m:
            shapes[m.group(1)] = m.group(2)
    out = {op: 0 for op in COLLECTIVE_OPS}
    counts = {op: 0 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        op = m.group(3)
        base = None
        for c in COLLECTIVE_OPS:
            if op == c or op.startswith(c + "-start") or op == c + "-done":
                base = c
                break
        if base is None or op.endswith("-done"):
            continue
        # operand names inside (...) after the op token
        paren = line[line.find("(", line.find(op)) + 1:]
        depth, cur, args = 1, "", []
        for ch in paren:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    args.append(cur)
                    break
            if depth >= 1:
                cur += ch
        names = [a.strip().lstrip("%") for a in args[0].split(",")] if args else []
        b = 0
        for nm in names:
            nm = nm.split(" ")[0].strip()
            if nm in shapes:
                b += _shape_bytes(shapes[nm])
        if b == 0:  # fallback: output size
            b = _shape_bytes(m.group(2))
        out[base] += b
        counts[base] += 1
    return {"bytes": out, "counts": counts}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             keep_hlo: Optional[str] = None,
             policy: Optional[str] = None) -> Dict:
    import jax
    step, args, mesh, meta = build_step(arch, shape_name, multi_pod, policy)
    with mesh:
        lowered = step.lower(*args)
        compiled = lowered.compile()
    from repro.compat import cost_analysis_dict
    cost = cost_analysis_dict(compiled)
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # pragma: no cover
        mem_d = {"error": str(e)}
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    if keep_hlo:
        with open(keep_hlo, "w") as f:
            f.write(hlo)
    out = dict(meta)
    out.update({
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "collective_bytes": coll["bytes"],
        "collective_counts": coll["counts"],
        "memory": mem_d,
        "n_devices": int(np.prod(mesh.devices.shape)),
    })
    return out


# ---------------------------------------------------------------------------
# sweep driver (subprocess per cell: isolation + memory reclamation)
# ---------------------------------------------------------------------------


def sweep(meshes=("single", "multi"), archs=None, shapes=None,
          out_path="results/dryrun.jsonl", timeout: int = 1800):
    from repro.configs import ARCH_IDS, cells
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    done = set()
    if os.path.exists(out_path):
        with open(out_path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"]))
                except Exception:
                    pass
    failures = []
    for arch in (archs or ARCH_IDS):
        for shape in cells(arch):
            if shapes and shape.name not in shapes:
                continue
            for mesh_kind in meshes:
                key = (arch, shape.name, mesh_kind)
                if key in done:
                    print(f"[skip] {key}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape.name,
                       "--mesh", mesh_kind, "--append", out_path]
                print(f"[run ] {arch} x {shape.name} x {mesh_kind}",
                      flush=True)
                try:
                    r = subprocess.run(cmd, capture_output=True, text=True,
                                       timeout=timeout)
                    if r.returncode != 0:
                        failures.append((key, r.stderr[-2000:]))
                        print(f"[FAIL] {key}\n{r.stderr[-2000:]}", flush=True)
                except subprocess.TimeoutExpired:
                    failures.append((key, "timeout"))
                    print(f"[TIME] {key}", flush=True)
    print(f"sweep done; {len(failures)} failures")
    for key, err in failures:
        print("FAILED:", key)
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--append", help="append result JSON to this file")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--keep-hlo")
    ap.add_argument("--policy", help="override the sharding policy (perf)")
    args = ap.parse_args()
    if args.sweep:
        failures = sweep(out_path=args.out)
        sys.exit(1 if failures else 0)
    res = run_cell(args.arch, args.shape, args.mesh == "multi",
                   keep_hlo=args.keep_hlo, policy=args.policy)
    js = json.dumps(res)
    print(js)
    if args.append:
        with open(args.append, "a") as f:
            f.write(js + "\n")


if __name__ == "__main__":
    main()
