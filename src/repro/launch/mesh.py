"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run must set
``--xla_force_host_platform_device_count`` before first jax init).

Mesh axes:
  single-pod: (16, 16)      -> ("data", "model")      = 256 chips
  multi-pod:  (2, 16, 16)   -> ("pod", "data", "model") = 512 chips

Batch shards over ("pod", "data"); TP/EP over "model"; the "pod" axis is
the slow inter-pod link where gradient compression applies.
"""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes, axis_types=True)


def make_host_mesh():
    """Whatever devices exist, as a 1-D 'data' mesh (tests/smoke runs)."""
    n = len(jax.devices())
    return compat.make_mesh((n,), ("data",), axis_types=True)
