"""MCAL labeling-campaign launcher — the paper's end-to-end system.

Live mode (real training on this host):
    PYTHONPATH=src python -m repro.launch.label --live --pool 4000 \
        --classes 10 --difficulty 0.3 --eps 0.05 --service amazon

Replay mode (paper-scale emulated learning curves):
    PYTHONPATH=src python -m repro.launch.label --dataset cifar10 \
        --arch resnet18 --service amazon

Campaign state (ledger, pool bitmap, per-theta history, fitted power
laws, engine pack-shape cache keys) checkpoints to ``--state`` after
every iteration, so a preempted campaign resumes mid-loop — and during
the commit sweep a resumable ``SweepCheckpoint`` cursor is embedded
every ``--sweep-ckpt-pages`` pages, so even a mid-pool L(.) sweep
survives a restart.  ``--iters-per-run`` bounds how many iterations one
invocation runs (preemptible-worker style): when the campaign is not
done yet the invocation saves state and exits with a resumable report.
"""
from __future__ import annotations

import argparse
import json
import os


# every selection-module metric plus the paper's random baseline
# (supported by select_for_training but previously missing from the CLI).
# A literal, not `selection.METRICS`: importing repro.core pulls in jax,
# and the launcher must stay cheap until parsing succeeds (--help never
# pays for it).  tests/test_label_launcher.py asserts the sets match, so
# drift fails CI.
METRIC_CHOICES = ("margin", "entropy", "least_confidence", "kcenter",
                  "random")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--live", action="store_true")
    ap.add_argument("--dataset", default="cifar10",
                    choices=("fashion", "cifar10", "cifar100", "imagenet"))
    ap.add_argument("--arch", default="resnet18")
    ap.add_argument("--pool", type=int, default=4000)
    ap.add_argument("--classes", type=int, default=10)
    ap.add_argument("--difficulty", type=float, default=0.3)
    ap.add_argument("--eps", type=float, default=0.05)
    ap.add_argument("--budget", type=float, default=None)
    ap.add_argument("--metric", default="margin", choices=METRIC_CHOICES)
    ap.add_argument("--service", default="amazon",
                    choices=("amazon", "satyam"))
    ap.add_argument("--sweep-page", type=int, default=8192,
                    help="pool-sweep runtime page rows (the paged, "
                         "double-buffered L(.)/M(.) pool passes)")
    ap.add_argument("--sweep-async", action="store_true",
                    help="overlap each iteration's M(.) sweep with the "
                         "host-side power-law fits + joint search")
    ap.add_argument("--fit-fused", dest="fit_fused", action="store_true",
                    default=True,
                    help="fused-scan retrain engine: the whole fixed-epoch "
                         "retrain as one device program (default)")
    ap.add_argument("--no-fit-fused", dest="fit_fused", action="store_false",
                    help="per-step host training loop (the exact-agreement "
                         "oracle path)")
    ap.add_argument("--fit-async", action="store_true",
                    help="defer each retrain + its measurement sweep onto "
                         "the fit-engine worker thread (overlaps the "
                         "retrain dispatch; iteration records are "
                         "identical to the synchronous campaign)")
    ap.add_argument("--fit-resident", action="store_true",
                    help="keep the labeled set device-resident across "
                         "iterations; only newly bought labels upload")
    ap.add_argument("--state", default="",
                    help="campaign state file: saved every iteration (and "
                         "every --sweep-ckpt-pages pages of the commit "
                         "sweep); an existing file is resumed")
    ap.add_argument("--sweep-ckpt-pages", type=int, default=0,
                    help="cut a resumable commit-sweep cursor into --state "
                         "every N pages (0 disables)")
    ap.add_argument("--iters-per-run", type=int, default=0,
                    help="run at most N iterations this invocation, then "
                         "save --state and exit resumable (0 = run to "
                         "completion)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    return ap


def _save_state(path: str, campaign=None, cursor=None, campaign_blob=None):
    """Atomic-ish state write: campaign loop state + optional mid-sweep
    cursor (the cursor is only meaningful for the commit sweep cut against
    the saved loop state).  Pass ``campaign_blob`` to reuse an already
    serialized campaign dict — cursor cuts fire every few pages and the
    loop state is frozen for the whole commit sweep, so re-serializing
    the O(pool) label list per cut would dominate the sweep itself."""
    blob = {"campaign": campaign_blob if campaign_blob is not None
            else campaign.state_dict()}
    if cursor is not None:
        blob["sweep_cursor"] = cursor.to_json()
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(blob, f)
    os.replace(tmp, path)


def run_campaign(task, service, cfg, *, state_path: str = "",
                 sweep_ckpt_pages: int = 0, iters_per_run: int = 0):
    """Drive one campaign with optional ``--state`` fault tolerance.
    Returns (MCALResult | None, campaign) — result is None when
    ``iters_per_run`` preempted the loop before completion."""
    from repro.core import MCALCampaign
    from repro.serving.sweep import SweepCheckpoint

    camp = MCALCampaign(task, service, cfg)
    if state_path and os.path.exists(state_path):
        with open(state_path) as f:
            blob = json.load(f)
        camp.load_state_dict(blob["campaign"])
        if "sweep_cursor" in blob:
            camp.resume_sweep_checkpoint = SweepCheckpoint.from_json(
                blob["sweep_cursor"])
    else:
        camp.bootstrap()
        if state_path:
            _save_state(state_path, camp)

    if state_path and sweep_ckpt_pages:
        camp.sweep_checkpoint_every = sweep_ckpt_pages
        frozen = {}   # campaign blob serialized once at the first cut

        def save_cursor(ck):
            if "blob" not in frozen:
                frozen["blob"] = camp.state_dict()
            _save_state(state_path, cursor=ck,
                        campaign_blob=frozen["blob"])

        camp.on_sweep_checkpoint = save_cursor

    ran = 0
    while not camp.done:
        camp.iteration()
        ran += 1
        if state_path:
            _save_state(state_path, camp)
        if iters_per_run and ran >= iters_per_run and not camp.done:
            return None, camp
    res = camp.commit()
    if state_path and os.path.exists(state_path):
        os.remove(state_path)   # campaign complete: the state is spent
    return res, camp


def main():
    args = build_parser().parse_args()

    from repro.core import (MCALConfig, SERVICES, LiveTask,
                            make_emulated_task)
    from repro.data.synth import make_classification

    service = SERVICES[args.service]
    cfg = MCALConfig(eps_target=args.eps, metric=args.metric,
                     budget=args.budget, seed=args.seed,
                     sweep_async=args.sweep_async,
                     fit_async=args.fit_async)
    if args.live:
        x, y = make_classification(args.pool, num_classes=args.classes,
                                   difficulty=args.difficulty,
                                   seed=args.seed)
        task = LiveTask(features=x, groundtruth=y, num_classes=args.classes,
                        seed=args.seed, sweep_page=args.sweep_page,
                        fit_fused=args.fit_fused,
                        fit_resident=args.fit_resident)
    else:
        task = make_emulated_task(args.dataset, args.arch, seed=args.seed,
                                  sweep_page=args.sweep_page)

    res, camp = run_campaign(task, service, cfg, state_path=args.state,
                             sweep_ckpt_pages=args.sweep_ckpt_pages,
                             iters_per_run=args.iters_per_run)
    if res is None:
        report = {"resumable": True, "state": args.state,
                  "iterations": len(camp.history),
                  "B_size": len(camp.pool.B_idx)}
        print(json.dumps(report, indent=2))
        return
    X = task.pool_size
    human_all = X * service.price_per_label
    report = {
        "decision": res.decision,
        "B_frac": res.B_size / X,
        "S_frac": res.S_size / X,
        "theta_final": res.theta_final,
        "measured_error": res.measured_error,
        "cost": res.total_cost,
        "human_all_cost": human_all,
        "savings": 1.0 - res.total_cost / human_all,
        "ledger": res.ledger,
        "iterations": len(res.history),
    }
    print(json.dumps(report, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f)


if __name__ == "__main__":
    main()
