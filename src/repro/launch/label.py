"""MCAL labeling-campaign launcher — the paper's end-to-end system.

Live mode (real training on this host):
    PYTHONPATH=src python -m repro.launch.label --live --pool 4000 \
        --classes 10 --difficulty 0.3 --eps 0.05 --service amazon

Replay mode (paper-scale emulated learning curves):
    PYTHONPATH=src python -m repro.launch.label --dataset cifar10 \
        --arch resnet18 --service amazon

Noisy annotation service (repeated labeling, aggregated on device):
    PYTHONPATH=src python -m repro.launch.label --dataset cifar10 \
        --annotator-noise 0.2 --label-repeats 3 --annotator-aggregate ds \
        --adaptive-repeats --max-repeats 5

``--annotator-noise > 0`` (or ``--label-repeats > 1``) replaces the
perfect oracle with a seeded noisy-annotator pool: every human label is
an aggregation (majority vote or Dawid-Skene EM, jit-compiled on device)
over per-worker votes, every vote is charged at the service rate, and
the campaign folds the residual aggregated-label error into its accuracy
target (``MCALConfig.label_quality``).

Campaign state (ledger, pool bitmap, per-theta history, fitted power
laws, engine pack-shape cache keys) checkpoints to ``--state`` after
every iteration, so a preempted campaign resumes mid-loop — and during
the commit sweep a resumable ``SweepCheckpoint`` cursor is embedded
every ``--sweep-ckpt-pages`` pages, so even a mid-pool L(.) sweep
survives a restart.  ``--iters-per-run`` bounds how many iterations one
invocation runs (preemptible-worker style): when the campaign is not
done yet the invocation saves state and exits with a resumable report.
"""
from __future__ import annotations

import argparse
import json
import os


# every selection-module metric plus the paper's random baseline
# (supported by select_for_training but previously missing from the CLI).
# A literal, not `selection.METRICS`: importing repro.core pulls in jax,
# and the launcher must stay cheap until parsing succeeds (--help never
# pays for it).  tests/test_label_launcher.py asserts the sets match, so
# drift fails CI.
METRIC_CHOICES = ("margin", "entropy", "least_confidence", "kcenter",
                  "random")

# annotation.service.AGGREGATORS, duplicated as a literal for the same
# reason as METRIC_CHOICES (parsing must not import jax); the launcher
# tests assert the sets match.
AGGREGATE_CHOICES = ("majority", "ds")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--live", action="store_true")
    ap.add_argument("--dataset", default="cifar10",
                    choices=("fashion", "cifar10", "cifar100", "imagenet"))
    ap.add_argument("--arch", default="resnet18")
    ap.add_argument("--pool", type=int, default=4000)
    ap.add_argument("--classes", type=int, default=10)
    ap.add_argument("--difficulty", type=float, default=0.3)
    ap.add_argument("--eps", type=float, default=0.05)
    ap.add_argument("--budget", type=float, default=None)
    ap.add_argument("--metric", default="margin", choices=METRIC_CHOICES)
    ap.add_argument("--service", default="amazon",
                    choices=("amazon", "satyam"))
    ap.add_argument("--sweep-page", type=int, default=8192,
                    help="pool-sweep runtime page rows (the paged, "
                         "double-buffered L(.)/M(.) pool passes)")
    ap.add_argument("--sweep-async", action="store_true",
                    help="overlap each iteration's M(.) sweep with the "
                         "host-side power-law fits + joint search")
    ap.add_argument("--fit-fused", dest="fit_fused", action="store_true",
                    default=True,
                    help="fused-scan retrain engine: the whole fixed-epoch "
                         "retrain as one device program (default)")
    ap.add_argument("--no-fit-fused", dest="fit_fused", action="store_false",
                    help="per-step host training loop (the exact-agreement "
                         "oracle path)")
    ap.add_argument("--fit-async", action="store_true",
                    help="defer each retrain + its measurement sweep onto "
                         "the fit-engine worker thread (overlaps the "
                         "retrain dispatch; iteration records are "
                         "identical to the synchronous campaign)")
    ap.add_argument("--fit-resident", action="store_true",
                    help="keep the labeled set device-resident across "
                         "iterations; only newly bought labels upload")
    ap.add_argument("--state", default="",
                    help="campaign state file: saved every iteration (and "
                         "every --sweep-ckpt-pages pages of the commit "
                         "sweep); an existing file is resumed")
    ap.add_argument("--autosave", default="", metavar="PATH",
                    help="crash-safe sidecar: on any unhandled fault past "
                         "bootstrap the campaign flushes its trace and "
                         "writes state_dict here (atomic rename); the "
                         "next invocation resumes from it bit-identically "
                         "(--state, when present, wins)")
    ap.add_argument("--sweep-timeout", type=float, default=None,
                    metavar="SECONDS",
                    help="straggler wall budget for the async M(.) sweep "
                         "fold: a hung sweep job raises StragglerTimeout "
                         "instead of blocking forever (default: wait "
                         "forever)")
    ap.add_argument("--fit-timeout", type=float, default=None,
                    metavar="SECONDS",
                    help="straggler wall budget for the async retrain "
                         "fold (default: wait forever)")
    ap.add_argument("--chaos", action="store_true",
                    help="demo fault injection: run under the standard "
                         "transient FaultPlan (flaky annotation backend, "
                         "one broker-job crash per engine, one torn trace "
                         "write) with the default RetryPolicy — the "
                         "campaign must complete and its trace must diff "
                         "clean against a fault-free sibling")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="fault-plan seed (default: --seed)")
    ap.add_argument("--sweep-ckpt-pages", type=int, default=0,
                    help="cut a resumable commit-sweep cursor into --state "
                         "every N pages (0 disables)")
    ap.add_argument("--iters-per-run", type=int, default=0,
                    help="run at most N iterations this invocation, then "
                         "save --state and exit resumable (0 = run to "
                         "completion)")
    ap.add_argument("--mesh", default="",
                    help="host/device mesh spec, e.g. 'data=4': the "
                         "scoring sweep and the fused-fit program shard "
                         "over it (live mode; smoke-testable under "
                         "--xla_force_host_platform_device_count)")
    # -- annotation service (noisy multi-annotator oracle) -----------------
    ap.add_argument("--annotator-noise", type=float, default=0.0,
                    help="per-vote error rate of the noisy annotator "
                         "pool (0 = the paper's perfect-oracle "
                         "assumption, no service attached)")
    ap.add_argument("--annotator-workers", type=int, default=5,
                    help="annotator pool size (each worker votes at most "
                         "once per item)")
    ap.add_argument("--annotator-spammers", type=float, default=0.0,
                    help="fraction of workers answering uniformly at "
                         "random")
    ap.add_argument("--annotator-aggregate", default="majority",
                    choices=AGGREGATE_CHOICES,
                    help="vote aggregation: device majority vote or "
                         "Dawid-Skene EM")
    ap.add_argument("--label-repeats", type=int, default=1,
                    help="votes bought per human label (repeated "
                         "labeling; each vote is charged at the service "
                         "rate)")
    ap.add_argument("--max-repeats", type=int, default=0,
                    help="adaptive-repeats vote cap (0 = --label-repeats, "
                         "no top-up)")
    ap.add_argument("--adaptive-repeats", action="store_true",
                    help="stop buying votes for an item once its "
                         "aggregated posterior confidence clears "
                         "--repeat-confidence (Liao et al.)")
    ap.add_argument("--repeat-confidence", type=float, default=0.9,
                    help="adaptive-repeats confidence threshold")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    # -- campaign trace (event bus) -----------------------------------------
    ap.add_argument("--trace", default="",
                    help="append-only campaign trace (JSONL): every "
                         "decision/charge/measurement event; a campaign "
                         "resumed via --state appends to it at the "
                         "checkpointed cursor.  Watch it live with "
                         "python -m repro.launch.report")
    ap.add_argument("--trace-replay", default="", metavar="TRACE",
                    help="reconstruct a campaign report from its trace "
                         "alone (no engines, zero recompute) and exit")
    ap.add_argument("--trace-diff", nargs=2, default=None,
                    metavar=("TRACE_A", "TRACE_B"),
                    help="first-divergence analysis between two sibling "
                         "campaign traces, then exit")
    # -- runtime metrics & profiling (repro.obs) ----------------------------
    ap.add_argument("--metrics", default="", metavar="PATH",
                    help="record runtime telemetry (spans, counters, "
                         "compile-cache hits) as metric events at PATH; "
                         "pass the --trace path to interleave them into "
                         "the campaign trace (replay/diff ignore them).  "
                         "View with python -m repro.launch.report "
                         "--metrics")
    ap.add_argument("--slo", default="", metavar="SPEC.json",
                    help="streaming health engine: judge the campaign "
                         "against the declarative SLO spec (cost per "
                         "committed label, iteration-latency p95, "
                         "projected quality) plus the detector suite "
                         "(budget burn ETA, annotator drift, fit "
                         "quality, cache storms, queue saturation, "
                         "fault pressure) at every iteration boundary; "
                         "hysteresis-gated alert events interleave into "
                         "--trace (observability kinds — replay/diff "
                         "ignore them).  Render with python -m "
                         "repro.launch.report --health")
    ap.add_argument("--prom", default="", metavar="PATH",
                    help="write a Prometheus textfile snapshot of the "
                         "metrics registry at campaign teardown")
    ap.add_argument("--profile", default="", metavar="DIR",
                    help="bracket one iteration (see --profile-iter) with "
                         "jax.profiler.trace into DIR")
    ap.add_argument("--profile-iter", type=int, default=1,
                    help="which iteration --profile brackets (1-based, "
                         "default: the first)")
    return ap


def build_mesh(spec: str):
    """``--mesh data=4`` -> a host mesh with those axes (None for '')."""
    if not spec:
        return None
    from repro import compat
    axes, shape = [], []
    for part in spec.split(","):
        name, _, n = part.partition("=")
        axes.append(name.strip())
        shape.append(int(n))
    return compat.make_mesh(tuple(shape), tuple(axes), axis_types=True)


def build_annotation(args, num_classes: int, service):
    """The campaign's annotation-service runtime from the CLI flags —
    None when the flags describe the perfect oracle (no noise, single
    vote, no adaptive policy)."""
    if args.annotator_noise <= 0 and args.label_repeats <= 1 \
            and not args.adaptive_repeats:
        return None
    from repro.annotation import make_annotation_service
    return make_annotation_service(
        num_classes, n_workers=args.annotator_workers,
        noise=args.annotator_noise, spammer_frac=args.annotator_spammers,
        repeats=args.label_repeats,
        max_repeats=args.max_repeats or None,
        adaptive=args.adaptive_repeats,
        confidence=args.repeat_confidence,
        aggregator=args.annotator_aggregate,
        pricing=service, seed=args.seed)


def _save_state(path: str, campaign=None, cursor=None, campaign_blob=None):
    """Atomic-ish state write: campaign loop state + optional mid-sweep
    cursor (the cursor is only meaningful for the commit sweep cut against
    the saved loop state).  Pass ``campaign_blob`` to reuse an already
    serialized campaign dict — cursor cuts fire every few pages and the
    loop state is frozen for the whole commit sweep, so re-serializing
    the O(pool) label list per cut would dominate the sweep itself."""
    blob = {"campaign": campaign_blob if campaign_blob is not None
            else campaign.state_dict()}
    if cursor is not None:
        blob["sweep_cursor"] = cursor.to_json()
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(blob, f)
    os.replace(tmp, path)


def run_campaign(task, service, cfg, *, state_path: str = "",
                 sweep_ckpt_pages: int = 0, iters_per_run: int = 0,
                 trace_path: str = "", campaign_id: str = "campaign",
                 metrics_path: str = "", prom_path: str = "",
                 profile_dir: str = "", profile_iter: int = 1,
                 autosave_path: str = "", sweep_timeout=None,
                 fit_timeout=None, faults=None, retry=None,
                 slo_path: str = ""):
    """Drive one campaign with optional ``--state`` fault tolerance and
    an optional ``--trace`` event log.  Returns (MCALResult | None,
    campaign) — result is None when ``iters_per_run`` preempted the loop
    before completion.  A resumed campaign whose state checkpoint embeds
    a trace cursor APPENDS to its existing trace (no gaps, no duplicate
    sequence numbers); otherwise the trace starts fresh.

    ``metrics_path``/``prom_path``/``profile_dir`` wire the runtime
    observability layer (``repro.obs``): any of them builds a
    ``MetricsRegistry`` and attaches it to the campaign.  When
    ``metrics_path`` names the same file as ``trace_path`` the metric
    events interleave into the campaign trace (they are observability
    kinds — replay and diff ignore them); a distinct path gets its own
    store.  ``profile_dir`` brackets iteration ``profile_iter`` with
    ``jax.profiler.trace``."""
    from repro.core import MCALCampaign
    from repro.serving.sweep import SweepCheckpoint

    camp = MCALCampaign(task, service, cfg)
    camp.sweep_timeout = sweep_timeout
    camp.fit_timeout = fit_timeout
    blob = None
    if state_path and os.path.exists(state_path):
        with open(state_path) as f:
            blob = json.load(f)
    elif autosave_path and os.path.exists(autosave_path):
        # a prior invocation died past bootstrap and left its crash-safe
        # sidecar: resume from it (an explicit --state blob wins above —
        # it is at least as recent, saved every iteration)
        with open(autosave_path) as f:
            blob = json.load(f)

    trace = None
    if trace_path:
        from repro.trace import TraceStore
        cursor = blob["campaign"].get("trace") if blob is not None else None
        if cursor and os.path.exists(trace_path):
            trace = TraceStore.resume(trace_path, cursor["next_seq"])
        else:
            trace = TraceStore(trace_path, campaign_id)
        # attach BEFORE bootstrap/load so the trace opens with the
        # campaign's first event (campaign_begin or the resume marker)
        camp.attach_trace(trace)

    metrics = None
    metrics_store = None     # owned here iff metrics get their own file
    if metrics_path or prom_path or profile_dir:
        from repro.obs import MetricsRegistry
        metrics = MetricsRegistry()
        if (metrics_path and trace is not None
                and os.path.abspath(metrics_path)
                == os.path.abspath(trace_path)):
            metrics.attach_trace(trace)
        elif metrics_path:
            from repro.trace import TraceStore
            metrics_store = TraceStore(metrics_path, campaign_id)
            metrics.attach_trace(metrics_store)
        camp.attach_metrics(metrics)

    if slo_path:
        # after attach_trace/attach_metrics: the health engine inherits
        # whatever surfaces the campaign already observes with
        from repro.obs import HealthEngine, SLOSpec
        camp.attach_health(HealthEngine(SLOSpec.load(slo_path)))

    if faults is not None:
        # after attach_trace/attach_metrics: the injector mirrors its
        # events into whatever the campaign already observes with
        camp.attach_faults(faults, retry)

    bootstrapped = False
    try:
        if blob is not None:
            camp.load_state_dict(blob["campaign"])
            if "sweep_cursor" in blob:
                camp.resume_sweep_checkpoint = SweepCheckpoint.from_json(
                    blob["sweep_cursor"])
        else:
            camp.bootstrap()
            if state_path:
                _save_state(state_path, camp)
        bootstrapped = True

        if state_path and sweep_ckpt_pages:
            camp.sweep_checkpoint_every = sweep_ckpt_pages
            frozen = {}   # campaign blob serialized once at the first cut

            def save_cursor(ck):
                if "blob" not in frozen:
                    frozen["blob"] = camp.state_dict()
                _save_state(state_path, cursor=ck,
                            campaign_blob=frozen["blob"])

            camp.on_sweep_checkpoint = save_cursor

        try:
            ran = 0
            while not camp.done:
                if profile_dir and ran + 1 == profile_iter:
                    from repro.obs import profile_block
                    with profile_block(profile_dir):
                        camp.iteration()
                else:
                    camp.iteration()
                ran += 1
                if state_path:
                    _save_state(state_path, camp)
                if iters_per_run and ran >= iters_per_run and not camp.done:
                    return None, camp
            res = camp.commit()
        except BaseException:
            # crash-safe autosave: anything that unwinds past bootstrap —
            # including an injected kill — leaves a resumable sidecar.
            # Best-effort by design: the original exception always wins.
            if autosave_path and bootstrapped:
                try:
                    if trace is not None:
                        trace.emit("autosave", path=autosave_path,
                                   iterations=len(camp.history))
                    _save_state(autosave_path, camp)
                except Exception:
                    pass
            raise
        if state_path and os.path.exists(state_path):
            os.remove(state_path)   # campaign complete: the state is spent
        if autosave_path and os.path.exists(autosave_path):
            os.remove(autosave_path)
        return res, camp
    finally:
        # teardown order matters: close the campaign first (joins the
        # sweep/fit/annotation broker threads, so nothing can emit), then
        # the final metrics snapshot (it writes through the still-open
        # stores), then the stores.  A partial run (iters_per_run) exits
        # the process after this anyway — resume rebuilds the brokers
        # lazily.
        camp.close()
        if metrics is not None:
            metrics.emit_snapshot(scope="campaign")
            if prom_path:
                metrics.write_prometheus(prom_path)
        if metrics_store is not None:
            metrics_store.close()
        if trace is not None:
            trace.close()


def main():
    args = build_parser().parse_args()

    # trace analysis modes exit before any task/engine construction:
    # they read event files, not devices
    if args.trace_diff is not None:
        from repro.trace import diff
        d = diff(*args.trace_diff)
        if d is None:
            print(json.dumps({"identical": True}))
        else:
            print(json.dumps({"identical": False,
                              "divergence": d.describe(),
                              "index": d.index, "kind_a": d.kind_a,
                              "kind_b": d.kind_b, "fields": d.fields},
                             indent=2))
        return
    if args.trace_replay:
        from repro.trace import replay
        rp = replay(args.trace_replay)
        report = {
            "campaign": rp.campaign, "replayed_from": args.trace_replay,
            "decision": rp.decision, "done_reason": rp.done_reason,
            "iterations": len(rp.history), "cost": rp.total_cost,
            "ledger": rp.ledger, "votes": rp.votes,
            "config": rp.config, "runtime": rp.runtime,
        }
        if rp.result is not None:
            report.update(theta_final=rp.result.theta_final,
                          measured_error=rp.result.measured_error,
                          B_size=rp.result.B_size,
                          S_size=rp.result.S_size)
        print(json.dumps(report, indent=2))
        if args.out:
            with open(args.out, "w") as f:
                json.dump(report, f)
        return

    from repro.core import (MCALConfig, SERVICES, LiveTask,
                            make_emulated_task)
    from repro.data.synth import make_classification

    service = SERVICES[args.service]
    if args.live:
        num_classes = args.classes
    else:
        from repro.core.emulator import DATASETS
        num_classes = DATASETS[args.dataset]["classes"]
    annotation = build_annotation(args, num_classes, service)
    cfg = MCALConfig(eps_target=args.eps, metric=args.metric,
                     budget=args.budget, seed=args.seed,
                     sweep_async=args.sweep_async,
                     fit_async=args.fit_async,
                     # measured (calibration-batch) quality: what DS +
                     # adaptive repeats actually deliver, deterministic
                     # per seed so resumed runs rebuild the same config
                     label_quality=(annotation.calibrate()
                                    if annotation is not None else None))
    if args.live:
        x, y = make_classification(args.pool, num_classes=args.classes,
                                   difficulty=args.difficulty,
                                   seed=args.seed)
        task = LiveTask(features=x, groundtruth=y, num_classes=args.classes,
                        seed=args.seed, sweep_page=args.sweep_page,
                        fit_fused=args.fit_fused,
                        fit_resident=args.fit_resident,
                        mesh=build_mesh(args.mesh), annotation=annotation)
    else:
        task = make_emulated_task(args.dataset, args.arch, seed=args.seed,
                                  sweep_page=args.sweep_page)
        task.annotation = annotation

    faults = retry = None
    if args.chaos:
        from repro.faults import FaultInjector, FaultPlan, RetryPolicy
        chaos_seed = (args.seed if args.chaos_seed is None
                      else args.chaos_seed)
        faults = FaultInjector(FaultPlan.standard_transient(chaos_seed))
        retry = RetryPolicy(seed=chaos_seed)

    campaign_id = (f"{'live' if args.live else args.dataset}-"
                   f"{args.arch}-s{args.seed}")
    res, camp = run_campaign(task, service, cfg, state_path=args.state,
                             sweep_ckpt_pages=args.sweep_ckpt_pages,
                             iters_per_run=args.iters_per_run,
                             trace_path=args.trace,
                             campaign_id=campaign_id,
                             metrics_path=args.metrics,
                             prom_path=args.prom,
                             profile_dir=args.profile,
                             profile_iter=args.profile_iter,
                             autosave_path=args.autosave,
                             sweep_timeout=args.sweep_timeout,
                             fit_timeout=args.fit_timeout,
                             faults=faults, retry=retry,
                             slo_path=args.slo)
    if res is None:
        report = {"resumable": True, "state": args.state,
                  "iterations": len(camp.history),
                  "B_size": len(camp.pool.B_idx)}
        if args.trace:
            report["trace"] = args.trace
        print(json.dumps(report, indent=2))
        return
    X = task.pool_size
    human_all = X * service.price_per_label
    if annotation is not None:   # the honest baseline pays repeats too
        human_all *= cfg.label_quality.avg_repeats
    report = {
        "decision": res.decision,
        "B_frac": res.B_size / X,
        "S_frac": res.S_size / X,
        "theta_final": res.theta_final,
        "measured_error": res.measured_error,
        "cost": res.total_cost,
        "human_all_cost": human_all,
        "savings": 1.0 - res.total_cost / human_all,
        "ledger": res.ledger,
        "iterations": len(res.history),
    }
    if args.trace:
        report["trace"] = args.trace
    if args.metrics:
        report["metrics"] = args.metrics
    if faults is not None:
        report["chaos"] = {"faults_injected": faults.fired,
                           "sites_ticked": faults.counters()}
    if args.slo and camp.health is not None:
        report["health"] = camp.health.counts()
    if annotation is not None:
        report["annotation"] = {
            "votes": annotation.votes_bought,
            "avg_repeats": annotation.avg_repeats(),
            "residual_error_est": annotation.estimated_residual_error(),
            "worker_accuracy": annotation.worker_accuracy().tolist(),
        }
    print(json.dumps(report, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f)


if __name__ == "__main__":
    main()
