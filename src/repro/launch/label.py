"""MCAL labeling-campaign launcher — the paper's end-to-end system.

Live mode (real training on this host):
    PYTHONPATH=src python -m repro.launch.label --live --pool 4000 \
        --classes 10 --difficulty 0.3 --eps 0.05 --service amazon

Replay mode (paper-scale emulated learning curves):
    PYTHONPATH=src python -m repro.launch.label --dataset cifar10 \
        --arch resnet18 --service amazon

Campaign state (ledger, pool bitmap, per-theta history) checkpoints to
--state so a preempted campaign resumes mid-loop.
"""
from __future__ import annotations

import argparse
import json


# every selection-module metric plus the paper's random baseline
# (supported by select_for_training but previously missing from the CLI).
# A literal, not `selection.METRICS`: importing repro.core pulls in jax,
# and the launcher must stay cheap until parsing succeeds (--help never
# pays for it).  tests/test_label_launcher.py asserts the sets match, so
# drift fails CI.
METRIC_CHOICES = ("margin", "entropy", "least_confidence", "kcenter",
                  "random")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--live", action="store_true")
    ap.add_argument("--dataset", default="cifar10",
                    choices=("fashion", "cifar10", "cifar100", "imagenet"))
    ap.add_argument("--arch", default="resnet18")
    ap.add_argument("--pool", type=int, default=4000)
    ap.add_argument("--classes", type=int, default=10)
    ap.add_argument("--difficulty", type=float, default=0.3)
    ap.add_argument("--eps", type=float, default=0.05)
    ap.add_argument("--budget", type=float, default=None)
    ap.add_argument("--metric", default="margin", choices=METRIC_CHOICES)
    ap.add_argument("--service", default="amazon",
                    choices=("amazon", "satyam"))
    ap.add_argument("--sweep-page", type=int, default=8192,
                    help="pool-sweep runtime page rows (the paged, "
                         "double-buffered L(.)/M(.) pool passes)")
    ap.add_argument("--sweep-async", action="store_true",
                    help="overlap each iteration's M(.) sweep with the "
                         "host-side power-law fits + joint search")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    return ap


def main():
    args = build_parser().parse_args()

    from repro.core import (MCALConfig, SERVICES, LiveTask, run_mcal,
                            make_emulated_task)
    from repro.data.synth import make_classification

    service = SERVICES[args.service]
    cfg = MCALConfig(eps_target=args.eps, metric=args.metric,
                     budget=args.budget, seed=args.seed,
                     sweep_async=args.sweep_async)
    if args.live:
        x, y = make_classification(args.pool, num_classes=args.classes,
                                   difficulty=args.difficulty,
                                   seed=args.seed)
        task = LiveTask(features=x, groundtruth=y, num_classes=args.classes,
                        seed=args.seed, sweep_page=args.sweep_page)
    else:
        task = make_emulated_task(args.dataset, args.arch, seed=args.seed,
                                  sweep_page=args.sweep_page)

    res = run_mcal(task, service, cfg)
    X = task.pool_size
    human_all = X * service.price_per_label
    report = {
        "decision": res.decision,
        "B_frac": res.B_size / X,
        "S_frac": res.S_size / X,
        "theta_final": res.theta_final,
        "measured_error": res.measured_error,
        "cost": res.total_cost,
        "human_all_cost": human_all,
        "savings": 1.0 - res.total_cost / human_all,
        "ledger": res.ledger,
        "iterations": len(res.history),
    }
    print(json.dumps(report, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f)


if __name__ == "__main__":
    main()
