"""Multi-tenant campaign orchestrator: many campaigns, one mesh.

One process hosts N concurrent :class:`~repro.core.mcal.MCALCampaign`s
that SHARE the engine families — one
:class:`~repro.core.scoring.PoolScoringEngine`, one
:class:`~repro.serving.sweep.PoolSweepRunner`, one
:class:`~repro.training.fit_device.FitEngine`, and (optionally) one
:class:`~repro.annotation.service.AnnotationService` — so tenant #2's
first retrain at a pack shape tenant #1 already compiled reuses the
cached program instead of paying XLA again (the engines' pow2
``pack_shape`` bucketing + ``cache_keys()`` make matched-shape fleets
compile once, run N times).

What stays per-tenant — and what makes per-tenant results bit-identical
to running the same campaign alone:

* the campaign itself (pool bitmap, RNG stream, measurement history,
  fitted laws) and its params — engines are stateless per call given
  params (``fit_resident`` is refused under sharing);
* the :class:`~repro.annotation.service.AnnotationSession`: request
  cursor + vote/label counters, so worker schedules (hence vote
  streams) and ``buy_labels`` charges are pure functions of each
  tenant's OWN request history;
* the :class:`~repro.trace.store.TraceStore` (campaign id = tenant id):
  each tenant's decision stream diffs clean against its solo sibling.

Scheduling is round-based: bootstrap everyone, then rounds of one
``iteration()`` per running tenant (threads in concurrent mode, a plain
loop in serial mode — SAME code path, so the two modes produce
identical decision streams), with the
:class:`~repro.core.tenant.FleetController` rebalancing budgets at
every round boundary.  Fleet-level budget events land in a separate
fleet trace.

CLI::

    PYTHONPATH=src python -m repro.launch.orchestrator \
        --tenants fleet.json --global-budget 120 --trace-dir traces/

    PYTHONPATH=src python -m repro.launch.orchestrator --report traces/

``fleet.json`` is a list of tenant specs::

    [{"tenant_id": "t0", "priority": 2, "budget": 40.0, "seed": 0,
      "cfg": {"eps_target": 0.1, "max_iters": 4}}, ...]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import threading
from typing import Dict, List, Optional

from repro.faults.errors import FaultError


@dataclasses.dataclass
class SharedEngines:
    """The fleet's one-of-each engine bundle.

    Built once, injected into every tenant's
    :class:`~repro.core.task.LiveTask` (``engines=...``), closed once by
    the fleet (tenant teardown leaves shared engines alone).  The model
    and train config ride along so every tenant's params fit the
    bundle's compiled programs."""

    cfg: object                  # ModelConfig
    model: object
    tc: object                   # TrainConfig
    scoring: object              # PoolScoringEngine
    sweep: object                # PoolSweepRunner
    fit: object                  # FitEngine
    service: Optional[object] = None   # shared AnnotationService
    input_dim: int = 0
    num_classes: int = 0

    @classmethod
    def build(cls, input_dim: int, num_classes: int, *,
              arch_name: str = "mlp", hidden: int = 64, depth: int = 2,
              epochs: int = 40, batch_size: int = 256,
              learning_rate: float = 1e-2, score_microbatch: int = 2048,
              sweep_page: int = 8192, mesh=None,
              service=None) -> "SharedEngines":
        """One engine family set for a fleet of matched-shape tenants —
        the same construction :class:`~repro.core.task.LiveTask` does
        privately, hoisted to fleet scope."""
        from repro.configs.base import ModelConfig, TrainConfig
        from repro.core.scoring import PoolScoringEngine, ScoringConfig
        from repro.models.registry import get_model
        from repro.serving.sweep import (EngineSweepAdapter,
                                         PoolSweepRunner, SweepConfig)
        from repro.training.fit_device import FitConfig, FitEngine
        cfg = ModelConfig(
            name=f"{arch_name}-fleet", family="mlp", num_layers=depth,
            d_model=hidden, num_classes=num_classes, input_dim=input_dim,
            dtype="float32", remat="none")
        model = get_model(cfg)
        tc = TrainConfig(learning_rate=learning_rate, schedule="constant",
                         weight_decay=1e-4, grad_clip=1.0)
        scoring = PoolScoringEngine(
            model, ScoringConfig(microbatch=score_microbatch), mesh=mesh)
        sweep = PoolSweepRunner(EngineSweepAdapter(scoring),
                                SweepConfig(page_rows=sweep_page))
        fit = FitEngine(model, tc, FitConfig(epochs=epochs,
                                             batch_size=batch_size),
                        mesh=mesh)
        return cls(cfg=cfg, model=model, tc=tc, scoring=scoring,
                   sweep=sweep, fit=fit, service=service,
                   input_dim=input_dim, num_classes=num_classes)

    def cache_keys(self) -> Dict:
        """The pow2 pack-shape buckets compiled so far, per engine —
        the shared-compile-cache observability hook (the orchestrator
        bench gates on this not growing after tenant #1)."""
        return {"scoring": [list(k) for k in self.scoring.cache_keys()],
                "fit": [list(k) for k in self.fit.cache_keys()]}

    def compiled_count(self) -> int:
        return sum(len(v) for v in self.cache_keys().values())

    def close(self) -> None:
        """Idempotent fleet-engine shutdown: join the sweep, fit, and
        annotation broker threads."""
        self.sweep.close()
        self.fit.close()
        if self.service is not None:
            self.service.close()

    def __enter__(self) -> "SharedEngines":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class CampaignOrchestrator:
    """Round-based scheduler over a tenant fleet sharing one engine
    bundle.  ``concurrent=True`` runs each round's iterations on
    threads (one per running tenant, joined at the round barrier);
    ``concurrent=False`` runs the identical schedule serially — the
    bit-identical baseline the acceptance diff compares against."""

    def __init__(self, tenants: List, controller, *,
                 engines: Optional[SharedEngines] = None,
                 concurrent: bool = True, metrics=None,
                 metrics_trace=None):
        self.tenants = list(tenants)
        self.controller = controller
        self.engines = engines
        self.concurrent = concurrent
        # runtime metrics registry (repro.obs); the fleet shares ONE —
        # per-tenant attribution rides the bound `tenant` label each
        # round pushes onto its worker thread
        self.metrics = metrics
        self._metrics_trace = metrics_trace   # owned metrics.jsonl store

    # -- barrier-parallel helper -------------------------------------------
    def _run_round(self, jobs: List, phase: str = "iteration") -> None:
        """Run ``(tenant, fn)`` jobs — threads + join in concurrent
        mode, in fleet order serially otherwise (the SAME guarded code
        path, so failure semantics are mode-independent).

        Failure semantics, applied after the barrier:

        * a TERMINAL resilience fault (:class:`repro.faults.FaultError`:
          retries exhausted, straggler wall budget blown) QUARANTINES
          the failing tenant via the controller — the round goes on and
          the fleet commits everyone else;
        * anything else still fails the fleet, but no longer loses its
          siblings: the first error in FLEET ORDER (deterministic, not
          completion order) is raised with every other concurrent
          tenant failure attached as ``__notes__`` (and the raw
          exceptions on ``sibling_errors``).

        With metrics attached, each job runs inside a tenant-labeled
        ``round`` span (and a thread-local label bind, so every engine
        metric the tenant records attributes to it)."""
        m = self.metrics
        if m is not None:
            def timed(t, fn):
                def run():
                    with m.bind(tenant=t.tenant_id), \
                            m.span("round", phase=phase,
                                   tenant=t.tenant_id):
                        fn()
                return run
            jobs = [(t, timed(t, fn)) for t, fn in jobs]
        errors: List = []        # (job_index, tenant, exc) — fleet order
        quarantines: List = []
        lock = threading.Lock()

        def guarded(i, t, fn):
            def run():
                try:
                    fn()
                except FaultError as e:
                    with lock:
                        quarantines.append((t, e))
                except BaseException as e:   # noqa: BLE001 - re-raised
                    with lock:
                        errors.append((i, t, e))
            return run

        if not self.concurrent or len(jobs) <= 1:
            for i, (t, fn) in enumerate(jobs):
                guarded(i, t, fn)()
        else:
            threads = [threading.Thread(target=guarded(i, t, fn),
                                        name=f"tenant-{t.tenant_id}",
                                        daemon=True)
                       for i, (t, fn) in enumerate(jobs)]
            for th in threads:
                th.start()
            for th in threads:
                th.join()
        for t, e in quarantines:
            if self.controller.quarantine(t, e, phase=phase) \
                    and m is not None:
                m.inc("tenants_quarantined_total", tenant=t.tenant_id)
        if errors:
            errors.sort(key=lambda ite: ite[0])
            primary = errors[0][2]
            for _i, t, e in errors[1:]:
                note = (f"concurrent tenant failure [{t.tenant_id}]: "
                        f"{type(e).__name__}: {e}")
                if hasattr(primary, "add_note"):      # 3.11+
                    primary.add_note(note)
            primary.sibling_errors = tuple(e for _i, _t, e in errors[1:])
            raise primary

    # -- the fleet loop ----------------------------------------------------
    def run(self) -> Dict[str, object]:
        """Bootstrap everyone, iterate in rebalanced rounds until every
        tenant is done, commit everyone.  Returns
        ``{tenant_id: MCALResult}``."""
        m = self.metrics
        self._run_round([(t, t.campaign.bootstrap) for t in self.tenants],
                        phase="bootstrap")
        while any(t.running for t in self.tenants):
            if m is not None:
                with m.span("rebalance"):
                    self.controller.rebalance()
                m.inc("fleet_rounds_total")
            else:
                self.controller.rebalance()
            active = [t for t in self.tenants if t.running and not t.paused]
            if not active:
                # every running tenant is paused: the ceiling cannot be
                # met by waiting (nothing will get cheaper) — resolve
                # the stall by forcing the rest out, least-critical
                # first, instead of spinning on identical rounds
                self.controller.resolve_stall()
                break
            self._run_round([(t, t.campaign.iteration) for t in active])
        results: Dict[str, object] = {}
        lock = threading.Lock()

        def committer(t):
            def commit():
                res = t.campaign.commit()
                with lock:
                    results[t.tenant_id] = res
            return commit

        # quarantined tenants never commit: their campaign ended on a
        # fault, and committing would charge residual labels for a
        # tenant the fleet already wrote off
        self._run_round([(t, committer(t)) for t in self.tenants
                         if not t.quarantined], phase="commit")
        self.controller.finish()
        if m is not None:
            # compile-cache census + one final registry snapshot: the
            # report's fleet --metrics panel reads these from the
            # metrics stream alone
            if self.engines is not None:
                for eng, keys in self.engines.cache_keys().items():
                    m.set_gauge("compiled_programs", len(keys),
                                engine=eng)
            m.emit_snapshot(scope="fleet")
        return results

    def close(self) -> None:
        """Tenant teardown (traces + owned task resources), then the
        shared engine bundle (and the fleet's owned metrics stream)."""
        for t in self.tenants:
            t.close()
            if t.trace is not None:
                t.trace.close()
        if self.engines is not None:
            self.engines.close()
        if self._metrics_trace is not None:
            self._metrics_trace.close()


def build_fleet(features, groundtruth, specs, *, service,
                global_budget: Optional[float] = None,
                trace_dir: str = "", concurrent: bool = True,
                annotation_service=None, engine_kw: Optional[Dict] = None,
                task_kw: Optional[Dict] = None,
                metrics=None, sweep_timeout: Optional[float] = None,
                fit_timeout: Optional[float] = None,
                health=None,
                slo_enforce: bool = False) -> CampaignOrchestrator:
    """Wire a whole fleet: one :class:`SharedEngines` bundle, one
    :class:`~repro.core.task.LiveTask` + campaign +
    :class:`~repro.core.tenant.Tenant` per spec (per-tenant
    ``AnnotationSession`` when a shared annotation service is given),
    per-tenant traces under ``trace_dir`` (campaign id = tenant id) plus
    a fleet trace, and the :class:`~repro.core.tenant.FleetController`
    over them all.

    ``metrics`` is an optional ``repro.obs.MetricsRegistry`` shared by
    the whole fleet (tenant attribution via the orchestrator's bound
    labels).  With a ``trace_dir`` its events stream into
    ``metrics.jsonl`` beside the tenant traces — observability kinds
    only, so tenant decision streams still diff clean.

    ``health`` is an optional ``repro.obs.HealthEngine``: the controller
    ticks it at every rebalance boundary, its alert events ride the
    FLEET trace (tenant decision streams untouched), and with
    ``slo_enforce`` its enforceable SLO breach verdicts drive the
    downgrade cascade."""
    import numpy as np

    from repro.core.mcal import MCALCampaign
    from repro.core.task import LiveTask
    from repro.core.tenant import FleetController, Tenant

    features = np.asarray(features, np.float32)
    groundtruth = np.asarray(groundtruth, np.int64)
    num_classes = int(groundtruth.max()) + 1
    engines = SharedEngines.build(features.shape[1], num_classes,
                                  service=annotation_service,
                                  **(engine_kw or {}))
    fleet_trace = None
    if trace_dir:
        os.makedirs(trace_dir, exist_ok=True)
    tenants = []
    for spec in specs:
        ann = None
        if annotation_service is not None:
            ann = annotation_service.session(spec.tenant_id)
        task = LiveTask(features=features, groundtruth=groundtruth,
                        num_classes=num_classes, seed=spec.seed,
                        engines=engines, annotation=ann,
                        **(task_kw or {}))
        camp = MCALCampaign(task, service, spec.cfg)
        # straggler wall budgets (--sweep-timeout/--fit-timeout): a hung
        # async fold raises StragglerTimeout -> FaultError -> quarantine
        camp.sweep_timeout = sweep_timeout
        camp.fit_timeout = fit_timeout
        trace = None
        if trace_dir:
            from repro.trace import TraceStore
            trace = TraceStore(
                os.path.join(trace_dir, f"{spec.tenant_id}.jsonl"),
                spec.tenant_id)
            camp.attach_trace(trace)
        if metrics is not None:
            camp.attach_metrics(metrics)
        tenants.append(Tenant(spec, camp, trace))
    if trace_dir:
        from repro.trace import TraceStore
        fleet_trace = TraceStore(os.path.join(trace_dir, "fleet.jsonl"),
                                 "fleet")
    metrics_trace = None
    if metrics is not None and trace_dir and metrics.trace is None:
        from repro.trace import TraceStore
        metrics_trace = TraceStore(os.path.join(trace_dir, "metrics.jsonl"),
                                   "fleet-metrics")
        metrics.attach_trace(metrics_trace)
    if health is not None:
        # fleet-level judgment rides the fleet trace (alert kinds are
        # not FLEET_KINDS, so fleet traces still diff clean under them)
        if health.trace is None and fleet_trace is not None:
            health.attach_trace(fleet_trace)
        if health.metrics is None and metrics is not None:
            health.attach_metrics(metrics)
    controller = FleetController(tenants, global_budget, fleet_trace,
                                 health=health, slo_enforce=slo_enforce)
    return CampaignOrchestrator(tenants, controller, engines=engines,
                                concurrent=concurrent, metrics=metrics,
                                metrics_trace=metrics_trace)


# -- fleet report ------------------------------------------------------------

def fleet_report(trace_dir: str) -> Dict:
    """The ``--report`` fleet view: per-tenant campaign summaries (the
    single-campaign ``launch.report`` machinery, one trace each) rolled
    up with the fleet trace's budget decisions."""
    from repro.launch.report import summarize
    from repro.trace.store import read_trace

    out: Dict = {"tenants": {}, "fleet": None}
    for name in sorted(os.listdir(trace_dir)):
        if not name.endswith(".jsonl") or name in ("fleet.jsonl",
                                                   "metrics.jsonl"):
            continue
        path = os.path.join(trace_dir, name)
        out["tenants"][name[:-len(".jsonl")]] = summarize(path)
    fleet_path = os.path.join(trace_dir, "fleet.jsonl")
    if os.path.exists(fleet_path):
        rounds, downgrades, redistributions, final = 0, [], [], None
        quarantines = []
        ceiling = None
        for e in read_trace(fleet_path):
            if e.kind == "fleet_begin":
                ceiling = e.payload.get("ceiling")
            elif e.kind == "fleet_round":
                rounds += 1
            elif e.kind == "downgrade":
                downgrades.append(e.payload)
            elif e.kind == "redistribute":
                redistributions.append(e.payload)
            elif e.kind == "quarantine":
                quarantines.append(e.payload)
            elif e.kind == "fleet_done":
                final = e.payload
        out["fleet"] = {"ceiling": ceiling, "rounds": rounds,
                        "downgrades": downgrades,
                        "redistributions": redistributions,
                        "quarantines": quarantines,
                        "final": final}
    return out


def render_fleet(report: Dict) -> str:
    lines = ["== fleet =="]
    fl = report.get("fleet")
    if fl:
        lines.append(f"  ceiling   {fl['ceiling']}")
        lines.append(f"  rounds    {fl['rounds']}")
        lines.append(f"  downgrades {len(fl['downgrades'])}"
                     + ("".join(f"\n    r{d['round']} {d['action']:>13} "
                                f"{d['tenant']}"
                                for d in fl["downgrades"])))
        if fl.get("quarantines"):
            lines.append(
                f"  quarantined {len(fl['quarantines'])}"
                + "".join(f"\n    r{q['round']} {q['tenant']} "
                          f"({q.get('phase', '?')}: {q.get('error', '')})"
                          for q in fl["quarantines"]))
        if fl.get("final"):
            lines.append(f"  spent     ${fl['final']['total']:.4f}")
    for tid, s in report.get("tenants", {}).items():
        led = s.get("ledger") or {}
        lines.append(f"-- {tid}: iters={len(s.get('iterations') or ())} "
                     f"done={s.get('done_reason')} "
                     f"total=${led.get('total', 0.0):.4f}")
    return "\n".join(lines)


# -- CLI ---------------------------------------------------------------------

def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", default="",
                    help="fleet config JSON: a list of tenant specs "
                         "({tenant_id, priority, budget, seed, cfg})")
    ap.add_argument("--global-budget", type=float, default=None,
                    help="hard fleet spend ceiling: breaching it runs "
                         "the criticality-ordered downgrade cascade")
    ap.add_argument("--trace-dir", default="traces",
                    help="per-tenant traces (<tenant_id>.jsonl) + the "
                         "fleet trace (fleet.jsonl) land here")
    ap.add_argument("--report", default="", metavar="TRACE_DIR",
                    help="render the fleet view from a trace dir and "
                         "exit (no engines)")
    ap.add_argument("--serial", action="store_true",
                    help="run the identical round schedule without "
                         "threads (the bit-identical baseline)")
    ap.add_argument("--metrics", action="store_true",
                    help="runtime metrics: per-tenant round spans + "
                         "engine telemetry stream into "
                         "<trace-dir>/metrics.jsonl and a Prometheus "
                         "snapshot lands at <trace-dir>/metrics.prom "
                         "(render with launch.report --metrics)")
    ap.add_argument("--slo", default="", metavar="SPEC.json",
                    help="streaming health engine: judge every tenant "
                         "against the declarative SLO spec (cost per "
                         "committed label, iteration-latency p95, "
                         "projected quality) at every rebalance "
                         "boundary; hysteresis-gated alert events land "
                         "in fleet.jsonl (render with launch.report "
                         "--health)")
    ap.add_argument("--slo-enforce", action="store_true",
                    help="act on enforceable SLO breaches: breaching "
                         "tenants walk the downgrade cascade (pause -> "
                         "shrink_votes -> force_commit, one step per "
                         "breached rebalance, deterministic walk order)")
    ap.add_argument("--sweep-timeout", type=float, default=None,
                    metavar="SECONDS",
                    help="straggler wall budget for async M(.) sweep "
                         "folds: a hung sweep job raises "
                         "StragglerTimeout and quarantines its tenant "
                         "(default: wait forever)")
    ap.add_argument("--fit-timeout", type=float, default=None,
                    metavar="SECONDS",
                    help="straggler wall budget for async retrain "
                         "folds (default: wait forever)")
    ap.add_argument("--pool", type=int, default=2000)
    ap.add_argument("--classes", type=int, default=4)
    ap.add_argument("--difficulty", type=float, default=0.3)
    ap.add_argument("--service", default="amazon",
                    choices=("amazon", "satyam"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--annotator-noise", type=float, default=0.0)
    ap.add_argument("--annotator-workers", type=int, default=5)
    ap.add_argument("--label-repeats", type=int, default=1)
    ap.add_argument("--out", default="")
    return ap


def main():
    args = build_parser().parse_args()
    if args.report:
        rep = fleet_report(args.report)
        print(render_fleet(rep))
        if args.out:
            with open(args.out, "w") as f:
                json.dump(rep, f, indent=2)
        return
    if not args.tenants:
        raise SystemExit("--tenants config.json required (or --report)")

    from repro.core import SERVICES
    from repro.core.tenant import TenantSpec
    from repro.data.synth import make_classification

    with open(args.tenants) as f:
        specs = [TenantSpec.from_dict(d) for d in json.load(f)]
    service = SERVICES[args.service]
    x, y = make_classification(args.pool, num_classes=args.classes,
                               difficulty=args.difficulty, seed=args.seed)
    annotation = None
    if args.annotator_noise > 0 or args.label_repeats > 1:
        from repro.annotation import make_annotation_service
        annotation = make_annotation_service(
            args.classes, n_workers=args.annotator_workers,
            noise=args.annotator_noise, repeats=args.label_repeats,
            pricing=service, seed=args.seed)

    metrics = None
    if args.metrics:
        from repro.obs import MetricsRegistry
        metrics = MetricsRegistry()
    health = None
    if args.slo:
        from repro.obs import HealthEngine, SLOSpec
        health = HealthEngine(SLOSpec.load(args.slo))
    elif args.slo_enforce:
        raise SystemExit("--slo-enforce requires --slo SPEC.json")
    orch = build_fleet(x, y, specs, service=service,
                       global_budget=args.global_budget,
                       trace_dir=args.trace_dir,
                       concurrent=not args.serial,
                       annotation_service=annotation,
                       metrics=metrics,
                       sweep_timeout=args.sweep_timeout,
                       fit_timeout=args.fit_timeout,
                       health=health, slo_enforce=args.slo_enforce)
    try:
        results = orch.run()
    finally:
        if metrics is not None and args.trace_dir:
            metrics.write_prometheus(
                os.path.join(args.trace_dir, "metrics.prom"))
        orch.close()
    report = {
        "tenants": {tid: {"decision": r.decision, "cost": r.total_cost,
                          "B_size": r.B_size, "S_size": r.S_size,
                          "measured_error": r.measured_error,
                          "iterations": len(r.history)}
                    for tid, r in results.items()},
        "fleet": orch.controller.ledger_snapshot(),
        "compiled_programs": (orch.engines.compiled_count()
                              if orch.engines else None),
        "trace_dir": args.trace_dir,
    }
    if health is not None:
        report["health"] = health.counts()
    print(json.dumps(report, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f)


if __name__ == "__main__":
    main()
