"""Roofline analysis: compute / memory / collective terms per cell.

Why analytic: XLA's HloCostAnalysis counts while-loop bodies ONCE, so on
scanned-layer models ``compiled.cost_analysis()`` undercounts FLOPs/bytes
by ~the layer count (verified: a 4-layer toy reports 8.8 GF scanned vs
30.0 GF unrolled == 6*N*D).  The terms below are therefore derived from the
config algebra — the same napkin math the perf loop optimizes — and the
formulas are validated in tests against XLA cost_analysis on small
UNROLLED configs (tests/test_roofline.py).  The dry-run's parsed HLO
collectives (loops-counted-once) are kept in the record as cross-checks.

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

Terms (seconds, per device, per step):
  compute    = FLOPs_local / PEAK_FLOPS
  memory     = HBM_bytes_local / HBM_BW
  collective = wire_bytes_local / ICI_BW
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, Optional, Tuple

from repro.configs.base import ModelConfig, ShapeConfig

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link (one direction)

BYTES_W = 2                # bf16 weights/activations
BYTES_G = 4                # f32 grad reduction


# ---------------------------------------------------------------------------
# parameter counts
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamCounts:
    total: float          # every stored parameter
    body_active: float    # matmul params exercised per token (no embed/head)
    head: float           # LM-head matmul params
    embed: float          # gather-only embedding params

    @property
    def active(self) -> float:
        return self.body_active + self.head + self.embed


def param_counts(cfg: ModelConfig) -> ParamCounts:
    """Analytic parameter accounting.  ``body_active`` is what 6*N*D-style
    MODEL_FLOPS should count alongside the head (embeddings are gathers,
    not matmuls)."""
    D, F, V = cfg.d_model, cfg.d_ff, cfg.vocab_size
    hd = cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads

    def attn():
        return D * hd * (nq + 2 * nkv) + nq * hd * D

    def mlp(f=None):
        f = f or F
        return 3 * D * f if cfg.act == "swiglu" else 2 * D * f

    embed = float(V * D)
    head = float(D * V)  # tied or not, logits matmul exercises D*V weights
    stored_head = 0.0 if cfg.tie_embeddings else head
    total = embed + stored_head
    body = 0.0

    if cfg.family in ("dense", "vlm"):
        body = cfg.num_layers * (attn() + mlp())
        total += body
    elif cfg.family == "moe":
        E, k, ns = cfg.num_experts, cfg.experts_per_token, cfg.num_shared_experts
        expert = 3 * D * F
        shared = mlp(ns * F) if ns else 0
        router = D * E
        total += cfg.num_layers * (attn() + E * expert + shared + router)
        body = cfg.num_layers * (attn() + k * expert + shared + router)
    elif cfg.family == "ssm":
        body = cfg.num_layers * _mamba_params(cfg)
        total += body
    elif cfg.family == "hybrid":
        per = _mamba_params(cfg)
        shared_blk = attn() + mlp()
        total += cfg.num_layers * per + shared_blk
        napp = cfg.num_layers // cfg.shared_attn_every
        body = cfg.num_layers * per + napp * shared_blk  # executions count
    elif cfg.family == "audio":
        per = attn() + mlp()
        xattn = attn()
        body = (cfg.encoder_layers * per + cfg.num_layers * (per + xattn))
        total += body
        if cfg.pos_embed == "learned":
            total += (cfg.encoder_tokens + cfg.max_seq_len) * D
    return ParamCounts(total=float(total), body_active=float(body),
                       head=head, embed=embed)


def _mamba_params(cfg: ModelConfig) -> float:
    D, di = cfg.d_model, cfg.ssm_d_inner
    N, H, K = cfg.ssm_state, cfg.ssm_num_heads, cfg.ssm_conv_kernel
    return (2 * D * di      # w_z, w_x
            + 2 * D * N     # w_B, w_C
            + D * H         # w_dt
            + K * di        # conv
            + di * D)       # out


# ---------------------------------------------------------------------------
# forward FLOPs
# ---------------------------------------------------------------------------


def _attn_ctx_flops(cfg: ModelConfig, T_q: float, T_ctx: float,
                    window: int) -> float:
    """Score+PV FLOPs for T_q query tokens against avg context T_ctx."""
    eff = min(window, T_ctx) if window > 0 else T_ctx
    return 4.0 * T_q * eff * cfg.num_heads * cfg.resolved_head_dim


def _layer_flops(cfg: ModelConfig, T_q: float, T_ctx: float,
                 is_global: bool) -> float:
    """One transformer layer, T_q tokens, matmuls + attention."""
    D, F = cfg.d_model, cfg.d_ff
    hd = cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    qkvo = 2.0 * T_q * (D * hd * (nq + 2 * nkv) + nq * hd * D)
    window = 0 if is_global else cfg.sliding_window
    attn = _attn_ctx_flops(cfg, T_q, T_ctx, window)
    if cfg.family == "moe":
        E, k, ns = cfg.num_experts, cfg.experts_per_token, cfg.num_shared_experts
        mlp = 2.0 * T_q * (D * E + k * 3 * D * F + (3 * D * ns * F if ns else 0))
    else:
        mlp = 2.0 * T_q * (3 * D * F if cfg.act == "swiglu" else 2 * D * F)
    return qkvo + attn + mlp


def _mamba_layer_flops(cfg: ModelConfig, T_q: float) -> float:
    D, di = cfg.d_model, cfg.ssm_d_inner
    N, H, hd = cfg.ssm_state, cfg.ssm_num_heads, cfg.ssm_head_dim
    C = min(cfg.ssm_chunk, int(max(T_q, 1)))
    proj = 2.0 * T_q * (2 * D * di + 2 * D * N + D * H + di * D)
    conv = 2.0 * T_q * cfg.ssm_conv_kernel * di
    # SSD: intra-chunk scores C*N + C*H*hd per (token, chunk-peer) + states
    intra = 2.0 * T_q * C * (N + H * hd)
    states = 4.0 * T_q * H * hd * N  # build S + apply C to h
    return proj + conv + intra + states


def forward_flops(cfg: ModelConfig, T_q: float, T_ctx: float,
                  with_head_tokens: float = 0.0) -> float:
    """Full-model forward FLOPs for T_q tokens (per sequence position
    average context T_ctx; pass T_ctx=(T+1)/2 for causal full-sequence)."""
    total = 0.0
    if cfg.family in ("dense", "vlm", "moe"):
        if cfg.local_global_ratio:
            r = cfg.local_global_ratio + 1
            n_global = cfg.num_layers // r
            n_local = cfg.num_layers - n_global
            total += n_global * _layer_flops(cfg, T_q, T_ctx, True)
            total += n_local * _layer_flops(cfg, T_q, T_ctx, False)
        else:
            total += cfg.num_layers * _layer_flops(cfg, T_q, T_ctx, True)
    elif cfg.family == "ssm":
        total += cfg.num_layers * _mamba_layer_flops(cfg, T_q)
    elif cfg.family == "hybrid":
        total += cfg.num_layers * _mamba_layer_flops(cfg, T_q)
        napp = cfg.num_layers // cfg.shared_attn_every
        total += napp * _layer_flops(cfg, T_q, T_ctx, True)
    elif cfg.family == "audio":
        Te = cfg.encoder_tokens
        total += cfg.encoder_layers * _layer_flops(cfg, Te, Te, True)
        total += cfg.num_layers * (_layer_flops(cfg, T_q, T_ctx, True)
                                   + _layer_flops(cfg, T_q, Te, True))
    total += 2.0 * with_head_tokens * cfg.d_model * cfg.vocab_size
    return total


# ---------------------------------------------------------------------------
# per-cell roofline
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_local: float
    hbm_bytes_local: float
    wire_bytes_local: float
    model_flops: float          # 6*N(_active)*D tokens (the useful floor)
    hlo_flops_local: float      # analytic compiled-work estimate
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        total = self.flops_local * self.n_devices
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """MODEL_FLOPS / (step time * peak * chips) — roofline-implied MFU."""
        denom = self.step_s * PEAK_FLOPS * self.n_devices
        return self.model_flops / denom if denom else 0.0

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "devices": self.n_devices,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops,
            "hlo_flops": self.flops_local * self.n_devices,
            "useful_ratio": self.useful_ratio, "mfu": self.mfu,
        }


def mesh_sizes(mesh_kind: str) -> Dict[str, int]:
    return ({"pod": 2, "data": 16, "model": 16} if mesh_kind == "multi"
            else {"data": 16, "model": 16})


def analyze_cell(cfg: ModelConfig, shape: ShapeConfig, mesh_kind: str,
                 grad_accum: int = 1,
                 overrides: Optional[Dict] = None) -> Roofline:
    """Analytic roofline for one (arch x shape x mesh) cell.

    ``overrides`` lets the perf loop model candidate changes without
    re-lowering: {"remat_factor": float, "ce_materialize": bool,
    "tp_act_collectives": bool, "fsdp_gather_per_microbatch": bool,
    "grad_bytes": int, "wd": int (weight-sharding ways), ...}.
    """
    o = dict(overrides or {})
    sizes = mesh_sizes(mesh_kind)
    n_dev = math.prod(sizes.values())
    dp = sizes.get("pod", 1) * sizes.get("data", 1)
    tp = sizes.get("model", 1)

    pc = param_counts(cfg)
    B, T = shape.global_batch, shape.seq_len
    D, V = cfg.d_model, cfg.vocab_size

    params_bytes = pc.total * BYTES_W
    serving = shape.kind != "train"
    # Serving keeps weights TP-resident when a 1/tp shard fits one chip
    # (policy "tp"); otherwise (and for training) fsdp_tp shards weights
    # over data x model and the data-axis shards are re-gathered per use.
    policy = cfg.sharding
    if serving and cfg.family != "moe" and params_bytes / tp < 12e9 and \
            policy == "fsdp_tp":
        policy = "tp"
    if policy == "fsdp":       # pure ZeRO-DP: the model axis is extra DP
        dp, tp = dp * tp, 1
    wd = o.get("wd", dp * tp if policy in ("fsdp_tp", "fsdp")
               else (1 if policy == "seq_serve" else tp))
    params_local = params_bytes / wd
    n_layers = cfg.num_layers + cfg.encoder_layers
    dense_total = (pc.total - pc.embed -
                   (0.0 if cfg.tie_embeddings else pc.head))
    if cfg.family == "moe":
        expert_layer = (cfg.num_experts * 3 * D * cfg.d_ff)
        dense_layer_bytes = (dense_total / n_layers - expert_layer) * BYTES_W
        expert_layer_bytes = expert_layer * BYTES_W
    else:
        dense_layer_bytes = dense_total / max(n_layers, 1) * BYTES_W
        expert_layer_bytes = 0.0

    def wire_per_layer(micro_tokens_dp: float) -> float:
        """Per-device wire bytes for ONE layer on one microbatch pass.

        Dense/attention: weights stay model-sharded; under fsdp_tp the
        data-axis shards are all-gathered per use (ingress ~ shard x
        (dp-1)/dp); TP partial sums cost 2 activation all-reduces (ring
        ~2x payload).  MoE: min(our ZeRO-3 expert-F gather route, the
        EP-resident token all-to-all route).
        """
        if policy == "seq_serve":
            # replicated weights, seq-sharded activations: K/V gathered
            # over "model" per layer is the only layer collective
            kv = 2.0 * (B / dp) * T * cfg.num_kv_heads * \
                cfg.resolved_head_dim * BYTES_W
            return kv * (tp - 1) / tp if tp > 1 else 0.0
        gather = (dense_layer_bytes / tp * (dp - 1) / dp
                  if policy in ("fsdp_tp", "fsdp") and dp > 1 else 0.0)
        tp_ar = (2.0 * micro_tokens_dp * D * BYTES_W * 2.0
                 if tp > 1 else 0.0)
        out = gather + tp_ar
        if cfg.family == "moe":
            k = cfg.experts_per_token
            if o.get("moe_a2a", False):
                # candidate EP route (modeled, §Perf): experts resident,
                # tokens all-to-all'd to their owners — dispatch + combine
                out += 2.0 * micro_tokens_dp * k * D * BYTES_W
            else:
                # the code's route: ZeRO-3 expert-F shards gathered per use
                # (halved when moe_gather_dtype == int8), combine via psum
                gb = 1 if cfg.moe_gather_dtype == "int8" else BYTES_W
                out += (expert_layer_bytes / BYTES_W * gb / tp * (dp - 1) / dp
                        if dp > 1 else 0.0)
        return out

    if shape.kind == "train":
        tokens = B * T
        tokens_local = tokens / dp
        micro_tokens_local = tokens_local / grad_accum
        T_ctx = (T + 1) / 2
        remat_f = o.get("remat_factor", 1.0)
        body = forward_flops(cfg, tokens, T_ctx)
        head = 2.0 * tokens * D * V
        flops_global = body * (3.0 + remat_f) + head * 3.0
        model_flops = 6.0 * (pc.body_active + pc.head) * tokens
        flops_local = flops_global / n_dev

        # HBM traffic (per device):
        #  weights streamed fwd+recompute+bwd per microbatch + optimizer
        w_reads = (2.0 + remat_f) * grad_accum * params_local
        opt = o.get("opt_bytes_factor", 3.0) * pc.total * 4 / wd
        #  residual carries written fwd / read bwd + working activations
        act = 6.0 * n_layers * tokens_local * D * BYTES_W
        #  CE logits traffic (XLA materializes chunked logits in HBM;
        #  a Pallas-fused CE removes this -> override ce_fused)
        ce = 0.0 if o.get("ce_fused", False) else \
            3.0 * tokens_local * V * 4 / tp
        hbm = w_reads + opt + act + ce

        # wire: per-layer route x layers x passes x microbatches
        passes = 2.0 + remat_f   # fwd + recompute + bwd traffic
        wire_layers = wire_per_layer(micro_tokens_local) * n_layers * \
            passes * grad_accum
        grad_bytes = o.get("grad_bytes", BYTES_G)
        # grads of model-sharded weights reduce over the data axis only
        grad_rs = pc.total / tp * grad_bytes * (dp - 1) / dp
        wire = wire_layers + grad_rs
    elif shape.kind == "prefill":
        tokens = B * T
        tokens_local = tokens / dp
        T_ctx = (T + 1) / 2
        flops_global = forward_flops(cfg, tokens, T_ctx, with_head_tokens=B)
        model_flops = 2.0 * pc.body_active * tokens + 2.0 * B * D * V
        flops_local = flops_global / n_dev
        kv_bytes = _cache_bytes(cfg, B, T)
        hbm = (params_local + 4.0 * n_layers * tokens_local * D * BYTES_W
               + kv_bytes / n_dev)
        wire = wire_per_layer(tokens_local) * n_layers
    else:  # decode: one token per sequence, cache of T
        tokens = B
        flops_global = forward_flops(cfg, tokens, T, with_head_tokens=B)
        model_flops = 2.0 * pc.body_active * tokens + 2.0 * B * D * V
        flops_local = flops_global / n_dev
        cache = _cache_bytes(cfg, B, T)
        hbm = params_local + cache / n_dev  # stream weights + cache once
        wire = wire_per_layer(float(B) / dp) * n_layers
    return Roofline(
        arch=cfg.name, shape=shape.name, mesh=mesh_kind, n_devices=n_dev,
        flops_local=flops_local, hbm_bytes_local=hbm, wire_bytes_local=wire,
        model_flops=model_flops, hlo_flops_local=flops_local,
        compute_s=flops_local / PEAK_FLOPS,
        memory_s=hbm / HBM_BW,
        collective_s=wire / ICI_BW,
    )


def _cache_bytes(cfg: ModelConfig, B: int, S: int) -> float:
    if cfg.family == "ssm":
        return B * cfg.num_layers * (
            cfg.ssm_num_heads * cfg.ssm_head_dim * cfg.ssm_state * 4
            + (cfg.ssm_conv_kernel - 1) * cfg.ssm_d_inner * BYTES_W)
    kv = 2 * B * S * cfg.num_kv_heads * cfg.resolved_head_dim * BYTES_W
    if cfg.family == "hybrid":
        napp = cfg.num_layers // cfg.shared_attn_every
        ssm = B * cfg.num_layers * (
            cfg.ssm_num_heads * cfg.ssm_head_dim * cfg.ssm_state * 4
            + (cfg.ssm_conv_kernel - 1) * cfg.ssm_d_inner * BYTES_W)
        return napp * kv + ssm
    if cfg.family == "audio":
        xkv = 2 * B * cfg.encoder_tokens * cfg.num_kv_heads * \
            cfg.resolved_head_dim * BYTES_W
        return cfg.num_layers * (kv + xkv)
    return cfg.num_layers * kv


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------


def full_table(grad_accums: Optional[Dict] = None, mesh_kind: str = "single"):
    from repro.configs import ARCH_IDS, cells, get_config
    rows = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in cells(arch):
            ga = (grad_accums or {}).get((arch, shape.name), 1)
            rows.append(analyze_cell(cfg, shape, mesh_kind, ga))
    return rows


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=("single", "multi"))
    ap.add_argument("--dryrun-jsonl", default="results/dryrun.jsonl")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    accums = {}
    try:
        with open(args.dryrun_jsonl) as f:
            for line in f:
                r = json.loads(line)
                if "grad_accum" in r:
                    accums[(r["arch"], r["shape"])] = r["grad_accum"]
    except FileNotFoundError:
        pass

    rows = full_table(accums, args.mesh)
    if args.json:
        print(json.dumps([r.row() for r in rows]))
        return
    hdr = (f"{'arch':22s} {'shape':12s} {'comp(ms)':>9s} {'mem(ms)':>9s} "
           f"{'coll(ms)':>9s} {'dominant':>10s} {'useful':>7s} {'MFU':>6s}")
    print(hdr)
    for r in rows:
        print(f"{r.arch:22s} {r.shape:12s} {r.compute_s*1e3:9.2f} "
              f"{r.memory_s*1e3:9.2f} {r.collective_s*1e3:9.2f} "
              f"{r.dominant:>10s} {r.useful_ratio:7.2f} {r.mfu:6.3f}")


if __name__ == "__main__":
    main()
