"""Device-resident vote aggregation: majority vote + Dawid-Skene EM.

The annotation service answers every label request with a column of votes
per worker — an ``(items, workers)`` int matrix with ``-1`` where a worker
was not asked.  Turning votes into labels is the aggregation hot path
(every human-label purchase runs it, adaptive-repeats policies run it
once per top-up round), so it follows the same engine convention as
scoring / selection / fit:

* :class:`VoteAggregator` runs aggregation as jit-compiled device
  programs — one-hot vote counting + first-index ``argmax`` for majority,
  a ``lax.fori_loop`` EM (M-step then E-step per iteration, all items ×
  workers × classes batched as dense einsums) for Dawid-Skene;
* the item dimension is padded through ``scoring.pack_shape``'s pow2
  bucketing (padded rows carry no votes and are masked out of the prior /
  confusion sums), so growing request batches across MCAL iterations
  reuse O(log N) compiled programs (``cache_keys()`` mirrors the other
  engines' checkpoint-persistable compile-cache convention);
* the host NumPy references (:func:`majority_vote_host`,
  :func:`dawid_skene_host`) keep the natural per-worker loop shape — the
  oracles the device programs are validated against and the baseline
  ``benchmarks/bench_annotation.py`` enforces the >= 2x gate over.

Oracle-test contract (tests/test_annotation.py)
-----------------------------------------------

Majority vote must agree EXACTLY with the host reference — vote counts
are small integers, and both sides tie-break by FIRST class index
(``argmax`` returns the first maximum on host and device alike).
Dawid-Skene posteriors are float (host float64 vs device float32), so the
contract is atol-bounded posteriors with IDENTICAL argmax labels across
seeded (items, workers, classes, repeats, ragged-batch) grids — sound
because the EM smoothing keeps every confusion entry strictly positive
and the seeded pools keep worker confusions distinct, so posterior
argmaxes are decided by margins far above float32 resolution.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.scoring import pack_shape


# ---------------------------------------------------------------------------
# host references (the oracles)
# ---------------------------------------------------------------------------


def vote_counts_host(votes: np.ndarray, num_classes: int) -> np.ndarray:
    """(N, C) per-class vote counts; ``votes`` is (N, W) with -1 = no vote."""
    votes = np.asarray(votes, np.int64)
    N, W = votes.shape
    counts = np.zeros((N, num_classes), np.int64)
    for w in range(W):
        col = votes[:, w]
        m = col >= 0
        np.add.at(counts, (np.nonzero(m)[0], col[m]), 1)
    return counts


def majority_vote_host(votes: np.ndarray, num_classes: int
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Majority vote with FIRST-class-index tie-break.  Returns
    ``(labels, confidence)`` where confidence = top count / total votes
    (0 for rows with no votes, labeled class 0 by the same convention
    the device program pads with)."""
    counts = vote_counts_host(votes, num_classes)
    labels = np.argmax(counts, axis=1).astype(np.int64)
    total = counts.sum(axis=1)
    top = counts[np.arange(len(counts)), labels]
    conf = np.divide(top, np.maximum(total, 1), dtype=np.float64)
    return labels, conf


@dataclasses.dataclass
class DSResult:
    """Dawid-Skene deliverable: per-item posteriors + aggregated labels +
    the estimated per-worker confusion stack and class prior."""

    posterior: np.ndarray    # (N, C)
    labels: np.ndarray       # (N,) argmax posterior
    confidence: np.ndarray   # (N,) max posterior
    confusion: np.ndarray    # (W, C, C) estimated P(vote=l | true=c)
    prior: np.ndarray        # (C,)


def dawid_skene_host(votes: np.ndarray, num_classes: int, *,
                     em_iters: int = 12, smoothing: float = 0.01
                     ) -> DSResult:
    """The NumPy reference EM (float64, per-worker python loop — the seed
    host-loop shape every engine keeps as its oracle).  Initialized from
    soft majority counts; each iteration runs the M-step (class prior +
    per-worker confusion from the current posteriors, Laplace-smoothed)
    then the E-step (log-posterior accumulation over workers)."""
    votes = np.asarray(votes, np.int64)
    N, W = votes.shape
    C = num_classes
    mask = votes >= 0
    v = np.where(mask, votes, 0)
    counts = vote_counts_host(votes, C).astype(np.float64)
    post = (counts + 1.0 / C) / (counts.sum(1, keepdims=True) + 1.0)
    onehot = np.zeros((N, W, C), np.float64)
    for w in range(W):
        onehot[np.arange(N), w, v[:, w]] = mask[:, w]
    for _ in range(max(em_iters, 1)):
        prior = post.mean(axis=0)
        conf = np.full((W, C, C), smoothing, np.float64)
        for w in range(W):
            conf[w] += post.T @ onehot[:, w, :]          # (C, C)
        conf /= conf.sum(axis=2, keepdims=True)
        logp = np.log(prior)[None, :]
        logp = np.repeat(logp, N, axis=0)
        for w in range(W):
            lw = np.log(conf[w][:, v[:, w]]).T            # (N, C)
            logp = logp + np.where(mask[:, w][:, None], lw, 0.0)
        logp -= logp.max(axis=1, keepdims=True)
        post = np.exp(logp)
        post /= post.sum(axis=1, keepdims=True)
    labels = np.argmax(post, axis=1).astype(np.int64)
    return DSResult(posterior=post, labels=labels,
                    confidence=post.max(axis=1),
                    confusion=conf, prior=prior)


# ---------------------------------------------------------------------------
# the device engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AggregateConfig:
    em_iters: int = 12
    smoothing: float = 0.01
    microbatch: int = 1024   # pack_shape bucketing granularity for the
                             # item dimension (pow2 compile-cache reuse)


@jax.jit
def _scatter_rows(votes, rows, vals):
    """Scatter updated vote rows into the resident padded matrix.
    ``rows`` may repeat (the pow2 row-pack pads by repeating row 0) —
    duplicates carry identical values, so the scatter is idempotent."""
    return votes.at[rows].set(vals)


@functools.partial(jax.jit, static_argnames=("num_classes",))
def _majority_device(votes, num_classes: int):
    """(Npad, W) -> (labels, confidence): one-hot counts + first-index
    argmax (``jnp.argmax`` prefers the first maximum, matching the host
    oracle's tie-break exactly — counts are exact small integers)."""
    mask = votes >= 0
    onehot = jax.nn.one_hot(jnp.where(mask, votes, 0), num_classes,
                            dtype=jnp.int32) * mask[..., None]
    counts = onehot.sum(axis=1)                       # (Npad, C)
    labels = jnp.argmax(counts, axis=1)
    total = jnp.maximum(counts.sum(axis=1), 1)
    top = jnp.take_along_axis(counts, labels[:, None], axis=1)[:, 0]
    return labels, top.astype(jnp.float32) / total.astype(jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("num_classes", "em_iters", "smoothing"))
def _dawid_skene_device(votes, n, num_classes: int, em_iters: int,
                        smoothing: float):
    """The fused EM: same M-then-E iteration as the host oracle, items
    padded (rows >= n carry no votes and are masked out of the prior),
    ``em_iters`` fixed iterations in one ``lax.fori_loop``."""
    Npad, W = votes.shape
    C = num_classes
    mask = (votes >= 0).astype(jnp.float32)           # (Npad, W)
    v = jnp.where(votes >= 0, votes, 0)
    onehot = jax.nn.one_hot(v, C, dtype=jnp.float32) * mask[..., None]
    # flattened (Npad, W*C) vote indicator: both EM contractions become
    # plain gemms against it — the per-(item, worker) gather/einsum
    # formulations ran at host-loop speed on XLA:CPU and lost the
    # benchmark gate; the only non-gemm work left per iteration is
    # O(W * C^2) reshapes of the confusion stack
    onehot2 = onehot.reshape(Npad, W * C)
    row_valid = (jnp.arange(Npad) < n).astype(jnp.float32)
    counts = onehot.sum(axis=1)                       # (Npad, C)
    post = (counts + 1.0 / C) / (counts.sum(1, keepdims=True) + 1.0)

    def one_iter(_, carry):
        post, _conf, _prior = carry
        pv = post * row_valid[:, None]
        prior = pv.sum(axis=0) / jnp.maximum(n, 1)
        # M-step: conf[w, c, l] = smoothing + sum_i pv[i, c] onehot[i, w, l]
        num = pv.T @ onehot2                          # (C, W*C) gemm
        conf = smoothing + num.reshape(C, W, C).transpose(1, 0, 2)
        conf = conf / conf.sum(axis=2, keepdims=True)
        # E-step: sum_w log conf[w, c, v_iw] = <onehot2, log conf> (gemm)
        flat = jnp.log(conf).transpose(0, 2, 1).reshape(W * C, C)
        logp = jnp.log(prior)[None, :] + onehot2 @ flat
        logp = logp - logp.max(axis=1, keepdims=True)
        post = jnp.exp(logp)
        post = post / post.sum(axis=1, keepdims=True)
        return post, conf, prior

    conf0 = jnp.full((W, C, C), 1.0 / C, jnp.float32)
    prior0 = jnp.full((C,), 1.0 / C, jnp.float32)
    post, conf, prior = jax.lax.fori_loop(
        0, max(em_iters, 1), one_iter, (post, conf0, prior0))
    return post, conf, prior


@dataclasses.dataclass
class ResidentVotes:
    """A request batch's padded vote matrix, resident on device.

    ``upload`` pays the full (Npad, W) h2d once per batch;
    :meth:`VoteAggregator.scatter` then updates only the rows an
    adaptive top-up round changed (mirroring ``FitEngine``'s
    ``extend_resident`` delta-upload convention), so re-aggregating
    after a top-up never re-materializes or re-uploads the matrix."""

    dev: jax.Array   # (Npad, W) int32, -1 = no vote (padding rows too)
    n: int           # valid rows


class VoteAggregator:
    """Device-resident aggregation engine for one ``num_classes``.

    ``majority(votes)`` / ``dawid_skene(votes)`` consume a host (N, W)
    vote matrix, pad the item dimension through ``scoring.pack_shape``'s
    pow2 bucketing (padding rows hold -1: no votes), run the jit-compiled
    program and trim back to N.  The (n_mb, mb) buckets swept so far are
    the compile-cache key set (``cache_keys()``), matching the other
    engines' checkpoint convention.

    The resident path (``upload``/``scatter``/``aggregate_resident``)
    keeps one batch's padded matrix on device across adaptive top-up
    rounds: the service uploads once, scatters only updated rows, and
    re-aggregates from the resident buffer — exact-agreement with the
    re-upload path by construction (identical values through the same
    compiled programs; ``tests/test_annotation.py`` asserts it).
    """

    def __init__(self, num_classes: int,
                 cfg: AggregateConfig = AggregateConfig()):
        assert num_classes >= 2
        self.num_classes = num_classes
        self.cfg = cfg
        self.pack_keys: set = set()
        # runtime metrics (repro.obs.MetricsRegistry); None = free no-op
        self.metrics = None

    # -- packing -----------------------------------------------------------
    def _pad(self, votes) -> Tuple[jax.Array, int]:
        votes = np.asarray(votes, np.int32)
        assert votes.ndim == 2, "votes must be (items, workers)"
        n = votes.shape[0]
        n_mb, mb = pack_shape(n, self.cfg.microbatch)
        if self.metrics is not None:
            self.metrics.inc(
                "pack_cache_hits_total" if (n_mb, mb) in self.pack_keys
                else "pack_cache_misses_total", engine="votes")
        self.pack_keys.add((n_mb, mb))
        pad = n_mb * mb - n
        if pad:
            votes = np.concatenate(
                [votes, np.full((pad, votes.shape[1]), -1, np.int32)])
        return jnp.asarray(votes), n

    def cache_keys(self) -> List[Tuple[int, int]]:
        """Sorted (n_mb, mb) pack buckets aggregated so far."""
        return sorted(self.pack_keys)

    # -- the resident batch path -------------------------------------------
    def upload(self, votes) -> ResidentVotes:
        """Pad + upload one batch's host vote matrix — the single full
        h2d a request batch pays (top-up rounds :meth:`scatter` deltas
        into the returned buffer instead of re-uploading)."""
        vd, n = self._pad(votes)
        return ResidentVotes(dev=vd, n=n)

    def scatter(self, res: ResidentVotes, rows, vals) -> ResidentVotes:
        """Scatter updated rows into the resident matrix: ``rows`` (k,)
        row indices, ``vals`` (k, W) their new vote rows.  The row count
        is padded to a pow2 bucket by REPEATING the first row (duplicate
        identical-value scatters are idempotent), so growing top-up
        activity reuses O(log k) compiled scatter programs."""
        rows = np.asarray(rows, np.int32)
        vals = np.asarray(vals, np.int32)
        k = len(rows)
        if k == 0:
            return res
        k_pad = 8
        while k_pad < k:
            k_pad *= 2
        if k_pad > k:
            rows = np.concatenate([rows, np.full(k_pad - k, rows[0],
                                                 np.int32)])
            vals = np.concatenate([vals, np.repeat(vals[:1], k_pad - k,
                                                   axis=0)])
        return ResidentVotes(
            dev=_scatter_rows(res.dev, jnp.asarray(rows),
                              jnp.asarray(vals)),
            n=res.n)

    # -- the compiled programs (device in, host out) -----------------------
    def _majority_dev(self, vd: jax.Array, n: int
                      ) -> Tuple[np.ndarray, np.ndarray]:
        labels, conf = _majority_device(vd, self.num_classes)
        return (np.asarray(labels[:n], np.int64),
                np.asarray(conf[:n], np.float64))

    def _dawid_skene_dev(self, vd: jax.Array, n: int) -> DSResult:
        post, conf, prior = _dawid_skene_device(
            vd, jnp.int32(n), self.num_classes, self.cfg.em_iters,
            float(self.cfg.smoothing))
        post = np.asarray(post[:n], np.float64)
        return DSResult(
            posterior=post,
            labels=np.argmax(post, axis=1).astype(np.int64),
            confidence=post.max(axis=1) if n else np.zeros((0,)),
            confusion=np.asarray(conf, np.float64),
            prior=np.asarray(prior, np.float64))

    # -- public API --------------------------------------------------------
    def majority(self, votes) -> Tuple[np.ndarray, np.ndarray]:
        """Device majority vote -> host ``(labels, confidence)``; exact
        twin of :func:`majority_vote_host` including the tie-break."""
        vd, n = self._pad(votes)
        return self._majority_dev(vd, n)

    def dawid_skene(self, votes) -> DSResult:
        """Device Dawid-Skene EM -> host :class:`DSResult`; atol-twin of
        :func:`dawid_skene_host` with identical argmax labels."""
        vd, n = self._pad(votes)
        return self._dawid_skene_dev(vd, n)

    def aggregate(self, votes, method: str = "majority"
                  ) -> Tuple[np.ndarray, np.ndarray, Optional[DSResult]]:
        """One entry point for the service: ``(labels, confidence,
        ds_result-or-None)`` under either aggregation method."""
        if method == "majority":
            labels, conf = self.majority(votes)
            return labels, conf, None
        if method == "ds":
            res = self.dawid_skene(votes)
            return res.labels, res.confidence, res
        raise ValueError(f"unknown aggregation method {method!r}")

    def aggregate_resident(self, res: ResidentVotes, method: str = "majority"
                           ) -> Tuple[np.ndarray, np.ndarray,
                                      Optional[DSResult]]:
        """:meth:`aggregate` over an already-resident batch — the same
        compiled programs over the same buffer contents, so the labels /
        confidences are bit-identical to re-uploading the host matrix."""
        if method == "majority":
            labels, conf = self._majority_dev(res.dev, res.n)
            return labels, conf, None
        if method == "ds":
            out = self._dawid_skene_dev(res.dev, res.n)
            return out.labels, out.confidence, out
        raise ValueError(f"unknown aggregation method {method!r}")
